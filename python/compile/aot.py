"""AOT lowering: JAX entry points -> HLO text artifacts for the rust runtime.

HLO *text* (never `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the `xla` crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage (normally via `make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts

Also writes `manifest.txt` (one line per artifact: name, n_lanes,
n_steps, operand count) which the rust loader sanity-checks against.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True, so the
    rust side unwraps with to_tuple())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


STEPS = {
    "activate_sense": model.STEPS_ACTIVATE,
    "rbm_hop": model.STEPS_RBM,
    "precharge_single": model.STEPS_PRECHARGE,
    "precharge_linked": model.STEPS_PRECHARGE,
    "copy_energy": model.STEPS_RBM,  # per-hop steps; MAX_HOPS hops inside
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--lanes", type=int, default=model.N_LANES)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name, (fn, specs) in model.example_args(args.lanes).items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} lanes={args.lanes} steps={STEPS[name]} "
                        f"operands={len(specs)}")
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
