//! Experiment drivers for the circuit-level artifacts (Table 1, RBM,
//! LIP, area) plus thin derivations of the paper's figure aggregates
//! from the declarative experiment API (`sim/spec.rs`). The
//! system-level grids themselves — which configs run on which
//! workloads — live in the spec registry; this module only reduces
//! unified [`spec::Report`]s to the figure-shaped summaries the bench
//! targets and examples print.

use anyhow::Result;

use crate::config::{Calibration, CopyMechanism, SimConfig};
use crate::copy::isolated_copy;
use crate::dram::area::AreaModel;
use crate::dram::timing::SpeedBin;
use crate::energy::EnergyModel;
use crate::lisa::lip::{lip_report, LipReport};
use crate::lisa::rbm::{rbm_bandwidth, RbmBandwidth};
use crate::metrics::Comparison;
use crate::sim::engine::{alone_ipcs, run_workload};
use crate::sim::spec::{self, RunOptions};
use crate::workloads::Workload;

/// E1 (Table 1 / Fig. 2): one row per copy mechanism.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub label: String,
    pub paper_latency_ns: f64,
    pub latency_ns: f64,
    pub paper_energy_uj: f64,
    pub energy_uj: f64,
}

/// Regenerate Table 1: 8 KB copy latency and DRAM energy per
/// mechanism (paper values embedded for side-by-side comparison).
pub fn table1(cal: &Calibration) -> Result<Vec<Table1Row>> {
    let em = EnergyModel::from_calibration(cal);
    let speed = SpeedBin::Ddr3_1600;
    let mut rows = Vec::new();
    let cases: [(&str, CopyMechanism, usize, f64, f64); 8] = [
        ("memcpy (via channel)", CopyMechanism::MemcpyChannel, 7, 1366.25, 6.2),
        ("RC-InterSA", CopyMechanism::RowCloneInterSa, 7, 1363.75, 4.33),
        ("RC-Bank", CopyMechanism::RowCloneInterBank, 0, 701.25, 2.08),
        ("RC-IntraSA", CopyMechanism::RowCloneIntraSa, 0, 83.75, 0.06),
        ("LISA-RISC (1 hop)", CopyMechanism::LisaRisc, 1, 148.5, 0.09),
        ("LISA-RISC (7 hops)", CopyMechanism::LisaRisc, 7, 196.5, 0.12),
        ("LISA-RISC (15 hops)", CopyMechanism::LisaRisc, 15, 260.5, 0.17),
        ("LISA-RISC (4 hops)", CopyMechanism::LisaRisc, 4, 172.5, 0.105),
    ];
    for (label, mech, hops, p_lat, p_en) in cases {
        let r = isolated_copy(mech, hops, speed, cal)?;
        rows.push(Table1Row {
            label: label.to_string(),
            paper_latency_ns: p_lat,
            latency_ns: r.latency_ns,
            paper_energy_uj: p_en,
            energy_uj: em.breakdown_uj(&r.stats, 0, speed.tck_ns()).total,
        });
    }
    Ok(rows)
}

/// E2: RBM bandwidth vs the memory channel (paper §2).
pub fn rbm_report(cal: &Calibration) -> RbmBandwidth {
    rbm_bandwidth(SpeedBin::Ddr4_2400, cal, 8192)
}

/// E3: linked precharge latency (paper §3.3 SPICE results).
pub fn lip_circuit_report(cal: &Calibration) -> LipReport {
    lip_report(SpeedBin::Ddr3_1600, cal)
}

/// E8: die-area overhead (paper §2).
pub fn area_report(cfg: &SimConfig) -> crate::dram::area::AreaReport {
    AreaModel::default().overhead(&cfg.dram)
}

// ---------------------------------------------------------------------------
// Weighted-speedup helpers (shared by the bench targets).
// ---------------------------------------------------------------------------

/// One configuration's weighted-speedup measurement on a workload.
#[derive(Debug, Clone)]
pub struct WsPoint {
    pub ws: f64,
    pub energy_uj: f64,
    pub villa_hit_rate: f64,
}

/// Measure a config's WS on a workload, normalized by the supplied
/// alone-run IPCs. Following the multiprogrammed-evaluation
/// methodology of the paper's lineage (SALP / TL-DRAM / RowClone),
/// the alone runs are measured ONCE on the baseline system and shared
/// by every configuration, so WS improvements reflect shared-mode
/// performance changes.
pub fn ws_point_with(cfg: &SimConfig, workload: &Workload, alone: &[f64]) -> WsPoint {
    let shared = run_workload(cfg, workload);
    // try_: a miscounted alone-run vector must fail loudly here, not
    // be zip-truncated into a plausible WS (see RunReport docs).
    let ws = shared
        .try_weighted_speedup(alone)
        .expect("alone-run IPCs measured on the same workload");
    WsPoint { ws, energy_uj: shared.energy.total, villa_hit_rate: shared.villa_hit_rate }
}

/// Convenience: measure with the config's own alone runs.
pub fn ws_point(cfg: &SimConfig, workload: &Workload) -> WsPoint {
    let alone = alone_ipcs(cfg, workload);
    ws_point_with(cfg, workload, &alone)
}

/// Improvement of one measured point over a baseline point:
/// (WS improvement fraction, energy reduction fraction).
pub fn improvement(base: &WsPoint, cfg: &WsPoint) -> (f64, f64) {
    let imp = if base.ws > 0.0 { cfg.ws / base.ws - 1.0 } else { 0.0 };
    let en = if base.energy_uj > 0.0 {
        1.0 - cfg.energy_uj / base.energy_uj
    } else {
        0.0
    };
    (imp, en)
}

/// Weighted-speedup improvement of `cfg` over `base` on a workload:
/// (WS_cfg / WS_base) - 1, each normalized by its own alone runs.
/// Also returns the energy reduction fraction and villa hit rate.
pub fn ws_improvement(
    base: &SimConfig,
    cfg: &SimConfig,
    workload: &Workload,
) -> (f64, f64, f64) {
    let b = ws_point(base, workload);
    let c = ws_point(cfg, workload);
    let (imp, en) = improvement(&b, &c);
    (imp, en, c.villa_hit_rate)
}

// ---------------------------------------------------------------------------
// Figure-shaped derivations over the declarative experiment API.
// ---------------------------------------------------------------------------

fn run_builtin(name: &str, requests: u64, max_mixes: usize, threads: usize) -> spec::Report {
    let s = spec::spec_by_name(name).expect("built-in spec present");
    let opts = RunOptions::default()
        .requests(requests)
        .mixes(max_mixes)
        .threads(threads);
    spec::run(&s, &opts).expect("built-in grid runs")
}

/// E4 (Fig. 3) row.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub workload: String,
    pub villa_improvement: f64,
    pub villa_hit_rate: f64,
    pub rc_inter_improvement: f64,
}

/// E4 (Fig. 3): LISA-VILLA improvement + hit rate per hot-region
/// workload, plus the RC-InterSA-movement comparison — the `fig3`
/// spec's {workload × baseline/risc-villa/villa-rc} grid reduced to
/// the figure's per-workload rows.
pub fn fig3(requests: u64, max_mixes: usize, threads: usize) -> Vec<Fig3Row> {
    let report = run_builtin("fig3", requests, max_mixes, threads);
    // Select records by axis value (never by grid position) so edits
    // to the fig3 spec's preset list cannot silently misalign rows.
    let mut workloads: Vec<&str> = Vec::new();
    for r in &report.records {
        if let Some(w) = r.axis("workload") {
            if !workloads.contains(&w) {
                workloads.push(w);
            }
        }
    }
    let find = |w: &str, p: &str| {
        report
            .records
            .iter()
            .find(|r| r.axis("workload") == Some(w) && r.axis("preset") == Some(p))
    };
    workloads
        .iter()
        .filter_map(|w| {
            let base = find(w, "baseline")?;
            let villa = find(w, "risc-villa")?;
            let rc = find(w, "villa-rc")?;
            let imp = |r: &spec::Record| match (base.ws, r.ws) {
                (Some(b), Some(c)) if b > 0.0 => c / b - 1.0,
                _ => 0.0,
            };
            Some(Fig3Row {
                workload: w.to_string(),
                villa_improvement: imp(villa),
                villa_hit_rate: villa.report.villa_hit_rate,
                rc_inter_improvement: imp(rc),
            })
        })
        .collect()
}

/// E5/E6 (Fig. 4): comparisons of RISC / RISC+VILLA / All over the
/// baseline across the copy mixes (the `fig4` spec's WS summary with
/// the figure's configuration labels).
pub fn fig4(requests: u64, max_mixes: usize, threads: usize) -> Vec<Comparison> {
    let report = run_builtin("fig4", requests, max_mixes, threads);
    let mut cmps = report.ws_summary();
    for c in &mut cmps {
        c.name = match c.name.as_str() {
            "risc" => "LISA-RISC".to_string(),
            "risc-villa" => "LISA-(RISC+VILLA)".to_string(),
            "all" => "LISA-All".to_string(),
            other => other.to_string(),
        };
    }
    cmps
}

/// E7: LISA-LIP alone across the copy mixes (paper: +10.3% average
/// over 50 workloads) — the `lip-system` spec's WS summary.
pub fn lip_system(requests: u64, max_mixes: usize, threads: usize) -> Comparison {
    let report = run_builtin("lip-system", requests, max_mixes, threads);
    let mut cmp = report.ws_summary().pop().unwrap_or_default();
    cmp.name = "LISA-LIP".to_string();
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_matches_paper() {
        let rows = table1(&Calibration::default()).unwrap();
        let find = |label: &str| {
            rows.iter()
                .find(|r| r.label.starts_with(label))
                .unwrap_or_else(|| panic!("{label} missing"))
        };
        let memcpy = find("memcpy");
        let rc_inter = find("RC-InterSA");
        let rc_intra = find("RC-IntraSA");
        let lisa1 = find("LISA-RISC (1 hop)");
        let lisa15 = find("LISA-RISC (15 hops)");
        // Ordering (who wins).
        assert!(lisa15.latency_ns < rc_inter.latency_ns / 3.0);
        assert!(rc_intra.latency_ns < lisa1.latency_ns);
        assert!(memcpy.latency_ns > 1000.0);
        // Factors: LISA ~9x faster, ~20-50x less energy than RC-InterSA.
        assert!(rc_inter.latency_ns / lisa1.latency_ns > 6.0);
        assert!(rc_inter.energy_uj / lisa1.energy_uj > 20.0);
        // Energy within band of the paper's absolute numbers.
        assert!((memcpy.energy_uj - 6.2).abs() < 1.0);
    }

    #[test]
    fn area_report_under_one_percent() {
        let r = area_report(&SimConfig::default());
        assert!(r.total_fraction < 0.01);
    }

    #[test]
    fn fig3_rows_derive_from_the_spec_grid() {
        // One mix, tiny runs: the derivation must key rows by workload
        // and compute improvements against the baseline preset record.
        let rows = fig3(200, 1, 2);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].workload.starts_with("villa-"));
        assert!(rows[0].villa_improvement.is_finite());
        assert!(rows[0].rc_inter_improvement.is_finite());
    }

    #[test]
    fn fig4_uses_figure_labels() {
        let cmps = fig4(150, 1, 2);
        let names: Vec<&str> = cmps.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, ["LISA-RISC", "LISA-(RISC+VILLA)", "LISA-All"]);
        assert!(cmps.iter().all(|c| c.ws_improvements.len() == 1));
    }
}
