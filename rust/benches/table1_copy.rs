//! Bench E1 (Table 1 / Fig. 2): 8 KB copy latency + DRAM energy for
//! every mechanism, with wall-clock timing of the simulator itself.

use lisa::config::Calibration;
use lisa::sim::experiments::table1;
use lisa::util::bench::{fmt_ns, time_it, Table};

fn main() -> anyhow::Result<()> {
    println!("=== E1 / Table 1: 8 KB copy latency and energy ===\n");
    let cal = Calibration::default();
    let rows = table1(&cal)?;
    let mut t = Table::new(&[
        "mechanism",
        "paper ns",
        "ours ns",
        "ratio",
        "paper uJ",
        "ours uJ",
    ]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}", r.paper_latency_ns),
            format!("{:.2}", r.latency_ns),
            format!("{:.2}", r.latency_ns / r.paper_latency_ns),
            format!("{:.3}", r.paper_energy_uj),
            format!("{:.3}", r.energy_uj),
        ]);
    }
    t.print();

    // Key claims.
    let get = |p: &str| rows.iter().find(|r| r.label.starts_with(p)).unwrap();
    let rc_inter = get("RC-InterSA");
    let lisa1 = get("LISA-RISC (1 hop)");
    println!(
        "\nLISA vs RC-InterSA: {:.1}x latency, {:.1}x energy (paper: 9x, 48x)",
        rc_inter.latency_ns / lisa1.latency_ns,
        rc_inter.energy_uj / lisa1.energy_uj
    );

    let s = time_it(2, 10, || {
        table1(&cal).unwrap();
    });
    println!(
        "\n[harness] table1 regeneration: {} ± {} per run",
        fmt_ns(s.mean()),
        fmt_ns(s.stddev())
    );
    Ok(())
}
