//! Trace subsystem contracts: record→replay byte-identity against
//! direct runs, trace-backed experiment grids deterministic at any
//! thread count, the streaming reader's memory bound, and rejection
//! of hand-corrupted files.

use std::path::PathBuf;

use lisa::config::SimConfig;
use lisa::cpu::trace::TraceOp;
use lisa::sim::engine::{run_workload, trace_ops_per_core};
use lisa::sim::spec::{self, RunOptions};
use lisa::trace::reader::CHUNK_BYTES;
use lisa::trace::{format, workload_from_file, write_trace, TraceReader};
use lisa::workloads::mixes;

fn tmp(name: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("lisa-trace-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn small_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.requests_per_core = 400;
    cfg
}

/// Record a workload to `path` exactly as `lisa trace record` does.
fn record(cfg: &SimConfig, workload: &str, path: &PathBuf) {
    let wl = mixes::workload_by_name(workload, cfg).unwrap();
    let traces = wl.traces(cfg, trace_ops_per_core(cfg.requests_per_core));
    write_trace(path, &wl.name, &traces).unwrap();
}

#[test]
fn record_then_replay_is_byte_identical_to_the_direct_run() {
    // A trimmed grid over the synthetic families: a plain mix, an OS
    // scenario, a SALP conflict mix and a GC workload — recorded,
    // reloaded and re-run, the replay report must serialize to the
    // exact bytes of the direct run's.
    for (i, name) in ["stream4", "os-fork", "salp-copy-conflict4", "gc-chase"]
        .iter()
        .enumerate()
    {
        let cfg = small_cfg();
        let wl = mixes::workload_by_name(name, &cfg).unwrap();
        let direct = run_workload(&cfg, &wl);

        let path = tmp(&format!("oracle-{i}.trc"));
        record(&cfg, name, &path);
        let replayed_wl = workload_from_file(&path).unwrap();
        assert_eq!(replayed_wl.name, *name);
        let replayed = run_workload(&cfg, &replayed_wl);
        assert_eq!(
            direct.to_json(),
            replayed.to_json(),
            "replay of '{name}' diverged from the direct run"
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn e11_gc_grid_is_byte_identical_across_thread_counts_and_backends() {
    let s = spec::spec_by_name("e11-gc").unwrap();
    let opts = RunOptions::default()
        .requests(300)
        .axis("workload", &["gc-chase", "gc-gen"])
        .axis("mech", &["memcpy", "lisa-risc"])
        .axis("policy", &["random"])
        .axis("mode", &["none", "masa"])
        .backend(&["cycle", "analytical"]);
    let serial = spec::run(&s, &opts.clone().threads(1)).unwrap();
    assert_eq!(serial.records.len(), 16);
    // Backend-major (implicit outermost axis), then workload-major.
    assert!(serial.records[..8]
        .iter()
        .all(|r| r.axis("backend") == Some("cycle")));
    assert!(serial.records[8..]
        .iter()
        .all(|r| r.axis("backend") == Some("analytical")));
    // GC workloads are OS-backed (bulk ops) and actually chase.
    assert!(serial
        .records
        .iter()
        .all(|r| r.report.os.is_some()));
    let json1 = serial.to_json();
    for threads in [2, 8] {
        let rows = spec::run(&s, &opts.clone().threads(threads)).unwrap();
        assert_eq!(serial, rows, "threads={threads}");
        assert_eq!(json1, rows.to_json(), "threads={threads}");
    }
}

#[test]
fn trace_files_are_first_class_experiment_workloads() {
    // Record one point, then run an e11 grid whose workload axis is
    // the trace file. The grid must expand (digest folded into the
    // workload), run under both backends, and stay byte-identical
    // across thread counts.
    let cfg = small_cfg();
    let path = tmp("axis.trc");
    record(&cfg, "gc-semispace", &path);
    let axis_value = format!("trace:{}", path.display());

    let s = spec::spec_by_name("e11-gc").unwrap();
    let opts = RunOptions::default()
        .requests(300)
        .axis("workload", &[axis_value.as_str()])
        .axis("mech", &["memcpy"])
        .axis("policy", &["random"])
        .axis("mode", &["none"])
        .backend(&["cycle", "analytical"]);

    // Expansion resolves the file once and carries its content digest.
    let points = spec::expand(&s, &opts).unwrap();
    assert_eq!(points.len(), 2);
    for p in &points {
        assert_eq!(p.workload.name, "gc-semispace");
        let src = p.workload.source.as_ref().expect("trace-backed workload");
        assert_eq!(src.digest.len(), 32);
    }

    let serial = spec::run(&s, &opts.clone().threads(1)).unwrap();
    assert_eq!(serial.records.len(), 2);
    assert!(serial
        .records
        .iter()
        .all(|r| r.axis("workload") == Some(axis_value.as_str())));
    for threads in [2, 8] {
        let rows = spec::run(&s, &opts.clone().threads(threads)).unwrap();
        assert_eq!(serial, rows, "threads={threads}");
    }

    // A missing file fails expansion with context, never a panic.
    let gone = format!("trace:{}", tmp("nonexistent.trc").display());
    let bad = RunOptions::default()
        .axis("workload", &[gone.as_str()])
        .axis("mech", &["memcpy"])
        .axis("policy", &["random"])
        .axis("mode", &["none"]);
    assert!(spec::expand(&s, &bad).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn million_op_replay_stays_within_the_reader_chunk_budget() {
    // ~1M strided Mem ops: the writer streams them out, and the
    // reader must stream them back without ever holding more than the
    // header plus one chunk. The assertion is on the reader's own
    // high-water accounting, deliberately not on process RSS.
    const N: u64 = 1_000_000;
    let ops: Vec<TraceOp> = (0..N)
        .map(|i| TraceOp::Mem {
            nonmem: 3,
            addr: (i * 64) % (1 << 28),
            is_write: i % 5 == 0,
            dependent: false,
        })
        .collect();
    let path = tmp("million.trc");
    write_trace(&path, "million", &[lisa::cpu::trace::Trace::new(ops)]).unwrap();

    let mut rd = TraceReader::open(&path).unwrap();
    assert_eq!(rd.header().streams[0].op_count, N);
    let mut it = rd.ops(0).unwrap();
    let mut prev = 0u64;
    let mut count = 0u64;
    while let Some(op) = it.next_op(&mut prev) {
        op.unwrap();
        count += 1;
    }
    assert_eq!(count, N);
    assert!(
        rd.high_water() <= CHUNK_BYTES + 4096,
        "reader high water {} exceeds the {CHUNK_BYTES}-byte chunk budget",
        rd.high_water()
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn hand_corrupted_streams_error_instead_of_panicking() {
    // An over-long varint inside a stream, reached through the real
    // file path (the unit tests cover the decoder in isolation).
    let header = format::TraceHeader {
        name: "bad".into(),
        streams: vec![format::StreamDesc {
            op_count: 1,
            offset: format::TraceHeader::byte_len("bad", 1),
            len: 12,
        }],
    };
    let mut bytes = header.encode();
    bytes.push(format::TAG_MEM);
    bytes.extend_from_slice(&[0x80; 11]); // nonmem varint never terminates
    let path = tmp("overlong.trc");
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", workload_from_file(&path).unwrap_err());
    assert!(err.contains("over-long varint"), "{err}");

    // A directory whose stream points past EOF.
    let header = format::TraceHeader {
        name: "bad".into(),
        streams: vec![format::StreamDesc {
            op_count: 1,
            offset: format::TraceHeader::byte_len("bad", 1),
            len: 10_000,
        }],
    };
    std::fs::write(&path, header.encode()).unwrap();
    let err = format!("{:#}", workload_from_file(&path).unwrap_err());
    assert!(err.contains("past end of file"), "{err}");

    // Empty file and bad magic.
    std::fs::write(&path, b"").unwrap();
    let err = format!("{:#}", workload_from_file(&path).unwrap_err());
    assert!(err.contains("truncated") || err.contains("header"), "{err}");
    std::fs::write(&path, b"NOTATRACEFILE-------------------").unwrap();
    let err = format!("{:#}", workload_from_file(&path).unwrap_err());
    assert!(err.contains("bad magic"), "{err}");
    std::fs::remove_file(&path).ok();
}
