//! `lisa lint` — a project-invariant static-analysis pass over the
//! source tree (DESIGN.md §"Static analysis: lisa lint").
//!
//! The simulator's correctness rests on cross-file *conventions* the
//! type system cannot see: every `SimConfig` field folded into the
//! TOML round trip and the content hash, every channel-state mutation
//! invalidating the horizon cache, every serialized JSON key read
//! back by its `from_json` twin, every probe call gated on
//! `observing()`, and no panics on the hot path. This module checks
//! those conventions on every commit instead of hoping a property
//! test draws the broken path.
//!
//! Stdlib-only by design (like `minitoml`): a line-lexer plus a
//! brace-depth scanner, no `syn`. Diagnostics are rustc-style
//! `file:line: rule: message` lines; `--json` emits a stable document
//! for CI artifacts and golden files.

pub mod lexer;
pub mod rules;

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

use lexer::FileScan;

/// One lint finding. `rule` is a stable name from the catalog
/// (`config-coverage`, …) or `lint-directive` for malformed
/// `// lint:` comments.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the linted root, forward slashes.
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// Collect `**/*.rs` under `dir`, sorted for determinism — the same
/// walk `build.rs` uses for the build fingerprint, so the lint pass
/// and the fingerprint agree on what "the source tree" is.
pub fn collect_rs_files(dir: &Path) -> Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
        let mut entries: Vec<_> = std::fs::read_dir(dir)
            .with_context(|| format!("lint: reading {}", dir.display()))?
            .collect::<std::io::Result<_>>()?;
        entries.sort_by_key(|e| e.path());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                walk(&p, out)?;
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(dir, &mut out)?;
    Ok(out)
}

/// Normalise a rule selector: accepts `L1`…`L5`, canonical names, and
/// the `panic` alias.
pub fn resolve_rule(sel: &str) -> Option<&'static str> {
    match sel {
        "L1" | "l1" | "config-coverage" => Some(rules::L1),
        "L2" | "l2" | "horizon-invalidate" => Some(rules::L2),
        "L3" | "l3" | "json-key-drift" => Some(rules::L3),
        "L4" | "l4" | "probe-gating" => Some(rules::L4),
        "L5" | "l5" | "panic" | "no-panic-hot-path" => Some(rules::L5),
        _ => None,
    }
}

/// Lint every `.rs` file under `root`. `only`: restrict to a rule
/// subset (`None` = all rules). Malformed `// lint:` directives are
/// always reported — a typo must not silently disable a rule.
pub fn run_dir(root: &Path, only: Option<&[&'static str]>) -> Result<Vec<Diagnostic>> {
    let enabled = |rule: &str| only.is_none_or(|set| set.contains(&rule));
    let mut out = Vec::new();
    for path in collect_rs_files(root)? {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("lint: reading {}", path.display()))?;
        let rel = path.strip_prefix(root).unwrap_or(&path);
        let scan = FileScan::scan(rel, &text);
        for (line, msg) in &scan.errors {
            out.push(Diagnostic {
                file: scan.rel.clone(),
                line: *line,
                rule: "lint-directive",
                message: msg.clone(),
            });
        }
        rules::run(&scan, &enabled, &mut out);
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.message.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.message.as_str()))
    });
    Ok(out)
}

/// Rustc-style text rendering, one line per finding.
pub fn render_text(diags: &[Diagnostic]) -> String {
    let mut s = String::new();
    for d in diags {
        s.push_str(&d.to_string());
        s.push('\n');
    }
    s
}

/// Stable JSON document for CI artifacts and golden files. Carries no
/// volatile fields (no timings, no absolute paths) so a clean tree
/// always produces the same bytes.
pub fn render_json(diags: &[Diagnostic]) -> String {
    use crate::metrics::json::string;
    let mut s = String::from("{\"lint\":{\"version\":1,\"errors\":[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"file\":{},\"line\":{},\"rule\":{},\"message\":{}}}",
            string(&d.file),
            d.line,
            string(d.rule),
            string(&d.message)
        ));
    }
    s.push_str("]}}\n");
    s
}

/// Resolve the default lint root: the crate's `src/` directory,
/// whether invoked from `rust/` (cargo's working dir) or the repo
/// root.
pub fn default_root() -> Result<PathBuf> {
    for cand in ["src/lint", "rust/src/lint"] {
        let p = Path::new(cand);
        if p.is_dir() {
            return Ok(p.parent().expect("lint dir has a parent").to_path_buf());
        }
    }
    bail!("lint: cannot find the src/ tree; pass --root DIR")
}

/// CLI entry: `lisa lint [--root DIR] [--rules L1,L5,…] [--json]
/// [--out FILE]`. Exits nonzero (via the returned error) when any
/// diagnostic fires.
pub fn cmd(args: &crate::cli::Args) -> Result<()> {
    let root = match args.opt("root") {
        Some(r) => PathBuf::from(r),
        None => default_root()?,
    };
    let only: Option<Vec<&'static str>> = match args.opt("rules") {
        None => None,
        Some(list) => Some(
            list.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    resolve_rule(s)
                        .ok_or_else(|| anyhow::anyhow!("lint: unknown rule '{s}'"))
                })
                .collect::<Result<_>>()?,
        ),
    };
    let diags = run_dir(&root, only.as_deref())?;
    if args.has_flag("json") {
        let doc = render_json(&diags);
        match args.opt("out") {
            Some(path) => std::fs::write(path, &doc)
                .with_context(|| format!("lint: writing {path}"))?,
            None => print!("{doc}"),
        }
        // The human summary goes to stderr so the JSON stream stays
        // machine-clean.
        eprintln!("lisa lint: {} file(s), {} error(s)", count_files(&root)?, diags.len());
    } else {
        eprint!("{}", render_text(&diags));
        eprintln!("lisa lint: {} file(s), {} error(s)", count_files(&root)?, diags.len());
    }
    if !diags.is_empty() {
        bail!("lisa lint: {} error(s)", diags.len());
    }
    Ok(())
}

fn count_files(root: &Path) -> Result<usize> {
    Ok(collect_rs_files(root)?.len())
}
