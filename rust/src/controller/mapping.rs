//! Physical-address-to-DRAM-coordinate mapping schemes.
//!
//! The scheme decides which address bits select channel / rank / bank /
//! row / column — i.e. how much bank-level parallelism and row-buffer
//! locality a linear access stream sees.

use anyhow::{bail, Result};

use crate::config::DramConfig;
use crate::dram::geometry::Address;

/// Supported mapping schemes (bit order from least significant, after
/// the 6-bit cache-line offset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingScheme {
    /// ch : col : bank : rank : row   (row-interleaved, maximizes
    /// row-buffer locality for streams — the paper's baseline).
    RowRankBankColCh,
    /// ch : bank : col : rank : row   (bank-interleaved streams).
    RowRankColBankCh,
}

impl MappingScheme {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "row-bank-col" | "robaco" => Self::RowRankBankColCh,
            "row-col-bank" | "rocoba" => Self::RowRankColBankCh,
            _ => bail!("unknown mapping scheme '{s}'"),
        })
    }
}

/// Address mapper for a fixed geometry. When LISA-VILLA is enabled,
/// the fast-subarray rows at the bottom of every bank are *reserved*
/// as cache slots and excluded from the OS-visible address space
/// (`reserved` rows per bank); application rows map above them.
#[derive(Debug, Clone)]
pub struct Mapper {
    scheme: MappingScheme,
    channels: usize,
    ranks: usize,
    banks: usize,
    /// OS-visible rows per bank (total minus reserved).
    rows: usize,
    /// Reserved (cache-slot) rows per bank.
    reserved: usize,
    cols: usize,
}

fn log2(x: usize) -> u32 {
    debug_assert!(x.is_power_of_two());
    x.trailing_zeros()
}

impl Mapper {
    pub fn new(cfg: &DramConfig, scheme: MappingScheme) -> Self {
        Self::with_reserved(cfg, scheme, 0)
    }

    /// Reserve the first `reserved` rows of every bank (VILLA cache
    /// slots) out of the mappable space.
    pub fn with_reserved(cfg: &DramConfig, scheme: MappingScheme, reserved: usize) -> Self {
        assert!(reserved < cfg.rows_per_bank());
        Self {
            scheme,
            channels: cfg.channels,
            ranks: cfg.ranks,
            banks: cfg.banks,
            rows: cfg.rows_per_bank() - reserved,
            reserved,
            cols: cfg.columns,
        }
    }

    /// Total mappable bytes.
    pub fn capacity(&self) -> u64 {
        (self.channels * self.ranks * self.banks * self.rows * self.cols) as u64 * 64
    }

    /// Map a byte address to DRAM coordinates (wraps modulo capacity).
    pub fn map(&self, byte_addr: u64) -> Address {
        let mut a = (byte_addr >> 6) % (self.capacity() >> 6);
        let mut take = |n: usize| -> usize {
            if n <= 1 {
                return 0;
            }
            let bits = log2(n);
            let v = (a & ((1 << bits) - 1)) as usize;
            a >>= bits;
            v
        };
        match self.scheme {
            MappingScheme::RowRankBankColCh => {
                let channel = take(self.channels);
                let col = take(self.cols);
                let bank = take(self.banks);
                let rank = take(self.ranks);
                // Row is the top field: whatever remains of `a` is the
                // app row index (< self.rows by the capacity bound; not
                // necessarily a power of two when rows are reserved).
                let row = self.reserved + a as usize;
                Address { channel, rank, bank, row, col }
            }
            MappingScheme::RowRankColBankCh => {
                let channel = take(self.channels);
                let bank = take(self.banks);
                let col = take(self.cols);
                let rank = take(self.ranks);
                let row = self.reserved + a as usize;
                Address { channel, rank, bank, row, col }
            }
        }
    }

    /// Inverse mapping: DRAM coordinates back to a byte address.
    pub fn unmap(&self, addr: &Address) -> u64 {
        let mut bits = 0u32;
        let mut out = 0u64;
        let mut put = |v: usize, n: usize| {
            if n <= 1 {
                return;
            }
            let b = log2(n);
            out |= (v as u64) << bits;
            bits += b;
        };
        let app_row = addr.row - self.reserved;
        match self.scheme {
            MappingScheme::RowRankBankColCh => {
                put(addr.channel, self.channels);
                put(addr.col, self.cols);
                put(addr.bank, self.banks);
                put(addr.rank, self.ranks);
            }
            MappingScheme::RowRankColBankCh => {
                put(addr.channel, self.channels);
                put(addr.bank, self.banks);
                put(addr.col, self.cols);
                put(addr.rank, self.ranks);
            }
        }
        // Row is the top field (no power-of-two requirement).
        out |= (app_row as u64) << bits;
        out << 6
    }

    /// Byte address of the start of the row containing `byte_addr`
    /// (useful for aligning bulk copies).
    pub fn row_base(&self, byte_addr: u64) -> u64 {
        let mut a = self.map(byte_addr);
        a.col = 0;
        self.unmap(&a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn mapper(scheme: MappingScheme) -> Mapper {
        Mapper::new(&DramConfig::default(), scheme)
    }

    #[test]
    fn map_unmap_round_trip() {
        for scheme in [
            MappingScheme::RowRankBankColCh,
            MappingScheme::RowRankColBankCh,
        ] {
            let m = mapper(scheme);
            check("map/unmap round trip", 500, |g| {
                let addr = (g.u64(m.capacity() >> 6) << 6) | g.u64(64);
                let mapped = m.map(addr);
                // unmap returns the line-aligned address.
                assert_eq!(m.unmap(&mapped), addr & !63);
            });
        }
    }

    #[test]
    fn consecutive_lines_same_row_in_row_locality_scheme() {
        let m = mapper(MappingScheme::RowRankBankColCh);
        let a0 = m.map(0);
        let a1 = m.map(64);
        assert_eq!(a0.row, a1.row);
        assert_eq!(a0.bank, a1.bank);
        assert_eq!(a1.col, a0.col + 1);
    }

    #[test]
    fn bank_interleave_scheme_spreads_banks() {
        let m = mapper(MappingScheme::RowRankColBankCh);
        let a0 = m.map(0);
        let a1 = m.map(64);
        assert_ne!(a0.bank, a1.bank);
    }

    #[test]
    fn row_base_aligns() {
        let m = mapper(MappingScheme::RowRankBankColCh);
        // Default geometry: 128 cols * 64 B = 8192 B rows, contiguous
        // in this scheme.
        assert_eq!(m.row_base(8192 + 555), 8192);
        assert_eq!(m.map(m.row_base(12345)).col, 0);
    }

    #[test]
    fn addresses_cover_all_banks() {
        let m = mapper(MappingScheme::RowRankBankColCh);
        let mut seen = vec![false; 8];
        for i in 0..8 {
            // Bank bits sit above the column bits (128 cols * 64 B).
            let addr = i * 8192;
            seen[m.map(addr).bank] = true;
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }
}
