//! The end-to-end simulation loop: trace-driven cores with their cache
//! hierarchy in front of the memory controller and DRAM device, run to
//! a per-core request budget.

use anyhow::Result;

use crate::backend::{self, MemoryModel};
use crate::config::{BackendKind, SimConfig};
use crate::cpu::cache::Hierarchy;
use crate::cpu::core::{Core, CoreWake};
use crate::metrics::RunReport;
use crate::obs::Probe;
use crate::os::OsLayer;
use crate::workloads::Workload;

/// One simulation instance (one workload on one configuration). The
/// memory side is a [`MemoryModel`] trait object selected from
/// `cfg.backend` — the engine never names a concrete backend.
pub struct Simulation {
    pub cfg: SimConfig,
    mem: Box<dyn MemoryModel>,
    pub hier: Hierarchy,
    pub cores: Vec<Core>,
    /// OS layer (page tables + frame allocator + bulk engine); present
    /// only when the workload's traces carry OS bulk ops, so non-OS
    /// workloads behave bit-identically to a build without it.
    pub os: Option<OsLayer>,
    workload_name: String,
}

impl Simulation {
    pub fn new(cfg: SimConfig, workload: Workload) -> Self {
        let mem = backend::build(&cfg);
        Self::with_model(cfg, workload, mem)
    }

    /// Build a simulation around an explicitly constructed memory
    /// model (the injection point backend cross-validation tests use;
    /// `new` is this plus [`backend::build`]).
    pub fn with_model(
        cfg: SimConfig,
        workload: Workload,
        mem: Box<dyn MemoryModel>,
    ) -> Self {
        let n_ops = trace_ops_per_core(cfg.requests_per_core);
        let traces = workload.traces(&cfg, n_ops);
        let os = traces.iter().any(|t| t.needs_os()).then(|| OsLayer::new(&cfg));
        let hier = Hierarchy::new(&cfg.cpu);
        let cores = traces
            .into_iter()
            .enumerate()
            .map(|(i, t)| Core::new(i, t, &cfg.cpu, cfg.requests_per_core))
            .collect();
        Self {
            cfg,
            mem,
            hier,
            cores,
            os,
            workload_name: workload.name,
        }
    }

    /// Read access to the memory model (stats/diagnostics; benches and
    /// integration tests that used to reach into `sim.ctrl`).
    pub fn memory(&self) -> &dyn MemoryModel {
        &*self.mem
    }

    /// Turn on latency attribution: the report gains an `"obs"` block
    /// decomposing every demand request's latency. Attribution is an
    /// observer — simulated behavior and every other report field stay
    /// bit-identical (pinned by `tests/engine_equivalence.rs`).
    pub fn enable_obs(&mut self) {
        self.mem.enable_attribution();
    }

    /// Attach a trace probe (e.g. a `SharedTraceRing`) to the
    /// memory model. Probes observe; they never change behavior.
    pub fn set_probe(&mut self, probe: Box<dyn Probe>) {
        self.mem.set_probe(probe);
    }

    /// Build a simulation where only `active_core` executes its trace
    /// (the paper's "alone" runs for weighted speedup).
    pub fn new_alone(cfg: SimConfig, workload: &Workload, active_core: usize) -> Self {
        let solo = Workload {
            name: format!("{}@core{active_core}", workload.name),
            cores: vec![workload.cores[active_core]],
            // Trace-backed workloads decompose the same way: the alone
            // run replays only the active core's recorded stream.
            source: workload.source.clone().map(|mut s| {
                s.only_core = Some(active_core);
                s
            }),
        };
        Self::new(cfg, solo)
    }

    /// Run to completion (all cores drained their budget) or the
    /// configured cycle cap; returns the report. Uses the event-driven
    /// fast-forward engine: whenever every core is memory-stalled and
    /// no DRAM command is issuable, the clock jumps straight to the
    /// next-event horizon instead of ticking idle cycles. Results are
    /// cycle-exact — `tests/engine_equivalence.rs` asserts identical
    /// `RunReport`s against `reference_run` across the full config
    /// matrix.
    pub fn run(&mut self) -> RunReport {
        self.try_run().expect("simulation failed")
    }

    pub fn try_run(&mut self) -> Result<RunReport> {
        self.drive(true)
    }

    /// The original per-cycle loop, kept as the golden reference for
    /// equivalence tests and for debugging suspected engine bugs.
    pub fn reference_run(&mut self) -> RunReport {
        self.try_reference_run().expect("simulation failed")
    }

    pub fn try_reference_run(&mut self) -> Result<RunReport> {
        self.drive(false)
    }

    fn drive(&mut self, fast_forward: bool) -> Result<RunReport> {
        let ratio = self.cfg.cpu.clock_ratio;
        let mut cycles: u64 = 0;
        // Perf heuristic only (results are identical either way, since
        // skipping less is always exact): after a failed skip attempt,
        // busy phases pause the horizon query for a few ticks instead
        // of paying for it every cycle.
        let mut cooldown: u32 = 0;
        while cycles < self.cfg.max_cycles {
            self.mem.tick()?;
            cycles += 1;
            for c in self.mem.drain_completions() {
                if c.was_copy {
                    // The OS layer may hold a frame alive until its
                    // migration copy has read it.
                    if let Some(os) = self.os.as_mut() {
                        os.on_copy_complete(c.id);
                    }
                    self.cores[c.core].on_copy_complete(c.id);
                } else {
                    self.cores[c.core].on_mem_complete(c.id);
                }
            }
            let mut all_done = true;
            for core in self.cores.iter_mut() {
                for _ in 0..ratio {
                    core.cycle(&mut self.hier, &mut *self.mem, self.os.as_mut());
                }
                all_done &= core.finished();
            }
            if all_done {
                break;
            }
            if fast_forward {
                if cooldown > 0 {
                    cooldown -= 1;
                } else {
                    let gap = self.idle_gap(ratio).min(self.cfg.max_cycles - cycles);
                    if gap > 0 {
                        self.mem.fast_forward(gap);
                        for core in self.cores.iter_mut() {
                            core.advance_idle(gap * ratio);
                        }
                        cycles += gap;
                    } else {
                        cooldown = 3;
                    }
                }
            }
        }
        Ok(self.report(cycles))
    }

    /// DRAM cycles, starting at the controller's current cycle, during
    /// which provably nothing happens anywhere in the system: the
    /// controller neither delivers an event nor issues a command
    /// (`Controller::next_event_cycle`), and every core only burns
    /// clock (`Core::next_wake`). Returns 0 when anything is active.
    ///
    /// The controller query is cheap to repeat: its per-channel
    /// component is cached inside the controller and only recomputed
    /// after that channel's state actually changed, so the common
    /// probe pattern here — repeated queries across core-limited
    /// partial jumps while the DRAM side is frozen — no longer re-walks
    /// the queues, refresh deadlines and copy sequences each time.
    fn idle_gap(&self, ratio: u64) -> u64 {
        let now = self.mem.now();
        let mut horizon = self.mem.next_event_cycle();
        if horizon <= now {
            return 0;
        }
        for core in &self.cores {
            match core.next_wake(&*self.mem) {
                CoreWake::Active => return 0,
                CoreWake::Blocked => {}
                CoreWake::At(t_cpu) => {
                    // The core runs CPU cycles (c, c + ratio] during
                    // the next DRAM tick; find the first tick whose
                    // batch reaches t_cpu.
                    let ahead = t_cpu.saturating_sub(core.cpu_cycles);
                    debug_assert!(ahead >= 2, "At(t) within the next batch is Active");
                    horizon = horizon.min(now + (ahead - 1) / ratio.max(1));
                }
            }
        }
        horizon - now
    }

    fn report(&self, cycles: u64) -> RunReport {
        let parts = self.mem.report_parts(cycles);
        RunReport {
            workload: self.workload_name.clone(),
            config_name: config_name(&self.cfg),
            ipc: self.cores.iter().map(|c| c.ipc()).collect(),
            dram_cycles: cycles,
            reads: parts.reads,
            writes: parts.writes,
            copies: parts.copies,
            avg_read_latency_cycles: parts.avg_read_latency_cycles,
            row_hit_rate: parts.row_hit_rate,
            villa_hit_rate: parts.villa_hit_rate,
            lip_coverage: parts.lip_coverage,
            energy: parts.energy,
            os: self.os.as_ref().map(|o| o.summary()),
            obs: parts.obs,
        }
    }
}

/// Human-readable configuration label for reports. Every knob an
/// experiment axis can move appears in the name when it is off its
/// default, so two distinct grid points can never alias (collision-
/// tested over the full built-in registry grid in
/// `tests/experiment_api.rs`). Default-valued knobs are elided to
/// keep the common labels short ("memcpy", "lisa-risc+villa", ...).
pub fn config_name(cfg: &SimConfig) -> String {
    let mut parts = vec![cfg.copy_mechanism.name().to_string()];
    if cfg.lisa.villa {
        parts.push("villa".into());
    }
    if cfg.lisa.lip {
        parts.push("lip".into());
    }
    if cfg.dram.salp != crate::config::SalpMode::None {
        parts.push(format!("salp:{}", cfg.dram.salp.name()));
    }
    let default_placement = crate::config::OsConfig::default().placement;
    if cfg.os.placement != default_placement {
        parts.push(format!("place:{}", cfg.os.placement.name()));
    }
    // The backend folds into the label (and therefore into journal and
    // cache keys, which embed `config_name`) so cycle-exact and
    // analytical results can never alias. Default (cycle) is elided:
    // pre-existing labels stay byte-identical.
    if cfg.backend != BackendKind::Cycle {
        parts.push(format!("backend:{}", cfg.backend.name()));
    }
    parts.join("+")
}

/// Ops generated per core before the trace cycles: enough distinct
/// ops to defeat trivial trace-level caching, bounded to keep memory
/// sane. Shared by `Simulation::with_model` and `lisa trace record`,
/// so a recorded file captures exactly what a direct run feeds the
/// cores — the record→replay byte-identity contract depends on it.
pub fn trace_ops_per_core(requests_per_core: u64) -> usize {
    (requests_per_core as usize).clamp(1_000, 200_000)
}

/// Run a workload on a config.
pub fn run_workload(cfg: &SimConfig, workload: &Workload) -> RunReport {
    Simulation::new(cfg.clone(), workload.clone()).run()
}

/// [`run_workload`] with latency attribution optionally enabled (the
/// campaign path for `--obs`; `obs == false` is byte-identical to
/// `run_workload`).
pub fn run_workload_obs(cfg: &SimConfig, workload: &Workload, obs: bool) -> RunReport {
    let mut sim = Simulation::new(cfg.clone(), workload.clone());
    if obs {
        sim.enable_obs();
    }
    sim.run()
}

/// Alone-run IPCs for every core of a workload on a config (the
/// denominator of weighted speedup).
pub fn alone_ipcs(cfg: &SimConfig, workload: &Workload) -> Vec<f64> {
    (0..workload.cores.len())
        .map(|i| {
            let mut sim = Simulation::new_alone(cfg.clone(), workload, i);
            sim.run().ipc[0]
        })
        .collect()
}

/// Weighted speedup of a workload on a config (shared run over alone
/// runs on the same config).
pub fn weighted_speedup(cfg: &SimConfig, workload: &Workload) -> (f64, RunReport) {
    let alone = alone_ipcs(cfg, workload);
    let shared = run_workload(cfg, workload);
    (shared.weighted_speedup(&alone), shared)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CopyMechanism;
    use crate::workloads::mixes;

    fn small_cfg() -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 2_000;
        cfg.max_cycles = 20_000_000;
        cfg
    }

    #[test]
    fn stream_workload_runs_to_completion() {
        let cfg = small_cfg();
        let wl = mixes::workload_by_name("stream4", &cfg).unwrap();
        let mut sim = Simulation::new(cfg, wl);
        let r = sim.run();
        assert_eq!(r.ipc.len(), 4);
        assert!(r.ipc.iter().all(|&i| i > 0.0), "{:?}", r.ipc);
        assert!(r.reads > 0);
        assert!(r.dram_cycles > 0);
        assert!(r.energy.total > 0.0);
        // Streams are row-buffer friendly.
        assert!(r.row_hit_rate > 0.5, "row hit rate {}", r.row_hit_rate);
    }

    #[test]
    fn alone_ipc_at_least_shared() {
        let cfg = small_cfg();
        let wl = mixes::workload_by_name("random4", &cfg).unwrap();
        let alone = alone_ipcs(&cfg, &wl);
        let shared = run_workload(&cfg, &wl);
        // Interference can only hurt.
        for (a, s) in alone.iter().zip(&shared.ipc) {
            assert!(s <= &(a * 1.05), "shared {s} > alone {a}");
        }
        let ws = shared.weighted_speedup(&alone);
        assert!(ws > 0.0 && ws <= 4.2, "ws {ws}");
    }

    #[test]
    fn lisa_risc_beats_memcpy_on_copy_workload() {
        let mut base = small_cfg();
        base.copy_mechanism = CopyMechanism::MemcpyChannel;
        let mut lisa = small_cfg();
        lisa.lisa.risc = true;
        lisa.copy_mechanism = CopyMechanism::LisaRisc;

        let wl = mixes::workload_by_name("fork4", &base).unwrap();
        let r_base = run_workload(&base, &wl);
        let r_lisa = run_workload(&lisa, &wl);
        assert!(r_base.copies > 0 && r_lisa.copies > 0);
        let ipc_base = r_base.ipc_sum();
        let ipc_lisa = r_lisa.ipc_sum();
        assert!(
            ipc_lisa > ipc_base,
            "LISA {ipc_lisa} should beat memcpy {ipc_base} on copy workloads"
        );
        // And finish in fewer DRAM cycles.
        assert!(r_lisa.dram_cycles < r_base.dram_cycles);
    }

    #[test]
    fn villa_gets_hits_on_hotspot_workload() {
        let mut cfg = small_cfg();
        cfg.lisa.villa = true;
        cfg.lisa.risc = true;
        cfg.lisa.villa_epoch_cycles = 20_000;
        cfg.copy_mechanism = CopyMechanism::LisaRisc;
        let wl = mixes::workload_by_name("hotspot4", &cfg).unwrap();
        let r = run_workload(&cfg, &wl);
        assert!(
            r.villa_hit_rate > 0.0,
            "villa hit rate {}",
            r.villa_hit_rate
        );
    }

    #[test]
    fn fast_forward_matches_reference_loop() {
        // Quick in-module sanity check; the full configuration matrix
        // lives in tests/engine_equivalence.rs.
        let mut cfg = small_cfg();
        cfg.requests_per_core = 600;
        cfg.lisa.risc = true;
        cfg.copy_mechanism = CopyMechanism::LisaRisc;
        let wl = mixes::workload_by_name("fork4", &cfg).unwrap();
        let fast = Simulation::new(cfg.clone(), wl.clone()).run();
        let reference = Simulation::new(cfg, wl).reference_run();
        assert_eq!(fast, reference);
    }

    #[test]
    fn lip_covers_most_precharges() {
        let mut cfg = small_cfg();
        cfg.lisa.lip = true;
        let wl = mixes::workload_by_name("random4", &cfg).unwrap();
        let r = run_workload(&cfg, &wl);
        assert!(r.lip_coverage > 0.9, "lip coverage {}", r.lip_coverage);
    }
}
