"""Kernel-vs-oracle correctness: the CORE L1 signal.

Compares the Pallas bitline kernel (interpret=True) against the pure-jnp
reference for every phase configuration the calibration uses, plus
hypothesis sweeps over shapes, initial conditions and scalar parameters.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m
from compile.kernels import bitline as bl
from compile.kernels.ref import phase_ref

jax.config.update("jax_platform_name", "cpu")

# Small populations / short horizons keep interpret-mode runtime sane.
N_FAST = 64
STEPS_FAST = 300


def _mk_inputs(n, seed=0, va=0.6, vb=1.2, sigma=0.05):
    rng = np.random.default_rng(seed)
    va0 = jnp.full((n,), va, jnp.float32)
    vb0 = jnp.full((n,), vb, jnp.float32)
    gmul = jnp.asarray(np.exp(rng.normal(0.0, sigma, n)), jnp.float32)
    cmul = jnp.asarray(np.exp(rng.normal(0.0, sigma, n)), jnp.float32)
    return va0, vb0, gmul, cmul


def _assert_matches(scalars, va=0.6, vb=1.2, n=N_FAST, steps=STEPS_FAST,
                    seed=0, block=32):
    va0, vb0, gmul, cmul = _mk_inputs(n, seed=seed, va=va, vb=vb)
    got = bl.phase(va0, vb0, gmul, cmul, scalars, n_steps=steps, block=block)
    want = phase_ref(va0, vb0, gmul, cmul, scalars, n_steps=steps)
    names = ["v_a", "v_b", "t_sense", "t_settle", "energy"]
    for g, w, name in zip(got, want, names):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
    return got


class TestPhaseConfigs:
    """Kernel == oracle for each calibration phase."""

    def test_activate(self):
        _assert_matches(m.scalars_activate(), va=0.6, vb=1.2)

    def test_activate_fast_subarray(self):
        _assert_matches(m.scalars_activate(fast=True), va=0.6, vb=1.2)

    def test_activate_low_cell(self):
        # Cell stores a 0: bitline must swing DOWN and latch at 0.
        got = _assert_matches(m.scalars_activate(), va=0.6, vb=0.0,
                              steps=1500)
        assert float(np.asarray(got[0])[0]) < 0.1

    def test_rbm(self):
        _assert_matches(m.scalars_rbm(), va=0.6, vb=1.2)

    def test_rbm_fast(self):
        _assert_matches(m.scalars_rbm(fast=True), va=0.6, vb=1.2)

    def test_precharge_single(self):
        _assert_matches(m.scalars_precharge(), va=1.2, vb=1.2, steps=1500)

    def test_precharge_linked(self):
        _assert_matches(m.scalars_precharge(linked=True), va=1.2, vb=1.2,
                        steps=800)

    def test_precharge_from_zero(self):
        _assert_matches(m.scalars_precharge(), va=0.0, vb=0.0, steps=1500)


class TestBlockingInvariance:
    """Pallas tiling must not change the numbers."""

    @pytest.mark.parametrize("block", [8, 16, 32, 64])
    def test_block_sizes(self, block):
        s = m.scalars_rbm()
        va0, vb0, gmul, cmul = _mk_inputs(64, seed=3)
        ref_out = bl.phase(va0, vb0, gmul, cmul, s, n_steps=200, block=64)
        out = bl.phase(va0, vb0, gmul, cmul, s, n_steps=200, block=block)
        for a, b in zip(ref_out, out):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_non_divisible_block_falls_back(self):
        s = m.scalars_rbm()
        va0, vb0, gmul, cmul = _mk_inputs(48, seed=4)
        out = bl.phase(va0, vb0, gmul, cmul, s, n_steps=100, block=32)
        assert out[0].shape == (48,)


class TestPhysicsInvariants:
    """Sanity of the circuit model itself (on the oracle)."""

    def test_precharge_monotone_in_drive(self):
        # Stronger precharge unit => never slower settle.
        base = m.PhysParams()
        prev = None
        for g in [15.0, 20.0, 25.0, 35.0]:
            p = m.PhysParams(g_precharge=g)
            s = m.scalars_precharge(p)
            va0, vb0, gmul, cmul = _mk_inputs(8, va=1.2, vb=1.2, sigma=0.0)
            _, _, _, tt, _ = phase_ref(va0, vb0, gmul, cmul, s, n_steps=2500)
            t = float(np.asarray(tt)[0])
            if prev is not None:
                assert t <= prev + 1e-6
            prev = t

    def test_linked_precharge_strictly_faster(self):
        va0, vb0, gmul, cmul = _mk_inputs(8, va=1.2, vb=1.2, sigma=0.0)
        _, _, _, t1, _ = phase_ref(va0, vb0, gmul, cmul,
                                   m.scalars_precharge(), n_steps=2500)
        _, _, _, t2, _ = phase_ref(va0, vb0, gmul, cmul,
                                   m.scalars_precharge(linked=True),
                                   n_steps=2500)
        assert float(np.asarray(t2)[0]) < float(np.asarray(t1)[0])
        # Paper §3.3: ~2.6x
        ratio = float(np.asarray(t1)[0]) / float(np.asarray(t2)[0])
        assert 2.0 < ratio < 3.5

    def test_paper_anchor_points(self):
        """SPICE anchors from the paper: tRP ~ 13 ns, tRP_LIP ~ 5 ns."""
        va0, vb0, gmul, cmul = _mk_inputs(8, va=1.2, vb=1.2, sigma=0.0)
        _, _, _, t1, _ = phase_ref(va0, vb0, gmul, cmul,
                                   m.scalars_precharge(), n_steps=2500)
        _, _, _, t2, _ = phase_ref(va0, vb0, gmul, cmul,
                                   m.scalars_precharge(linked=True),
                                   n_steps=2500)
        assert 11.0 < float(np.asarray(t1)[0]) < 15.0
        assert 4.0 < float(np.asarray(t2)[0]) < 6.5

    def test_rbm_settles_at_rail(self):
        va0, vb0, gmul, cmul = _mk_inputs(8, va=0.6, vb=1.2, sigma=0.0)
        va, vb, ts, tt, en = phase_ref(va0, vb0, gmul, cmul,
                                       m.scalars_rbm(), n_steps=1500)
        assert float(np.asarray(va)[0]) > 1.15   # dst latched high
        assert 3.0 < float(np.asarray(tt)[0]) < 8.0  # ~5 ns raw

    def test_rbm_symmetric_for_zero(self):
        # Moving a 0 must be as fast as moving a 1 (within tolerance).
        va0, vb0, gmul, cmul = _mk_inputs(8, va=0.6, vb=0.0, sigma=0.0)
        va, _, _, tt0, _ = phase_ref(
            va0, vb0, gmul, cmul,
            # settle target = 0 for data value 0
            m.scalars_rbm().at[bl.S_SETTLE_TGT].set(0.0), n_steps=1500)
        assert float(np.asarray(va)[0]) < 0.05
        va0, vb0, gmul, cmul = _mk_inputs(8, va=0.6, vb=1.2, sigma=0.0)
        _, _, _, tt1, _ = phase_ref(va0, vb0, gmul, cmul, m.scalars_rbm(),
                                    n_steps=1500)
        assert abs(float(np.asarray(tt0)[0]) -
                   float(np.asarray(tt1)[0])) < 1.0

    def test_fast_subarray_faster(self):
        """VILLA premise: shorter bitlines => faster sense AND restore."""
        va0, vb0, gmul, cmul = _mk_inputs(8, va=0.6, vb=1.2, sigma=0.0)
        _, _, s_slow, t_slow, _ = phase_ref(
            va0, vb0, gmul, cmul, m.scalars_activate(), n_steps=4000)
        _, _, s_fast, t_fast, _ = phase_ref(
            va0, vb0, gmul, cmul, m.scalars_activate(fast=True),
            n_steps=4000)
        assert float(np.asarray(s_fast)[0]) < float(np.asarray(s_slow)[0])
        assert float(np.asarray(t_fast)[0]) < float(np.asarray(t_slow)[0])

    def test_energy_nonnegative_and_finite(self):
        for s in [m.scalars_activate(), m.scalars_rbm(),
                  m.scalars_precharge(), m.scalars_precharge(linked=True)]:
            va0, vb0, gmul, cmul = _mk_inputs(16, seed=7, va=0.9, vb=1.1)
            _, _, _, _, en = phase_ref(va0, vb0, gmul, cmul, s, n_steps=400)
            e = np.asarray(en)
            assert np.all(e >= 0) and np.all(np.isfinite(e))

    def test_variation_spreads_settle_times(self):
        """Process variation must produce a worst bitline strictly slower
        than the median — the basis of the paper's guard-band method."""
        va0, vb0, gmul, cmul = _mk_inputs(256, seed=9, va=1.2, vb=1.2,
                                          sigma=0.08)
        _, _, _, tt, _ = phase_ref(va0, vb0, gmul, cmul,
                                   m.scalars_precharge(), n_steps=2500)
        t = np.asarray(tt)
        assert t.max() > np.median(t) * 1.02


# ---------------------------------------------------------------------------
# Hypothesis sweeps: kernel == oracle over random shapes/params/initials.
# ---------------------------------------------------------------------------

@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    n=st.sampled_from([16, 32, 48, 64, 96]),
    block=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    va=st.floats(0.0, 1.2),
    vb=st.floats(0.0, 1.2),
)
def test_hypothesis_kernel_matches_ref(n, block, seed, va, vb):
    s = m.scalars_rbm()
    va0, vb0, gmul, cmul = _mk_inputs(n, seed=seed, va=va, vb=vb)
    got = bl.phase(va0, vb0, gmul, cmul, s, n_steps=120, block=block)
    want = phase_ref(va0, vb0, gmul, cmul, s, n_steps=120)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    g_ext=st.floats(1.0, 80.0),
    g_link=st.floats(0.0, 80.0),
    gm=st.floats(0.0, 60.0),
    ca=st.floats(10.0, 200.0),
    cb=st.floats(10.0, 200.0),
)
def test_hypothesis_random_circuits(g_ext, g_link, gm, ca, cb):
    """Arbitrary (stable) circuit parameters: kernel == oracle, voltages
    stay inside the rails, energy is finite."""
    p = m.DEFAULT_PARAMS
    s = m._scalars(p, {bl.S_G_EXT_A: g_ext, bl.S_V_EXT_A: 0.6,
                       bl.S_G_LINK: g_link, bl.S_GM_A: gm,
                       bl.S_C_A: ca, bl.S_C_B: cb})
    va0, vb0, gmul, cmul = _mk_inputs(32, seed=1, va=1.0, vb=0.2)
    got = bl.phase(va0, vb0, gmul, cmul, s, n_steps=150, block=16)
    want = phase_ref(va0, vb0, gmul, cmul, s, n_steps=150)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)
    v = np.asarray(got[0])
    assert np.all(v >= 0.0) and np.all(v <= 1.2 + 1e-6)


class TestCopyEnergy:
    def test_copy_energy_composition(self):
        """copy_energy == 2*activation + hops * one-hop RBM energy."""
        n = 32
        va0, vb0, gmul, cmul = _mk_inputs(n, seed=5, va=1.0, vb=1.2,
                                          sigma=0.0)
        s_act = m.scalars_activate()
        s_rbm = m.scalars_rbm()
        for hops in [1.0, 7.0, 15.0]:
            e_tot, e_act, e_hop, t_act, t_rbm = m.copy_energy(
                va0, vb0, gmul, cmul, s_act, s_rbm,
                jnp.asarray([hops], jnp.float32))
            want = 2.0 * np.asarray(e_act) + hops * np.asarray(e_hop)
            np.testing.assert_allclose(np.asarray(e_tot), want,
                                       rtol=1e-4)

    def test_copy_energy_monotone_in_hops(self):
        n = 16
        va0, vb0, gmul, cmul = _mk_inputs(n, seed=6, va=1.0, vb=1.2)
        s_act, s_rbm = m.scalars_activate(), m.scalars_rbm()
        prev = None
        for hops in [1.0, 4.0, 8.0, 15.0]:
            e_tot, *_ = m.copy_energy(va0, vb0, gmul, cmul, s_act, s_rbm,
                                      jnp.asarray([hops], jnp.float32))
            e = float(np.asarray(e_tot).sum())
            if prev is not None:
                assert e > prev
            prev = e
