//! System-level metrics: IPC, weighted speedup (the paper's
//! multi-programmed metric [Snavely & Tullsen, Eyerman & Eeckhout]),
//! and the experiment report structures.

use anyhow::{anyhow, bail, Result};

use crate::config::CopyMechanism;
use crate::energy::EnergyBreakdown;
use crate::obs::ObsReport;
use crate::util::json::Value;
use crate::util::stats::geomean;

/// Aggregate statistics of the OS layer (`os/bulk.rs`) for one run.
/// Attached to `RunReport` when the workload carried OS bulk ops.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OsSummary {
    /// Page copies dispatched (including zero fills).
    pub pages_copied: u64,
    /// Subset of `pages_copied` that were page-zeroing copies.
    pub pages_zeroed: u64,
    /// Copy-on-write breaks (fork's lazy copies).
    pub cow_faults: u64,
    /// Demand-zero fills of unmapped pages.
    pub demand_faults: u64,
    pub forks: u64,
    pub checkpoints: u64,
    pub promotions: u64,
    /// Page copies whose src/dst shared a bank — i.e. serviceable by
    /// LISA-RISC (or RowClone intra-SA) without leaving the bank. The
    /// placement policy's figure of merit.
    pub risc_hits: u64,
    /// Pages per effective copy mechanism, indexed by `mech_index`:
    /// [memcpy, rc-intra, rc-bank, rc-inter, lisa-risc].
    pub mech_pages: [u64; 5],
}

impl OsSummary {
    /// Index into `mech_pages` for a `CopyMechanism::name()`. Unknown
    /// names are an error, not a panic — callers on a parse path
    /// propagate context; hot-path callers that already hold the enum
    /// use the infallible [`Self::mech_slot`] instead.
    pub fn mech_index(name: &str) -> Result<usize> {
        match name {
            "memcpy" => Ok(0),
            "rc-intra" => Ok(1),
            "rc-bank" => Ok(2),
            "rc-inter" => Ok(3),
            "lisa-risc" => Ok(4),
            other => bail!(
                "unknown copy mechanism name '{other}' (expected one of \
                 memcpy, rc-intra, rc-bank, rc-inter, lisa-risc)"
            ),
        }
    }

    /// The `mech_pages` slot for a resolved mechanism — no string
    /// lookup and no failure mode (the dispatch hot path).
    pub fn mech_slot(mech: CopyMechanism) -> usize {
        match mech {
            CopyMechanism::MemcpyChannel => 0,
            CopyMechanism::RowCloneIntraSa => 1,
            CopyMechanism::RowCloneInterBank => 2,
            CopyMechanism::RowCloneInterSa => 3,
            CopyMechanism::LisaRisc => 4,
        }
    }

    /// Fraction of page copies the placement kept within RISC reach.
    pub fn risc_hit_rate(&self) -> f64 {
        if self.pages_copied == 0 {
            0.0
        } else {
            self.risc_hits as f64 / self.pages_copied as f64
        }
    }

    // lint: allow(json-key-drift: risc_hit_rate) reason=derived from risc_hits/pages_copied, recomputed on read
    pub fn to_json(&self) -> String {
        format!(
            "{{\"pages_copied\":{},\"pages_zeroed\":{},\"cow_faults\":{},\
             \"demand_faults\":{},\"forks\":{},\"checkpoints\":{},\
             \"promotions\":{},\"risc_hits\":{},\"risc_hit_rate\":{},\
             \"mech_pages\":{{\"memcpy\":{},\"rc_intra\":{},\"rc_bank\":{},\
             \"rc_inter\":{},\"lisa_risc\":{}}}}}",
            self.pages_copied,
            self.pages_zeroed,
            self.cow_faults,
            self.demand_faults,
            self.forks,
            self.checkpoints,
            self.promotions,
            self.risc_hits,
            json::number(self.risc_hit_rate()),
            self.mech_pages[0],
            self.mech_pages[1],
            self.mech_pages[2],
            self.mech_pages[3],
            self.mech_pages[4],
        )
    }

    /// Rebuild from the object [`Self::to_json`] emits (the campaign
    /// journal / result-cache read path). `risc_hit_rate` is derived,
    /// not stored, so the round trip is exact.
    pub fn from_json(v: &Value) -> Result<Self> {
        let mech = v
            .get("mech_pages")
            .ok_or_else(|| anyhow!("os summary missing 'mech_pages'"))?;
        let mut mech_pages = [0u64; 5];
        for (i, key) in ["memcpy", "rc_intra", "rc_bank", "rc_inter", "lisa_risc"]
            .iter()
            .enumerate()
        {
            mech_pages[i] = field_u64(mech, key)?;
        }
        Ok(Self {
            pages_copied: field_u64(v, "pages_copied")?,
            pages_zeroed: field_u64(v, "pages_zeroed")?,
            cow_faults: field_u64(v, "cow_faults")?,
            demand_faults: field_u64(v, "demand_faults")?,
            forks: field_u64(v, "forks")?,
            checkpoints: field_u64(v, "checkpoints")?,
            promotions: field_u64(v, "promotions")?,
            risc_hits: field_u64(v, "risc_hits")?,
            mech_pages,
        })
    }
}

fn field_u64(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_u64)
        .ok_or_else(|| anyhow!("report field '{key}' is not a u64"))
}

fn field_f64(v: &Value, key: &str) -> Result<f64> {
    // The emitter writes non-finite floats as null; they read back as
    // NaN and re-serialize as null, keeping round trips byte-stable.
    v.get(key)
        .and_then(Value::as_f64_or_nan)
        .ok_or_else(|| anyhow!("report field '{key}' is not a number"))
}

fn field_str(v: &Value, key: &str) -> Result<String> {
    Ok(v.get(key)
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("report field '{key}' is not a string"))?
        .to_string())
}

/// Result of simulating one workload on one configuration.
/// `PartialEq` is exact float equality — used by the engine
/// equivalence tests (fast-forward vs per-cycle reference) and the
/// campaign determinism tests (N threads vs 1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunReport {
    pub workload: String,
    pub config_name: String,
    /// Per-core instructions-per-cycle (CPU cycles).
    pub ipc: Vec<f64>,
    /// DRAM cycles simulated.
    pub dram_cycles: u64,
    pub reads: u64,
    pub writes: u64,
    pub copies: u64,
    pub avg_read_latency_cycles: f64,
    pub row_hit_rate: f64,
    pub villa_hit_rate: f64,
    pub lip_coverage: f64,
    pub energy: EnergyBreakdown,
    /// OS-layer statistics; `None` for workloads without bulk ops.
    pub os: Option<OsSummary>,
    /// Latency attribution (`--obs` runs only). When `None` the
    /// serialized report is byte-identical to a build without the
    /// observability layer: the `"obs"` key is simply absent.
    pub obs: Option<ObsReport>,
}

impl RunReport {
    /// Weighted speedup against per-core alone-run IPCs:
    /// WS = sum_i IPC_shared,i / IPC_alone,i.
    ///
    /// The two slices must be the same length — `zip` would otherwise
    /// silently truncate to the shorter one and return a plausible but
    /// wrong WS (cores dropped from the sum). Debug builds assert; the
    /// campaign paths go through [`Self::try_weighted_speedup`] so the
    /// mismatch fails loudly there in release builds too.
    pub fn weighted_speedup(&self, alone_ipc: &[f64]) -> f64 {
        debug_assert_eq!(
            self.ipc.len(),
            alone_ipc.len(),
            "weighted speedup needs one alone-run IPC per shared-run core"
        );
        self.ipc
            .iter()
            .zip(alone_ipc)
            .map(|(s, a)| if *a > 0.0 { s / a } else { 0.0 })
            .sum()
    }

    /// [`Self::weighted_speedup`] with the length mismatch as a hard
    /// error — the campaign/experiment paths use this so a miscounted
    /// alone-run vector cannot produce a silently-truncated WS.
    pub fn try_weighted_speedup(&self, alone_ipc: &[f64]) -> Result<f64> {
        if self.ipc.len() != alone_ipc.len() {
            bail!(
                "weighted speedup over {} shared-run cores needs {} alone-run \
                 IPCs, got {} (workload '{}')",
                self.ipc.len(),
                self.ipc.len(),
                alone_ipc.len(),
                self.workload
            );
        }
        Ok(self.weighted_speedup(alone_ipc))
    }

    pub fn ipc_sum(&self) -> f64 {
        self.ipc.iter().sum()
    }

    /// Convenience for single-config summaries where WS is taken
    /// against itself (== number of cores when alone == shared).
    pub fn weighted_speedup_sum(&self) -> f64 {
        self.ipc_sum()
    }

    /// Serialize as a JSON object (hand-rolled: no serde offline).
    /// The `"obs"` key appears only for `--obs` runs, so plain reports
    /// serialize byte-identically to builds predating the key.
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"workload\":{},\"config\":{},\"ipc\":[{}],\"dram_cycles\":{},\
             \"reads\":{},\"writes\":{},\"copies\":{},\
             \"avg_read_latency_cycles\":{},\"row_hit_rate\":{},\
             \"villa_hit_rate\":{},\"lip_coverage\":{},\
             \"energy_uj\":{{\"total\":{},\"background\":{},\"rbm\":{}}},\
             \"os\":{}",
            json::string(&self.workload),
            json::string(&self.config_name),
            self.ipc.iter().map(|&x| json::number(x)).collect::<Vec<_>>().join(","),
            self.dram_cycles,
            self.reads,
            self.writes,
            self.copies,
            json::number(self.avg_read_latency_cycles),
            json::number(self.row_hit_rate),
            json::number(self.villa_hit_rate),
            json::number(self.lip_coverage),
            json::number(self.energy.total),
            json::number(self.energy.background_uj),
            json::number(self.energy.rbm_uj),
            self.os
                .as_ref()
                .map_or_else(|| "null".to_string(), |o| o.to_json()),
        );
        if let Some(obs) = self.obs.as_ref() {
            out.push_str(",\"obs\":");
            out.push_str(&obs.to_json());
        }
        out.push('}');
        out
    }

    /// Rebuild a report from the object [`Self::to_json`] emits — the
    /// read path of the campaign checkpoint journal and result cache.
    ///
    /// The round trip is byte-stable through `to_json` but lossy in
    /// memory where the JSON is: the energy breakdown only serializes
    /// its total/background/rbm components (the rest read back as
    /// zero), and non-finite floats read back as NaN. Campaign reports
    /// only ever compare and re-emit through JSON, so neither loss is
    /// observable there.
    pub fn from_json(v: &Value) -> Result<Self> {
        let ipc = v
            .get("ipc")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("report missing 'ipc' array"))?
            .iter()
            .map(|x| {
                x.as_f64_or_nan()
                    .ok_or_else(|| anyhow!("non-numeric IPC entry"))
            })
            .collect::<Result<Vec<f64>>>()?;
        let e = v
            .get("energy_uj")
            .ok_or_else(|| anyhow!("report missing 'energy_uj'"))?;
        let energy = EnergyBreakdown::from_serialized(
            field_f64(e, "total")?,
            field_f64(e, "background")?,
            field_f64(e, "rbm")?,
        );
        let os = match v.get("os") {
            None | Some(Value::Null) => None,
            Some(o) => Some(OsSummary::from_json(o)?),
        };
        let obs = match v.get("obs") {
            None | Some(Value::Null) => None,
            Some(o) => Some(ObsReport::from_json(o)?),
        };
        Ok(Self {
            workload: field_str(v, "workload")?,
            config_name: field_str(v, "config")?,
            ipc,
            dram_cycles: field_u64(v, "dram_cycles")?,
            reads: field_u64(v, "reads")?,
            writes: field_u64(v, "writes")?,
            copies: field_u64(v, "copies")?,
            avg_read_latency_cycles: field_f64(v, "avg_read_latency_cycles")?,
            row_hit_rate: field_f64(v, "row_hit_rate")?,
            villa_hit_rate: field_f64(v, "villa_hit_rate")?,
            lip_coverage: field_f64(v, "lip_coverage")?,
            energy,
            os,
            obs,
        })
    }
}

/// Minimal JSON emission helpers (the offline registry has no serde;
/// the campaign runner's reports only need strings and numbers).
pub mod json {
    /// Quote + escape a string.
    pub fn string(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
        out
    }

    /// Format a float as a JSON number (non-finite values, which JSON
    /// cannot represent, become null).
    pub fn number(x: f64) -> String {
        if x.is_finite() {
            format!("{x}")
        } else {
            "null".to_string()
        }
    }
}

/// Comparison of a mechanism against a baseline across workloads.
#[derive(Debug, Clone, Default)]
pub struct Comparison {
    pub name: String,
    /// Per-workload WS improvement fractions (e.g. 0.25 = +25%).
    pub ws_improvements: Vec<f64>,
    /// Per-workload energy reduction fractions.
    pub energy_reductions: Vec<f64>,
}

impl Comparison {
    pub fn mean_ws_improvement(&self) -> f64 {
        if self.ws_improvements.is_empty() {
            return 0.0;
        }
        self.ws_improvements.iter().sum::<f64>() / self.ws_improvements.len() as f64
    }

    pub fn geomean_speedup(&self) -> f64 {
        let ratios: Vec<f64> = self.ws_improvements.iter().map(|i| 1.0 + i).collect();
        geomean(&ratios)
    }

    pub fn max_ws_improvement(&self) -> f64 {
        if self.ws_improvements.is_empty() {
            // Folding from f64::MIN would report finite garbage
            // (-1.7e308) that even slips past the non-finite→null
            // JSON audit; empty aggregates are 0.0 like the mean.
            return 0.0;
        }
        self.ws_improvements.iter().cloned().fold(f64::MIN, f64::max)
    }

    pub fn mean_energy_reduction(&self) -> f64 {
        if self.energy_reductions.is_empty() {
            return 0.0;
        }
        self.energy_reductions.iter().sum::<f64>() / self.energy_reductions.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_speedup_math() {
        let r = RunReport { ipc: vec![1.0, 2.0], ..Default::default() };
        let ws = r.weighted_speedup(&[2.0, 2.0]);
        assert!((ws - 1.5).abs() < 1e-12);
        // Degenerate alone IPC contributes zero, not a panic.
        let ws = r.weighted_speedup(&[0.0, 2.0]);
        assert!((ws - 1.0).abs() < 1e-12);
        assert!((r.try_weighted_speedup(&[2.0, 2.0]).unwrap() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn weighted_speedup_length_mismatch_fails_loudly() {
        // Regression: `zip` used to truncate a short alone-run vector
        // and return a plausible-but-wrong WS (here 0.5 instead of an
        // error — core 1's term silently vanished).
        let r = RunReport { ipc: vec![1.0, 2.0], ..Default::default() };
        let err = r.try_weighted_speedup(&[2.0]).unwrap_err().to_string();
        assert!(err.contains("2 shared-run cores"), "{err}");
        assert!(err.contains("got 1"), "{err}");
        assert!(r.try_weighted_speedup(&[2.0, 2.0, 2.0]).is_err());
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "one alone-run IPC per shared-run core")]
    fn weighted_speedup_mismatch_asserts_in_debug() {
        let r = RunReport { ipc: vec![1.0, 2.0], ..Default::default() };
        r.weighted_speedup(&[2.0]);
    }

    #[test]
    fn json_escaping_and_report_shape() {
        assert_eq!(json::string("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json::number(1.5), "1.5");
        assert_eq!(json::number(f64::NAN), "null");
        let r = RunReport {
            workload: "stream4".into(),
            config_name: "memcpy".into(),
            ipc: vec![1.0, 2.0],
            dram_cycles: 10,
            ..Default::default()
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"workload\":\"stream4\""), "{j}");
        assert!(j.contains("\"ipc\":[1,2]"), "{j}");
        assert!(j.contains("\"dram_cycles\":10"), "{j}");
    }

    #[test]
    fn json_number_rejects_all_nonfinite_values() {
        // JSON has no NaN/Infinity tokens; all three must become null
        // so reports from empty/degenerate runs stay parseable.
        assert_eq!(json::number(f64::NAN), "null");
        assert_eq!(json::number(f64::INFINITY), "null");
        assert_eq!(json::number(f64::NEG_INFINITY), "null");
        assert_eq!(json::number(0.0), "0");
        assert_eq!(json::number(-2.5e-3), "-0.0025");
    }

    #[test]
    fn json_string_escapes_control_and_meta_characters() {
        // Quotes, backslashes, the named escapes, and every other
        // C0 control character (as \u00xx).
        assert_eq!(json::string("\"\\"), "\"\\\"\\\\\"");
        assert_eq!(json::string("\n\r\t"), "\"\\n\\r\\t\"");
        assert_eq!(json::string("\u{1}\u{1f}"), "\"\\u0001\\u001f\"");
        // Non-ASCII passes through unescaped (JSON is UTF-8).
        assert_eq!(json::string("héllo"), "\"héllo\"");
    }

    #[test]
    fn degenerate_report_serializes_without_nonfinite_tokens() {
        // An "empty run" report: zero cores, NaN/inf statistics.
        let r = RunReport {
            workload: "weird \"name\"\n".into(),
            avg_read_latency_cycles: f64::NAN,
            row_hit_rate: f64::INFINITY,
            villa_hit_rate: f64::NEG_INFINITY,
            ..Default::default()
        };
        let j = r.to_json();
        assert!(j.contains("\"avg_read_latency_cycles\":null"), "{j}");
        assert!(j.contains("\"row_hit_rate\":null"), "{j}");
        assert!(!j.contains("NaN") && !j.contains("inf"), "{j}");
        assert!(j.contains("\"ipc\":[]"), "{j}");
        assert!(j.contains("weird \\\"name\\\"\\n"), "{j}");
        assert!(j.contains("\"os\":null"), "{j}");
    }

    #[test]
    fn report_json_round_trips_byte_identically() {
        // The campaign journal / result cache store reports as the
        // exact JSON `to_json` emits; reading them back and re-emitting
        // must reproduce the bytes — including NaN→null→NaN floats and
        // the OS summary block.
        let os = OsSummary {
            pages_copied: 8,
            risc_hits: 6,
            mech_pages: [2, 0, 0, 0, 6],
            ..Default::default()
        };
        let r = RunReport {
            workload: "os-fork \"weird\"\n".into(),
            config_name: "risc+salp:masa".into(),
            ipc: vec![1.0, 1.0 / 3.0, f64::NAN],
            dram_cycles: 123_456_789,
            reads: 42,
            writes: 7,
            copies: 3,
            avg_read_latency_cycles: 88.125,
            row_hit_rate: f64::INFINITY,
            villa_hit_rate: 0.25,
            lip_coverage: 0.0,
            energy: EnergyBreakdown::from_serialized(12.5, 3.25, 0.0625),
            os: Some(os),
            obs: None,
        };
        let emitted = r.to_json();
        let parsed = crate::util::json::parse(&emitted).unwrap();
        let back = RunReport::from_json(&parsed).unwrap();
        assert_eq!(back.to_json(), emitted);
        // Exact fields survive; non-finite floats degrade to NaN only.
        assert_eq!(back.dram_cycles, r.dram_cycles);
        assert_eq!(back.workload, r.workload);
        assert!(back.row_hit_rate.is_nan());
        assert_eq!(back.os.as_ref().unwrap().mech_pages, [2, 0, 0, 0, 6]);
        // A report without an OS layer round-trips too.
        let plain = RunReport { os: None, ..r.clone() };
        let emitted = plain.to_json();
        let back =
            RunReport::from_json(&crate::util::json::parse(&emitted).unwrap())
                .unwrap();
        assert_eq!(back.to_json(), emitted);
        assert!(back.os.is_none());
        // Truncated or reshaped documents fail loudly.
        assert!(RunReport::from_json(&Value::Null).is_err());
        let half = crate::util::json::parse("{\"workload\":\"x\"}").unwrap();
        assert!(RunReport::from_json(&half).is_err());
    }

    #[test]
    fn os_summary_serializes_and_rates() {
        let mut o = OsSummary::default();
        assert_eq!(o.risc_hit_rate(), 0.0, "empty summary must not NaN");
        assert!(o.to_json().contains("\"risc_hit_rate\":0"));
        o.pages_copied = 8;
        o.risc_hits = 6;
        o.mech_pages[OsSummary::mech_index("lisa-risc").unwrap()] = 6;
        o.mech_pages[OsSummary::mech_index("memcpy").unwrap()] = 2;
        assert!((o.risc_hit_rate() - 0.75).abs() < 1e-12);
        let j = o.to_json();
        assert!(j.contains("\"pages_copied\":8"), "{j}");
        assert!(j.contains("\"lisa_risc\":6"), "{j}");
        let r = RunReport { os: Some(o), ..Default::default() };
        assert!(r.to_json().contains("\"os\":{\"pages_copied\":8"));
    }

    #[test]
    fn mech_index_errors_on_unknown_and_agrees_with_mech_slot() {
        let err = OsSummary::mech_index("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus") && err.contains("lisa-risc"), "{err}");
        for m in CopyMechanism::ALL {
            assert_eq!(
                OsSummary::mech_index(m.name()).unwrap(),
                OsSummary::mech_slot(m),
                "{}",
                m.name()
            );
        }
    }

    #[test]
    fn report_with_obs_block_round_trips_and_plain_reports_omit_it() {
        let obs = ObsReport {
            requests: 2,
            sum_queueing: 5,
            sum_service: 50,
            lat_p50: 20.0,
            bank_util: vec![0.5, 0.25],
            ..Default::default()
        };
        let r = RunReport {
            workload: "w".into(),
            obs: Some(obs),
            ..Default::default()
        };
        let j = r.to_json();
        assert!(j.contains("\"obs\":{\"requests\":2"), "{j}");
        let back =
            RunReport::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.to_json(), j);
        assert!(back.obs.is_some());
        // Without `--obs` the key is absent entirely — byte identity
        // with pre-observability reports.
        let plain = RunReport { obs: None, ..r };
        assert!(!plain.to_json().contains("\"obs\""), "{}", plain.to_json());
    }

    #[test]
    fn empty_comparison_aggregates_are_zero_not_fold_garbage() {
        let c = Comparison::default();
        assert_eq!(c.max_ws_improvement(), 0.0);
        assert_eq!(c.mean_ws_improvement(), 0.0);
        assert_eq!(c.mean_energy_reduction(), 0.0);
        assert!(c.max_ws_improvement().is_finite());
        // Non-empty all-negative comparisons still report the true
        // (negative) maximum — only the empty case is pinned to zero.
        let c = Comparison {
            ws_improvements: vec![-0.2, -0.05],
            ..Default::default()
        };
        assert!((c.max_ws_improvement() + 0.05).abs() < 1e-12);
    }

    #[test]
    fn comparison_aggregates() {
        let c = Comparison {
            name: "x".into(),
            ws_improvements: vec![0.10, 0.30],
            energy_reductions: vec![0.5, 0.3],
        };
        assert!((c.mean_ws_improvement() - 0.20).abs() < 1e-12);
        assert!((c.mean_energy_reduction() - 0.40).abs() < 1e-12);
        assert!((c.geomean_speedup() - (1.1f64 * 1.3).sqrt()).abs() < 1e-12);
        assert!((c.max_ws_improvement() - 0.30).abs() < 1e-12);
    }
}
