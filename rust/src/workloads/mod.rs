//! Synthetic workload substrate: trace generators reproducing the
//! memory-behaviour classes of the paper's Pin-based SPEC/TBB/copy
//! workloads (DESIGN.md substitution map row 3), the 50 four-core
//! mixes the evaluation sweeps over, the E9 OS scenarios and the E11
//! GC / heap-traversal family.

pub mod gc;
pub mod generators;
pub mod mixes;
pub mod os_scenarios;

pub use gc::GcScenario;
pub use generators::{CoreSpec, WorkloadKind};
pub use mixes::{all_mixes, workload_by_name, Workload};
pub use os_scenarios::OsScenario;
