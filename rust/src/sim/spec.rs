//! The declarative experiment API: named axes → config grid →
//! campaign → one report schema.
//!
//! An [`ExperimentSpec`] names its axes (workloads, copy mechanisms,
//! SALP modes, placement policies, speed bins, LISA presets) and their
//! default values; [`expand`] turns the cartesian product into
//! `SimConfig` grid points via [`SimConfigBuilder`]; [`run`] shards
//! the points across the campaign runner and returns a [`Report`] —
//! one record per point, one JSON serializer for every experiment.
//! The built-in registry covers the paper's system-level experiments
//! (`fig3`, `fig4`, `lip-system`, `e9-os`, `e10-salp`, `e11-gc`,
//! `sweep`); the
//! legacy CLI subcommands are thin aliases onto it, and a new scenario
//! is one more [`ExperimentSpec`] value — no CLI surgery required.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::backend::analytical::{IPC_TOLERANCE_PCT, WS_TOLERANCE_PCT};
use crate::cli::Args;
use crate::config::builder::LisaPreset;
use crate::config::{
    BackendKind, CopyMechanism, PlacementPolicy, SalpMode, SimConfig,
    SimConfigBuilder,
};
use crate::dram::timing::SpeedBin;
use crate::metrics::{json, Comparison, RunReport};
use crate::obs::{CampaignProfile, SharedTraceRing, TraceEvent};
use crate::sim::engine::{alone_ipcs, run_workload_obs, Simulation};
use crate::sim::{cache, campaign, journal};
use crate::util::bench::Table;
use crate::util::hash;
use crate::util::json::Value;
use crate::workloads::{mixes, Workload};

/// What an axis value means — how it is validated and applied to the
/// config builder during grid expansion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AxisKind {
    /// Selects the workload (not a config field). Every spec has
    /// exactly one.
    Workload,
    Mechanism,
    SalpMode,
    Placement,
    Speed,
    /// Named LISA feature combination — the config axis of the
    /// weighted-speedup experiments.
    Preset,
    /// Which [`MemoryModel`](crate::backend::MemoryModel) evaluates the
    /// point. Never part of a spec's declared axes: every spec gains it
    /// implicitly (outermost) when `--backend` is given, and default
    /// runs carry no backend coordinate at all — their records stay
    /// byte-identical to builds that predate backend plurality.
    Backend,
}

impl AxisKind {
    /// The value set, for generated usage text.
    pub fn choices(&self) -> &'static str {
        match self {
            Self::Workload => {
                "any suite workload (see `lisa list-workloads`) or trace:<file>"
            }
            Self::Mechanism => "memcpy|rc-intra|rc-bank|rc-inter|lisa-risc",
            Self::SalpMode => "none|salp1|salp2|masa",
            Self::Placement => "random|packed|spread|villa-aware",
            Self::Speed => "ddr3-1600|ddr4-2400",
            Self::Preset => "baseline|risc|risc-villa|all|villa-rc|lip",
            Self::Backend => "cycle|analytical",
        }
    }

    /// Parse-validate one value (workloads are resolved against the
    /// suite during expansion, where the registry is built once).
    fn validate(&self, v: &str) -> Result<()> {
        match self {
            Self::Workload => Ok(()),
            Self::Mechanism => CopyMechanism::parse(v).map(|_| ()),
            Self::SalpMode => SalpMode::parse(v).map(|_| ()),
            Self::Placement => PlacementPolicy::parse(v).map(|_| ()),
            Self::Speed => SpeedBin::parse(v).map(|_| ()),
            Self::Preset => LisaPreset::parse(v).map(|_| ()),
            Self::Backend => BackendKind::parse(v).map(|_| ()),
        }
    }
}

/// One named axis of an experiment grid.
#[derive(Debug, Clone)]
pub struct AxisDef {
    /// Record/JSON key (`workload`, `mech`, `mode`, `policy`, ...).
    pub name: String,
    /// CLI option that overrides the values (`--<flag> a,b,c`); kept
    /// distinct from `name` so legacy spellings (`--mechs`,
    /// `--scenarios`) stay valid.
    pub flag: String,
    pub kind: AxisKind,
    /// Default values (the full built-in grid).
    pub values: Vec<String>,
    /// How `--mixes N` re-derives this axis's values, for the specs
    /// whose workload set is "first N of a mix family".
    pub with_mixes: Option<fn(usize) -> Vec<String>>,
}

impl AxisDef {
    pub fn new(name: &str, flag: &str, kind: AxisKind, values: Vec<String>) -> Self {
        Self {
            name: name.to_string(),
            flag: flag.to_string(),
            kind,
            values,
            with_mixes: None,
        }
    }

    pub fn with_mixes(mut self, f: fn(usize) -> Vec<String>) -> Self {
        self.with_mixes = Some(f);
        self
    }
}

/// How the grid is evaluated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Eval {
    /// One independent simulation per grid point.
    Raw,
    /// The paper's multiprogrammed methodology: per workload, measure
    /// alone-run IPCs once on the first preset (the baseline), then
    /// one shared run per preset; each record carries its weighted
    /// speedup against those alone runs. Requires exactly two axes:
    /// a `Workload` axis followed by a `Preset` axis.
    WeightedSpeedup,
}

/// A declarative experiment: axes + defaults + evaluation mode.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Registry key (`lisa exp <name>`).
    pub name: String,
    /// One-line description for `--list` and the generated usage text.
    pub title: String,
    /// Default requests per core (`--requests` overrides).
    pub requests: u64,
    pub eval: Eval,
    /// Grid axes, outermost first — records come back in this
    /// cartesian order regardless of thread count.
    pub axes: Vec<AxisDef>,
}

impl ExperimentSpec {
    /// Grid size with the default axis values.
    pub fn default_points(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }
}

/// Where `lisa exp` caches finished campaign jobs unless `--cache-dir`
/// redirects or `--no-cache` disables it (relative to the working
/// directory — under `cargo run` that is the crate's `target/`
/// neighborhood, wiped by `cargo clean`). Library callers
/// (`RunOptions::default()`) get no cache unless they opt in.
pub const DEFAULT_CACHE_DIR: &str = "target/lisa-cache";

/// Per-invocation overrides (CLI options or test parameters).
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Requests per core; `None` means the spec default.
    pub requests: Option<u64>,
    /// RNG seed override (`--seed`).
    pub seed: Option<u64>,
    /// Base configuration the grid specializes (`--config FILE`);
    /// `None` means the defaults.
    pub base: Option<SimConfig>,
    /// Worker threads; `0` auto-detects.
    pub threads: usize,
    /// `--mixes N` — re-derive mix-family workload axes to their
    /// first N entries.
    pub mixes: Option<usize>,
    /// Explicit per-axis value overrides, keyed by axis *name*.
    pub axes: Vec<(String, Vec<String>)>,
    /// `--backend cycle,analytical` — evaluate the grid under these
    /// memory-model backends. Empty means the config default (cycle)
    /// with *no* backend axis: default records and their JSON stay
    /// byte-identical to pre-backend builds. Non-empty prepends an
    /// implicit outermost `backend` axis to every spec.
    pub backend: Vec<String>,
    /// `--journal FILE` — checkpoint finished jobs here as they
    /// complete.
    pub journal: Option<PathBuf>,
    /// `--resume FILE` — adopt matching finished jobs from a prior
    /// journal, then keep appending to it (unless `journal` points
    /// elsewhere). A missing file is a fresh start, not an error.
    pub resume: Option<PathBuf>,
    /// Result-cache directory; `None` disables caching.
    pub cache_dir: Option<PathBuf>,
    /// `--obs` — run with latency attribution; every record's report
    /// gains an `"obs"` block. Off by default: plain reports stay
    /// byte-identical to builds without the observability layer.
    pub obs: bool,
}

impl RunOptions {
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    pub fn requests(mut self, n: u64) -> Self {
        self.requests = Some(n);
        self
    }

    pub fn mixes(mut self, n: usize) -> Self {
        self.mixes = Some(n);
        self
    }

    pub fn base(mut self, cfg: SimConfig) -> Self {
        self.base = Some(cfg);
        self
    }

    pub fn backend(mut self, values: &[&str]) -> Self {
        self.backend = values.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn axis(mut self, name: &str, values: &[&str]) -> Self {
        self.axes
            .push((name.to_string(), values.iter().map(|s| s.to_string()).collect()));
        self
    }

    pub fn journal(mut self, path: impl Into<PathBuf>) -> Self {
        self.journal = Some(path.into());
        self
    }

    pub fn resume(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(path.into());
        self
    }

    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    pub fn obs(mut self, on: bool) -> Self {
        self.obs = on;
        self
    }

    /// Extract overrides from parsed CLI arguments: `--requests`,
    /// `--threads`, `--mixes`, the campaign flags (`--journal`,
    /// `--resume`, `--cache-dir`, `--no-cache`), plus one
    /// `--<flag> a,b,c` list option per spec axis. Shared by
    /// `lisa exp <name>` and every legacy alias subcommand, which is
    /// what keeps their behaviour (and JSON) identical by
    /// construction. The CLI caches by default ([`DEFAULT_CACHE_DIR`]);
    /// `--no-cache` wins over `--cache-dir` if both appear.
    pub fn from_args(spec: &ExperimentSpec, args: &Args) -> Result<Self> {
        let base = match args.opt("config") {
            Some(path) => Some(SimConfig::from_file(Path::new(path))?),
            None => None,
        };
        let cache_dir = if args.has_flag("no-cache") {
            None
        } else {
            Some(args.opt("cache-dir").map_or_else(
                || PathBuf::from(DEFAULT_CACHE_DIR),
                PathBuf::from,
            ))
        };
        let mut opts = RunOptions {
            requests: args.opt_u64("requests")?,
            seed: args.opt_u64("seed")?,
            base,
            threads: campaign::resolve_threads(args.opt_usize("threads")?),
            mixes: args.opt_usize("mixes")?,
            axes: Vec::new(),
            backend: args.opt_list("backend").unwrap_or_default(),
            journal: args.opt("journal").map(PathBuf::from),
            resume: args.opt("resume").map(PathBuf::from),
            cache_dir,
            obs: args.has_flag("obs"),
        };
        for axis in &spec.axes {
            if let Some(values) = args.opt_list(&axis.flag) {
                opts.axes.push((axis.name.clone(), values));
            }
        }
        Ok(opts)
    }

    /// Where checkpoints go: `--journal` if given, else the `--resume`
    /// file itself (resuming keeps journaling into the same file, so a
    /// twice-killed campaign still resumes from one place).
    fn journal_path(&self) -> Option<&Path> {
        self.journal.as_deref().or(self.resume.as_deref())
    }

    fn axis_override(&self, name: &str) -> Option<&[String]> {
        self.axes
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
    }
}

/// The implicit `backend` axis definition — shared by every spec, so
/// its record key, flag and usage line cannot drift between them.
fn backend_axis() -> AxisDef {
    AxisDef::new("backend", "backend", AxisKind::Backend, strings(&["cycle"]))
}

/// The effective value list of each axis under `opts`: explicit
/// override > `--mixes` re-derivation > spec default. A `--backend`
/// override prepends the implicit [`backend_axis`] outermost (so
/// cycle/analytical twins of the whole grid sit side by side); without
/// it no backend axis exists and records are byte-identical to
/// pre-backend builds. Values are parse-validated here — with the
/// axis's [`AxisKind::choices`] in the error — so a typo fails with
/// the valid value list before any simulation runs.
pub fn effective_axes(
    spec: &ExperimentSpec,
    opts: &RunOptions,
) -> Result<Vec<(AxisDef, Vec<String>)>> {
    let mut out = Vec::with_capacity(spec.axes.len() + 1);
    if !opts.backend.is_empty() {
        out.push((backend_axis(), opts.backend.clone()));
    }
    for axis in &spec.axes {
        let values: Vec<String> =
            if let Some(explicit) = opts.axis_override(&axis.name) {
                explicit.to_vec()
            } else if let (Some(n), Some(derive)) = (opts.mixes, axis.with_mixes) {
                derive(n)
            } else {
                axis.values.clone()
            };
        out.push((axis.clone(), values));
    }
    for (axis, values) in &out {
        if values.is_empty() {
            bail!("experiment '{}': axis '{}' has no values", spec.name, axis.name);
        }
        for v in values {
            axis.kind.validate(v).with_context(|| {
                format!(
                    "axis '{}' (--{}): valid values are {}",
                    axis.name,
                    axis.flag,
                    axis.kind.choices()
                )
            })?;
        }
    }
    Ok(out)
}

/// One expanded grid point: the axis coordinates, the fully built
/// config and the resolved workload.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub axes: Vec<(String, String)>,
    pub cfg: SimConfig,
    pub workload: Workload,
}

/// Expand a spec into its config grid (cartesian product in axis
/// order, first axis outermost). The workload suite is constructed
/// once and shared across points, so expansion cost is O(grid) — it
/// never touches the simulated hot path.
pub fn expand(spec: &ExperimentSpec, opts: &RunOptions) -> Result<Vec<GridPoint>> {
    let axes = effective_axes(spec, opts)?;
    let requests = opts.requests.unwrap_or(spec.requests);
    let base = opts.base.clone().unwrap_or_default();
    // Workloads scale with the base config's core count; the suite is
    // built once and shared by every grid point.
    let mut suite: BTreeMap<String, Workload> = mixes::all_mixes(&base)
        .into_iter()
        .map(|w| (w.name.clone(), w))
        .collect();
    // `trace:<path>` axis values resolve to trace-backed workloads.
    // Each file is opened, fully validated and digested exactly once
    // per expansion, keyed by its axis spelling (the path): two grid
    // points naming the same file share one Workload.
    for (axis, values) in &axes {
        if axis.kind != AxisKind::Workload {
            continue;
        }
        for v in values {
            if let Some(path) = v.strip_prefix("trace:") {
                if !suite.contains_key(v) {
                    let wl = crate::trace::workload_from_file(Path::new(path))
                        .with_context(|| format!("workload '{v}'"))?;
                    suite.insert(v.clone(), wl);
                }
            }
        }
    }
    let n_points: usize = axes.iter().map(|(_, v)| v.len()).product();
    let mut points = Vec::with_capacity(n_points);
    let mut idx = vec![0usize; axes.len()];
    loop {
        let mut builder =
            SimConfigBuilder::from_config(base.clone()).requests(requests);
        if let Some(seed) = opts.seed {
            builder = builder.seed(seed);
        }
        let mut coords = Vec::with_capacity(axes.len());
        let mut workload: Option<&Workload> = None;
        for (d, (axis, values)) in axes.iter().enumerate() {
            let v = &values[idx[d]];
            coords.push((axis.name.clone(), v.clone()));
            match axis.kind {
                AxisKind::Workload => {
                    workload = Some(suite.get(v).ok_or_else(|| {
                        anyhow::anyhow!("unknown workload '{v}' (axis '{}')", axis.name)
                    })?);
                }
                AxisKind::Mechanism => {
                    builder = builder.mechanism(CopyMechanism::parse(v)?);
                }
                AxisKind::SalpMode => builder = builder.salp(SalpMode::parse(v)?),
                AxisKind::Placement => {
                    builder = builder.placement(PlacementPolicy::parse(v)?);
                }
                AxisKind::Speed => builder = builder.speed(SpeedBin::parse(v)?),
                AxisKind::Preset => builder = builder.preset(LisaPreset::parse(v)?),
                AxisKind::Backend => {
                    builder = builder.backend(BackendKind::parse(v)?);
                }
            }
        }
        let Some(workload) = workload else {
            bail!("experiment '{}' has no workload axis", spec.name);
        };
        points.push(GridPoint {
            axes: coords,
            cfg: builder.build()?,
            workload: workload.clone(),
        });
        // Odometer increment, last axis fastest.
        let mut d = axes.len();
        loop {
            if d == 0 {
                return Ok(points);
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < axes[d].1.len() {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// One finished grid point: where it sits in the grid, its weighted
/// speedup (WS evaluations only) and the full run report.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub axes: Vec<(String, String)>,
    pub ws: Option<f64>,
    pub report: RunReport,
}

impl Record {
    /// The value of a named axis, if the record has it.
    pub fn axis(&self, name: &str) -> Option<&str> {
        self.axes
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Serialize as one element of the report's `records` array. Also
    /// the campaign journal / result-cache entry format: the write →
    /// [`Self::from_json`] → write round trip is byte-identical, which
    /// is what makes resumed and cached campaigns byte-identical to
    /// fresh ones.
    // lint: allow(json-key-drift: config) reason=config name rides in report; from_json ignores the duplicate
    pub fn to_json(&self) -> String {
        let axes: Vec<String> = self
            .axes
            .iter()
            .map(|(n, v)| format!("{}:{}", json::string(n), json::string(v)))
            .collect();
        format!(
            "{{\"config\":{},\"axes\":{{{}}},\"ws\":{},\"report\":{}}}",
            json::string(&self.report.config_name),
            axes.join(","),
            self.ws.map_or_else(|| "null".to_string(), json::number),
            self.report.to_json()
        )
    }

    /// Rebuild a record from the object [`Self::to_json`] emits — the
    /// journal/cache read path. The top-level `config` field is
    /// redundant with the embedded report's and is ignored. A `ws` of
    /// `null` reads back as `None`; a shared run whose WS was NaN also
    /// serialized as `null` (JSON has no NaN), so it re-serializes
    /// identically either way.
    pub fn from_json(v: &Value) -> Result<Self> {
        let axes = v
            .get("axes")
            .and_then(Value::as_object)
            .ok_or_else(|| anyhow::anyhow!("record missing 'axes' object"))?
            .iter()
            .map(|(n, val)| {
                val.as_str()
                    .map(|s| (n.clone(), s.to_string()))
                    .ok_or_else(|| anyhow::anyhow!("axis '{n}' is not a string"))
            })
            .collect::<Result<Vec<_>>>()?;
        let ws = match v.get("ws") {
            None => bail!("record missing 'ws'"),
            Some(Value::Null) => None,
            Some(x) => Some(
                x.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("record 'ws' is not a number"))?,
            ),
        };
        let report = RunReport::from_json(
            v.get("report")
                .ok_or_else(|| anyhow::anyhow!("record missing 'report'"))?,
        )?;
        Ok(Self { axes, ws, report })
    }
}

/// How a campaign's jobs were satisfied: adopted from a `--resume`
/// journal, returned by the result cache, or actually simulated.
/// `resumed + cache_hits + ran` is the total job count.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CampaignStats {
    pub resumed: usize,
    pub cache_hits: usize,
    pub ran: usize,
}

impl CampaignStats {
    pub fn total(&self) -> usize {
        self.resumed + self.cache_hits + self.ran
    }

    /// Fraction of jobs that did not need simulation, as a percentage.
    pub fn reuse_pct(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            (self.resumed + self.cache_hits) as f64 * 100.0 / self.total() as f64
        }
    }

    /// Stable one-line JSON for scripts and CI (the human-readable
    /// stderr summary is free to change; this line is not). A fully
    /// reused campaign shows `"ran":0` and `"reuse_pct":100`.
    pub fn to_json_line(&self, experiment: &str) -> String {
        format!(
            "{{\"campaign\":{{\"experiment\":{},\"jobs\":{},\"resumed\":{},\
             \"cache_hits\":{},\"ran\":{},\"reuse_pct\":{}}}}}",
            json::string(experiment),
            self.total(),
            self.resumed,
            self.cache_hits,
            self.ran,
            json::number(self.reuse_pct()),
        )
    }
}

/// The unified result document: every experiment — built-in or
/// user-registered — serializes through this one schema.
#[derive(Debug, Clone)]
pub struct Report {
    pub experiment: String,
    pub requests: u64,
    pub records: Vec<Record>,
    /// Provenance counters for this invocation (resumed / cached /
    /// simulated). Deliberately outside both `to_json` and `==`: they
    /// describe how the report was produced, not what it says, and a
    /// resumed or fully-cached report must stay byte-identical (and
    /// equal) to a fresh one. `main` prints them to stderr instead.
    pub stats: CampaignStats,
    /// Harness self-profile for this invocation (phase timers +
    /// per-worker scheduler counters). Wall-clock, so — like `stats` —
    /// outside both `to_json` and `==`; `main` emits it as one
    /// machine-readable stderr line.
    pub profile: CampaignProfile,
}

/// Content equality only — see the `stats` field doc.
impl PartialEq for Report {
    fn eq(&self, other: &Self) -> bool {
        self.experiment == other.experiment
            && self.requests == other.requests
            && self.records == other.records
    }
}

impl Report {
    /// The single JSON serializer of the experiment surface:
    /// `{"experiment", "schema", "requests", "records": [{config,
    /// axes, ws, report}]}` with `report` a full `RunReport`. Grids
    /// run with an explicit `--backend` axis additionally carry the
    /// cross-validation contract as a `backend_tolerance` object (the
    /// IPC / weighted-speedup error bands the analytical twin is held
    /// to); default runs omit the key so their bytes never move.
    pub fn to_json(&self) -> String {
        let body: Vec<String> = self.records.iter().map(Record::to_json).collect();
        let tolerance = if self.records.iter().any(|r| r.axis("backend").is_some())
        {
            format!(
                "\"backend_tolerance\":{{\"ipc_pct\":{},\"ws_pct\":{}}},",
                json::number(IPC_TOLERANCE_PCT),
                json::number(WS_TOLERANCE_PCT)
            )
        } else {
            String::new()
        };
        format!(
            "{{\"experiment\":{},\"schema\":1,{}\"requests\":{},\"records\":[\n{}\n]}}\n",
            json::string(&self.experiment),
            tolerance,
            self.requests,
            body.join(",\n")
        )
    }

    /// Human-readable table over the common columns (axes + the
    /// headline metrics every record carries).
    pub fn table(&self) -> Table {
        let axis_names: Vec<String> =
            self.records.first().map_or_else(Vec::new, |r| {
                r.axes.iter().map(|(n, _)| n.clone()).collect()
            });
        let has_ws = self.records.iter().any(|r| r.ws.is_some());
        let mut headers: Vec<&str> = axis_names.iter().map(String::as_str).collect();
        headers.extend(["config", "cycles", "IPC sum"]);
        if has_ws {
            headers.push("WS");
        }
        headers.extend(["row-hit %", "copies", "energy uJ"]);
        let mut t = Table::new(&headers);
        for r in &self.records {
            let mut cells: Vec<String> =
                r.axes.iter().map(|(_, v)| v.clone()).collect();
            cells.push(r.report.config_name.clone());
            cells.push(format!("{}", r.report.dram_cycles));
            cells.push(format!("{:.3}", r.report.ipc_sum()));
            if has_ws {
                cells.push(r.ws.map_or_else(String::new, |w| format!("{w:.3}")));
            }
            cells.push(format!("{:.1}", r.report.row_hit_rate * 100.0));
            cells.push(format!("{}", r.report.copies));
            cells.push(format!("{:.1}", r.report.energy.total));
            t.row(&cells);
        }
        t
    }

    /// Weighted-speedup summaries for WS experiments: one
    /// [`Comparison`] per non-baseline preset value (WS improvement
    /// and energy reduction per workload vs the baseline preset, in
    /// workload order). Empty for raw grids.
    pub fn ws_summary(&self) -> Vec<Comparison> {
        let mut presets: Vec<&str> = Vec::new();
        let mut workloads: Vec<&str> = Vec::new();
        for r in &self.records {
            if let (Some(w), Some(p)) = (r.axis("workload"), r.axis("preset")) {
                if !presets.contains(&p) {
                    presets.push(p);
                }
                if !workloads.contains(&w) {
                    workloads.push(w);
                }
            }
        }
        if presets.len() < 2 {
            return Vec::new();
        }
        let find = |w: &str, p: &str| {
            self.records
                .iter()
                .find(|r| r.axis("workload") == Some(w) && r.axis("preset") == Some(p))
        };
        let baseline = presets[0];
        presets[1..]
            .iter()
            .map(|p| {
                let mut cmp =
                    Comparison { name: p.to_string(), ..Default::default() };
                for w in &workloads {
                    let (Some(b), Some(c)) = (find(w, baseline), find(w, p)) else {
                        continue;
                    };
                    let (Some(b_ws), Some(c_ws)) = (b.ws, c.ws) else { continue };
                    cmp.ws_improvements
                        .push(if b_ws > 0.0 { c_ws / b_ws - 1.0 } else { 0.0 });
                    let (be, ce) = (b.report.energy.total, c.report.energy.total);
                    cmp.energy_reductions
                        .push(if be > 0.0 { 1.0 - ce / be } else { 0.0 });
                }
                cmp
            })
            .collect()
    }
}

/// One schedulable campaign job: the consecutive grid points it
/// evaluates together (a single point for raw grids; one workload's
/// preset chunk for WS grids, so the alone runs are measured once per
/// workload) plus the content key that addresses it in the checkpoint
/// journal and the result cache.
#[derive(Debug, Clone)]
struct CampaignJob {
    points: Vec<GridPoint>,
    key: String,
}

/// Content key of one campaign job: a hash over everything its records
/// depend on — code version, evaluation mode (plus the `--obs` switch:
/// an attributed report has an extra block, so it must never satisfy a
/// plain campaign or vice versa), the *base* config TOML (workload
/// suites are generated from the base config, so the same workload
/// name can mean different traces under a different base), and per
/// point its axis coordinates, workload name and fully-built config.
/// Two invocations agree on a job's key iff the job would produce the
/// same records, which is what makes journal resume and cache hits
/// safe.
fn job_key(eval: Eval, obs: bool, base_toml: &str, points: &[GridPoint]) -> String {
    let mut text = String::new();
    text.push_str(&cache::code_version());
    text.push('\n');
    text.push_str(match eval {
        Eval::Raw => "raw",
        Eval::WeightedSpeedup => "ws",
    });
    if obs {
        text.push_str("+obs");
    }
    text.push('\n');
    text.push_str(base_toml);
    for p in points {
        text.push('\u{1f}');
        for (name, value) in &p.axes {
            text.push_str(name);
            text.push('=');
            text.push_str(value);
            text.push(';');
        }
        text.push_str(&p.workload.name);
        // Trace-backed points fold in the trace file's *content*
        // digest: editing the file in place changes the key (and so
        // invalidates journal/cache entries) even though its path —
        // and therefore the axis coordinates — did not move.
        if let Some(src) = &p.workload.source {
            text.push('#');
            text.push_str(&src.digest);
        }
        text.push('\n');
        text.push_str(&p.cfg.content_hash());
    }
    hash::content_key(&text)
}

/// Evaluate one job. WS jobs follow the paper lineage's
/// multiprogrammed methodology (SALP / TL-DRAM / RowClone): the alone
/// runs are measured once on the chunk's first point (the baseline
/// preset) and shared by every preset's shared run.
fn eval_job(eval: Eval, obs: bool, points: &[GridPoint]) -> Result<Vec<Record>> {
    match eval {
        Eval::Raw => Ok(points
            .iter()
            .map(|p| Record {
                axes: p.axes.clone(),
                ws: None,
                report: run_workload_obs(&p.cfg, &p.workload, obs),
            })
            .collect()),
        Eval::WeightedSpeedup => {
            let baseline = &points[0];
            // Alone runs only feed the WS denominator; attribution on
            // them would be thrown away, so only shared runs get it.
            let alone = alone_ipcs(&baseline.cfg, &baseline.workload);
            points
                .iter()
                .map(|p| {
                    let shared = run_workload_obs(&p.cfg, &p.workload, obs);
                    let ws = shared.try_weighted_speedup(&alone).with_context(|| {
                        format!("grid point {:?}", p.axes)
                    })?;
                    Ok(Record { axes: p.axes.clone(), ws: Some(ws), report: shared })
                })
                .collect()
        }
    }
}

/// Run an experiment spec: expand the grid, chunk it into keyed jobs,
/// satisfy each from the `--resume` journal, then the result cache,
/// then the work-stealing campaign runner (streaming completions back
/// to journal and cache), and return the unified report. Record order
/// is the grid order at any thread count, resumed or not (campaign
/// determinism: results are keyed by grid index, never by completion
/// order).
pub fn run(spec: &ExperimentSpec, opts: &RunOptions) -> Result<Report> {
    let t_total = Instant::now();
    let requests = opts.requests.unwrap_or(spec.requests);
    let threads = campaign::resolve_threads(Some(opts.threads));
    let t_expand = Instant::now();
    let points = expand(spec, opts)?;
    let chunk = match spec.eval {
        Eval::Raw => 1,
        Eval::WeightedSpeedup => {
            if spec.axes.len() != 2
                || spec.axes[0].kind != AxisKind::Workload
                || spec.axes[1].kind != AxisKind::Preset
            {
                bail!(
                    "experiment '{}': WeightedSpeedup needs a workload axis then a preset axis",
                    spec.name
                );
            }
            // Points arrive workload-major (backend-major above that,
            // if `--backend` added the implicit axis); chunk them back
            // into per-workload jobs by preset count — looked up by
            // kind, not position, so the implicit backend axis can
            // never shift it.
            effective_axes(spec, opts)?
                .iter()
                .find(|(a, _)| a.kind == AxisKind::Preset)
                .map(|(_, v)| v.len())
                .expect("WS spec has a preset axis (validated above)")
        }
    };
    let base_toml = opts.base.clone().unwrap_or_default().to_toml();
    let jobs: Vec<CampaignJob> = points
        .chunks(chunk)
        .map(|c| CampaignJob {
            key: job_key(spec.eval, opts.obs, &base_toml, c),
            points: c.to_vec(),
        })
        .collect();
    let expand_ms = ms_since(t_expand);
    let (records, stats, mut profile) = run_campaign(spec.eval, jobs, threads, opts)?;
    profile.expand_ms = expand_ms;
    profile.total_ms = ms_since(t_total);
    Ok(Report { experiment: spec.name.clone(), requests, records, stats, profile })
}

/// Trace one grid point of an experiment: run it alone with a
/// [`SharedTraceRing`] probe attached (and attribution, if `--obs` is
/// also on) and return the recorded events plus how many fell out of
/// the ring. The campaign itself is untouched — tracing is an extra
/// run, so `--trace-point` can never perturb the report.
pub fn run_traced(
    spec: &ExperimentSpec,
    opts: &RunOptions,
    point_idx: usize,
    ring_cap: usize,
) -> Result<(Vec<TraceEvent>, u64)> {
    let points = expand(spec, opts)?;
    let n = points.len();
    let Some(p) = points.into_iter().nth(point_idx) else {
        bail!(
            "--trace-point {point_idx} is out of range: experiment '{}' expands to {n} points",
            spec.name
        );
    };
    let ring = SharedTraceRing::new(ring_cap.max(1));
    let mut sim = Simulation::new(p.cfg, p.workload);
    sim.set_probe(Box::new(ring.clone()));
    if opts.obs {
        sim.enable_obs();
    }
    sim.run();
    Ok((ring.snapshot(), ring.dropped()))
}

fn ms_since(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// Nanosecond accumulator for phases timed inside worker sinks.
fn add_elapsed(acc: &AtomicU64, t: Instant) {
    acc.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
}

fn ns_to_ms(acc: &AtomicU64) -> f64 {
    acc.load(Ordering::Relaxed) as f64 / 1e6
}

/// The campaign core: resume → cache → simulate, with completions
/// streamed to the journal and cache as they happen, and jobs one
/// artifact satisfied written through to the other so journal and
/// cache each end the run self-complete.
fn run_campaign(
    eval: Eval,
    jobs: Vec<CampaignJob>,
    threads: usize,
    opts: &RunOptions,
) -> Result<(Vec<Record>, CampaignStats, CampaignProfile)> {
    let n = jobs.len();
    let mut slots: Vec<Option<Vec<Record>>> = (0..n).map(|_| None).collect();
    let mut stats = CampaignStats::default();
    // Phase accumulators. Serialize/journal/cache tick inside worker
    // sinks (and the main-thread adopt/write-through paths), so they
    // are atomics; their sum can exceed `sim_ms` at threads > 1.
    let serialize_ns = AtomicU64::new(0);
    let journal_ns = AtomicU64::new(0);
    let cache_ns = AtomicU64::new(0);

    // 1. Adopt finished jobs from a prior journal. Only entries whose
    // key matches what *this* invocation computes for that index are
    // trusted; anything else (edited grid, different base config,
    // older code, torn records) silently degrades to "re-run". Later
    // entries supersede earlier ones.
    if let Some(path) = &opts.resume {
        if path.exists() {
            for entry in journal::read(path)? {
                let Some(job) = jobs.get(entry.idx) else { continue };
                if job.key != entry.key || entry.records.len() != job.points.len() {
                    continue;
                }
                if let Ok(records) = parse_records(&entry.records) {
                    slots[entry.idx] = Some(records);
                }
            }
            stats.resumed = slots.iter().filter(|s| s.is_some()).count();
        }
    }
    let resumed_idxs: Vec<usize> = (0..n).filter(|i| slots[*i].is_some()).collect();

    // 2. Consult the content-addressed cache for what's still open.
    let t_consult = Instant::now();
    let cache = match &opts.cache_dir {
        Some(dir) => Some(cache::ResultCache::open(dir)?),
        None => None,
    };
    let mut hit_idxs: Vec<usize> = Vec::new();
    if let Some(cache) = &cache {
        for (i, job) in jobs.iter().enumerate() {
            if slots[i].is_some() {
                continue;
            }
            let Some(raw) = cache.get(&job.key) else { continue };
            if raw.len() != job.points.len() {
                continue;
            }
            if let Ok(records) = parse_records(&raw) {
                slots[i] = Some(records);
                hit_idxs.push(i);
                stats.cache_hits += 1;
            }
        }
        add_elapsed(&cache_ns, t_consult);
    }

    // 3. Simulate the rest on the work-stealing pool, streaming each
    // completion to the journal (flushed per job — a killed run keeps
    // everything finished) and the cache. Sink failures are remembered
    // and surfaced once the pool drains: a campaign whose checkpoints
    // are silently lost would defeat the point of asking for them.
    let writer = match opts.journal_path() {
        Some(path) => Some(Mutex::new(journal::JournalWriter::append_to(path)?)),
        None => None,
    };
    let keys: Vec<String> = jobs.iter().map(|j| j.key.clone()).collect();

    // Write each artifact through to the other, so both are
    // self-complete: journal-adopted jobs warm the cache, cache hits
    // are journaled. A kill later in this run then leaves no journal
    // missing cache-satisfied jobs (or vice versa). The round-trip
    // re-serialization is byte-identical (see `Record::to_json`), so
    // written-through entries equal what a fresh run would write.
    if let Some(cache) = &cache {
        for &i in &resumed_idxs {
            let records = slots[i].as_ref().expect("resumed slot");
            let t = Instant::now();
            let json: Vec<String> = records.iter().map(Record::to_json).collect();
            add_elapsed(&serialize_ns, t);
            let t = Instant::now();
            cache.put(&keys[i], &json)?;
            add_elapsed(&cache_ns, t);
        }
    }
    if let Some(w) = &writer {
        let mut w = w.lock().expect("journal writer");
        for &i in &hit_idxs {
            let records = slots[i].as_ref().expect("cache-hit slot");
            let t = Instant::now();
            let json: Vec<String> = records.iter().map(Record::to_json).collect();
            add_elapsed(&serialize_ns, t);
            let t = Instant::now();
            w.append(i, &keys[i], &json)?;
            add_elapsed(&journal_ns, t);
        }
    }
    let sink_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let obs = opts.obs;
    let pending: Vec<(usize, _)> = jobs
        .into_iter()
        .enumerate()
        .filter(|(i, _)| slots[*i].is_none())
        .map(|(i, job)| (i, move || eval_job(eval, obs, &job.points)))
        .collect();
    stats.ran = pending.len();
    let sink = |idx: usize, result: &Result<Vec<Record>>| {
        let Ok(records) = result else { return };
        let t = Instant::now();
        let json: Vec<String> = records.iter().map(Record::to_json).collect();
        add_elapsed(&serialize_ns, t);
        let t = Instant::now();
        let journaled = match &writer {
            Some(w) => {
                w.lock().expect("journal writer").append(idx, &keys[idx], &json)
            }
            None => Ok(()),
        };
        add_elapsed(&journal_ns, t);
        let t = Instant::now();
        let cached = match &cache {
            Some(c) => c.put(&keys[idx], &json),
            None => Ok(()),
        };
        add_elapsed(&cache_ns, t);
        if let Err(e) = journaled.and(cached) {
            sink_err.lock().expect("sink error slot").get_or_insert(e);
        }
    };
    let t_sim = Instant::now();
    let (results, workers) =
        campaign::run_jobs_sparse_profiled(pending, threads, sink);
    let sim_ms = ms_since(t_sim);
    if let Some(e) = sink_err.into_inner().expect("sink error slot") {
        return Err(e.context("campaign checkpointing failed"));
    }
    for (idx, result) in results {
        slots[idx] = Some(result?);
    }
    let records =
        slots.into_iter().flat_map(|s| s.expect("every job resolved")).collect();
    let profile = CampaignProfile {
        threads,
        expand_ms: 0.0, // filled by `run`
        sim_ms,
        serialize_ms: ns_to_ms(&serialize_ns),
        journal_ms: ns_to_ms(&journal_ns),
        cache_ms: ns_to_ms(&cache_ns),
        total_ms: 0.0, // filled by `run`
        workers,
    };
    Ok((records, stats, profile))
}

/// Parse one journal/cache entry's record array.
fn parse_records(raw: &[Value]) -> Result<Vec<Record>> {
    raw.iter().map(Record::from_json).collect()
}

// ---------------------------------------------------------------------------
// Built-in registry.
// ---------------------------------------------------------------------------

fn default_cores() -> usize {
    SimConfig::default().cpu.cores
}

fn villa_mix_names(n: usize) -> Vec<String> {
    mixes::villa_mixes(default_cores())
        .into_iter()
        .take(n)
        .map(|w| w.name)
        .collect()
}

fn copy_mix_names(n: usize) -> Vec<String> {
    mixes::copy_mixes(default_cores())
        .into_iter()
        .take(n)
        .map(|w| w.name)
        .collect()
}

/// The default `sweep` workload grid: the micro suite plus the first
/// `n` copy mixes.
fn sweep_workloads(n: usize) -> Vec<String> {
    let mut w: Vec<String> =
        vec!["stream4".into(), "random4".into(), "hotspot4".into(), "fork4".into()];
    w.extend(copy_mix_names(n));
    w
}

fn strings(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

/// Every built-in experiment spec. Adding a scenario here is the
/// entire registration step — the `exp` subcommand, its usage text,
/// the legacy-alias table and the JSON schema all derive from this
/// list.
pub fn registry() -> Vec<ExperimentSpec> {
    vec![
        ExperimentSpec {
            name: "fig3".into(),
            title: "E4 (Fig. 3): LISA-VILLA vs RC-InterSA movement on hot-region mixes"
                .into(),
            requests: 3_000,
            eval: Eval::WeightedSpeedup,
            axes: vec![
                AxisDef::new(
                    "workload",
                    "workloads",
                    AxisKind::Workload,
                    villa_mix_names(usize::MAX),
                )
                .with_mixes(villa_mix_names),
                AxisDef::new(
                    "preset",
                    "presets",
                    AxisKind::Preset,
                    strings(&["baseline", "risc-villa", "villa-rc"]),
                ),
            ],
        },
        ExperimentSpec {
            name: "fig4".into(),
            title: "E5/E6 (Fig. 4): RISC / +VILLA / All speedups over the copy mixes"
                .into(),
            requests: 3_000,
            eval: Eval::WeightedSpeedup,
            axes: vec![
                AxisDef::new(
                    "workload",
                    "workloads",
                    AxisKind::Workload,
                    copy_mix_names(usize::MAX),
                )
                .with_mixes(copy_mix_names),
                AxisDef::new(
                    "preset",
                    "presets",
                    AxisKind::Preset,
                    strings(&["baseline", "risc", "risc-villa", "all"]),
                ),
            ],
        },
        ExperimentSpec {
            name: "lip-system".into(),
            title: "E7: LISA-LIP alone at the system level".into(),
            requests: 3_000,
            eval: Eval::WeightedSpeedup,
            axes: vec![
                AxisDef::new(
                    "workload",
                    "workloads",
                    AxisKind::Workload,
                    copy_mix_names(usize::MAX),
                )
                .with_mixes(copy_mix_names),
                AxisDef::new(
                    "preset",
                    "presets",
                    AxisKind::Preset,
                    strings(&["baseline", "lip"]),
                ),
            ],
        },
        ExperimentSpec {
            name: "e9-os".into(),
            title: "E9: OS bulk ops (fork/zero/checkpoint/promote) × mechanism × placement"
                .into(),
            requests: 2_000,
            eval: Eval::Raw,
            axes: vec![
                AxisDef::new(
                    "workload",
                    "scenarios",
                    AxisKind::Workload,
                    strings(&["os-fork", "os-zero", "os-checkpoint", "os-promote"]),
                ),
                AxisDef::new(
                    "mech",
                    "mechs",
                    AxisKind::Mechanism,
                    strings(&["memcpy", "rc-inter", "lisa-risc"]),
                ),
                AxisDef::new(
                    "policy",
                    "policies",
                    AxisKind::Placement,
                    strings(&["random", "packed", "spread", "villa-aware"]),
                ),
            ],
        },
        ExperimentSpec {
            name: "e10-salp".into(),
            title: "E10: SALP/MASA modes composed with LISA on intra-bank conflicts"
                .into(),
            requests: 2_000,
            eval: Eval::Raw,
            axes: vec![
                AxisDef::new(
                    "workload",
                    "workloads",
                    AxisKind::Workload,
                    strings(&[
                        "salp-pingpong4",
                        "salp-shared-bank4",
                        "salp-copy-conflict4",
                        "os-fork",
                    ]),
                ),
                AxisDef::new(
                    "mech",
                    "mechs",
                    AxisKind::Mechanism,
                    strings(&["memcpy", "lisa-risc"]),
                ),
                AxisDef::new(
                    "mode",
                    "modes",
                    AxisKind::SalpMode,
                    strings(&["none", "salp1", "salp2", "masa"]),
                ),
                AxisDef::new(
                    "policy",
                    "policies",
                    AxisKind::Placement,
                    strings(&["random", "packed", "spread", "villa-aware"]),
                ),
            ],
        },
        ExperimentSpec {
            name: "e11-gc".into(),
            title: "E11: GC/pointer-chase family (traverse/semispace/mark/generational) × mechanism × placement × SALP"
                .into(),
            requests: 2_000,
            eval: Eval::Raw,
            axes: vec![
                AxisDef::new(
                    "workload",
                    "workloads",
                    AxisKind::Workload,
                    strings(&["gc-chase", "gc-semispace", "gc-mark", "gc-gen"]),
                ),
                AxisDef::new(
                    "mech",
                    "mechs",
                    AxisKind::Mechanism,
                    strings(&["memcpy", "rc-inter", "lisa-risc"]),
                ),
                AxisDef::new(
                    "policy",
                    "policies",
                    AxisKind::Placement,
                    strings(&["random", "packed"]),
                ),
                AxisDef::new(
                    "mode",
                    "modes",
                    AxisKind::SalpMode,
                    strings(&["none", "masa"]),
                ),
            ],
        },
        ExperimentSpec {
            name: "sweep".into(),
            title: "Mechanism × speed-bin × workload sweep campaign".into(),
            requests: 2_000,
            eval: Eval::Raw,
            axes: vec![
                AxisDef::new(
                    "workload",
                    "workloads",
                    AxisKind::Workload,
                    sweep_workloads(4),
                )
                .with_mixes(sweep_workloads),
                AxisDef::new(
                    "speed",
                    "speeds",
                    AxisKind::Speed,
                    strings(&["ddr3-1600"]),
                ),
                AxisDef::new(
                    "mech",
                    "mechs",
                    AxisKind::Mechanism,
                    strings(&["memcpy", "lisa-risc"]),
                ),
            ],
        },
    ]
}

/// Look up a built-in spec by registry name.
pub fn spec_by_name(name: &str) -> Result<ExperimentSpec> {
    let specs = registry();
    let known: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
    specs
        .iter()
        .find(|s| s.name == name)
        .cloned()
        .ok_or_else(|| {
            anyhow::anyhow!("unknown experiment '{name}' (expected one of: {})", known.join(", "))
        })
}

/// Legacy subcommand → registry-spec name. The legacy subcommands are
/// thin aliases: same option flags, same pipeline, byte-identical
/// JSON (tested in `tests/experiment_api.rs`).
pub const LEGACY_ALIASES: &[(&str, &str)] = &[
    ("fig3", "fig3"),
    ("fig4", "fig4"),
    ("lip-system", "lip-system"),
    ("os", "e9-os"),
    ("salp", "e10-salp"),
    ("sweep", "sweep"),
];

/// Resolve a legacy subcommand to its spec.
pub fn spec_for_alias(alias: &str) -> Result<ExperimentSpec> {
    let Some((_, name)) = LEGACY_ALIASES.iter().find(|(a, _)| *a == alias) else {
        bail!("'{alias}' is not a legacy experiment subcommand");
    };
    spec_by_name(name)
}

/// Generated usage text for the `exp` subcommand: one block per
/// registered spec (name, grid, axis flags with defaults). USAGE can
/// never drift from the registry because it *is* the registry.
pub fn usage() -> String {
    let mut out = String::from(
        "lisa exp <name> [--requests N] [--threads N] [--mixes N] [--seed N]\n\
         \x20        [--config FILE] [--out FILE] [--backend cycle,analytical]\n\
         \x20        [--journal FILE] [--resume FILE] [--cache-dir DIR] [--no-cache]\n\
         lisa exp --list\n\nEXPERIMENTS\n",
    );
    for spec in registry() {
        out.push_str(&format!(
            "  {:<12} {} ({} points)\n",
            spec.name,
            spec.title,
            spec.default_points()
        ));
        for axis in &spec.axes {
            let preview: Vec<&str> =
                axis.values.iter().take(4).map(String::as_str).collect();
            let ellipsis = if axis.values.len() > 4 { ",..." } else { "" };
            out.push_str(&format!(
                "      --{} {}{}   ({})\n",
                axis.flag,
                preview.join(","),
                ellipsis,
                axis.kind.choices()
            ));
        }
    }
    out.push_str(
        "\nLegacy aliases (same flags, same JSON): fig3, fig4, lip-system, \
         os -> e9-os, salp -> e10-salp, sweep.\n\
         \nCampaigns checkpoint to --journal as jobs finish; --resume FILE \
         adopts a\nprior journal's finished jobs (and keeps appending to it), \
         byte-identical\nto an uninterrupted run. Results are cached under \
         target/lisa-cache\n(--cache-dir overrides, --no-cache disables): an \
         unchanged re-invocation\nre-runs zero points.\n\
         \nEvery experiment also takes --backend cycle,analytical \
         (cycle|analytical):\nan implicit outermost axis selecting the memory \
         model. The default is the\ncycle-exact controller with no backend \
         column; `--backend analytical` runs\nthe calibrated event-count twin \
         (~100x faster, held to the tolerance band\nthe report states), and \
         listing both runs the grid under each for\nside-by-side \
         cross-validation.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_specs_expand_with_defaults() {
        for spec in registry() {
            let points = expand(&spec, &RunOptions::default())
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert_eq!(points.len(), spec.default_points(), "{}", spec.name);
            // Every point carries a workload and a valid config.
            for p in &points {
                assert!(p.axes.iter().any(|(n, _)| n == "workload"));
                p.cfg.validate().unwrap();
            }
        }
    }

    #[test]
    fn grid_order_is_axis_major() {
        let spec = spec_by_name("e10-salp").unwrap();
        let opts = RunOptions::default()
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["memcpy", "lisa-risc"])
            .axis("mode", &["none", "masa"])
            .axis("policy", &["packed"]);
        let points = expand(&spec, &opts).unwrap();
        assert_eq!(points.len(), 4);
        // workload-major, then mech, then mode (odometer order).
        let coord = |i: usize, name: &str| {
            points[i]
                .axes
                .iter()
                .find(|(n, _)| n == name)
                .unwrap()
                .1
                .clone()
        };
        assert_eq!(coord(0, "mech"), "memcpy");
        assert_eq!(coord(0, "mode"), "none");
        assert_eq!(coord(1, "mode"), "masa");
        assert_eq!(coord(2, "mech"), "lisa-risc");
        assert_eq!(points[0].cfg.dram.salp, SalpMode::None);
        assert_eq!(points[1].cfg.dram.salp, SalpMode::Masa);
        assert_eq!(points[2].cfg.copy_mechanism, CopyMechanism::LisaRisc);
        assert!(points[2].cfg.lisa.risc);
    }

    #[test]
    fn bad_axis_values_fail_before_any_simulation() {
        let spec = spec_by_name("e10-salp").unwrap();
        let bad_mode = RunOptions::default().axis("mode", &["salp9"]);
        assert!(expand(&spec, &bad_mode).is_err());
        let bad_wl = RunOptions::default().axis("workload", &["no-such-workload"]);
        assert!(expand(&spec, &bad_wl).is_err());
    }

    #[test]
    fn unknown_axis_values_error_with_the_valid_choices() {
        // The validation error leads with the axis, its flag, and the
        // exact `AxisKind::choices()` list — a typo'd `--backend` (or
        // any axis value) tells the user what would have worked.
        let spec = spec_by_name("e10-salp").unwrap();
        let err = expand(&spec, &RunOptions::default().backend(&["quantum"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis 'backend' (--backend)"), "{err}");
        assert!(err.contains(AxisKind::Backend.choices()), "{err}");
        let err = expand(&spec, &RunOptions::default().axis("mode", &["salp9"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("axis 'mode' (--modes)"), "{err}");
        assert!(err.contains(AxisKind::SalpMode.choices()), "{err}");
    }

    #[test]
    fn backend_axis_is_implicit_outermost_and_off_by_default() {
        let spec = spec_by_name("e10-salp").unwrap();
        let narrow = RunOptions::default()
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["memcpy"])
            .axis("mode", &["none"])
            .axis("policy", &["packed"]);
        // Default: no backend coordinate anywhere, cycle-exact config —
        // records (and their JSON) are indistinguishable from builds
        // that predate backend plurality.
        let points = expand(&spec, &narrow).unwrap();
        assert_eq!(points.len(), 1);
        assert!(points[0].axes.iter().all(|(n, _)| n != "backend"));
        assert_eq!(points[0].cfg.backend, BackendKind::Cycle);
        // --backend cycle,analytical doubles the grid, backend-major
        // (outermost), and the coordinate drives the built config.
        let both =
            expand(&spec, &narrow.clone().backend(&["cycle", "analytical"]))
                .unwrap();
        assert_eq!(both.len(), 2);
        assert_eq!(both[0].axes[0].0, "backend");
        assert_eq!(both[0].axes[0].1, "cycle");
        assert_eq!(both[0].cfg.backend, BackendKind::Cycle);
        assert_eq!(both[1].axes[0].1, "analytical");
        assert_eq!(both[1].cfg.backend, BackendKind::Analytical);
        // The twins differ only in backend, so their content hashes —
        // and therefore every journal/cache key built from them — must
        // differ.
        assert_ne!(both[0].cfg.content_hash(), both[1].cfg.content_hash());
    }

    #[test]
    fn options_from_args_reads_the_backend_flag() {
        let spec = spec_by_name("e9-os").unwrap();
        let parse = |line: &str| {
            let args =
                Args::parse(line.split_whitespace().map(str::to_string)).unwrap();
            RunOptions::from_args(&spec, &args).unwrap()
        };
        assert_eq!(
            parse("os --backend cycle,analytical").backend,
            vec!["cycle".to_string(), "analytical".to_string()]
        );
        // Absent flag: empty list, so no implicit axis is added.
        assert!(parse("os --requests 10").backend.is_empty());
    }

    #[test]
    fn backend_runs_record_the_tolerance_band_in_report_json() {
        let mk = |axes: Vec<(String, String)>| Report {
            experiment: "x".into(),
            requests: 1,
            records: vec![Record { axes, ws: None, report: RunReport::default() }],
            stats: CampaignStats::default(),
            profile: CampaignProfile::default(),
        };
        let plain = mk(vec![("workload".into(), "os-fork".into())]);
        assert!(!plain.to_json().contains("backend_tolerance"));
        let twin = mk(vec![
            ("backend".into(), "analytical".into()),
            ("workload".into(), "os-fork".into()),
        ]);
        let j = twin.to_json();
        assert!(j.contains("\"backend_tolerance\""), "{j}");
        let ipc = format!("\"ipc_pct\":{}", json::number(IPC_TOLERANCE_PCT));
        let ws = format!("\"ws_pct\":{}", json::number(WS_TOLERANCE_PCT));
        assert!(j.contains(&ipc), "{j}");
        assert!(j.contains(&ws), "{j}");
    }

    #[test]
    fn mixes_override_truncates_mix_family_axes() {
        let spec = spec_by_name("fig4").unwrap();
        let axes = effective_axes(&spec, &RunOptions::default().mixes(3)).unwrap();
        assert_eq!(axes[0].1, vec!["copy-mix-00", "copy-mix-01", "copy-mix-02"]);
        // Explicit values win over --mixes.
        let both = RunOptions::default().mixes(3).axis("workload", &["copy-mix-07"]);
        let axes = effective_axes(&spec, &both).unwrap();
        assert_eq!(axes[0].1, vec!["copy-mix-07"]);
        // Sweep's --mixes appends to the micro suite.
        let sweep = spec_by_name("sweep").unwrap();
        let axes = effective_axes(&sweep, &RunOptions::default().mixes(1)).unwrap();
        assert_eq!(axes[0].1.len(), 5);
        assert_eq!(axes[0].1[4], "copy-mix-00");
    }

    #[test]
    fn options_from_args_reads_axis_flags() {
        let spec = spec_by_name("e9-os").unwrap();
        let args = Args::parse(
            "os --requests 500 --threads 2 --mechs memcpy,lisa-risc --scenarios os-zero"
                .split_whitespace()
                .map(str::to_string),
        )
        .unwrap();
        let opts = RunOptions::from_args(&spec, &args).unwrap();
        assert_eq!(opts.requests, Some(500));
        assert_eq!(opts.threads, 2);
        assert_eq!(opts.seed, None);
        assert!(opts.base.is_none());
        assert_eq!(
            opts.axis_override("mech").unwrap(),
            &["memcpy".to_string(), "lisa-risc".to_string()]
        );
        assert_eq!(opts.axis_override("workload").unwrap(), &["os-zero".to_string()]);
    }

    #[test]
    fn options_from_args_reads_campaign_flags() {
        let spec = spec_by_name("e9-os").unwrap();
        let parse = |line: &str| {
            let args =
                Args::parse(line.split_whitespace().map(str::to_string)).unwrap();
            RunOptions::from_args(&spec, &args).unwrap()
        };
        // CLI default: cache on at the default location, no journal.
        let opts = parse("os --requests 10");
        assert_eq!(opts.cache_dir.as_deref(), Some(Path::new(DEFAULT_CACHE_DIR)));
        assert!(opts.journal.is_none() && opts.resume.is_none());
        assert!(opts.journal_path().is_none());
        // Explicit plumbing.
        let opts = parse("os --journal a.jsonl --resume b.jsonl --cache-dir /tmp/c");
        assert_eq!(opts.journal.as_deref(), Some(Path::new("a.jsonl")));
        assert_eq!(opts.resume.as_deref(), Some(Path::new("b.jsonl")));
        assert_eq!(opts.cache_dir.as_deref(), Some(Path::new("/tmp/c")));
        // --journal wins as the checkpoint target; --resume alone
        // means "keep appending to the file being resumed".
        assert_eq!(opts.journal_path(), Some(Path::new("a.jsonl")));
        assert_eq!(
            parse("os --resume b.jsonl").journal_path(),
            Some(Path::new("b.jsonl"))
        );
        // --no-cache wins over --cache-dir; library default is off.
        assert!(parse("os --cache-dir /tmp/c --no-cache").cache_dir.is_none());
        assert!(RunOptions::default().cache_dir.is_none());
    }

    #[test]
    fn record_json_round_trips_byte_identically() {
        use crate::metrics::{OsSummary, RunReport};
        let mk = |ws: Option<f64>, os: Option<OsSummary>| Record {
            axes: vec![
                ("workload".into(), "os-fork".into()),
                ("mech\"quoted".into(), "lisa-risc\n".into()),
            ],
            ws,
            report: RunReport {
                workload: "os-fork".into(),
                config_name: "lisa-risc".into(),
                ipc: vec![0.5, 1.0 / 3.0, f64::NAN],
                dram_cycles: 123_456,
                os,
                ..Default::default()
            },
        };
        let os = OsSummary { pages_copied: 8, risc_hits: 6, ..Default::default() };
        for rec in [mk(Some(2.5), None), mk(None, Some(os)), mk(Some(0.1), None)] {
            let text = rec.to_json();
            let back = Record::from_json(&crate::util::json::parse(&text).unwrap())
                .unwrap();
            assert_eq!(back.to_json(), text);
            assert_eq!(back.axes, rec.axes);
        }
        // Half a record (a torn journal line's parse) errors, never
        // fabricates defaults.
        let bad = crate::util::json::parse("{\"config\":\"x\"}").unwrap();
        assert!(Record::from_json(&bad).is_err());
    }

    #[test]
    fn job_keys_are_content_addressed() {
        let spec = spec_by_name("e10-salp").unwrap();
        let opts = RunOptions::default()
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["memcpy", "lisa-risc"])
            .axis("mode", &["none"])
            .axis("policy", &["packed"]);
        let base = SimConfig::default().to_toml();
        let points = expand(&spec, &opts).unwrap();
        let k0 = job_key(Eval::Raw, false, &base, &points[..1]);
        assert_eq!(k0.len(), 32, "32-hex content key");
        // Deterministic across invocations...
        let again = expand(&spec, &opts).unwrap();
        assert_eq!(k0, job_key(Eval::Raw, false, &base, &again[..1]));
        // ...and sensitive to every input: the point, the eval mode,
        // the base config, the code version's inputs.
        assert_ne!(k0, job_key(Eval::Raw, false, &base, &points[1..2]));
        assert_ne!(k0, job_key(Eval::WeightedSpeedup, false, &base, &points[..1]));
        // --obs reports carry an extra block, so the key must move.
        assert_ne!(k0, job_key(Eval::Raw, true, &base, &points[..1]));
        let mut other_base = SimConfig::default();
        other_base.cpu.cores = 2;
        assert_ne!(k0, job_key(Eval::Raw, false, &other_base.to_toml(), &points[..1]));
        // A --requests override changes the per-point config, not just
        // the base, and must move the key.
        let more = expand(&spec, &opts.clone().requests(999)).unwrap();
        assert_ne!(k0, job_key(Eval::Raw, false, &base, &more[..1]));
    }

    #[test]
    fn campaign_resumes_and_caches_byte_identically() {
        let tag = format!("spec-campaign-{}", std::process::id());
        let dir = std::env::temp_dir().join(tag);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let spec = spec_by_name("e10-salp").unwrap();
        let base_opts = RunOptions::default()
            .requests(120)
            .threads(2)
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["memcpy", "lisa-risc"])
            .axis("mode", &["none", "masa"])
            .axis("policy", &["packed"]);
        let clean = run(&spec, &base_opts).unwrap();
        assert_eq!(
            clean.stats,
            CampaignStats { resumed: 0, cache_hits: 0, ran: 4 }
        );

        // Journal a run, then resume from the intact journal: all four
        // jobs adopt, zero simulate, bytes identical.
        let journal = dir.join("run.jsonl");
        let journaled =
            run(&spec, &base_opts.clone().journal(&journal)).unwrap();
        assert_eq!(journaled.to_json(), clean.to_json());
        let resumed = run(&spec, &base_opts.clone().resume(&journal)).unwrap();
        assert_eq!(
            resumed.stats,
            CampaignStats { resumed: 4, cache_hits: 0, ran: 0 }
        );
        assert_eq!(resumed.to_json(), clean.to_json());
        assert_eq!(resumed, clean, "stats stay out of equality");

        // A journal from a *different* grid is matched (idx, key)
        // pair by pair: dropping the "masa" mode keeps the old grid's
        // point 0 at index 0 (resumes) but shifts "lisa-risc/none"
        // from index 2 to 1, where the journaled key no longer
        // matches — that point re-runs instead of resurrecting the
        // wrong record. (Reshaped grids are the cache's job.)
        let mut narrower = base_opts.clone();
        narrower.axes.retain(|(n, _)| n != "mode");
        let narrower = narrower.axis("mode", &["none"]);
        let partial = run(&spec, &narrower.resume(&journal)).unwrap();
        assert_eq!(partial.records.len(), 2);
        assert_eq!(
            partial.stats,
            CampaignStats { resumed: 1, cache_hits: 0, ran: 1 }
        );

        // Cache: first run misses and fills, second hits everything,
        // bytes identical to the uncached run.
        let cache_dir = dir.join("cache");
        let warmed =
            run(&spec, &base_opts.clone().cache_dir(&cache_dir)).unwrap();
        assert_eq!(warmed.stats.ran, 4);
        assert_eq!(warmed.to_json(), clean.to_json());
        let cached = run(&spec, &base_opts.clone().cache_dir(&cache_dir)).unwrap();
        assert_eq!(
            cached.stats,
            CampaignStats { resumed: 0, cache_hits: 4, ran: 0 }
        );
        assert_eq!(cached.to_json(), clean.to_json());
        assert_eq!(cached.stats.reuse_pct(), 100.0);
        // A changed grid reuses the unchanged points via the cache.
        let mut widened = base_opts.clone().cache_dir(&cache_dir);
        widened.axes.retain(|(n, _)| n != "policy");
        widened.axes.push(("policy".into(), vec!["packed".into(), "spread".into()]));
        let wider = run(&spec, &widened).unwrap();
        assert_eq!(wider.records.len(), 8);
        assert_eq!(wider.stats.cache_hits, 4);
        assert_eq!(wider.stats.ran, 4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seed_and_base_config_specialize_every_grid_point() {
        let spec = spec_by_name("e10-salp").unwrap();
        let mut base = SimConfig::default();
        base.cpu.cores = 2;
        let opts = RunOptions::default()
            .base(base)
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["memcpy"])
            .axis("mode", &["masa"])
            .axis("policy", &["packed"]);
        let mut opts = opts;
        opts.seed = Some(77);
        let points = expand(&spec, &opts).unwrap();
        assert_eq!(points.len(), 1);
        assert_eq!(points[0].cfg.cpu.cores, 2, "base config survives the axes");
        assert_eq!(points[0].cfg.seed, 77);
        assert_eq!(points[0].cfg.dram.salp, SalpMode::Masa);
    }

    #[test]
    fn raw_run_produces_one_record_per_point() {
        let spec = spec_by_name("e10-salp").unwrap();
        let opts = RunOptions::default()
            .requests(120)
            .threads(2)
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["lisa-risc"])
            .axis("mode", &["none", "masa"])
            .axis("policy", &["packed"]);
        let report = run(&spec, &opts).unwrap();
        assert_eq!(report.experiment, "e10-salp");
        assert_eq!(report.requests, 120);
        assert_eq!(report.records.len(), 2);
        assert_eq!(report.records[0].axis("mode"), Some("none"));
        assert_eq!(report.records[1].axis("mode"), Some("masa"));
        assert!(report.records.iter().all(|r| r.ws.is_none()));
        let j = report.to_json();
        assert!(j.contains("\"experiment\":\"e10-salp\""), "{j}");
        assert!(j.contains("\"mode\":\"masa\""), "{j}");
        // One "config" key per record plus one inside each RunReport.
        assert_eq!(j.matches("\"config\":").count(), 4, "{j}");
        // The table renders without panicking and has one line per
        // record plus header + separator.
        assert_eq!(report.table().render().lines().count(), 2 + 2);
    }

    #[test]
    fn weighted_run_carries_ws_and_summary() {
        let spec = spec_by_name("fig3").unwrap();
        let opts = RunOptions::default()
            .requests(300)
            .threads(2)
            .mixes(2)
            .axis("preset", &["baseline", "risc-villa"]);
        let report = run(&spec, &opts).unwrap();
        assert_eq!(report.records.len(), 4);
        assert!(report.records.iter().all(|r| r.ws.is_some()));
        // Workload-major: records 0,1 share workload, differ in preset.
        assert_eq!(report.records[0].axis("workload"), report.records[1].axis("workload"));
        assert_eq!(report.records[0].axis("preset"), Some("baseline"));
        let summary = report.ws_summary();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].name, "risc-villa");
        assert_eq!(summary[0].ws_improvements.len(), 2);
    }

    #[test]
    fn alias_table_points_at_registered_specs() {
        for (alias, name) in LEGACY_ALIASES {
            let spec = spec_for_alias(alias).unwrap();
            assert_eq!(&spec.name, name);
        }
        assert!(spec_for_alias("table1").is_err());
        assert!(spec_by_name("nope").is_err());
    }

    #[test]
    fn campaign_stats_json_line_is_stable() {
        let s = CampaignStats { resumed: 0, cache_hits: 4, ran: 0 };
        assert_eq!(
            s.to_json_line("e10-salp"),
            "{\"campaign\":{\"experiment\":\"e10-salp\",\"jobs\":4,\
             \"resumed\":0,\"cache_hits\":4,\"ran\":0,\"reuse_pct\":100}}"
        );
        let mixed = CampaignStats { resumed: 1, cache_hits: 0, ran: 3 };
        let v = crate::util::json::parse(&mixed.to_json_line("x")).unwrap();
        let c = v.get("campaign").expect("campaign key");
        assert_eq!(c.get("jobs").and_then(|x| x.as_u64()), Some(4));
        assert_eq!(c.get("ran").and_then(|x| x.as_u64()), Some(3));
        assert_eq!(c.get("reuse_pct").and_then(|x| x.as_f64()), Some(25.0));
    }

    #[test]
    fn obs_runs_attach_attribution_and_leave_plain_reports_untouched() {
        let spec = spec_by_name("e10-salp").unwrap();
        let opts = RunOptions::default()
            .requests(120)
            .threads(2)
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["lisa-risc"])
            .axis("mode", &["masa"])
            .axis("policy", &["packed"]);
        let plain = run(&spec, &opts).unwrap();
        let attributed = run(&spec, &opts.clone().obs(true)).unwrap();
        assert!(plain.records[0].report.obs.is_none());
        let obs = attributed.records[0].report.obs.as_ref().expect("obs block");
        assert!(obs.requests > 0, "demand reads were decomposed");
        assert!(!obs.bank_util.is_empty());
        // Attribution observes; it never changes simulated behavior —
        // stripping the obs block recovers the plain report bytes.
        let mut stripped = attributed.clone();
        for r in &mut stripped.records {
            r.report.obs = None;
        }
        assert_eq!(stripped.to_json(), plain.to_json());
        // The profile came along: phase timers and a parseable line.
        assert_eq!(attributed.profile.threads, 2);
        assert!(attributed.profile.total_ms >= attributed.profile.sim_ms);
        let v = crate::util::json::parse(&attributed.profile.to_json()).unwrap();
        assert!(v.get("profile").is_some());
    }

    #[test]
    fn traced_point_yields_ordered_events_and_respects_the_grid() {
        let spec = spec_by_name("e10-salp").unwrap();
        let opts = RunOptions::default()
            .requests(120)
            .threads(1)
            .axis("workload", &["salp-copy-conflict4"])
            .axis("mech", &["lisa-risc"])
            .axis("mode", &["masa"])
            .axis("policy", &["packed"]);
        let (events, dropped) = run_traced(&spec, &opts, 0, 1 << 16).unwrap();
        assert_eq!(dropped, 0);
        assert!(!events.is_empty());
        // Events are recorded in global cycle order.
        assert!(events.windows(2).all(|w| w[0].cycle <= w[1].cycle));
        // An out-of-range point errors with grid context.
        let err = run_traced(&spec, &opts, 99, 64).unwrap_err().to_string();
        assert!(err.contains("99") && err.contains("1 points"), "{err}");
    }

    #[test]
    fn usage_text_tracks_the_registry() {
        let u = usage();
        for spec in registry() {
            assert!(u.contains(&spec.name), "usage misses {}", spec.name);
            for axis in &spec.axes {
                assert!(
                    u.contains(&format!("--{}", axis.flag)),
                    "usage misses --{} of {}",
                    axis.flag,
                    spec.name
                );
            }
        }
    }
}
