//! The workload trace format: the same information Ramulator consumes
//! from Pin traces (non-memory instruction counts between memory
//! operations), extended with bulk-copy operations for the paper's
//! copy workloads and OS-level bulk primitives (fork / zeroing /
//! checkpoint / migration) for the E9 system scenarios.

/// An OS-level bulk primitive, recorded in the trace at the virtual
/// address level. The OS layer (`os/bulk.rs`) translates these to
/// physical page-copy requests at simulation time, so the frame
/// placement policy is a runtime knob rather than baked into traces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BulkOp {
    /// Synchronous `memcpy(dst, src, pages * page_size)`.
    Memcpy { src_va: u64, dst_va: u64, pages: u32 },
    /// Bulk page zeroing (boot / mmap / security clearing).
    Zero { va: u64, pages: u32 },
    /// `fork()`: mark the whole address space copy-on-write; copies
    /// happen lazily at write-fault time.
    Fork,
    /// One load/store at a *virtual* address: page-table translation,
    /// demand-zero fill on unmapped pages, CoW break on shared pages.
    /// `dependent` marks the access as being on the critical path
    /// (pointer chasing through the heap): the issue window stalls on
    /// it just like a dependent `TraceOp::Mem` load.
    Touch {
        va: u64,
        is_write: bool,
        dependent: bool,
    },
    /// Checkpoint epoch: bulk-copy every page dirtied since the last
    /// checkpoint to its shadow frame.
    Checkpoint,
    /// Hot-page promotion: migrate the page into the reserved
    /// low-subarray zone of its bank (VILLA-adjacent placement).
    Promote { va: u64 },
}

/// One trace operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOp {
    /// `nonmem` non-memory instructions, then one memory access.
    /// `dependent` marks loads on the critical path (pointer chasing):
    /// the window cannot issue past them until they complete.
    Mem {
        nonmem: u32,
        addr: u64,
        is_write: bool,
        dependent: bool,
    },
    /// `nonmem` instructions, then a synchronous bulk copy
    /// (memcpy/memmove): `rows` DRAM rows from `src` to `dst`.
    Copy {
        nonmem: u32,
        src: u64,
        dst: u64,
        rows: u32,
    },
    /// `nonmem` instructions, then an OS-level bulk primitive routed
    /// through the OS layer (virtual addresses, page tables, frame
    /// allocation, fault-triggered copies).
    Bulk { nonmem: u32, op: BulkOp },
}

impl TraceOp {
    pub fn nonmem(&self) -> u32 {
        match self {
            TraceOp::Mem { nonmem, .. }
            | TraceOp::Copy { nonmem, .. }
            | TraceOp::Bulk { nonmem, .. } => *nonmem,
        }
    }

    /// Instructions this op represents (non-memory + the op itself).
    pub fn insts(&self) -> u64 {
        self.nonmem() as u64 + 1
    }
}

/// A per-core trace. Cores replay it cyclically until the simulation's
/// request budget is reached, so traces can stay compact.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub ops: Vec<TraceOp>,
}

impl Trace {
    pub fn new(ops: Vec<TraceOp>) -> Self {
        Self { ops }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total instructions in one pass of the trace.
    pub fn insts_per_pass(&self) -> u64 {
        self.ops.iter().map(|o| o.insts()).sum()
    }

    /// Memory operations in one pass.
    pub fn mem_ops_per_pass(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Mem { .. }))
            .count() as u64
    }

    pub fn copy_ops_per_pass(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Copy { .. }))
            .count() as u64
    }

    /// OS-level bulk primitives in one pass.
    pub fn bulk_ops_per_pass(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Bulk { .. }))
            .count() as u64
    }

    /// Does this trace require the OS layer (page tables + frame
    /// allocator + bulk engine) to execute?
    pub fn needs_os(&self) -> bool {
        self.ops.iter().any(|o| matches!(o, TraceOp::Bulk { .. }))
    }
}

/// Cyclic cursor over a trace.
#[derive(Debug, Clone)]
pub struct TraceCursor {
    pos: usize,
}

impl TraceCursor {
    pub fn new() -> Self {
        Self { pos: 0 }
    }

    pub fn next(&mut self, trace: &Trace) -> TraceOp {
        let op = trace.ops[self.pos];
        self.pos = (self.pos + 1) % trace.ops.len();
        op
    }
}

impl Default for TraceCursor {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_accounting() {
        let t = Trace::new(vec![
            TraceOp::Mem { nonmem: 3, addr: 0, is_write: false, dependent: false },
            TraceOp::Copy { nonmem: 10, src: 0, dst: 8192, rows: 1 },
            TraceOp::Mem { nonmem: 0, addr: 64, is_write: true, dependent: false },
        ]);
        assert_eq!(t.insts_per_pass(), 3 + 1 + 10 + 1 + 0 + 1);
        assert_eq!(t.mem_ops_per_pass(), 2);
        assert_eq!(t.copy_ops_per_pass(), 1);
        assert_eq!(t.bulk_ops_per_pass(), 0);
        assert!(!t.needs_os());
    }

    #[test]
    fn bulk_ops_mark_the_trace_as_os() {
        let t = Trace::new(vec![
            TraceOp::Bulk { nonmem: 5, op: BulkOp::Fork },
            TraceOp::Bulk {
                nonmem: 2,
                op: BulkOp::Touch { va: 8192, is_write: true, dependent: false },
            },
        ]);
        assert!(t.needs_os());
        assert_eq!(t.bulk_ops_per_pass(), 2);
        assert_eq!(t.insts_per_pass(), 5 + 1 + 2 + 1);
    }

    #[test]
    fn cursor_wraps() {
        let t = Trace::new(vec![
            TraceOp::Mem { nonmem: 1, addr: 0, is_write: false, dependent: false },
            TraceOp::Mem { nonmem: 2, addr: 64, is_write: false, dependent: false },
        ]);
        let mut c = TraceCursor::new();
        assert_eq!(c.next(&t).nonmem(), 1);
        assert_eq!(c.next(&t).nonmem(), 2);
        assert_eq!(c.next(&t).nonmem(), 1);
    }
}
