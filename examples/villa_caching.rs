//! LISA-VILLA in-DRAM caching study (paper Fig. 3): hot-region
//! workloads under (a) the baseline, (b) VILLA with LISA-RISC
//! movement, and (c) VILLA with RowClone inter-subarray movement —
//! the paper's point that VILLA is not practical without LISA.
//!
//! ```sh
//! cargo run --release --example villa_caching
//! ```

use lisa::sim::campaign::default_threads;
use lisa::sim::experiments::fig3;
use lisa::util::bench::Table;

fn main() {
    let requests = std::env::var("LISA_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3_000);
    let mixes = std::env::var("LISA_MIXES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);

    println!("== LISA-VILLA (Fig. 3), {requests} requests/core, {mixes} mixes ==\n");
    let rows = fig3(requests, mixes, default_threads());
    let mut t = Table::new(&["workload", "VILLA +%", "hit rate %", "VILLA w/ RC-InterSA +%"]);
    for r in &rows {
        t.row(&[
            r.workload.clone(),
            format!("{:+.1}", r.villa_improvement * 100.0),
            format!("{:.1}", r.villa_hit_rate * 100.0),
            format!("{:+.1}", r.rc_inter_improvement * 100.0),
        ]);
    }
    t.print();
    println!(
        "\npaper: up to +16.1%, geomean +5.1%; RC-InterSA movement: -52.3%.\n\
         Expected shape: VILLA positive and correlated with hit rate;\n\
         RC-InterSA-movement variant much worse (can be negative)."
    );
}
