//! END-TO-END DRIVER (Fig. 4 / EXPERIMENTS.md): runs the full system
//! — AOT circuit-model calibration through PJRT if artifacts are
//! present, then the cycle-accurate simulator over real multi-core
//! copy workloads — and reports the paper's headline metric: weighted
//! speedup of LISA-RISC / +VILLA / +LIP over the memcpy baseline,
//! plus memory energy reduction.
//!
//! ```sh
//! make artifacts && cargo run --release --example combined_speedup
//! # knobs: LISA_REQUESTS=3000 LISA_MIXES=10
//! ```

use lisa::sim::campaign::default_threads;
use lisa::sim::experiments::{fig4, lip_system};
use lisa::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let requests: u64 = std::env::var("LISA_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000);
    let mixes: usize = std::env::var("LISA_MIXES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);

    // Stage 1: calibrate the LISA timing parameters from the AOT
    // JAX/Pallas circuit artifacts (PJRT execution; python not
    // involved). Falls back to the checked-in analytic values if
    // artifacts are missing (or the PJRT runtime is not compiled in)
    // so the example always runs.
    #[cfg(feature = "runtime")]
    {
        use lisa::runtime::{calibrate, CalibrationInputs, Runtime};
        let artifacts = std::path::Path::new("artifacts");
        match Runtime::new(artifacts)
            .and_then(|rt| calibrate(&rt, &CalibrationInputs::default()))
        {
            Ok(cal) => {
                println!(
                    "calibrated from artifacts: tRBM={:.2} ns, tRP_LIP={:.2} ns, \
                     tRP={:.2} ns (x{:.1} guard band applied)",
                    cal.t_rbm_ns, cal.t_rp_lip_ns, cal.t_rp_circuit_ns, 1.6
                );
            }
            Err(e) => {
                println!("(no artifacts: {e}; using built-in calibration)");
            }
        }
    }
    #[cfg(not(feature = "runtime"))]
    println!("(runtime feature off: using built-in calibration)");

    // Stage 2: the system experiment.
    println!(
        "\n== Fig. 4: combined weighted-speedup improvement \
         ({mixes} copy mixes, {requests} reqs/core) ==\n"
    );
    let cmps = fig4(requests, mixes, default_threads());
    let mut t = Table::new(&["config", "mean WS +%", "max +%", "energy -%", "paper"]);
    let paper = ["+59.6% (alone)", "+76.1% (cum.)", "+94.8% (all)"];
    for (c, p) in cmps.iter().zip(paper) {
        t.row(&[
            c.name.clone(),
            format!("{:+.1}", c.mean_ws_improvement() * 100.0),
            format!("{:+.1}", c.max_ws_improvement() * 100.0),
            format!("{:.1}", c.mean_energy_reduction() * 100.0),
            p.to_string(),
        ]);
    }
    t.print();

    let lip = lip_system(requests, mixes.min(10), default_threads());
    println!(
        "\nLISA-LIP alone: {:+.1}% mean WS (paper: +10.3%)",
        lip.mean_ws_improvement() * 100.0
    );
    println!("(paper energy reduction with all three: 49.0%)");
    Ok(())
}
