//! `lisa` — CLI for the LISA reproduction: calibration, single
//! workload runs, and the declarative experiment registry (`lisa exp`)
//! covering the paper's evaluation grids E4–E10 plus sweeps. The
//! historical per-experiment subcommands (`fig3`, `os`, `salp`, ...)
//! are thin aliases onto the registry.

use std::path::Path;

use anyhow::{bail, Result};

use lisa::cli::Args;
use lisa::config::SimConfig;
use lisa::obs::{self, DEFAULT_RING_CAP};
use lisa::sim::campaign;
use lisa::sim::engine::run_workload;
use lisa::sim::experiments as exp;
use lisa::sim::spec::{self, ExperimentSpec, RunOptions};
use lisa::util::bench::Table;
use lisa::workloads::mixes;

/// The static half of the usage text; the experiment half is
/// generated from the spec registry (`usage()` below), so the two can
/// never drift.
const USAGE_HEADER: &str = "\
lisa — LISA (Low-Cost Inter-Linked Subarrays) full-system reproduction

USAGE: lisa <command> [options]

COMMANDS
  calibrate   --artifacts DIR [--out FILE]   run the circuit model via PJRT,
                                             write calibration.toml
                                             (needs the `runtime` feature)
  calibrate-backend  [--out FILE]            probe the cycle-exact controller
                                             and write the analytical backend's
                                             calibration table
                                             (src/backend/analytical_cal.toml)
  run         --workload NAME [--config F] [--requests N] [--threads N] [--ws]
  list-workloads
  table1      [--config F]                   E1: 8 KB copy latency/energy
  rbm         E2: RBM bandwidth vs channel
  lip         E3: linked precharge latency
  area        E8: die area overhead
  exp         declarative experiment grids — see below
  lint        [--root DIR] [--rules L1,..,L5] [--json] [--out FILE]
              project-invariant static analysis over src/**/*.rs
              (config round-trip coverage, horizon invalidation,
              JSON key drift, probe gating, hot-path panics);
              exits nonzero on any finding — part of tier-1 local
              verification (see DESIGN.md §Static analysis)
  trace       binary op-trace files (record / convert / info / replay):
                trace record  --workload NAME --out FILE [--report FILE]
                trace convert IN OUT [--to jsonl|binary]
                trace info    FILE
                trace replay  FILE [--out FILE]
              record dumps a workload's per-core op streams (any --config /
              --requests / --seed combination); replay re-drives a simulation
              from the file, byte-identical to the direct run. Recorded files
              are also first-class experiment workloads: pass
              `--workloads trace:FILE` to any `lisa exp` grid (cache keys fold
              in a digest of the file's content).

Every experiment subcommand accepts [--requests N] [--threads N]
[--out FILE]; `--threads 0` (or omitting --threads) auto-detects the
available hardware parallelism. Without --out the JSON report goes to
stdout and the table to stderr; with --out the JSON goes to the file.

Campaign subcommands also accept [--journal FILE] (checkpoint finished
jobs as they complete), [--resume FILE] (adopt a prior journal, then
keep appending to it) and [--cache-dir DIR] / [--no-cache] (reuse
finished jobs across invocations; default cache: target/lisa-cache).
Resumed and cached runs are byte-identical to fresh ones.

Observability (zero-cost when off): [--obs] attaches a latency
attribution block to each report under \"obs\"; [--trace-point IDX
--trace-out FILE] additionally re-runs one expanded grid point with
the command probe attached and writes a Chrome trace-event file
(Perfetto-viewable; use a .jsonl extension for line-delimited JSON
instead). Global [-v|-q] flags — or LISA_LOG=error|warn|info|debug —
set the stderr log level.

";

const COMMANDS: &[&str] = &[
    "calibrate",
    "calibrate-backend",
    "run",
    "sweep",
    "list-workloads",
    "table1",
    "rbm",
    "lip",
    "fig3",
    "fig4",
    "lip-system",
    "area",
    "os",
    "salp",
    "exp",
    "lint",
    "trace",
];

fn usage() -> String {
    format!("{USAGE_HEADER}{}", spec::usage())
}

fn load_config(args: &Args) -> Result<SimConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => SimConfig::from_file(Path::new(path))?,
        None => SimConfig::default(),
    };
    // Overlay calibration.toml if present (produced by `lisa calibrate`).
    let cal_path = Path::new(args.opt_or("calibration", "artifacts/calibration.toml"));
    if cal_path.exists() {
        let doc = lisa::config::minitoml::Document::parse(&std::fs::read_to_string(
            cal_path,
        )?)?;
        cfg.apply(&doc)?;
    }
    if let Some(n) = args.opt_u64("requests")? {
        cfg.requests_per_core = n;
    }
    if let Some(s) = args.opt_u64("seed")? {
        cfg.seed = s;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    lisa::util::log::set_level(lisa::util::log::resolve(
        args.verbose,
        args.quiet,
        std::env::var("LISA_LOG").ok().as_deref(),
    ));
    let Some(cmd) = args.check_subcommand(COMMANDS)?.map(str::to_string) else {
        print!("{}", usage());
        return Ok(());
    };
    match cmd.as_str() {
        "calibrate" => cmd_calibrate(&args),
        "calibrate-backend" => cmd_calibrate_backend(&args),
        "run" => cmd_run(&args),
        "list-workloads" => {
            let cfg = SimConfig::default();
            for w in mixes::all_mixes(&cfg) {
                println!("{}", w.name);
            }
            Ok(())
        }
        "table1" => cmd_table1(&args),
        "rbm" => {
            let cfg = load_config(&args)?;
            let r = exp::rbm_report(&cfg.calibration);
            println!(
                "RBM: {} B/hop in {:.2} ns = {:.0} GB/s vs channel {:.1} GB/s -> {:.1}x \
                 (paper: 500 GB/s vs 19.2 GB/s, 26x)",
                r.row_bytes, r.hop_ns, r.gbps, r.channel_gbps, r.speedup
            );
            Ok(())
        }
        "lip" => {
            let cfg = load_config(&args)?;
            let r = exp::lip_circuit_report(&cfg.calibration);
            println!(
                "LIP precharge: {:.2} ns vs baseline {:.2} ns = {:.2}x \
                 (paper: 5 ns vs 13 ns, 2.6x); tRP {} -> {} cycles",
                r.t_rp_lip_ns, r.t_rp_circuit_ns, r.speedup, r.t_rp_cycles, r.t_rp_lip_cycles
            );
            Ok(())
        }
        "area" => {
            let cfg = load_config(&args)?;
            let r = exp::area_report(&cfg);
            println!(
                "LISA area overhead: {:.3}% iso transistors ({} devices) + {:.3}% control \
                 = {:.3}% total (paper: 0.8%)",
                r.iso_fraction * 100.0,
                r.n_iso_transistors,
                r.control_fraction * 100.0,
                r.total_fraction * 100.0
            );
            Ok(())
        }
        "exp" => cmd_exp(&args),
        "lint" => lisa::lint::cmd(&args),
        "trace" => cmd_trace(&args),
        // Legacy experiment subcommands: thin aliases onto the spec
        // registry — same option flags, same pipeline, byte-identical
        // JSON to `lisa exp <spec>`.
        "fig3" | "fig4" | "lip-system" | "os" | "salp" | "sweep" => {
            run_experiment(&spec::spec_for_alias(&cmd)?, &args)
        }
        other => bail!("unknown command '{other}'\n{}", usage()),
    }
}

#[cfg(feature = "runtime")]
fn cmd_calibrate(args: &Args) -> Result<()> {
    use lisa::runtime::{calibrate, CalibrationInputs, Runtime};
    let dir = Path::new(args.opt_or("artifacts", "artifacts"));
    let out = args.opt_or("out", "artifacts/calibration.toml");
    let runtime = Runtime::new(dir)?;
    eprintln!("PJRT platform: {}", runtime.platform());
    let cal = calibrate(&runtime, &CalibrationInputs::default())?;
    println!(
        "calibrated: tRBM={:.2} ns  tRP(lip)={:.2} ns  tRP(circuit)={:.2} ns  \
         fast ratios act/ras/rp = {:.2}/{:.2}/{:.2}",
        cal.t_rbm_ns,
        cal.t_rp_lip_ns,
        cal.t_rp_circuit_ns,
        cal.fast_act_ratio,
        cal.fast_ras_ratio,
        cal.fast_rp_ratio
    );
    std::fs::write(out, SimConfig::calibration_toml(&cal))?;
    println!("wrote {out}");
    Ok(())
}

/// `lisa calibrate-backend [--out FILE]` — regenerate the analytical
/// backend's calibration table by probing the cycle-exact controller
/// (isolated single-request and single-copy runs per speed bin). With
/// `--out src/backend/analytical_cal.toml` the probed table is baked
/// into the next build; without `--out` it goes to stdout for
/// inspection. Needs no PJRT artifacts — the probes run the in-tree
/// simulator, so this works on any checkout.
fn cmd_calibrate_backend(args: &Args) -> Result<()> {
    let toml = lisa::backend::analytical::calibration_toml();
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &toml)?;
            println!("wrote {path}");
        }
        None => print!("{toml}"),
    }
    Ok(())
}

#[cfg(not(feature = "runtime"))]
fn cmd_calibrate(_args: &Args) -> Result<()> {
    bail!(
        "the PJRT calibration path is not compiled in; rebuild with \
         `cargo build --features runtime` (the simulator ships with the \
         same values as checked-in defaults, so calibration is optional)"
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let name = args.opt_or("workload", "stream4");
    let threads = campaign::resolve_threads(args.opt_usize("threads")?);
    let wl = mixes::workload_by_name(name, &cfg)?;
    if args.has_flag("ws") {
        // The N alone runs + the shared run go through the campaign
        // runner (deterministic regardless of --threads).
        let (ws, report) = campaign::weighted_speedup(&cfg, &wl, threads);
        println!("workload={name} config={} WS={ws:.3}", report.config_name);
        print_report(&report);
    } else {
        let report = run_workload(&cfg, &wl);
        print_report(&report);
    }
    Ok(())
}

fn print_report(r: &lisa::metrics::RunReport) {
    println!(
        "workload={} config={} cycles={} reads={} writes={} copies={}",
        r.workload, r.config_name, r.dram_cycles, r.reads, r.writes, r.copies
    );
    println!(
        "  IPC={:?} (sum {:.3})  read-lat={:.1} cyc  row-hit={:.1}%  villa-hit={:.1}%  \
         lip-cov={:.1}%",
        r.ipc.iter().map(|i| (i * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
        r.ipc_sum(),
        r.avg_read_latency_cycles,
        r.row_hit_rate * 100.0,
        r.villa_hit_rate * 100.0,
        r.lip_coverage * 100.0
    );
    println!(
        "  energy: total {:.1} uJ (dynamic {:.1}, background {:.1}, rbm {:.3})",
        r.energy.total,
        r.energy.dynamic_uj(),
        r.energy.background_uj,
        r.energy.rbm_uj
    );
}

fn cmd_table1(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rows = exp::table1(&cfg.calibration)?;
    let mut t = Table::new(&[
        "mechanism",
        "paper ns",
        "ours ns",
        "paper uJ",
        "ours uJ",
    ]);
    for r in rows {
        t.row(&[
            r.label,
            format!("{:.2}", r.paper_latency_ns),
            format!("{:.2}", r.latency_ns),
            format!("{:.3}", r.paper_energy_uj),
            format!("{:.3}", r.energy_uj),
        ]);
    }
    t.print();
    Ok(())
}

/// `lisa exp [--list] | lisa exp <name> [--<axis> a,b] [...]`.
fn cmd_exp(args: &Args) -> Result<()> {
    let name = args.positional.first().map(String::as_str);
    if name.is_none() || args.has_flag("list") {
        if args.has_flag("list") {
            // Compact registry listing (the CI smoke step).
            let mut t = Table::new(&["name", "points", "eval", "description"]);
            for s in spec::registry() {
                t.row(&[
                    s.name.clone(),
                    format!("{}", s.default_points()),
                    format!("{:?}", s.eval),
                    s.title.clone(),
                ]);
            }
            t.print();
        } else {
            print!("{}", spec::usage());
        }
        return Ok(());
    }
    let s = spec::spec_by_name(name.unwrap())?;
    run_experiment(&s, args)
}

/// `lisa trace <record|convert|info|replay>` — the trace subsystem's
/// CLI surface (DESIGN.md §Trace subsystem).
fn cmd_trace(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("record") => cmd_trace_record(args),
        Some("convert") => cmd_trace_convert(args),
        Some("info") => cmd_trace_info(args),
        Some("replay") => cmd_trace_replay(args),
        Some(other) => bail!("unknown trace verb '{other}' (record|convert|info|replay)"),
        None => bail!("usage: lisa trace <record|convert|info|replay> — see `lisa` for details"),
    }
}

/// `lisa trace record --workload NAME --out FILE [--report FILE]`:
/// generate the workload's per-core op streams exactly as a direct
/// simulation would (same config/requests/seed handling, same op
/// count) and write them as a binary trace file. With `--report`,
/// also run the direct simulation and save its report JSON — the
/// oracle `trace replay --out` output is compared against.
fn cmd_trace_record(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let name = args.opt_or("workload", "stream4");
    let Some(out) = args.opt("out") else {
        bail!("trace record needs --out FILE");
    };
    let wl = mixes::workload_by_name(name, &cfg)?;
    let n_ops = lisa::sim::engine::trace_ops_per_core(cfg.requests_per_core);
    let traces = wl.traces(&cfg, n_ops);
    lisa::trace::write_trace(Path::new(out), &wl.name, &traces)?;
    let total: usize = traces.iter().map(|t| t.len()).sum();
    eprintln!("recorded {} cores / {} ops -> {}", traces.len(), total, out);
    if let Some(report_path) = args.opt("report") {
        let report = run_workload(&cfg, &wl);
        std::fs::write(report_path, report.to_json())?;
        eprintln!("direct-run report -> {report_path}");
    }
    Ok(())
}

/// `lisa trace convert IN OUT [--to jsonl|binary]`: JSONL ⇄ binary,
/// direction inferred from the file extensions unless `--to` forces
/// it. The binary encoder is canonical, so binary → jsonl → binary is
/// byte-identical (the CI drill `cmp`s exactly that).
fn cmd_trace_convert(args: &Args) -> Result<()> {
    let (Some(input), Some(output)) =
        (args.positional.get(1), args.positional.get(2))
    else {
        bail!("usage: lisa trace convert IN OUT [--to jsonl|binary]");
    };
    let to_jsonl = match args.opt("to") {
        Some("jsonl") => true,
        Some("binary") => false,
        Some(other) => bail!("--to must be 'jsonl' or 'binary', got '{other}'"),
        None if output.ends_with(".jsonl") => true,
        None if input.ends_with(".jsonl") => false,
        None => bail!(
            "cannot infer conversion direction from '{input}' -> '{output}'; \
             pass --to jsonl|binary"
        ),
    };
    if to_jsonl {
        lisa::trace::jsonl::to_jsonl(Path::new(input), Path::new(output))?;
    } else {
        lisa::trace::jsonl::from_jsonl(Path::new(input), Path::new(output))?;
    }
    eprintln!("converted {input} -> {output}");
    Ok(())
}

/// `lisa trace info FILE`: header + per-core stream stats + an op-kind
/// histogram, computed streaming — a million-op file is summarized in
/// one bounded chunk buffer, never materialized.
fn cmd_trace_info(args: &Args) -> Result<()> {
    use lisa::cpu::trace::{BulkOp, TraceOp};
    let Some(path) = args.positional.get(1) else {
        bail!("usage: lisa trace info FILE");
    };
    let mut rd = lisa::trace::TraceReader::open(Path::new(path))?;
    let header = rd.header().clone();
    println!(
        "trace: \"{}\"  (format v1, {} cores)",
        header.name,
        header.streams.len()
    );
    let mut t = Table::new(&["core", "ops", "bytes", "mem", "copy", "bulk", "dependent"]);
    let mut hist: std::collections::BTreeMap<&'static str, u64> = Default::default();
    let (mut total_ops, mut total_insts) = (0u64, 0u64);
    for core in 0..header.streams.len() {
        let (mut mem, mut copy, mut bulk, mut dep) = (0u64, 0u64, 0u64, 0u64);
        let mut it = rd.ops(core)?;
        let mut prev = 0u64;
        while let Some(op) = it.next_op(&mut prev) {
            let op = op?;
            total_ops += 1;
            total_insts += 1;
            let kind = match op {
                TraceOp::Mem { nonmem, dependent, .. } => {
                    mem += 1;
                    dep += dependent as u64;
                    total_insts += nonmem as u64;
                    "mem"
                }
                TraceOp::Copy { nonmem, .. } => {
                    copy += 1;
                    total_insts += nonmem as u64;
                    "copy"
                }
                TraceOp::Bulk { nonmem, op } => {
                    bulk += 1;
                    total_insts += nonmem as u64;
                    match op {
                        BulkOp::Memcpy { .. } => "bulk:memcpy",
                        BulkOp::Zero { .. } => "bulk:zero",
                        BulkOp::Fork => "bulk:fork",
                        BulkOp::Touch { dependent, .. } => {
                            dep += dependent as u64;
                            "bulk:touch"
                        }
                        BulkOp::Checkpoint => "bulk:checkpoint",
                        BulkOp::Promote { .. } => "bulk:promote",
                    }
                }
            };
            *hist.entry(kind).or_default() += 1;
        }
        let desc = header.streams[core];
        t.row(&[
            format!("{core}"),
            format!("{}", desc.op_count),
            format!("{}", desc.len),
            format!("{mem}"),
            format!("{copy}"),
            format!("{bulk}"),
            format!("{dep}"),
        ]);
    }
    t.print();
    let parts: Vec<String> =
        hist.iter().map(|(k, n)| format!("{k}={n}")).collect();
    println!("op histogram: {}", parts.join("  "));
    println!(
        "{total_ops} ops / {total_insts} instructions per pass; reader high water {} bytes",
        rd.high_water()
    );
    Ok(())
}

/// `lisa trace replay FILE [--out FILE]`: drive a simulation from a
/// recorded trace. With the same config flags as the recording run,
/// the report is byte-identical to the direct run's.
fn cmd_trace_replay(args: &Args) -> Result<()> {
    let Some(path) = args.positional.get(1) else {
        bail!("usage: lisa trace replay FILE [--out FILE]");
    };
    let cfg = load_config(args)?;
    let wl = lisa::trace::workload_from_file(Path::new(path))?;
    let report = run_workload(&cfg, &wl);
    match args.opt("out") {
        Some(out) => {
            std::fs::write(out, report.to_json())?;
            eprintln!("replay report -> {out}");
        }
        None => print_report(&report),
    }
    Ok(())
}

/// The one experiment pipeline behind `lisa exp <name>` and every
/// legacy alias: parse shared options, expand + run the grid, emit
/// the unified table/JSON report.
fn run_experiment(s: &ExperimentSpec, args: &Args) -> Result<()> {
    let opts = RunOptions::from_args(s, args)?;
    let n_points: usize = spec::effective_axes(s, &opts)?
        .iter()
        .map(|(_, v)| v.len())
        .product();
    eprintln!("{}: {} points on {} threads", s.name, n_points, opts.threads);
    let t0 = std::time::Instant::now();
    let report = spec::run(s, &opts)?;
    // Provenance to stderr, never into the JSON: resumed/cached
    // reports stay byte-identical to fresh ones. The human line is
    // followed by two stable machine-readable lines — the campaign
    // reuse stats and the harness self-profile (CI greps the former).
    let st = report.stats;
    eprintln!(
        "{}: jobs {} = {} resumed + {} cache hits + {} ran ({:.1}% cached)",
        s.name,
        st.total(),
        st.resumed,
        st.cache_hits,
        st.ran,
        st.reuse_pct()
    );
    eprintln!("{}", st.to_json_line(&s.name));
    eprintln!("{}", report.profile.to_json());
    eprintln!("{}: done in {:.2} s", s.name, t0.elapsed().as_secs_f64());
    emit_report(args, &report)?;
    maybe_trace(s, &opts, args)
}

/// `--trace-point IDX --trace-out FILE`: re-run one expanded grid
/// point with the command probe attached and export the event ring.
/// This is an *extra* run after the campaign — the campaign itself
/// never sees a probe, so its reports (and the journal/cache bytes)
/// are unchanged by tracing.
fn maybe_trace(s: &ExperimentSpec, opts: &RunOptions, args: &Args) -> Result<()> {
    let point = args.opt_usize("trace-point")?;
    let out = args.opt("trace-out");
    let (idx, path) = match (point, out) {
        (None, None) => return Ok(()),
        (Some(i), Some(p)) => (i, p),
        (Some(_), None) => bail!("--trace-point requires --trace-out FILE"),
        (None, Some(_)) => bail!("--trace-out requires --trace-point IDX"),
    };
    let (events, dropped) = spec::run_traced(s, opts, idx, DEFAULT_RING_CAP)?;
    let body = if path.ends_with(".jsonl") {
        obs::to_jsonl(&events)
    } else {
        obs::to_chrome_trace(&events)
    };
    std::fs::write(path, body)?;
    eprintln!(
        "{}: traced point {} -> {} ({} events, {} dropped)",
        s.name,
        idx,
        path,
        events.len(),
        dropped
    );
    Ok(())
}

/// Shared report writing: JSON to `--out` (table + confirmation to
/// stdout), or JSON to stdout with the table on stderr so the
/// machine-readable document stays pipeable.
fn emit_report(args: &Args, report: &spec::Report) -> Result<()> {
    let table = report.table();
    let json = report.to_json();
    let summaries = report.ws_summary();
    let render_summary = |to_stderr: bool| {
        for c in &summaries {
            let line = format!(
                "{}: mean WS {:+.1}%  geomean {:.3}x  max {:+.1}%  energy -{:.1}% \
                 (vs the first preset, {} workloads)",
                c.name,
                c.mean_ws_improvement() * 100.0,
                c.geomean_speedup(),
                c.max_ws_improvement() * 100.0,
                c.mean_energy_reduction() * 100.0,
                c.ws_improvements.len()
            );
            if to_stderr {
                eprintln!("{line}");
            } else {
                println!("{line}");
            }
        }
    };
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &json)?;
            table.print();
            render_summary(false);
            println!("wrote {path}");
        }
        None => {
            eprintln!("{}", table.render());
            render_summary(true);
            print!("{json}");
        }
    }
    Ok(())
}
