//! Per-subarray row-buffer and activation state. LISA is fundamentally
//! a subarray-level substrate, and SALP/MASA expose the same structures
//! as independent activation state machines — so the device model
//! tracks each subarray's row buffer *and* its timing registers
//! individually. The baseline (`SalpMode::None`) configuration simply
//! enforces at most one non-precharged subarray per bank and consults
//! the bank-scope registers, which keeps it cycle-identical to the
//! pre-SALP model.

/// State of one subarray's row buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaState {
    /// Bitlines precharged to VDD/2; buffer holds nothing.
    Precharged,
    /// A row is open (activated) in this subarray.
    Open { row: usize },
    /// The row buffer holds latched data but no wordline is raised —
    /// the state RBM leaves destination/intermediate subarrays in.
    LatchedOnly,
}

/// One subarray: buffer state, the content tag used to verify
/// data-movement semantics (tags stand in for 8 KB of row data), and
/// the subarray-scope timing registers the SALP modes schedule
/// against. Registers are "next allowed cycle" timestamps — stale
/// values are always in the past, so they never need clearing.
#[derive(Debug, Clone)]
pub struct Subarray {
    pub state: SaState,
    /// Content tag of whatever the row buffer currently holds.
    pub buffer_tag: Option<u64>,
    /// Earliest cycle an ACT may (re)open this subarray. Charged with
    /// tRP by precharges; under SALP modes only the precharged
    /// subarray pays it — ACTs elsewhere overlap with the tRP.
    pub next_act: u64,
    /// Earliest cycle this subarray's open row may be precharged
    /// (tRAS restore, read-to-precharge, write recovery).
    pub next_pre: u64,
    /// Earliest RD/WR against this subarray's buffer (tRCD after ACT).
    pub next_rdwr: u64,
    /// When this subarray's last activation finishes restoring (tRAS).
    pub ras_done: u64,
    /// When this subarray's last activation finishes sensing (tRCD) —
    /// gates RBM and Transfer source readiness.
    pub sense_done: u64,
}

impl Default for Subarray {
    fn default() -> Self {
        Self {
            state: SaState::Precharged,
            buffer_tag: None,
            next_act: 0,
            next_pre: 0,
            next_rdwr: 0,
            ras_done: 0,
            sense_done: 0,
        }
    }
}

impl Subarray {
    pub fn is_precharged(&self) -> bool {
        self.state == SaState::Precharged
    }

    pub fn open_row(&self) -> Option<usize> {
        match self.state {
            SaState::Open { row } => Some(row),
            _ => None,
        }
    }

    /// Precharge: closes the wordline and clears the buffer. Timing
    /// registers are left alone — they are monotone timestamps and the
    /// caller charges `next_act` with the applicable tRP.
    pub fn precharge(&mut self) {
        self.state = SaState::Precharged;
        self.buffer_tag = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut sa = Subarray::default();
        assert!(sa.is_precharged());
        assert_eq!(sa.open_row(), None);

        sa.state = SaState::Open { row: 7 };
        sa.buffer_tag = Some(0xAB);
        sa.next_pre = 28;
        assert_eq!(sa.open_row(), Some(7));
        assert!(!sa.is_precharged());

        sa.state = SaState::LatchedOnly;
        assert_eq!(sa.open_row(), None);
        assert!(!sa.is_precharged());

        sa.precharge();
        assert!(sa.is_precharged());
        assert_eq!(sa.buffer_tag, None);
        // Timing registers survive the precharge (monotone timestamps).
        assert_eq!(sa.next_pre, 28);
    }
}
