//! The five `lisa lint` rules (L1–L5). Each rule is a pure function
//! over one lexed file (`FileScan`) — every invariant this pass
//! enforces is local to a file, which keeps the checker trivially
//! parallel-safe and incremental. See DESIGN.md §"Static analysis:
//! lisa lint" for the rule catalog and the reasoning behind each.

use super::lexer::{contains_word, FileScan, Item, ItemKind};
use super::Diagnostic;
use std::collections::BTreeMap;

pub const L1: &str = "config-coverage";
pub const L2: &str = "horizon-invalidate";
pub const L3: &str = "json-key-drift";
pub const L4: &str = "probe-gating";
pub const L5: &str = "no-panic-hot-path";

/// Known channel-state mutators in the controller, seeded so the rule
/// has teeth even before markers exist (ISSUE 10). Scoped to
/// `controller/mod.rs`; elsewhere only the explicit
/// `// lint: mutates-channel-state` marker applies.
const SEEDED_MUTATORS: &[&str] = &[
    "enqueue",
    "enqueue_copy",
    "tick",
    "tick_channel",
    "activate_next_copy",
    "generate_memcpy_reads",
    "issue_for_request",
];

/// Run every enabled rule on one file.
pub fn run(scan: &FileScan, enabled: &dyn Fn(&str) -> bool, out: &mut Vec<Diagnostic>) {
    if enabled(L1) {
        config_coverage(scan, out);
    }
    if enabled(L2) {
        horizon_invalidate(scan, out);
    }
    if enabled(L3) {
        json_key_drift(scan, out);
    }
    if enabled(L4) {
        probe_gating(scan, out);
    }
    if enabled(L5) {
        no_panic_hot_path(scan, out);
    }
}

fn diag(scan: &FileScan, line: usize, rule: &'static str, message: String) -> Diagnostic {
    Diagnostic { file: scan.rel.clone(), line, rule, message }
}

// ---------------------------------------------------------------- L1

/// Every field in the `SimConfig` struct tree must be folded into the
/// serialization side (`to_toml` + `calibration_toml`), the
/// deserialization side (`from_toml` + `apply`), and — via the
/// `to_toml`-chained `content_hash` — the cache key; every struct in
/// the tree must derive `PartialEq`. Matching is by field *identifier*
/// (word boundary) inside those fn bodies, not by TOML key, so a field
/// whose TOML spelling differs (`backend` → `kind`) still counts as
/// covered as long as the code reads and writes it.
fn config_coverage(scan: &FileScan, out: &mut Vec<Diagnostic>) {
    let Some(root) = scan
        .items
        .iter()
        .find(|i| i.kind == ItemKind::Struct && i.name == "SimConfig" && !i.is_test)
    else {
        return;
    };

    // Struct map for tree recursion (structs defined in this file).
    let structs: BTreeMap<&str, &Item> = scan
        .items
        .iter()
        .filter(|i| i.kind == ItemKind::Struct && !i.is_test)
        .map(|i| (i.name.as_str(), i))
        .collect();

    let body_of = |names: &[&str]| -> String {
        scan.items
            .iter()
            .filter(|i| {
                i.kind == ItemKind::Fn
                    && !i.is_test
                    && names.contains(&i.name.as_str())
                    && i.impl_type.as_deref() == Some("SimConfig")
            })
            .map(|i| scan.item_text(i))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let ser = body_of(&["to_toml", "calibration_toml"]);
    let de = body_of(&["from_toml", "apply"]);
    let hash = body_of(&["content_hash"]);

    if ser.is_empty() {
        out.push(diag(scan, root.line, L1, "SimConfig has no to_toml serializer".into()));
        return;
    }
    if de.is_empty() {
        out.push(diag(scan, root.line, L1, "SimConfig has no from_toml/apply deserializer".into()));
        return;
    }
    let hash_chained = contains_word(&hash, "to_toml");
    if hash.is_empty() || !hash_chained {
        out.push(diag(
            scan,
            root.line,
            L1,
            "SimConfig::content_hash must hash the to_toml form (cache/journal key)".into(),
        ));
    }

    // Walk the struct tree depth-first, checking each field.
    let mut stack = vec![(root, String::new())];
    let mut seen = vec![root.name.clone()];
    while let Some((st, prefix)) = stack.pop() {
        if !st.derives.iter().any(|d| d == "PartialEq")
            && !scan.allows_in(st.line.saturating_sub(2), st.line, L1)
        {
            out.push(diag(
                scan,
                st.line,
                L1,
                format!(
                    "struct {} is part of the SimConfig tree but does not derive PartialEq \
                     (config equality gates cache reuse)",
                    st.name
                ),
            ));
        }
        for f in &st.fields {
            let path = if prefix.is_empty() {
                f.name.clone()
            } else {
                format!("{prefix}.{}", f.name)
            };
            // Recurse into nested config structs defined in this file.
            let base = f
                .ty
                .trim_start_matches('&')
                .split(['<', '(', ' ', ','])
                .next()
                .unwrap_or("");
            if let Some(sub) = structs.get(base) {
                if !seen.contains(&sub.name) {
                    seen.push(sub.name.clone());
                    stack.push((sub, path));
                }
                continue;
            }
            if scan.allows(f.line, L1) {
                continue;
            }
            let mut missing = Vec::new();
            if !contains_word(&ser, &f.name) {
                missing.push("to_toml");
                if hash_chained {
                    // Hash is to_toml-chained: a field missing from the
                    // serialized form is missing from the cache key too.
                    missing.push("content_hash");
                }
            }
            if !contains_word(&de, &f.name) {
                missing.push("from_toml");
            }
            if !missing.is_empty() {
                out.push(diag(
                    scan,
                    f.line,
                    L1,
                    format!("SimConfig field `{path}` is missing from {}", missing.join(", ")),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------- L2

/// Every fn marked `// lint: mutates-channel-state` (anywhere), plus
/// the seeded mutator list in `controller/mod.rs`, must invalidate
/// the per-channel horizon cache on some path: either an
/// `invalidate_horizon(..)` call or a blanket `horizon … .set(None)`
/// sweep (what `tick` does).
fn horizon_invalidate(scan: &FileScan, out: &mut Vec<Diagnostic>) {
    let seeded_file = scan.rel == "controller/mod.rs";
    for it in &scan.items {
        if it.kind != ItemKind::Fn || it.is_test {
            continue;
        }
        let marked = scan.has_marker_in(it.line, it.body_start);
        // Seeded names cover inherent methods only: trait impls (the
        // MemoryModel surface) are one-line delegation shims onto the
        // inherent mutators, which are the checked sites.
        let seeded =
            seeded_file && !it.trait_impl && SEEDED_MUTATORS.contains(&it.name.as_str());
        if !(marked || seeded) {
            continue;
        }
        let body = scan.item_text(it);
        let invalidates = contains_word(&body, "invalidate_horizon")
            || (contains_word(&body, "horizon") && body.contains(".set(None)"));
        if !invalidates && !scan.allows_in(it.line, it.body_start, L2) {
            let how = if marked { "is marked mutates-channel-state" } else { "is a seeded channel-state mutator" };
            out.push(diag(
                scan,
                it.line,
                L2,
                format!(
                    "fn `{}` {how} but never invalidates the horizon cache \
                     (call invalidate_horizon(ch) or sweep horizon[..].set(None))",
                    it.name
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------- L3

/// For every impl that defines both `to_json` and `from_json`, the
/// string-literal keys written by the serializer must equal the keys
/// read back by the deserializer. A written-but-unread key silently
/// drops state on a journal/cache rehydration round trip; a
/// read-but-unwritten key can never be satisfied.
fn json_key_drift(scan: &FileScan, out: &mut Vec<Diagnostic>) {
    // Group (impl type → serializer fns, deserializer fns).
    let mut pairs: BTreeMap<&str, (Vec<&Item>, Vec<&Item>)> = BTreeMap::new();
    for it in &scan.items {
        if it.kind != ItemKind::Fn || it.is_test {
            continue;
        }
        let Some(ty) = it.impl_type.as_deref() else { continue };
        match it.name.as_str() {
            "to_json" => pairs.entry(ty).or_default().0.push(it),
            "from_json" => pairs.entry(ty).or_default().1.push(it),
            _ => {}
        }
    }
    for (ty, (sers, des)) in pairs {
        if sers.is_empty() || des.is_empty() {
            continue; // one-way serializers have no twin to drift from
        }
        let mut allowed: Vec<String> = Vec::new();
        let mut blanket_allow = false;
        let mut written: BTreeMap<String, usize> = BTreeMap::new();
        for f in &sers {
            for (k, line) in written_keys(scan, f) {
                written.entry(k).or_insert(line);
            }
            collect_allows(scan, f, &mut allowed, &mut blanket_allow);
        }
        let mut read: BTreeMap<String, usize> = BTreeMap::new();
        for f in &des {
            for (k, line) in read_keys(scan, f) {
                read.entry(k).or_insert(line);
            }
            collect_allows(scan, f, &mut allowed, &mut blanket_allow);
        }
        if blanket_allow {
            continue;
        }
        for (k, line) in &written {
            if !read.contains_key(k) && !allowed.contains(k) {
                out.push(diag(
                    scan,
                    *line,
                    L3,
                    format!(
                        "{ty}::to_json writes key \"{k}\" that {ty}::from_json never reads \
                         (state would be dropped on a round trip)"
                    ),
                ));
            }
        }
        for (k, line) in &read {
            if !written.contains_key(k) && !allowed.contains(k) {
                out.push(diag(
                    scan,
                    *line,
                    L3,
                    format!("{ty}::from_json reads key \"{k}\" that {ty}::to_json never writes"),
                ));
            }
        }
    }
}

fn collect_allows(scan: &FileScan, f: &Item, allowed: &mut Vec<String>, blanket: &mut bool) {
    // Suppressions may sit on the fn header or anywhere in its body.
    let lo = f.line.saturating_sub(2);
    let args = scan.allow_args_in(lo, f.body_end, L3);
    if args.is_empty() && scan.allows_in(lo, f.body_end, L3) {
        *blanket = true;
    }
    allowed.extend(args);
}

/// Keys a serializer writes: `"name":` patterns inside its string
/// literals, escapes normalised (`\"name\":` in a format string).
fn written_keys(scan: &FileScan, f: &Item) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for n in f.body_start..=f.body_end.min(scan.lines.len()) {
        for frag in &scan.lines[n - 1].strings {
            let norm = frag.replace("\\\"", "\"");
            let b: Vec<char> = norm.chars().collect();
            let mut i = 0;
            while i < b.len() {
                if b[i] == '"' {
                    let start = i + 1;
                    let mut j = start;
                    while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                        j += 1;
                    }
                    if j > start && b.get(j) == Some(&'"') && b.get(j + 1) == Some(&':') {
                        out.push((b[start..j].iter().collect(), n));
                        i = j + 2;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    out
}

/// Keys a deserializer reads: string literals whose entire content is
/// one identifier (`v.get("axes")`, `field_u64(v, "reads")`).
fn read_keys(scan: &FileScan, f: &Item) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for n in f.body_start..=f.body_end.min(scan.lines.len()) {
        for frag in &scan.lines[n - 1].strings {
            let is_ident = !frag.is_empty()
                && frag.chars().all(|c| c.is_alphanumeric() || c == '_')
                && frag.chars().next().is_some_and(|c| c.is_alphabetic() || c == '_');
            if is_ident {
                out.push((frag.clone(), n));
            }
        }
    }
    out
}

// ---------------------------------------------------------------- L4

/// Zero-cost observability: outside `src/obs/`, every `.observe(..)`
/// / `.observe_cmd(..)` probe call must sit inside a block whose
/// header tests `observing()` (or destructures `self.obs`), so that
/// the disabled path never constructs an event.
fn probe_gating(scan: &FileScan, out: &mut Vec<Diagnostic>) {
    if scan.rel.starts_with("obs/") {
        return;
    }
    for it in &scan.items {
        if it.kind != ItemKind::Fn || it.is_test {
            continue;
        }
        let mut stack: Vec<bool> = Vec::new();
        let mut header = String::new();
        let mut started = false; // seen the fn's opening `{`
        for n in it.body_start..=it.body_end.min(scan.lines.len()) {
            let code: Vec<char> = scan.lines[n - 1].code.chars().collect();
            let mut i = 0;
            while i < code.len() {
                let c = code[i];
                if !started {
                    if c == '{' {
                        started = true;
                        stack.push(false);
                    }
                    i += 1;
                    continue;
                }
                match c {
                    '{' => {
                        let gated =
                            stack.last().copied().unwrap_or(false) || header_gates(&header);
                        stack.push(gated);
                        header.clear();
                    }
                    '}' => {
                        stack.pop();
                        header.clear();
                        if stack.is_empty() {
                            break; // fn body closed
                        }
                    }
                    ';' => header.clear(),
                    _ => header.push(c),
                }
                for probe in [".observe(", ".observe_cmd("] {
                    if tail_starts_call(&code, i, probe) {
                        let gated =
                            stack.last().copied().unwrap_or(false) || header_gates(&header);
                        if !gated && !scan.allows(n, L4) {
                            out.push(diag(
                                scan,
                                n,
                                L4,
                                format!(
                                    "probe call `{}..)` in fn `{}` is not gated by observing() \
                                     (zero-cost observability: the disabled path must not \
                                     construct events)",
                                    probe, it.name
                                ),
                            ));
                        }
                    }
                }
                i += 1;
            }
            if stack.is_empty() && started {
                break;
            }
            header.push(' ');
        }
    }
}

fn header_gates(header: &str) -> bool {
    header.contains("observing()")
        || header.contains(".obs.as_mut()")
        || header.contains(".obs.as_ref()")
        || header.contains(".obs.is_some()")
}

/// `pat` starts at `i` and is not `.observe_cmd(` matching `.observe(`.
fn tail_starts_call(code: &[char], i: usize, pat: &str) -> bool {
    let ok = pat.chars().enumerate().all(|(k, c)| code.get(i + k) == Some(&c));
    if !ok {
        return false;
    }
    // `.observe(` must not fire inside `.observe_cmd(`: the char after
    // the matched ident prefix is the `(` included in `pat`, so an
    // exact match is already unambiguous.
    true
}

// ---------------------------------------------------------------- L5

/// No panics on the simulation hot path: `unwrap()`, `expect(`,
/// `panic!`, `unreachable!`, `todo!`, `unimplemented!` are forbidden
/// in `controller/`, `dram/`, `backend/`, and `trace/reader.rs`
/// outside `#[cfg(test)]` code. Escape hatch:
/// `// lint: allow(panic) reason=…` on the same line (or alone on the
/// line above).
fn no_panic_hot_path(scan: &FileScan, out: &mut Vec<Diagnostic>) {
    let in_scope = scan.rel.starts_with("controller/")
        || scan.rel.starts_with("dram/")
        || scan.rel.starts_with("backend/")
        || scan.rel == "trace/reader.rs";
    if !in_scope {
        return;
    }
    // Lines covered by any #[cfg(test)]-scoped item.
    let mut test_line = vec![false; scan.lines.len() + 1];
    for it in &scan.items {
        if it.is_test {
            for n in it.line..=it.body_end.min(scan.lines.len()) {
                test_line[n] = true;
            }
        }
    }
    const PATTERNS: &[(&str, &str)] = &[
        (".unwrap()", "unwrap()"),
        (".expect(", "expect(..)"),
        ("panic!(", "panic!"),
        ("unreachable!(", "unreachable!"),
        ("todo!(", "todo!"),
        ("unimplemented!(", "unimplemented!"),
    ];
    for (n, line) in scan.lines.iter().enumerate().map(|(i, l)| (i + 1, l)) {
        if test_line[n] || scan.allows(n, L5) {
            continue;
        }
        for (pat, label) in PATTERNS {
            let mut from = 0;
            while let Some(p) = line.code[from..].find(pat) {
                let at = from + p;
                // `.expect(` must not fire on `.expect_err(`; the `(`
                // in the pattern already excludes that. `debug_assert!`
                // does not contain `panic!(`. Skip `.unwrap_or*` via
                // the exact `()` suffix in the pattern.
                let misfire = *pat == "panic!(" && {
                    // `core::panic!(` is a panic; `expect_no_panic!(`
                    // style idents are not. Require a non-ident char
                    // (or start) before the match.
                    line.code[..at]
                        .chars()
                        .next_back()
                        .is_some_and(|c| c.is_alphanumeric() || c == '_')
                };
                if !misfire {
                    out.push(diag(
                        scan,
                        n,
                        L5,
                        format!(
                            "{label} on the hot path; return a contextual error, or annotate \
                             `// lint: allow(panic) reason=…` if provably unreachable"
                        ),
                    ));
                    break; // one diagnostic per pattern per line
                }
                from = at + pat.len();
            }
        }
    }
}
