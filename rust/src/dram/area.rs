//! Die-area overhead model (paper §2 "Die Area Overhead", experiment
//! E8): LISA adds one isolation transistor per bitline between
//! adjacent subarrays, plus control logic outside the banks. The
//! paper, using the row-buffer-decoupling area figures [O et al.,
//! ISCA 2014], reports 0.8% total overhead in a 28 nm process.
//!
//! This module reproduces that accounting analytically so the bench
//! target can regenerate the claim and explore sensitivity.

use crate::config::DramConfig;

/// Area model constants for a 28 nm DRAM process (normalized units:
/// one DRAM cell = 6 F^2 = 1.0 area unit).
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// Cell array fraction of total die area (typical commodity DRAM).
    pub cell_array_fraction: f64,
    /// Isolation transistor area relative to one cell. Isolation
    /// transistors are laid out in the sense-amp stripe pitch; prior
    /// work's 0.8% total for one transistor per bitline per subarray
    /// boundary implies ~8 cells' worth per bitline pair boundary.
    pub iso_transistor_cells: f64,
    /// Control logic overhead (fraction of die), outside the banks.
    pub control_logic_fraction: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            cell_array_fraction: 0.55,
            iso_transistor_cells: 8.0,
            control_logic_fraction: 0.0005,
        }
    }
}

/// Breakdown of the computed overhead.
#[derive(Debug, Clone)]
pub struct AreaReport {
    /// Isolation transistors as a fraction of total die area.
    pub iso_fraction: f64,
    /// Control logic fraction.
    pub control_fraction: f64,
    /// Total overhead fraction (paper: ~0.008).
    pub total_fraction: f64,
    pub n_iso_transistors: u64,
}

impl AreaModel {
    /// Compute the LISA area overhead for a given DRAM organization.
    pub fn overhead(&self, cfg: &DramConfig) -> AreaReport {
        let bitlines_per_subarray = (cfg.columns * 64 * 8) as u64; // row bits
        let boundaries_per_bank = (cfg.subarrays_per_bank - 1) as u64;
        let n_iso = bitlines_per_subarray
            * boundaries_per_bank
            * cfg.banks as u64
            * cfg.ranks as u64
            * cfg.channels as u64;

        // Cells per device.
        let n_cells = (cfg.capacity_bytes() as u64) * 8;

        // Iso transistor area, expressed in cell-equivalents, relative
        // to the full die (cell array / cell_array_fraction).
        let cell_area_total = n_cells as f64 / self.cell_array_fraction;
        let iso_area = n_iso as f64 * self.iso_transistor_cells;
        let iso_fraction = iso_area / cell_area_total;

        AreaReport {
            iso_fraction,
            control_fraction: self.control_logic_fraction,
            total_fraction: iso_fraction + self.control_logic_fraction,
            n_iso_transistors: n_iso,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_point_zero_point_eight_percent() {
        // Default organization: 16 subarrays/bank, 512 rows/subarray.
        let report = AreaModel::default().overhead(&DramConfig::default());
        assert!(
            report.total_fraction > 0.006 && report.total_fraction < 0.010,
            "total overhead {:.4} outside the paper's ~0.8% band",
            report.total_fraction
        );
    }

    #[test]
    fn overhead_scales_with_subarray_count() {
        let model = AreaModel::default();
        let base = model.overhead(&DramConfig::default());
        let mut dense = DramConfig::default();
        dense.subarrays_per_bank = 64;
        dense.rows_per_subarray = 128; // same capacity
        let more = model.overhead(&dense);
        assert!(more.total_fraction > base.total_fraction);
        // Same capacity => proportional to boundary count (63 vs 15).
        let ratio = more.iso_fraction / base.iso_fraction;
        assert!((ratio - 63.0 / 15.0).abs() < 0.01);
    }
}
