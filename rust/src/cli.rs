//! Command-line argument parsing (no clap offline): subcommand +
//! `--key value` / `--flag` options + positionals.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    /// Count of `-v` occurrences (stackable: `-vv` counts twice).
    pub verbose: u32,
    /// Count of `-q` occurrences.
    pub quiet: u32,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// A short verbosity token: `-v`, `-q`, or a stack like `-vvq`.
/// Deliberately narrow so negative-number option values (`-3`) are
/// never mistaken for it.
fn is_verbosity(t: &str) -> bool {
    t.len() > 1
        && t.starts_with('-')
        && !t.starts_with("--")
        && t[1..].chars().all(|c| c == 'v' || c == 'q')
}

impl Args {
    /// Parse `argv[1..]`. The first non-option token is the
    /// subcommand; `--key value` and `--key=value` pairs become
    /// options; a `--key` followed by another `--` token (or a
    /// verbosity flag, or end-of-line) is a flag. Values may be
    /// negative numbers (`--shift -3`); `-v`/`-q` anywhere before a
    /// bare `--` raise/lower verbosity; a bare `--` ends option
    /// parsing, so negative-number *positionals* can be passed after
    /// it.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        let mut options_done = false;
        while i < tokens.len() {
            let t = &tokens[i];
            if !options_done && t == "--" {
                // Conventional end-of-options separator.
                options_done = true;
            } else if !options_done && t.starts_with("--") {
                let key = &t[2..];
                // `--key=value` form (also the unambiguous way to pass
                // a value that itself starts with `--`).
                if let Some((k, v)) = key.split_once('=') {
                    if k.is_empty() {
                        bail!("option '{t}' has an empty key");
                    }
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                    && !is_verbosity(&tokens[i + 1])
                {
                    out.options.insert(key.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else if !options_done && is_verbosity(t) {
                // Short verbosity flags: `-v`, `-q`, stackable and
                // combinable (`-vv`, `-vq`). Any other single-dash
                // token (e.g. a negative number) falls through to the
                // positional branches below.
                for c in t[1..].chars() {
                    if c == 'v' {
                        out.verbose += 1;
                    } else {
                        out.quiet += 1;
                    }
                }
            } else if out.subcommand.is_none() && !options_done {
                out.subcommand = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Validate the subcommand against the known set; `Ok(None)` when
    /// no subcommand was given (callers print usage).
    pub fn check_subcommand<'a>(&'a self, known: &[&str]) -> Result<Option<&'a str>> {
        match self.subcommand.as_deref() {
            None => Ok(None),
            Some(s) if known.contains(&s) => Ok(Some(s)),
            Some(s) => bail!("unknown command '{s}' (expected one of: {})", known.join(", ")),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => Ok(Some(s.parse()?)),
        }
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        Ok(self.opt_u64(key)?.map(|v| v as usize))
    }

    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => Ok(Some(s.parse()?)),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// A comma-separated list option (`--mechs memcpy,lisa-risc`):
    /// `None` when absent, trimmed non-empty items otherwise. Shared
    /// by every axis flag of the experiment subcommands.
    pub fn opt_list(&self, key: &str) -> Option<Vec<String>> {
        self.opt(key).map(|s| {
            s.split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(str::to_string)
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --workload stream4 --seed 7 trailing");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("workload"), Some("stream4"));
        assert_eq!(a.opt_u64("seed").unwrap(), Some(7));
        assert_eq!(a.positional, vec!["trailing".to_string()]);
    }

    #[test]
    fn eq_form_and_flags() {
        let a = parse("bench --mech=lisa-risc --verbose");
        assert_eq!(a.opt("mech"), Some("lisa-risc"));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("x --flag --k v");
        assert!(a.has_flag("flag"));
        assert_eq!(a.opt("k"), Some("v"));
    }

    #[test]
    fn opt_list_splits_and_trims() {
        let a = parse("exp --mechs memcpy,lisa-risc --modes masa");
        assert_eq!(
            a.opt_list("mechs").unwrap(),
            vec!["memcpy".to_string(), "lisa-risc".to_string()]
        );
        assert_eq!(a.opt_list("modes").unwrap(), vec!["masa".to_string()]);
        assert_eq!(a.opt_list("policies"), None);
        let a = Args::parse(["x".to_string(), "--ws=a, b,,c ".to_string()]).unwrap();
        assert_eq!(
            a.opt_list("ws").unwrap(),
            vec!["a".to_string(), "b".to_string(), "c".to_string()]
        );
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --n abc");
        assert!(a.opt_u64("n").is_err());
    }

    #[test]
    fn eq_form_negative_numbers() {
        let a = parse("sweep --shift=-3 --scale=-2.5");
        assert_eq!(a.opt("shift"), Some("-3"));
        assert_eq!(a.opt_f64("scale").unwrap(), Some(-2.5));
    }

    #[test]
    fn space_form_negative_numbers() {
        // `-3` does not start with `--`, so it is the option's value,
        // not a flag boundary.
        let a = parse("sweep --shift -3");
        assert_eq!(a.opt_f64("shift").unwrap(), Some(-3.0));
        assert!(!a.has_flag("shift"));
    }

    #[test]
    fn flag_vs_option_disambiguation() {
        // A key followed by another `--` token is a flag; a key
        // followed by anything else is an option. `--key=value` is
        // always an option, even if the value starts with dashes.
        let a = parse("run --ws --threads 8 --label=--weird --verbose");
        assert!(a.has_flag("ws"));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("threads"));
        assert_eq!(a.opt_usize("threads").unwrap(), Some(8));
        assert_eq!(a.opt("label"), Some("--weird"));
    }

    #[test]
    fn threads_option_parses_on_every_campaign_subcommand() {
        // `--threads N` is plumbed through every campaign-backed
        // subcommand; absence (or `0`) means "use available
        // parallelism" — resolution lives in `campaign::resolve_threads`.
        for cmd in ["fig3", "fig4", "lip-system", "sweep", "os", "salp", "run"] {
            let a = parse(&format!("{cmd} --threads 3"));
            assert_eq!(a.subcommand.as_deref(), Some(cmd));
            assert_eq!(a.opt_usize("threads").unwrap(), Some(3), "{cmd}");
            let bare = parse(cmd);
            assert_eq!(bare.opt_usize("threads").unwrap(), None, "{cmd}");
            let zero = parse(&format!("{cmd} --threads 0"));
            assert_eq!(zero.opt_usize("threads").unwrap(), Some(0), "{cmd}");
        }
        assert!(parse("os --threads x").opt_usize("threads").is_err());
    }

    #[test]
    fn short_verbosity_flags_count_and_stack() {
        let a = parse("run -v --threads 2");
        assert_eq!(a.verbose, 1);
        assert_eq!(a.quiet, 0);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt_usize("threads").unwrap(), Some(2));

        let a = parse("-vv exp -q");
        assert_eq!(a.verbose, 2);
        assert_eq!(a.quiet, 1);
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert!(a.positional.is_empty());

        // `-vq` combines; plain defaults to zero.
        assert_eq!(parse("run -vq").verbose, 1);
        assert_eq!(parse("run -vq").quiet, 1);
        assert_eq!(parse("run").verbose, 0);

        // A verbosity token never becomes a preceding flag's value.
        let a = parse("run --ws -v --threads 2");
        assert!(a.has_flag("ws"));
        assert_eq!(a.verbose, 1);
        assert_eq!(a.opt_usize("threads").unwrap(), Some(2));

        // Negative numbers are not verbosity flags: as an option value,
        // or as a positional after `--`.
        let a = parse("sweep --shift -3");
        assert_eq!(a.opt("shift"), Some("-3"));
        assert_eq!(a.verbose, 0);
        let a = parse("run -- -v -5");
        assert_eq!(a.verbose, 0);
        assert_eq!(a.positional, vec!["-v".to_string(), "-5".to_string()]);
    }

    #[test]
    fn double_dash_ends_option_parsing() {
        let a = parse("run --ws -- --not-a-flag -5");
        assert!(a.has_flag("ws"));
        assert!(!a.has_flag("not-a-flag"));
        assert_eq!(
            a.positional,
            vec!["--not-a-flag".to_string(), "-5".to_string()]
        );
    }

    #[test]
    fn empty_key_is_an_error() {
        assert!(Args::parse(["--=v".to_string()]).is_err());
    }

    #[test]
    fn unknown_subcommand_is_rejected() {
        let known = ["run", "sweep", "table1"];
        let a = parse("sweep --threads 2");
        assert_eq!(a.check_subcommand(&known).unwrap(), Some("sweep"));
        let none = parse("--verbose");
        assert_eq!(none.check_subcommand(&known).unwrap(), None);
        let bad = parse("swep");
        let err = bad.check_subcommand(&known).unwrap_err().to_string();
        assert!(err.contains("unknown command 'swep'"), "{err}");
        assert!(err.contains("sweep"), "{err}");
    }
}
