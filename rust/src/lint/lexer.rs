//! A line-oriented Rust source lexer for `lisa lint` — stdlib-only,
//! like `minitoml`: no `syn`, no proc-macro machinery. It does three
//! jobs the rules build on:
//!
//! 1. **Strip comments and literals**: every line gets a `code` form
//!    with comments removed and string/char literal *contents* blanked
//!    (the delimiting quotes survive so downstream pattern matching
//!    never fires inside a literal). Handles `//`, nested `/* */`,
//!    raw strings `r#"…"#`, byte strings, escapes, and multi-line
//!    strings. String-literal content is preserved separately, split
//!    per source line, for the JSON-key rule.
//! 2. **Parse `// lint:` directives**: `allow(rule[: args]) reason=…`
//!    suppressions and the `mutates-channel-state` marker. Malformed
//!    directives are themselves diagnostics — a typo must not
//!    silently disable a rule.
//! 3. **Track nesting**: a scope stack over braces recognises
//!    `struct`/`enum`/`impl`/`fn`/`mod` items (with `#[derive]` lists,
//!    struct fields, and the enclosing `impl` type for methods) and
//!    propagates `#[cfg(test)]` scoping so rules can skip test code.

use std::path::Path;

/// A suppression or marker parsed from a `// lint: …` comment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DirectiveKind {
    /// `// lint: allow(rule[: arg, arg]) reason=text` — suppress
    /// `rule` on the attached line (args narrow the suppression for
    /// rules with sub-targets, e.g. JSON key names).
    Allow { rule: String, args: Vec<String>, reason: String },
    /// `// lint: mutates-channel-state` — marks the next `fn` as a
    /// channel-state mutator for the horizon-invalidate rule.
    MutatesChannelState,
}

#[derive(Debug, Clone)]
pub struct Directive {
    /// 1-based source line the comment sits on.
    pub line: usize,
    /// 1-based line the directive governs: its own line when it
    /// trails code, otherwise the next line carrying code.
    pub attach: usize,
    pub kind: DirectiveKind,
}

/// One source line after lexing.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code with comments removed and literal contents blanked.
    pub code: String,
    /// Raw string-literal fragments appearing on this line (escape
    /// sequences kept verbatim, so `\"key\":` is searchable).
    pub strings: Vec<String>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ItemKind {
    Struct,
    Enum,
    Fn,
    Impl,
    Mod,
}

#[derive(Debug, Clone)]
pub struct Field {
    pub name: String,
    pub ty: String,
    /// 1-based line of the field declaration.
    pub line: usize,
}

#[derive(Debug, Clone)]
pub struct Item {
    pub kind: ItemKind,
    /// Item name; for `impl` blocks, the `Self` type's last path
    /// segment (`impl Probe for TraceRing` → `TraceRing`).
    pub name: String,
    /// For `fn` items: the enclosing `impl` block's type, if any.
    pub impl_type: Option<String>,
    /// For `impl` items: this is a trait impl (`impl Trait for T`);
    /// for `fn` items: the enclosing impl is a trait impl. Rules use
    /// this to restrict seeded allowlists to inherent methods (trait
    /// impls are typically one-line delegation shims).
    pub trait_impl: bool,
    /// 1-based line where the item's header starts.
    pub line: usize,
    /// 1-based lines of the `{` … matching `}` span.
    pub body_start: usize,
    pub body_end: usize,
    /// Inside `#[cfg(test)]` (own attribute or any enclosing scope).
    pub is_test: bool,
    /// Struct fields (named-struct items only).
    pub fields: Vec<Field>,
    /// Traits listed in a `#[derive(…)]` attribute on the item.
    pub derives: Vec<String>,
}

/// A fully lexed file, ready for the rules.
#[derive(Debug)]
pub struct FileScan {
    /// Path relative to the lint root, forward slashes.
    pub rel: String,
    pub lines: Vec<Line>,
    pub directives: Vec<Directive>,
    pub items: Vec<Item>,
    /// Lexer-level problems (malformed `lint:` directives).
    pub errors: Vec<(usize, String)>,
}

/// Rule names a directive may reference, plus accepted aliases.
pub const RULE_NAMES: &[&str] = &[
    "config-coverage",
    "horizon-invalidate",
    "json-key-drift",
    "probe-gating",
    "no-panic-hot-path",
];

fn canonical_rule(name: &str) -> Option<&'static str> {
    match name {
        "panic" | "no-panic-hot-path" => Some("no-panic-hot-path"),
        _ => RULE_NAMES.iter().find(|r| **r == name).copied(),
    }
}

/// Lexer mode carried across lines.
enum Mode {
    Code,
    /// Nested block comment depth.
    Block(u32),
    /// Inside a normal string literal.
    Str,
    /// Inside a raw string literal terminated by `"` + n `#`s.
    RawStr(u32),
}

impl FileScan {
    pub fn scan(rel_path: &Path, text: &str) -> FileScan {
        let rel = rel_path
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let mut lines = Vec::new();
        let mut raw_directives: Vec<(usize, DirectiveKind)> = Vec::new();
        let mut errors = Vec::new();
        let mut mode = Mode::Code;
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let mut line = Line::default();
            let mut frag = String::new();
            let bytes: Vec<char> = raw.chars().collect();
            let mut j = 0;
            while j < bytes.len() {
                match mode {
                    Mode::Block(depth) => {
                        if starts(&bytes, j, "*/") {
                            j += 2;
                            mode = if depth > 1 { Mode::Block(depth - 1) } else { Mode::Code };
                        } else if starts(&bytes, j, "/*") {
                            j += 2;
                            mode = Mode::Block(depth + 1);
                        } else {
                            j += 1;
                        }
                    }
                    Mode::Str => {
                        if bytes[j] == '\\' && j + 1 < bytes.len() {
                            frag.push(bytes[j]);
                            frag.push(bytes[j + 1]);
                            j += 2;
                        } else if bytes[j] == '"' {
                            line.code.push('"');
                            j += 1;
                            mode = Mode::Code;
                            // Close out the fragment here so several
                            // literals on one line stay distinct.
                            if !frag.is_empty() {
                                line.strings.push(std::mem::take(&mut frag));
                            }
                        } else {
                            frag.push(bytes[j]);
                            j += 1;
                        }
                    }
                    Mode::RawStr(hashes) => {
                        if bytes[j] == '"' && has_hashes(&bytes, j + 1, hashes) {
                            line.code.push('"');
                            j += 1 + hashes as usize;
                            mode = Mode::Code;
                            if !frag.is_empty() {
                                line.strings.push(std::mem::take(&mut frag));
                            }
                        } else {
                            frag.push(bytes[j]);
                            j += 1;
                        }
                    }
                    Mode::Code => {
                        if starts(&bytes, j, "//") {
                            let comment: String = bytes[j + 2..].iter().collect();
                            parse_directive_comment(
                                &comment,
                                lineno,
                                &mut raw_directives,
                                &mut errors,
                            );
                            j = bytes.len();
                        } else if starts(&bytes, j, "/*") {
                            mode = Mode::Block(1);
                            j += 2;
                        } else if let Some(h) = raw_string_start(&bytes, j) {
                            // r"…", r#"…"#, br#"…"# — skip the prefix,
                            // keep one quote in the code form.
                            let prefix = bytes[j..]
                                .iter()
                                .take_while(|c| **c != '"')
                                .count();
                            line.code.push('"');
                            j += prefix + 1;
                            mode = Mode::RawStr(h);
                        } else if bytes[j] == '"' {
                            line.code.push('"');
                            j += 1;
                            mode = Mode::Str;
                        } else if bytes[j] == '\'' {
                            if let Some(end) = char_literal_end(&bytes, j) {
                                line.code.push_str("''");
                                j = end;
                            } else {
                                // A lifetime: keep the tick.
                                line.code.push('\'');
                                j += 1;
                            }
                        } else {
                            line.code.push(bytes[j]);
                            j += 1;
                        }
                    }
                }
            }
            // Close out this line's string fragment; a still-open
            // string continues on the next line (a fresh fragment).
            if !frag.is_empty() {
                line.strings.push(frag);
            }
            lines.push(line);
        }

        // Attach each directive: its own line when that line carries
        // code, else the next line that does.
        let directives = raw_directives
            .into_iter()
            .map(|(line, kind)| {
                let own = lines
                    .get(line - 1)
                    .is_some_and(|l| !l.code.trim().is_empty());
                let attach = if own {
                    line
                } else {
                    (line..lines.len())
                        .find(|&n| !lines[n].code.trim().is_empty())
                        .map_or(line, |n| n + 1)
                };
                Directive { line, attach, kind }
            })
            .collect();

        let items = build_items(&lines);
        FileScan { rel, lines, directives, items, errors }
    }

    /// Is `rule` suppressed on `line` (exact attach match)?
    pub fn allows(&self, line: usize, rule: &str) -> bool {
        self.directives.iter().any(|d| {
            d.attach == line
                && matches!(&d.kind, DirectiveKind::Allow { rule: r, .. } if r == rule)
        })
    }

    /// Is `rule` suppressed anywhere in `[lo, hi]`? (Item-scope
    /// suppressions: the attach line must fall inside the item.)
    pub fn allows_in(&self, lo: usize, hi: usize, rule: &str) -> bool {
        self.directives.iter().any(|d| {
            (lo..=hi).contains(&d.attach)
                && matches!(&d.kind, DirectiveKind::Allow { rule: r, .. } if r == rule)
        })
    }

    /// All args of `allow(rule: …)` directives attached in `[lo, hi]`.
    pub fn allow_args_in(&self, lo: usize, hi: usize, rule: &str) -> Vec<String> {
        let mut out = Vec::new();
        for d in &self.directives {
            if !(lo..=hi).contains(&d.attach) {
                continue;
            }
            if let DirectiveKind::Allow { rule: r, args, .. } = &d.kind {
                if r == rule {
                    out.extend(args.iter().cloned());
                }
            }
        }
        out
    }

    /// Marker directives (`mutates-channel-state`) attached at or
    /// inside the given span.
    pub fn has_marker_in(&self, lo: usize, hi: usize) -> bool {
        self.directives.iter().any(|d| {
            (lo..=hi).contains(&d.attach)
                && d.kind == DirectiveKind::MutatesChannelState
        })
    }

    /// The joined `code` text of an item's full span (header + body).
    pub fn item_text(&self, it: &Item) -> String {
        let lo = it.line.saturating_sub(1);
        let hi = it.body_end.min(self.lines.len());
        self.lines[lo..hi]
            .iter()
            .map(|l| l.code.as_str())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

fn starts(b: &[char], j: usize, pat: &str) -> bool {
    pat.chars().enumerate().all(|(k, c)| b.get(j + k) == Some(&c))
}

fn has_hashes(b: &[char], j: usize, n: u32) -> bool {
    (0..n as usize).all(|k| b.get(j + k) == Some(&'#'))
}

/// Detect `r"`, `r#"`, `br##"` … at `j`; returns the hash count.
fn raw_string_start(b: &[char], j: usize) -> Option<u32> {
    // Must not be the tail of an identifier (`for r"` vs `attr"`).
    if j > 0 && (b[j - 1].is_alphanumeric() || b[j - 1] == '_') {
        return None;
    }
    let mut k = j;
    if b.get(k) == Some(&'b') {
        k += 1;
    }
    if b.get(k) != Some(&'r') {
        return None;
    }
    k += 1;
    let mut hashes = 0;
    while b.get(k) == Some(&'#') {
        hashes += 1;
        k += 1;
    }
    (b.get(k) == Some(&'"')).then_some(hashes)
}

/// If a char literal starts at `j` (a `'`), return the index just
/// past its closing quote; `None` means it's a lifetime.
fn char_literal_end(b: &[char], j: usize) -> Option<usize> {
    if b.get(j + 1) == Some(&'\\') {
        // Escaped char: scan to the closing quote (handles \u{…}).
        let mut k = j + 2;
        while k < b.len() && k < j + 12 {
            if b[k] == '\'' {
                return Some(k + 1);
            }
            k += 1;
        }
        None
    } else if b.get(j + 2) == Some(&'\'') && b.get(j + 1) != Some(&'\'') {
        Some(j + 3)
    } else {
        None
    }
}

fn parse_directive_comment(
    comment: &str,
    line: usize,
    out: &mut Vec<(usize, DirectiveKind)>,
    errors: &mut Vec<(usize, String)>,
) {
    // Doc comments start with an extra `/` or `!`.
    let t = comment.trim_start_matches(['/', '!']).trim();
    let Some(rest) = t.strip_prefix("lint:") else { return };
    let rest = rest.trim();
    if rest == "mutates-channel-state" {
        out.push((line, DirectiveKind::MutatesChannelState));
        return;
    }
    if let Some(body) = rest.strip_prefix("allow(") {
        let Some(close) = body.find(')') else {
            errors.push((line, "lint directive: unclosed 'allow('".into()));
            return;
        };
        let inner = &body[..close];
        let (rule_raw, args_raw) = match inner.split_once(':') {
            Some((r, a)) => (r.trim(), Some(a)),
            None => (inner.trim(), None),
        };
        let Some(rule) = canonical_rule(rule_raw) else {
            errors.push((
                line,
                format!(
                    "lint directive: unknown rule '{rule_raw}' (expected one of: {})",
                    RULE_NAMES.join(", ")
                ),
            ));
            return;
        };
        let args: Vec<String> = args_raw
            .map(|a| {
                a.split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or_default();
        let tail = body[close + 1..].trim();
        let Some(reason) = tail.strip_prefix("reason=") else {
            errors.push((
                line,
                "lint directive: allow(…) needs a non-empty 'reason=…'".into(),
            ));
            return;
        };
        let reason = reason.trim();
        if reason.is_empty() {
            errors.push((
                line,
                "lint directive: allow(…) needs a non-empty 'reason=…'".into(),
            ));
            return;
        }
        out.push((
            line,
            DirectiveKind::Allow {
                rule: rule.to_string(),
                args,
                reason: reason.to_string(),
            },
        ));
        return;
    }
    errors.push((
        line,
        format!("lint directive: unrecognised form '{rest}' (allow(rule) reason=… | mutates-channel-state)"),
    ));
}

/// One entry of the scope stack during item building.
struct Scope {
    kind: Option<ItemKind>,
    /// Index into the items vec for item-like scopes.
    item: Option<usize>,
    is_test: bool,
}

fn build_items(lines: &[Line]) -> Vec<Item> {
    let mut items: Vec<Item> = Vec::new();
    let mut scopes: Vec<Scope> = Vec::new();
    let mut header = String::new();
    let mut header_line = 1usize;
    let mut attrs = String::new();
    let mut attr_depth = 0i32; // unbalanced `[` inside `#[…]` attrs

    for (i, line) in lines.iter().enumerate() {
        let lineno = i + 1;
        let trimmed = line.code.trim();
        // Attribute lines accumulate separately from the header (an
        // attribute may span lines via unbalanced brackets).
        if attr_depth > 0 || trimmed.starts_with("#[") || trimmed.starts_with("#![") {
            if attr_depth == 0 && trimmed.starts_with("#![") {
                continue; // inner attributes don't attach to items
            }
            attrs.push(' ');
            attrs.push_str(trimmed);
            attr_depth += trimmed.matches('[').count() as i32;
            attr_depth -= trimmed.matches(']').count() as i32;
            continue;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    if header.trim().is_empty() {
                        header_line = lineno;
                    }
                    let parent_test = scopes.last().is_some_and(|s| s.is_test);
                    let is_test = parent_test || attrs.contains("cfg(test)");
                    let (kind, name, is_trait_impl) = classify_header(&header);
                    let item = kind.map(|k| {
                        let enclosing_impl = (k == ItemKind::Fn)
                            .then(|| {
                                scopes.iter().rev().find_map(|s| {
                                    s.item.filter(|&ix| items[ix].kind == ItemKind::Impl)
                                })
                            })
                            .flatten();
                        let impl_type = enclosing_impl.map(|ix| items[ix].name.clone());
                        let trait_impl = match k {
                            ItemKind::Impl => is_trait_impl,
                            ItemKind::Fn => {
                                enclosing_impl.is_some_and(|ix| items[ix].trait_impl)
                            }
                            _ => false,
                        };
                        items.push(Item {
                            kind: k,
                            name,
                            impl_type,
                            trait_impl,
                            line: header_line,
                            body_start: lineno,
                            body_end: lineno,
                            is_test,
                            fields: Vec::new(),
                            derives: parse_derives(&attrs),
                        });
                        items.len() - 1
                    });
                    scopes.push(Scope { kind, item, is_test });
                    header.clear();
                    header_line = lineno + 1;
                    attrs.clear();
                }
                '}' => {
                    if let Some(s) = scopes.pop() {
                        if let Some(ix) = s.item {
                            items[ix].body_end = lineno;
                        }
                    }
                    header.clear();
                    header_line = lineno + 1;
                }
                ';' => {
                    header.clear();
                    header_line = lineno + 1;
                    attrs.clear();
                }
                c => {
                    if header.trim().is_empty() && !c.is_whitespace() {
                        header_line = lineno;
                    }
                    header.push(c);
                }
            }
        }
        header.push(' ');
        // Struct fields: a `name: Type,` line directly inside a
        // struct body (this tree declares one field per line).
        if let Some(s) = scopes.last() {
            if s.kind == Some(ItemKind::Struct) {
                if let Some(ix) = s.item {
                    if let Some(f) = parse_field(trimmed, lineno) {
                        items[ix].fields.push(f);
                    }
                }
            }
        }
    }
    items
}

/// Classify the accumulated text before a `{`. The bool is "this is
/// a trait impl" (only meaningful for `Impl`).
fn classify_header(header: &str) -> (Option<ItemKind>, String, bool) {
    let toks: Vec<&str> = header.split_whitespace().collect();
    let find = |kw: &str| toks.iter().position(|t| *t == kw);
    // `match x { … }` headers contain no item keyword; closures and
    // struct literals likewise fall through to `None`.
    if let Some(p) = find("fn") {
        let name = toks
            .get(p + 1)
            .map(|t| ident_prefix(t))
            .unwrap_or_default();
        return (Some(ItemKind::Fn), name, false);
    }
    if let Some(p) = find("struct") {
        let name = toks.get(p + 1).map(|t| ident_prefix(t)).unwrap_or_default();
        return (Some(ItemKind::Struct), name, false);
    }
    if let Some(p) = find("enum") {
        let name = toks.get(p + 1).map(|t| ident_prefix(t)).unwrap_or_default();
        return (Some(ItemKind::Enum), name, false);
    }
    if let Some(p) = toks.iter().position(|t| *t == "impl" || t.starts_with("impl<")) {
        // `impl Type`, `impl<T> Type`, `impl Trait for Type`. The
        // generic-parameter list may be glued to the keyword
        // (`impl<T: Clone>`), so skip tokens until the angle brackets
        // opened by `impl<` balance out, then the next token is the
        // trait or self type.
        let rest: Vec<&str> = toks[p..].to_vec();
        let for_pos = rest.iter().position(|t| *t == "for");
        let ty = match for_pos {
            Some(f) => rest.get(f + 1).copied(),
            None => {
                let mut depth = angle_delta(rest[0].trim_start_matches("impl"));
                let mut found = None;
                for t in rest.iter().skip(1) {
                    if depth > 0 || t.starts_with('<') {
                        depth += angle_delta(t);
                        continue;
                    }
                    if *t == "where" {
                        break;
                    }
                    found = Some(*t);
                    break;
                }
                found
            }
        };
        let name = ty
            .map(|t| {
                // Last path segment, generics stripped.
                let base = t.split('<').next().unwrap_or(t);
                base.rsplit("::").next().unwrap_or(base).to_string()
            })
            .unwrap_or_default();
        return (Some(ItemKind::Impl), name, for_pos.is_some());
    }
    if let Some(p) = find("mod") {
        let name = toks.get(p + 1).map(|t| ident_prefix(t)).unwrap_or_default();
        return (Some(ItemKind::Mod), name, false);
    }
    (None, String::new(), false)
}

/// Net angle-bracket depth change of one token, ignoring the `>` of a
/// `->` arrow (return types inside generic bounds).
fn angle_delta(t: &str) -> i32 {
    let mut d = 0;
    let mut prev = ' ';
    for c in t.chars() {
        if c == '<' {
            d += 1;
        } else if c == '>' && prev != '-' {
            d -= 1;
        }
        prev = c;
    }
    d
}

fn ident_prefix(t: &str) -> String {
    t.chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect()
}

fn parse_derives(attrs: &str) -> Vec<String> {
    let Some(p) = attrs.find("derive(") else { return Vec::new() };
    let body = &attrs[p + "derive(".len()..];
    let Some(close) = body.find(')') else { return Vec::new() };
    body[..close]
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

fn parse_field(trimmed: &str, lineno: usize) -> Option<Field> {
    let t = trimmed.strip_prefix("pub ").unwrap_or(trimmed).trim();
    let (name, ty) = t.split_once(':')?;
    let name = name.trim();
    if name.is_empty()
        || !name.chars().next().is_some_and(|c| c.is_lowercase() || c == '_')
        || !name.chars().all(|c| c.is_alphanumeric() || c == '_')
    {
        return None;
    }
    let ty = ty.trim().trim_end_matches(',').trim();
    if ty.is_empty() {
        return None;
    }
    Some(Field { name: name.to_string(), ty: ty.to_string(), line: lineno })
}

/// Word-boundary containment: `word` appears in `hay` not flanked by
/// identifier characters.
pub fn contains_word(hay: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(p) = hay[from..].find(word) {
        let at = from + p;
        let before_ok = at == 0
            || !hay[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = at + word.len();
        let after_ok = after >= hay.len()
            || !hay[after..]
                .chars()
                .next()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        from = at + word.len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn scan(text: &str) -> FileScan {
        FileScan::scan(&PathBuf::from("x.rs"), text)
    }

    #[test]
    fn line_comments_and_strings_are_stripped_from_code() {
        let s = scan("let a = \"fn bogus() {\"; // trailing { brace\nlet b = 2;\n");
        assert_eq!(s.lines[0].code, "let a = \"\"; ");
        assert_eq!(s.lines[0].strings, vec!["fn bogus() {".to_string()]);
        assert_eq!(s.lines[1].code, "let b = 2;");
        assert!(s.items.is_empty(), "no real items: {:?}", s.items);
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let s = scan("a /* one /* two */ still */ b\n/* open\nstill\n*/ c\n");
        assert_eq!(s.lines[0].code.split_whitespace().collect::<Vec<_>>(), ["a", "b"]);
        assert_eq!(s.lines[1].code, "");
        assert_eq!(s.lines[3].code.trim(), "c");
    }

    #[test]
    fn raw_strings_with_hashes_and_quotes_inside() {
        let s = scan("let x = r#\"quote \" and // not a comment\"# + 1;\n");
        assert_eq!(s.lines[0].code, "let x = \"\" + 1;");
        assert_eq!(s.lines[0].strings[0], "quote \" and // not a comment");
        // A plain raw string and a byte raw string.
        let s = scan("r\"a\"; br##\"b\"#\"##;\n");
        assert_eq!(s.lines[0].strings, vec!["a".to_string(), "b\"#".to_string()]);
    }

    #[test]
    fn multi_line_strings_split_fragments_per_line() {
        let s = scan("let x = \"first \\\n  second\";\nlet y = 1;\n");
        assert_eq!(s.lines[0].strings, vec!["first \\".to_string()]);
        assert_eq!(s.lines[1].strings, vec!["  second".to_string()]);
        assert_eq!(s.lines[2].code, "let y = 1;");
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let s = scan("fn f<'a>(x: &'a str) { let c = '{'; let d = '\\n'; }\n");
        // The brace char literal must not open a scope.
        assert_eq!(s.items.len(), 1);
        assert_eq!(s.items[0].name, "f");
        assert_eq!(s.items[0].body_end, 1);
    }

    #[test]
    fn items_nesting_and_cfg_test_scoping() {
        let text = "\
pub struct Cfg {
    pub a: u64,
    b: Vec<String>, // lint: allow(config-coverage) reason=derived
}
impl Cfg {
    pub fn go(&self) -> u64 {
        if x { y() } else { z() }
        self.a
    }
}
#[cfg(test)]
mod tests {
    fn helper() {}
    #[test]
    fn case() { helper().unwrap(); }
}
";
        let s = scan(text);
        let cfg = s.items.iter().find(|i| i.kind == ItemKind::Struct).unwrap();
        assert_eq!(cfg.name, "Cfg");
        assert_eq!(cfg.fields.len(), 2);
        assert_eq!(cfg.fields[1].name, "b");
        assert!(s.allows(3, "config-coverage"));
        assert!(!s.allows(2, "config-coverage"));
        let go = s.items.iter().find(|i| i.name == "go").unwrap();
        assert_eq!(go.impl_type.as_deref(), Some("Cfg"));
        assert!(!go.is_test);
        // Everything under the #[cfg(test)] mod is test-scoped.
        for name in ["helper", "case"] {
            let f = s.items.iter().find(|i| i.name == name).unwrap();
            assert!(f.is_test, "{name} must inherit cfg(test)");
        }
    }

    #[test]
    fn derives_are_recorded() {
        let s = scan("#[derive(Debug, Clone, PartialEq)]\npub struct X {\n    a: u8,\n}\n");
        let x = &s.items[0];
        assert_eq!(x.derives, ["Debug", "Clone", "PartialEq"]);
    }

    #[test]
    fn directive_attachment_same_line_vs_next_line() {
        let text = "\
let a = q.pop().unwrap(); // lint: allow(panic) reason=checked above
// lint: allow(panic) reason=non-empty by construction
let b = r.pop().unwrap();
";
        let s = scan(text);
        assert!(s.allows(1, "no-panic-hot-path"));
        assert!(s.allows(3, "no-panic-hot-path"));
        assert!(!s.allows(2, "no-panic-hot-path"));
    }

    #[test]
    fn malformed_directives_are_errors_not_silence() {
        let s = scan("// lint: allow(panic)\nx();\n");
        assert_eq!(s.errors.len(), 1, "missing reason must be flagged");
        let s = scan("// lint: allow(bogus-rule) reason=x\n");
        assert!(s.errors[0].1.contains("unknown rule"), "{:?}", s.errors);
        let s = scan("// lint: frobnicate\n");
        assert!(s.errors[0].1.contains("unrecognised"), "{:?}", s.errors);
        // Well-formed ones parse without noise.
        let s = scan("// lint: allow(json-key-drift: a, b) reason=derived keys\n");
        assert!(s.errors.is_empty(), "{:?}", s.errors);
        let args = s.allow_args_in(1, 2, "json-key-drift");
        assert_eq!(args, ["a", "b"]);
    }

    #[test]
    fn marker_directive_attaches_to_following_fn() {
        let text = "\
impl C {
    /// Docs.
    // lint: mutates-channel-state
    fn push(&mut self) {
        self.q.push(1);
    }
}
";
        let s = scan(text);
        let f = s.items.iter().find(|i| i.name == "push").unwrap();
        assert!(s.has_marker_in(f.line.saturating_sub(3), f.line));
    }

    #[test]
    fn contains_word_respects_identifier_boundaries() {
        assert!(contains_word("self.seed = seed;", "seed"));
        assert!(!contains_word("reseed(x)", "seed"));
        assert!(!contains_word("seeds", "seed"));
        assert!(contains_word("a.seed,", "seed"));
        assert!(!contains_word("", "seed"));
    }

    #[test]
    fn impl_header_variants_resolve_self_type() {
        let s = scan("impl<T: Clone> Probe for ring::TraceRing<T> {\n fn record(&mut self) {}\n}\n");
        let imp = s.items.iter().find(|i| i.kind == ItemKind::Impl).unwrap();
        assert_eq!(imp.name, "TraceRing");
        assert!(imp.trait_impl);
        let f = s.items.iter().find(|i| i.name == "record").unwrap();
        assert_eq!(f.impl_type.as_deref(), Some("TraceRing"));
        assert!(f.trait_impl, "fn inherits trait-impl flag");
        // Inherent impls are not trait impls.
        let s = scan("impl Controller {\n fn tick(&mut self) {}\n}\n");
        let f = s.items.iter().find(|i| i.name == "tick").unwrap();
        assert!(!f.trait_impl);
    }

    #[test]
    fn several_strings_on_one_line_stay_distinct_fragments() {
        let s = scan("f(\"alpha\", \"beta\"); g(\"\");\n");
        assert_eq!(s.lines[0].strings, vec!["alpha".to_string(), "beta".to_string()]);
    }
}
