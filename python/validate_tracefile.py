#!/usr/bin/env python3
"""Validate a binary op-trace file written by `lisa trace record` /
`lisa trace convert` (the v1 format in DESIGN.md §Trace subsystem).

An independent, stdlib-only decoder — it shares no code with the Rust
reader, so a format bug that the Rust round trip reproduces on both
sides still fails here. Checks, in order:

  1. magic, version, plausible core count and name length;
  2. the directory: every stream's [offset, offset+len) lies past the
     header and inside the file, streams do not overlap, op_count >= 1;
  3. every stream decodes to exactly op_count ops consuming exactly
     len bytes — valid tags, terminated minimal-progress varints
     (<= 10 bytes, 10th-byte payload <= 1), flag bytes <= 3;
  4. the streams tile the file: no gap or trailing garbage after the
     last stream.

Exits non-zero with a message on the first violated invariant; prints
a one-line summary on success. Stdlib only (CI runs it bare).
"""

import struct
import sys

MAGIC = b"LISATRCE"
VERSION = 1
MAX_CORES = 4096
MAX_NAME = 4096
FIXED_HEADER = 20
DESC = 24

# tag -> (name, has_flags byte, number of varint fields *excluding*
# nonmem, number of address-delta fields)
TAGS = {
    0: ("mem", True, 0, 1),
    1: ("copy", False, 1, 2),
    2: ("bulk:memcpy", False, 1, 2),
    3: ("bulk:zero", False, 1, 1),
    4: ("bulk:fork", False, 0, 0),
    5: ("bulk:touch", True, 0, 1),
    6: ("bulk:checkpoint", False, 0, 0),
    7: ("bulk:promote", False, 0, 1),
}


def fail(msg):
    print(f"validate_tracefile: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def read_varint(buf, pos, what):
    v = 0
    shift = 0
    for i in range(10):
        if pos >= len(buf):
            fail(f"{what}: varint truncated at stream byte {pos}")
        b = buf[pos]
        pos += 1
        payload = b & 0x7F
        if i == 9 and payload > 1:
            fail(f"{what}: over-long varint (10th byte 0x{b:02x})")
        v |= payload << shift
        if not (b & 0x80):
            return v, pos
        shift += 7
    fail(f"{what}: over-long varint (no terminator in 10 bytes)")


def decode_stream(buf, core, op_count):
    """Decode one stream buffer completely; returns the op-kind
    histogram."""
    pos = 0
    hist = {}
    for op_idx in range(op_count):
        where = f"core {core} op {op_idx}"
        if pos >= len(buf):
            fail(f"{where}: stream truncated")
        tag = buf[pos]
        pos += 1
        if tag not in TAGS:
            fail(f"{where}: unknown tag 0x{tag:02x}")
        name, has_flags, n_varints, n_addrs = TAGS[tag]
        _, pos = read_varint(buf, pos, f"{where} nonmem")
        if has_flags:
            if pos >= len(buf):
                fail(f"{where}: flags byte truncated")
            if buf[pos] > 3:
                fail(f"{where}: invalid flags byte 0x{buf[pos]:02x}")
            pos += 1
        for k in range(n_varints):
            v, pos = read_varint(buf, pos, f"{where} field {k}")
            if v > 0xFFFFFFFF:
                fail(f"{where}: count field {v} exceeds u32")
        for k in range(n_addrs):
            _, pos = read_varint(buf, pos, f"{where} addr {k}")
        hist[name] = hist.get(name, 0) + 1
    if pos != len(buf):
        fail(f"core {core}: {len(buf) - pos} trailing bytes after "
             f"{op_count} declared ops")
    return hist


def main():
    if len(sys.argv) != 2:
        fail("usage: validate_tracefile.py TRACE_FILE")
    path = sys.argv[1]
    with open(path, "rb") as f:
        data = f.read()
    if len(data) < FIXED_HEADER:
        fail(f"file is {len(data)} bytes, smaller than the fixed header")
    if data[:8] != MAGIC:
        fail(f"bad magic {data[:8]!r}")
    version, cores, name_len = struct.unpack_from("<III", data, 8)
    if version != VERSION:
        fail(f"unsupported version {version}")
    if not 1 <= cores <= MAX_CORES:
        fail(f"implausible core count {cores}")
    if name_len > MAX_NAME:
        fail(f"implausible name length {name_len}")
    header_end = FIXED_HEADER + name_len + cores * DESC
    if len(data) < header_end:
        fail(f"truncated header: file {len(data)} < header {header_end}")
    try:
        name = data[FIXED_HEADER:FIXED_HEADER + name_len].decode("utf-8")
    except UnicodeDecodeError:
        fail("workload name is not UTF-8")

    streams = []
    for core in range(cores):
        op_count, offset, length = struct.unpack_from(
            "<QQQ", data, FIXED_HEADER + name_len + core * DESC
        )
        if op_count == 0:
            fail(f"core {core}: empty stream (op_count = 0)")
        if offset < header_end:
            fail(f"core {core}: stream offset {offset} overlaps the header")
        if offset + length > len(data):
            fail(f"core {core}: stream [{offset}, {offset + length}) runs "
                 f"past end of file ({len(data)} bytes)")
        streams.append((core, op_count, offset, length))

    # Streams must tile the file contiguously after the header.
    expect = header_end
    for core, _, offset, length in sorted(streams, key=lambda s: s[2]):
        if offset != expect:
            fail(f"core {core}: gap or overlap at offset {offset} "
                 f"(expected {expect})")
        expect = offset + length
    if expect != len(data):
        fail(f"{len(data) - expect} trailing bytes after the last stream")

    hist = {}
    total = 0
    for core, op_count, offset, length in streams:
        for kind, n in decode_stream(
            data[offset:offset + length], core, op_count
        ).items():
            hist[kind] = hist.get(kind, 0) + n
        total += op_count
    summary = " ".join(f"{k}:{v}" for k, v in sorted(hist.items()))
    print(
        f"validate_tracefile: OK: \"{name}\" v{version}, {cores} cores, "
        f"{total} ops, {len(data)} bytes ({summary})"
    )


if __name__ == "__main__":
    main()
