//! Simulation engine (CPU ⇄ controller ⇄ DRAM binding), the parallel
//! campaign runner, the declarative experiment API (`spec`) and the
//! drivers that regenerate the paper's tables and figures.

pub mod cache;
pub mod campaign;
pub mod engine;
pub mod experiments;
pub mod journal;
pub mod spec;

pub use engine::Simulation;
