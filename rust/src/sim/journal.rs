//! Append-only campaign checkpoint journal: one JSON line per finished
//! campaign job, flushed as it completes, so a killed run can resume
//! with `--resume <journal>` and produce byte-identical output to an
//! uninterrupted one.
//!
//! Line format (version 1):
//!
//! ```text
//! {"v":1,"idx":<job index>,"key":"<32-hex content key>","records":[<Record JSON>,...]}
//! ```
//!
//! `idx` is the job's position in grid order — where the records slot
//! back into the report. `key` is the job's content key (see
//! `spec::job_key`): the resume path only trusts an entry whose key
//! matches what the *current* invocation computes for that index, so a
//! journal from an edited grid, different base config or older code
//! version silently degrades to "re-run" instead of resurrecting stale
//! results. The reader skips torn or malformed lines — the tail a
//! `kill -9` leaves mid-write — and lets later entries for an index
//! supersede earlier ones (a resumed run appends to the same file).
//! The writer heals a torn tail on open by terminating it with a
//! newline, so resumed appends never glue onto the fragment.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::metrics::json as emit;
use crate::util::json::{self, Value};

/// Journal line format version; bumped on incompatible changes.
pub const JOURNAL_VERSION: u64 = 1;

/// One parsed journal line.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    /// Campaign job index (grid order).
    pub idx: usize,
    /// Content key the writer computed for the job.
    pub key: String,
    /// The job's serialized `Record`s, one `Value` each.
    pub records: Vec<Value>,
}

/// Appending writer over a journal file. Created lazily by the
/// campaign runner when `--journal`/`--resume` is given; each
/// [`append`](Self::append) flushes, so at most the line being written
/// when the process dies is lost (and the reader skips it).
#[derive(Debug)]
pub struct JournalWriter {
    file: File,
}

impl JournalWriter {
    /// Open `path` for appending, creating it (and its parent
    /// directory) if missing. Appending — never truncating — is what
    /// lets `--resume FILE` keep journaling into the same file. If the
    /// existing file ends mid-line (the tail a `kill -9` leaves), a
    /// newline is written first: appending straight onto the torn
    /// fragment would glue the next entry to it and make both
    /// unreadable.
    pub fn append_to(path: &Path) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating journal dir {}", dir.display()))?;
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        let len = file.metadata().context("statting journal")?.len();
        if len > 0 {
            let mut last = [0u8; 1];
            file.seek(SeekFrom::End(-1)).context("seeking journal tail")?;
            file.read_exact(&mut last).context("reading journal tail")?;
            if last[0] != b'\n' {
                // O_APPEND: this lands at EOF regardless of the seek.
                file.write_all(b"\n").context("terminating torn journal tail")?;
            }
        }
        Ok(Self { file })
    }

    /// Append one finished job: its index, content key and serialized
    /// records (each already a complete `Record` JSON object).
    pub fn append(&mut self, idx: usize, key: &str, records_json: &[String]) -> Result<()> {
        let line = format!(
            "{{\"v\":{JOURNAL_VERSION},\"idx\":{idx},\"key\":{},\"records\":[{}]}}\n",
            emit::string(key),
            records_json.join(",")
        );
        self.file.write_all(line.as_bytes()).context("appending journal line")?;
        self.file.flush().context("flushing journal")
    }
}

/// Read every well-formed entry of a journal, in file order. Torn and
/// malformed lines (including whole-line garbage and wrong-version
/// entries) are skipped, not errors: the common case is the half-line
/// a killed run left at EOF. Only newline-terminated lines count —
/// an unterminated final line is the tail of an interrupted append
/// even when the cut happens to leave parseable JSON, so it re-runs
/// (and the next writer heals it) instead of being trusted.
pub fn read(path: &Path) -> Result<Vec<JournalEntry>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("opening journal {}", path.display()))?;
    let mut entries = Vec::new();
    for line in bytes.split_inclusive(|b| *b == b'\n') {
        if line.last() != Some(&b'\n') {
            continue;
        }
        let Ok(line) = std::str::from_utf8(line) else { continue };
        if let Some(entry) = parse_line(line) {
            entries.push(entry);
        }
    }
    Ok(entries)
}

fn parse_line(line: &str) -> Option<JournalEntry> {
    if line.trim().is_empty() {
        return None;
    }
    let v = json::parse(line).ok()?;
    if v.get("v")?.as_u64()? != JOURNAL_VERSION {
        return None;
    }
    Some(JournalEntry {
        idx: v.get("idx")?.as_u64()? as usize,
        key: v.get("key")?.as_str()?.to_string(),
        records: v.get("records")?.as_array()?.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("lisa-journal-test-{}-{tag}.jsonl", std::process::id()))
    }

    #[test]
    fn append_then_read_round_trips() {
        let path = temp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut w = JournalWriter::append_to(&path).unwrap();
        w.append(2, "00ff", &["{\"ws\":1.5}".to_string()]).unwrap();
        w.append(0, "a0b1", &["{\"ws\":null}".into(), "{\"ws\":2}".into()]).unwrap();
        drop(w);
        // Re-open appending (the --resume path) and add a superseding
        // entry for idx 2.
        let mut w = JournalWriter::append_to(&path).unwrap();
        w.append(2, "00ff", &["{\"ws\":1.75}".to_string()]).unwrap();
        drop(w);
        let entries = read(&path).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!((entries[0].idx, entries[0].key.as_str()), (2, "00ff"));
        assert_eq!(entries[1].records.len(), 2);
        assert!(entries[1].records[0].get("ws").unwrap().is_null());
        // File order is preserved: last write is last, so "latest
        // wins" is a simple forward fold for the consumer.
        assert_eq!(entries[2].records[0].get("ws").unwrap().as_f64(), Some(1.75));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn torn_and_malformed_lines_are_skipped() {
        let path = temp_path("torn");
        let good = format!(
            "{{\"v\":{JOURNAL_VERSION},\"idx\":1,\"key\":\"ab\",\"records\":[{{\"x\":1}}]}}"
        );
        let wrong_version = "{\"v\":999,\"idx\":2,\"key\":\"cd\",\"records\":[]}";
        // A torn tail: the same good line cut mid-record, no newline.
        let torn = &good[..good.len() - 7];
        std::fs::write(&path, format!("{good}\nnot json\n{wrong_version}\n\n{torn}"))
            .unwrap();
        let entries = read(&path).unwrap();
        assert_eq!(entries.len(), 1, "only the intact line survives");
        assert_eq!(entries[0].idx, 1);
        assert_eq!(entries[0].records[0].get("x").unwrap().as_u64(), Some(1));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn appending_to_a_torn_tail_heals_it_first() {
        let path = temp_path("heal");
        let good = format!(
            "{{\"v\":{JOURNAL_VERSION},\"idx\":0,\"key\":\"ab\",\"records\":[{{\"x\":1}}]}}"
        );
        // Simulate a kill mid-append: a complete line plus a torn,
        // newline-less fragment of the next one.
        std::fs::write(&path, format!("{good}\n{}", &good[..good.len() / 2])).unwrap();
        assert_eq!(read(&path).unwrap().len(), 1);
        let mut w = JournalWriter::append_to(&path).unwrap();
        w.append(1, "cd", &["{\"y\":2}".to_string()]).unwrap();
        drop(w);
        // The appended entry is readable: the fragment got its own
        // newline instead of swallowing the new line.
        let entries = read(&path).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!((entries[0].idx, entries[1].idx), (0, 1));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn unterminated_final_line_is_torn_even_if_parseable() {
        // A cut can land exactly between a record's closing brace and
        // its newline; the reader still treats it as torn (re-run)
        // rather than trusting an append the writer never finished.
        let path = temp_path("unterminated");
        let good = format!(
            "{{\"v\":{JOURNAL_VERSION},\"idx\":3,\"key\":\"ef\",\"records\":[{{\"x\":1}}]}}"
        );
        std::fs::write(&path, &good).unwrap();
        assert!(read(&path).unwrap().is_empty());
        // Re-opening for append heals it into a complete (and now
        // trusted) line.
        drop(JournalWriter::append_to(&path).unwrap());
        assert_eq!(read(&path).unwrap().len(), 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_journal_is_an_error_but_empty_is_fine() {
        assert!(read(Path::new("/no/such/lisa-journal")).is_err());
        let path = temp_path("empty");
        std::fs::write(&path, "").unwrap();
        assert!(read(&path).unwrap().is_empty());
        std::fs::remove_file(&path).unwrap();
    }
}
