//! L4 fixture (probe-gating): the first `.observe(..)` call is not
//! dominated by an `observing()` gate; the second is and must not
//! fire. Not compiled — lexed by lint tests only.

pub struct Core {
    obs: Option<u32>,
    steps: u64,
}

impl Core {
    fn observing(&self) -> bool {
        self.obs.is_some()
    }

    pub fn step(&mut self) {
        self.steps += 1;
        self.observe(self.steps as u32);
        if self.observing() {
            self.observe(0);
        }
    }

    fn observe(&mut self, ev: u32) {
        if let Some(o) = self.obs.as_mut() {
            *o = ev;
        }
    }
}
