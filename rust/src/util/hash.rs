//! Stable content hashing (FNV-1a) for the campaign result cache and
//! checkpoint journal. Built in-tree because the offline registry has
//! no hashing crates, and `std`'s `DefaultHasher` explicitly does not
//! promise stability across Rust versions — these keys become file
//! names and journal match tokens that must survive toolchain bumps.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `bytes` starting from an explicit basis. Distinct bases
/// yield independent-enough streams for the composite key below.
pub fn fnv1a_64(bytes: &[u8], basis: u64) -> u64 {
    let mut h = basis;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// 128-bit content key as 32 lowercase hex characters: two FNV-1a
/// passes from different bases, with the input length folded into the
/// second so prefix-extension collisions cannot alias both halves.
/// Used as the content address of campaign jobs — at 10k-point grids
/// the collision probability is negligible, and cache entries verify
/// the stored key on read as a second guard.
pub fn content_key(text: &str) -> String {
    let bytes = text.as_bytes();
    let a = fnv1a_64(bytes, FNV_OFFSET);
    let mut b = fnv1a_64(bytes, FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15);
    b ^= (bytes.len() as u64).wrapping_mul(FNV_PRIME);
    format!("{a:016x}{b:016x}")
}

/// Incremental version of [`content_key`] for byte streams fed in
/// chunks: two FNV-1a states advanced per chunk, with the total length
/// folded into the second half at the end. Feeding the whole input as
/// one chunk yields exactly `content_key(input)`. Used to digest trace
/// files into cache keys without reading them into memory at once.
#[derive(Debug, Clone)]
pub struct StreamDigest {
    a: u64,
    b: u64,
    len: u64,
}

impl StreamDigest {
    pub fn new() -> Self {
        Self {
            a: FNV_OFFSET,
            b: FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15,
            len: 0,
        }
    }

    pub fn update(&mut self, chunk: &[u8]) {
        self.a = fnv1a_64(chunk, self.a);
        self.b = fnv1a_64(chunk, self.b);
        self.len += chunk.len() as u64;
    }

    /// Finish into the 32-hex key. Non-consuming so a digest can be
    /// snapshotted mid-stream if ever needed.
    pub fn finish(&self) -> String {
        let b = self.b ^ self.len.wrapping_mul(FNV_PRIME);
        format!("{:016x}{b:016x}", self.a)
    }
}

impl Default for StreamDigest {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // Published FNV-1a test vectors (64-bit).
        assert_eq!(fnv1a_64(b"", FNV_OFFSET), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a", FNV_OFFSET), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar", FNV_OFFSET), 0x85944171f73967e8);
    }

    #[test]
    fn content_key_is_stable_and_distinguishes() {
        let k = content_key("workload=stream4;seed=1");
        assert_eq!(k.len(), 32);
        assert!(k.chars().all(|c| c.is_ascii_hexdigit()));
        // Deterministic across calls (it names cache files).
        assert_eq!(k, content_key("workload=stream4;seed=1"));
        // One-character edits move the key.
        assert_ne!(k, content_key("workload=stream4;seed=2"));
        assert_ne!(content_key(""), content_key("\u{0}"));
    }

    #[test]
    fn stream_digest_matches_content_key_regardless_of_chunking() {
        let text = "the quick brown fox jumps over the lazy dog";
        let whole = content_key(text);
        for chunk in [1usize, 2, 7, 44] {
            let mut d = StreamDigest::new();
            for piece in text.as_bytes().chunks(chunk) {
                d.update(piece);
            }
            assert_eq!(d.finish(), whole, "chunk size {chunk}");
        }
        assert_eq!(StreamDigest::new().finish(), content_key(""));
    }
}
