//! Experiment drivers: one function per paper table/figure (DESIGN.md
//! per-experiment index E1-E8). Shared by the bench targets, the
//! examples and the CLI so every surface reports identical numbers.

use anyhow::Result;

use crate::config::{Calibration, CopyMechanism, PlacementPolicy, SalpMode, SimConfig};
use crate::copy::isolated_copy;
use crate::dram::area::AreaModel;
use crate::dram::timing::SpeedBin;
use crate::energy::EnergyModel;
use crate::lisa::lip::{lip_report, LipReport};
use crate::lisa::rbm::{rbm_bandwidth, RbmBandwidth};
use crate::metrics::{json, Comparison, RunReport};
use crate::sim::campaign;
use crate::sim::engine::{alone_ipcs, run_workload, Simulation};
use crate::workloads::mixes;
use crate::workloads::Workload;

/// E1 (Table 1 / Fig. 2): one row per copy mechanism.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub label: String,
    pub paper_latency_ns: f64,
    pub latency_ns: f64,
    pub paper_energy_uj: f64,
    pub energy_uj: f64,
}

/// Regenerate Table 1: 8 KB copy latency and DRAM energy per
/// mechanism (paper values embedded for side-by-side comparison).
pub fn table1(cal: &Calibration) -> Result<Vec<Table1Row>> {
    let em = EnergyModel::from_calibration(cal);
    let speed = SpeedBin::Ddr3_1600;
    let mut rows = Vec::new();
    let cases: [(&str, CopyMechanism, usize, f64, f64); 8] = [
        ("memcpy (via channel)", CopyMechanism::MemcpyChannel, 7, 1366.25, 6.2),
        ("RC-InterSA", CopyMechanism::RowCloneInterSa, 7, 1363.75, 4.33),
        ("RC-Bank", CopyMechanism::RowCloneInterBank, 0, 701.25, 2.08),
        ("RC-IntraSA", CopyMechanism::RowCloneIntraSa, 0, 83.75, 0.06),
        ("LISA-RISC (1 hop)", CopyMechanism::LisaRisc, 1, 148.5, 0.09),
        ("LISA-RISC (7 hops)", CopyMechanism::LisaRisc, 7, 196.5, 0.12),
        ("LISA-RISC (15 hops)", CopyMechanism::LisaRisc, 15, 260.5, 0.17),
        ("LISA-RISC (4 hops)", CopyMechanism::LisaRisc, 4, 172.5, 0.105),
    ];
    for (label, mech, hops, p_lat, p_en) in cases {
        let r = isolated_copy(mech, hops, speed, cal)?;
        rows.push(Table1Row {
            label: label.to_string(),
            paper_latency_ns: p_lat,
            latency_ns: r.latency_ns,
            paper_energy_uj: p_en,
            energy_uj: em.breakdown_uj(&r.stats, 0, speed.tck_ns()).total,
        });
    }
    Ok(rows)
}

/// E2: RBM bandwidth vs the memory channel (paper §2).
pub fn rbm_report(cal: &Calibration) -> RbmBandwidth {
    rbm_bandwidth(SpeedBin::Ddr4_2400, cal, 8192)
}

/// E3: linked precharge latency (paper §3.3 SPICE results).
pub fn lip_circuit_report(cal: &Calibration) -> LipReport {
    lip_report(SpeedBin::Ddr3_1600, cal)
}

/// E8: die-area overhead (paper §2).
pub fn area_report(cfg: &SimConfig) -> crate::dram::area::AreaReport {
    AreaModel::default().overhead(&cfg.dram)
}

// ---------------------------------------------------------------------------
// System-level configurations (Fig. 3 / Fig. 4 / §3.1.2).
// ---------------------------------------------------------------------------

/// Baseline: memcpy over the channel, standard DRAM.
pub fn cfg_baseline(requests: u64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.copy_mechanism = CopyMechanism::MemcpyChannel;
    cfg.requests_per_core = requests;
    cfg
}

/// LISA-RISC only.
pub fn cfg_risc(requests: u64) -> SimConfig {
    let mut cfg = cfg_baseline(requests);
    cfg.lisa.risc = true;
    cfg.copy_mechanism = CopyMechanism::LisaRisc;
    cfg
}

/// LISA-RISC + LISA-VILLA.
pub fn cfg_risc_villa(requests: u64) -> SimConfig {
    let mut cfg = cfg_risc(requests);
    cfg.lisa.villa = true;
    // Short epochs relative to the bounded run lengths used in the
    // bench harness (the paper's epoch is sized against full SPEC
    // runs; what matters is epochs << run length).
    cfg.lisa.villa_epoch_cycles = 5_000;
    cfg
}

/// All three LISA applications (Fig. 4 "All").
pub fn cfg_all(requests: u64) -> SimConfig {
    let mut cfg = cfg_risc_villa(requests);
    cfg.lisa.lip = true;
    cfg
}

/// LIP only (E7).
pub fn cfg_lip(requests: u64) -> SimConfig {
    let mut cfg = cfg_baseline(requests);
    cfg.lisa.lip = true;
    cfg
}

/// VILLA with RowClone inter-subarray movement (Fig. 3's comparison:
/// the paper shows this LOSES 52.3% because RC movement is slow and
/// blocks the internal bus).
pub fn cfg_villa_rc(requests: u64) -> SimConfig {
    let mut cfg = cfg_baseline(requests);
    cfg.lisa.villa = true;
    cfg.lisa.risc = false; // fills fall back to RC-InterSA
    cfg.lisa.villa_epoch_cycles = 5_000;
    cfg
}

/// One configuration's weighted-speedup measurement on a workload.
#[derive(Debug, Clone)]
pub struct WsPoint {
    pub ws: f64,
    pub energy_uj: f64,
    pub villa_hit_rate: f64,
}

/// Measure a config's WS on a workload, normalized by the supplied
/// alone-run IPCs. Following the multiprogrammed-evaluation
/// methodology of the paper's lineage (SALP / TL-DRAM / RowClone),
/// the alone runs are measured ONCE on the baseline system and shared
/// by every configuration, so WS improvements reflect shared-mode
/// performance changes.
pub fn ws_point_with(cfg: &SimConfig, workload: &Workload, alone: &[f64]) -> WsPoint {
    let shared = run_workload(cfg, workload);
    WsPoint {
        ws: shared.weighted_speedup(alone),
        energy_uj: shared.energy.total,
        villa_hit_rate: shared.villa_hit_rate,
    }
}

/// Convenience: measure with the config's own alone runs.
pub fn ws_point(cfg: &SimConfig, workload: &Workload) -> WsPoint {
    let alone = alone_ipcs(cfg, workload);
    ws_point_with(cfg, workload, &alone)
}

/// Improvement of one measured point over a baseline point:
/// (WS improvement fraction, energy reduction fraction).
pub fn improvement(base: &WsPoint, cfg: &WsPoint) -> (f64, f64) {
    let imp = if base.ws > 0.0 { cfg.ws / base.ws - 1.0 } else { 0.0 };
    let en = if base.energy_uj > 0.0 {
        1.0 - cfg.energy_uj / base.energy_uj
    } else {
        0.0
    };
    (imp, en)
}

/// Weighted-speedup improvement of `cfg` over `base` on a workload:
/// (WS_cfg / WS_base) - 1, each normalized by its own alone runs.
/// Also returns the energy reduction fraction and villa hit rate.
/// (Prefer `ws_point` + `improvement` when comparing several configs
/// against one baseline — it avoids re-running the baseline.)
pub fn ws_improvement(
    base: &SimConfig,
    cfg: &SimConfig,
    workload: &Workload,
) -> (f64, f64, f64) {
    let b = ws_point(base, workload);
    let c = ws_point(cfg, workload);
    let (imp, en) = improvement(&b, &c);
    (imp, en, c.villa_hit_rate)
}

/// E4 (Fig. 3) row.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub workload: String,
    pub villa_improvement: f64,
    pub villa_hit_rate: f64,
    pub rc_inter_improvement: f64,
}

/// E4 (Fig. 3): LISA-VILLA improvement + hit rate per hot-region
/// workload, plus the RC-InterSA-movement comparison. Each mix is an
/// independent job, sharded across the campaign runner (result order
/// is the mix order regardless of thread count).
pub fn fig3(requests: u64, max_mixes: usize, threads: usize) -> Vec<Fig3Row> {
    let base = cfg_baseline(requests);
    let villa = cfg_risc_villa(requests);
    let villa_rc = cfg_villa_rc(requests);
    let mixes = mixes::villa_mixes(base.cpu.cores);
    let jobs: Vec<_> = mixes
        .iter()
        .take(max_mixes)
        .map(|wl| {
            let base = base.clone();
            let villa = villa.clone();
            let villa_rc = villa_rc.clone();
            move || {
                let alone = alone_ipcs(&base, wl);
                let b = ws_point_with(&base, wl, &alone);
                let v = ws_point_with(&villa, wl, &alone);
                let rc = ws_point_with(&villa_rc, wl, &alone);
                Fig3Row {
                    workload: wl.name.clone(),
                    villa_improvement: improvement(&b, &v).0,
                    villa_hit_rate: v.villa_hit_rate,
                    rc_inter_improvement: improvement(&b, &rc).0,
                }
            }
        })
        .collect();
    campaign::run_jobs(jobs, threads)
}

/// E5/E6 (Fig. 4): comparisons of RISC / RISC+VILLA / All over the
/// baseline across the copy mixes, one campaign job per mix.
pub fn fig4(requests: u64, max_mixes: usize, threads: usize) -> Vec<Comparison> {
    let base = cfg_baseline(requests);
    let configs = [
        ("LISA-RISC", cfg_risc(requests)),
        ("LISA-(RISC+VILLA)", cfg_risc_villa(requests)),
        ("LISA-All", cfg_all(requests)),
    ];
    let mixes = mixes::copy_mixes(base.cpu.cores);
    let jobs: Vec<_> = mixes
        .iter()
        .take(max_mixes)
        .map(|wl| {
            let base = base.clone();
            let configs = configs.clone();
            move || {
                // One set of baseline alone runs + one baseline
                // measurement, shared by all three configs.
                let alone = alone_ipcs(&base, wl);
                let b = ws_point_with(&base, wl, &alone);
                configs
                    .iter()
                    .map(|(_, cfg)| improvement(&b, &ws_point_with(cfg, wl, &alone)))
                    .collect::<Vec<_>>()
            }
        })
        .collect();
    let per_mix = campaign::run_jobs(jobs, threads);
    let mut cmps: Vec<Comparison> = configs
        .iter()
        .map(|(name, _)| Comparison { name: name.to_string(), ..Default::default() })
        .collect();
    for row in per_mix {
        for (i, (imp, en)) in row.into_iter().enumerate() {
            cmps[i].ws_improvements.push(imp);
            cmps[i].energy_reductions.push(en);
        }
    }
    cmps
}

/// E7: LISA-LIP alone across the copy mixes (paper: +10.3% average
/// over 50 workloads), one campaign job per mix.
pub fn lip_system(requests: u64, max_mixes: usize, threads: usize) -> Comparison {
    let base = cfg_baseline(requests);
    let lip = cfg_lip(requests);
    let mixes = mixes::copy_mixes(base.cpu.cores);
    let jobs: Vec<_> = mixes
        .iter()
        .take(max_mixes)
        .map(|wl| {
            let base = base.clone();
            let lip = lip.clone();
            move || {
                let alone = alone_ipcs(&base, wl);
                let b = ws_point_with(&base, wl, &alone);
                let c = ws_point_with(&lip, wl, &alone);
                improvement(&b, &c)
            }
        })
        .collect();
    let mut cmp = Comparison { name: "LISA-LIP".into(), ..Default::default() };
    for (imp, en) in campaign::run_jobs(jobs, threads) {
        cmp.ws_improvements.push(imp);
        cmp.energy_reductions.push(en);
    }
    cmp
}

// ---------------------------------------------------------------------------
// E9: OS-level bulk operations (fork / zeroing / checkpoint / promotion)
// across {copy mechanism} x {frame placement policy}.
// ---------------------------------------------------------------------------

/// The copy-mechanism axis of E9: memcpy over the channel, the best
/// RowClone the pair's geometry allows, and LISA-RISC.
pub const E9_MECHANISMS: [CopyMechanism; 3] = [
    CopyMechanism::MemcpyChannel,
    CopyMechanism::RowCloneInterSa,
    CopyMechanism::LisaRisc,
];

/// The four OS scenario workloads of E9.
pub const E9_SCENARIOS: [&str; 4] = ["os-fork", "os-zero", "os-checkpoint", "os-promote"];

/// One finished E9 grid point.
#[derive(Debug, Clone, PartialEq)]
pub struct OsRow {
    pub scenario: String,
    pub mechanism: &'static str,
    pub policy: &'static str,
    pub report: RunReport,
}

/// Configuration for one E9 point.
pub fn cfg_os(requests: u64, mech: CopyMechanism, policy: PlacementPolicy) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.requests_per_core = requests;
    cfg.copy_mechanism = mech;
    cfg.lisa.risc = mech == CopyMechanism::LisaRisc;
    cfg.os.placement = policy;
    cfg
}

/// E9 driver: run every {scenario x mechanism x placement} point
/// through the parallel campaign runner (scenario-major row order,
/// deterministic at any thread count).
pub fn e9_os(
    requests: u64,
    mechanisms: &[CopyMechanism],
    policies: &[PlacementPolicy],
    scenarios: &[String],
    threads: usize,
) -> Result<Vec<OsRow>> {
    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for scenario in scenarios {
        for &mech in mechanisms {
            for &policy in policies {
                let cfg = cfg_os(requests, mech, policy);
                let wl = mixes::workload_by_name(scenario, &cfg)?;
                labels.push((scenario.clone(), mech.name(), policy.name()));
                jobs.push(move || Simulation::new(cfg, wl).run());
            }
        }
    }
    let reports = campaign::run_jobs(jobs, threads);
    Ok(labels
        .into_iter()
        .zip(reports)
        .map(|((scenario, mechanism, policy), report)| OsRow {
            scenario,
            mechanism,
            policy,
            report,
        })
        .collect())
}

/// JSON document for an E9 run (`lisa os --out report.json`).
pub fn os_json(rows: &[OsRow]) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"scenario\":{},\"mechanism\":{},\"policy\":{},\"report\":{}}}",
                json::string(&r.scenario),
                json::string(r.mechanism),
                json::string(r.policy),
                r.report.to_json()
            )
        })
        .collect();
    format!("{{\"os\":[\n{}\n]}}\n", body.join(",\n"))
}

// ---------------------------------------------------------------------------
// E10: subarray-level parallelism (SALP/MASA) composed with LISA —
// {copy mechanism} x {parallelism mode} x {frame placement policy}.
// ---------------------------------------------------------------------------

/// The copy-mechanism axis of E10: the channel baseline vs LISA-RISC
/// (the two ends of the movement spectrum the modes compose with).
pub const E10_MECHANISMS: [CopyMechanism; 2] =
    [CopyMechanism::MemcpyChannel, CopyMechanism::LisaRisc];

/// The E10 workload set: the three intra-bank-conflict mixes that make
/// the parallelism modes visible, plus the fork scenario so the
/// placement axis exercises the OS layer's subarray-aware allocator.
pub const E10_WORKLOADS: [&str; 4] = [
    "salp-pingpong4",
    "salp-shared-bank4",
    "salp-copy-conflict4",
    "os-fork",
];

/// One finished E10 grid point.
#[derive(Debug, Clone, PartialEq)]
pub struct SalpRow {
    pub workload: String,
    pub mechanism: &'static str,
    pub mode: &'static str,
    pub policy: &'static str,
    pub report: RunReport,
}

/// Configuration for one E10 point.
pub fn cfg_salp(
    requests: u64,
    mech: CopyMechanism,
    mode: SalpMode,
    policy: PlacementPolicy,
) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.requests_per_core = requests;
    cfg.copy_mechanism = mech;
    cfg.lisa.risc = mech == CopyMechanism::LisaRisc;
    cfg.dram.salp = mode;
    cfg.os.placement = policy;
    cfg
}

/// E10 driver: run every {workload x mechanism x mode x placement}
/// point through the parallel campaign runner (workload-major row
/// order, deterministic at any thread count).
pub fn e10_salp(
    requests: u64,
    mechanisms: &[CopyMechanism],
    modes: &[SalpMode],
    policies: &[PlacementPolicy],
    workloads: &[String],
    threads: usize,
) -> Result<Vec<SalpRow>> {
    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for workload in workloads {
        // One lookup per workload (the suite registry is rebuilt per
        // call); the grid axes don't change workload construction.
        let wl0 = mixes::workload_by_name(workload, &SimConfig::default())?;
        for &mech in mechanisms {
            for &mode in modes {
                for &policy in policies {
                    let cfg = cfg_salp(requests, mech, mode, policy);
                    let wl = wl0.clone();
                    labels.push((workload.clone(), mech.name(), mode.name(), policy.name()));
                    jobs.push(move || Simulation::new(cfg, wl).run());
                }
            }
        }
    }
    let reports = campaign::run_jobs(jobs, threads);
    Ok(labels
        .into_iter()
        .zip(reports)
        .map(|((workload, mechanism, mode, policy), report)| SalpRow {
            workload,
            mechanism,
            mode,
            policy,
            report,
        })
        .collect())
}

/// JSON document for an E10 run (`lisa salp --out report.json`).
pub fn salp_json(rows: &[SalpRow]) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"workload\":{},\"mechanism\":{},\"mode\":{},\"policy\":{},\"report\":{}}}",
                json::string(&r.workload),
                json::string(r.mechanism),
                json::string(r.mode),
                json::string(r.policy),
                r.report.to_json()
            )
        })
        .collect();
    format!("{{\"salp\":[\n{}\n]}}\n", body.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_matches_paper() {
        let rows = table1(&Calibration::default()).unwrap();
        let find = |label: &str| {
            rows.iter()
                .find(|r| r.label.starts_with(label))
                .unwrap_or_else(|| panic!("{label} missing"))
        };
        let memcpy = find("memcpy");
        let rc_inter = find("RC-InterSA");
        let rc_intra = find("RC-IntraSA");
        let lisa1 = find("LISA-RISC (1 hop)");
        let lisa15 = find("LISA-RISC (15 hops)");
        // Ordering (who wins).
        assert!(lisa15.latency_ns < rc_inter.latency_ns / 3.0);
        assert!(rc_intra.latency_ns < lisa1.latency_ns);
        assert!(memcpy.latency_ns > 1000.0);
        // Factors: LISA ~9x faster, ~20-50x less energy than RC-InterSA.
        assert!(rc_inter.latency_ns / lisa1.latency_ns > 6.0);
        assert!(rc_inter.energy_uj / lisa1.energy_uj > 20.0);
        // Energy within band of the paper's absolute numbers.
        assert!((memcpy.energy_uj - 6.2).abs() < 1.0);
    }

    #[test]
    fn config_builders_compose() {
        assert!(!cfg_baseline(100).lisa.risc);
        assert!(cfg_risc(100).lisa.risc);
        let rv = cfg_risc_villa(100);
        assert!(rv.lisa.villa && rv.lisa.risc && !rv.lisa.lip);
        let all = cfg_all(100);
        assert!(all.lisa.villa && all.lisa.risc && all.lisa.lip);
        let rc = cfg_villa_rc(100);
        assert!(rc.lisa.villa && !rc.lisa.risc);
    }

    #[test]
    fn area_report_under_one_percent() {
        let r = area_report(&SimConfig::default());
        assert!(r.total_fraction < 0.01);
    }

    #[test]
    fn e10_grid_shape_and_config() {
        let cfg = cfg_salp(
            100,
            CopyMechanism::LisaRisc,
            SalpMode::Masa,
            PlacementPolicy::Random,
        );
        assert!(cfg.lisa.risc);
        assert_eq!(cfg.dram.salp, SalpMode::Masa);
        assert_eq!(cfg.os.placement, PlacementPolicy::Random);
        let rows = e10_salp(
            120,
            &[CopyMechanism::LisaRisc],
            &[SalpMode::None, SalpMode::Masa],
            &[PlacementPolicy::SubarrayPacked],
            &["salp-pingpong4".to_string()],
            2,
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.workload == "salp-pingpong4"));
        assert_eq!(rows[0].mode, "none");
        assert_eq!(rows[1].mode, "masa");
        let j = salp_json(&rows);
        assert_eq!(j.matches("\"mode\"").count(), 2);
        assert!(j.contains("\"mode\":\"masa\""), "{j}");
        // Unknown workloads fail fast.
        assert!(e10_salp(
            100,
            &[CopyMechanism::LisaRisc],
            &[SalpMode::Masa],
            &[PlacementPolicy::Random],
            &["no-such-workload".to_string()],
            1
        )
        .is_err());
    }

    #[test]
    fn e10_grid_is_byte_identical_across_thread_counts() {
        // The acceptance bar for `lisa salp`: the full JSON document is
        // byte-identical at 1, 2 and 8 threads.
        let run = |threads: usize| {
            e10_salp(
                150,
                &[CopyMechanism::MemcpyChannel, CopyMechanism::LisaRisc],
                &[SalpMode::None, SalpMode::Masa],
                &[PlacementPolicy::SubarrayPacked],
                &["salp-shared-bank4".to_string()],
                threads,
            )
            .unwrap()
        };
        let serial = run(1);
        assert_eq!(serial.len(), 4);
        let json1 = salp_json(&serial);
        for threads in [2, 8] {
            let rows = run(threads);
            assert_eq!(serial, rows, "threads={threads}");
            assert_eq!(json1, salp_json(&rows), "threads={threads}");
        }
    }

    #[test]
    fn e9_grid_shape_and_config() {
        let cfg = cfg_os(100, CopyMechanism::LisaRisc, PlacementPolicy::Random);
        assert!(cfg.lisa.risc);
        assert_eq!(cfg.os.placement, PlacementPolicy::Random);
        let rows = e9_os(
            120,
            &[CopyMechanism::LisaRisc],
            &[PlacementPolicy::SubarrayPacked, PlacementPolicy::Random],
            &["os-fork".to_string()],
            2,
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.scenario == "os-fork"));
        assert!(rows.iter().all(|r| {
            let os = r.report.os.as_ref().expect("OS summary present");
            os.pages_copied > 0
        }));
        let j = os_json(&rows);
        assert_eq!(j.matches("\"scenario\"").count(), 2);
        assert!(j.contains("\"policy\":\"packed\""), "{j}");
        // Unknown scenarios fail fast.
        assert!(e9_os(
            100,
            &[CopyMechanism::LisaRisc],
            &[PlacementPolicy::Random],
            &["no-such-scenario".to_string()],
            1
        )
        .is_err());
    }
}
