//! The memory controller: request queues, FR-FCFS scheduling, write
//! drain, refresh management, in-DRAM copy sequencing (RowClone /
//! LISA-RISC), memcpy-over-channel expansion, and the LISA-VILLA hooks
//! (access counting, address redirection, cache-fill copies).

pub mod mapping;
pub mod queue;
pub mod request;

use std::cell::Cell;
use std::collections::VecDeque;

use anyhow::{Context, Result};

use crate::backend::{Access, AccessKind, MemoryModel, ReportParts};
use crate::config::{CopyMechanism, SimConfig};
use crate::copy::CopyOp;
use crate::dram::bank::{Bank, CommandStats, DramDevice};
use crate::dram::command::Command;
use crate::dram::geometry::Address;
use crate::dram::timing::Timing;
use crate::energy::EnergyModel;
use crate::lisa::lip::lip_coverage;
use crate::lisa::villa::VillaManager;
use crate::obs::{Attribution, Obs, ObsReport, Probe, TraceEvent, TraceKind};
use crate::util::stats::Histogram;
use mapping::{Mapper, MappingScheme};
use queue::{BankedQueue, QueueLoc};
use request::{Completion, CopyRequest, MemRequest};

/// Queue capacities (per channel), Ramulator-like defaults.
const READ_Q_CAP: usize = 32;
const WRITE_Q_CAP: usize = 32;
const DRAIN_HI: usize = 24;
const DRAIN_LO: usize = 8;

/// Per-channel copy window for the page-copy queue: OS bulk operations
/// may enqueue hundreds of page copies at once; at most this many are
/// released into a channel's copy queue (one feeding the sequencer,
/// one queued behind it) so demand traffic and VILLA's backpressure
/// signal keep seeing a short queue.
const PAGE_COPY_WINDOW: usize = 2;

/// Controller statistics.
#[derive(Debug, Clone, Default)]
pub struct CtrlStats {
    pub reads_done: u64,
    pub writes_done: u64,
    pub copies_done: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub sum_read_latency: u64,
    pub read_latency: Histogram,
    pub sum_copy_latency: u64,
    pub villa_copies: u64,
}

impl CtrlStats {
    pub fn avg_read_latency(&self) -> f64 {
        if self.reads_done == 0 {
            0.0
        } else {
            self.sum_read_latency as f64 / self.reads_done as f64
        }
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }
}

/// Completion-side events waiting for their cycle.
#[derive(Debug, Clone)]
enum Event {
    ReadDone(Completion),
    WriteDone { copy_id: Option<u64>, ch: usize },
    MemcpyReadDone { ch: usize, col: usize, row_idx: usize },
    CopyDone(Completion),
}

/// In-flight memcpy-over-channel copy (expanded into RD/WR traffic).
#[derive(Debug, Clone)]
struct MemcpyState {
    req: CopyRequest,
    row_idx: usize,
    reads_issued: usize,
    writes_done: usize,
}

/// Per-channel controller state.
#[derive(Debug)]
struct ChannelState {
    read_q: BankedQueue,
    write_q: BankedQueue,
    copy_q: VecDeque<CopyRequest>,
    active_copy: Option<CopyOp>,
    pending_cmd: Option<Command>,
    active_memcpy: Option<MemcpyState>,
    drain_mode: bool,
    /// Per-rank next refresh due times + pending flags.
    next_refresh: Vec<u64>,
    refresh_pending: Vec<bool>,
}

/// The memory controller.
pub struct Controller {
    pub cfg: SimConfig,
    pub dev: DramDevice,
    pub mapper: Mapper,
    pub villa: Option<VillaManager>,
    chans: Vec<ChannelState>,
    /// Page-granularity copies from the OS layer, released into the
    /// per-channel copy queues `PAGE_COPY_WINDOW` at a time.
    page_copy_q: VecDeque<CopyRequest>,
    inflight: Vec<(u64, Event)>,
    completions: Vec<Completion>,
    /// Cached per-channel horizon (`channel_horizon`), dropped on any
    /// mutation of the channel's controller or device state. `Cell`
    /// keeps `next_event_cycle` a `&self` query. Purely a cache: the
    /// per-cycle reference loop never consults it, and tests pin the
    /// cached value against a fresh recomputation at every probe.
    horizon: Vec<Cell<Option<u64>>>,
    pub stats: CtrlStats,
    pub now: u64,
    /// Observability sinks (tracing probe and/or latency attribution).
    /// `None` in normal runs: every emit site is a single branch on
    /// this `Option`, and no event is ever constructed when it is off.
    pub obs: Option<Box<Obs>>,
}

impl Controller {
    pub fn new(cfg: SimConfig) -> Self {
        let timing = Timing::new(cfg.dram.speed, &cfg.calibration);
        let dev = DramDevice::new(cfg.dram.clone(), cfg.lisa.clone(), timing.clone());
        let reserved = VillaManager::reserved_rows(&cfg);
        let mapper =
            Mapper::with_reserved(&cfg.dram, MappingScheme::RowRankBankColCh, reserved);
        let villa = if cfg.lisa.villa {
            // Fig. 3's comparison point: when RISC is off but VILLA is
            // on, fills use RowClone inter-subarray (slow movement).
            let mech = if cfg.lisa.risc {
                CopyMechanism::LisaRisc
            } else {
                CopyMechanism::RowCloneInterSa
            };
            Some(VillaManager::new(&cfg, mech))
        } else {
            None
        };
        let chans: Vec<ChannelState> = (0..cfg.dram.channels)
            .map(|_| ChannelState {
                read_q: BankedQueue::new(cfg.dram.ranks, cfg.dram.banks),
                write_q: BankedQueue::new(cfg.dram.ranks, cfg.dram.banks),
                copy_q: VecDeque::new(),
                active_copy: None,
                pending_cmd: None,
                active_memcpy: None,
                drain_mode: false,
                next_refresh: (0..cfg.dram.ranks)
                    .map(|r| timing.t_refi + (r as u64 * 64))
                    .collect(),
                refresh_pending: vec![false; cfg.dram.ranks],
            })
            .collect();
        let horizon = (0..chans.len()).map(|_| Cell::new(None)).collect();
        Self {
            cfg,
            dev,
            mapper,
            villa,
            chans,
            page_copy_q: VecDeque::new(),
            inflight: Vec::new(),
            completions: Vec::new(),
            horizon,
            stats: CtrlStats::default(),
            now: 0,
            obs: None,
        }
    }

    /// Turn on latency attribution: every demand RD/WR gets its wait
    /// window decomposed, aggregated into the report's `"obs"` block.
    pub fn enable_attribution(&mut self) {
        let d = &self.cfg.dram;
        let a = Attribution::new(d.channels, d.ranks, d.banks, d.subarrays_per_bank);
        self.obs_mut().attrib = Some(a);
    }

    /// Attach an external trace sink (e.g. a `SharedTraceRing`).
    pub fn set_probe(&mut self, probe: Box<dyn Probe>) {
        self.obs_mut().probe = Some(probe);
    }

    fn obs_mut(&mut self) -> &mut Obs {
        self.obs.get_or_insert_with(Box::default)
    }

    /// The aggregated attribution block, when `--obs` enabled it.
    pub fn obs_report(&self, cycles: u64) -> Option<ObsReport> {
        self.obs
            .as_ref()
            .and_then(|o| o.attrib.as_ref())
            .map(|a| a.finalize(cycles))
    }

    #[inline]
    fn observing(&self) -> bool {
        self.obs.is_some()
    }

    /// Fan one event out to the attached sinks. Callers gate on
    /// [`Self::observing`] so field gathering stays off the hot path.
    fn observe(&mut self, ev: TraceEvent) {
        if let Some(o) = self.obs.as_mut() {
            o.observe(&ev);
        }
    }

    /// Emit the trace event for an issued DRAM command, tagging it
    /// with the owning request/copy when the caller knows it.
    fn observe_cmd(
        &mut self,
        ch: usize,
        cmd: &Command,
        done: u64,
        copy: bool,
        id: i64,
        arrive: u64,
    ) {
        let mut ev = TraceEvent::from_command(
            ch,
            cmd,
            self.now,
            done,
            self.cfg.dram.rows_per_subarray,
        );
        ev.copy = ev.copy || copy;
        ev.id = id;
        ev.arrive = arrive;
        // lint: allow(probe-gating) reason=helper shared by gated call sites; observe() re-checks obs presence
        self.observe(ev);
    }

    /// Drop channel `ch`'s cached horizon: some state consulted by
    /// `channel_horizon` changed. Every mutation of `chans[ch]` or of
    /// the device's channel `ch` must be followed by this.
    #[inline]
    fn invalidate_horizon(&self, ch: usize) {
        self.horizon[ch].set(None);
    }

    /// Room for another read/write on `ch`?
    pub fn can_accept(&self, ch: usize, is_write: bool) -> bool {
        let c = &self.chans[ch];
        if is_write {
            c.write_q.len() < WRITE_Q_CAP
        } else {
            c.read_q.len() < READ_Q_CAP
        }
    }

    /// Enqueue a cache-line request by physical byte address. Returns
    /// false (rejecting the request) when the target queue is full.
    #[deprecated(note = "use the typed `enqueue(Access)` entry point (map() the address)")]
    pub fn enqueue_mem(&mut self, id: u64, core: usize, byte_addr: u64, is_write: bool) -> bool {
        let addr = self.mapper.map(byte_addr);
        let kind = if is_write { AccessKind::Write } else { AccessKind::Read };
        self.enqueue(Access { id, core, addr, kind })
    }

    /// Enqueue a pre-mapped request (VILLA translation still applies).
    #[deprecated(note = "use the typed `enqueue(Access)` entry point")]
    pub fn enqueue_mem_mapped(
        &mut self,
        id: u64,
        core: usize,
        addr: Address,
        is_write: bool,
    ) -> bool {
        let kind = if is_write { AccessKind::Write } else { AccessKind::Read };
        self.enqueue(Access { id, core, addr, kind })
    }

    /// Admit one demand access (the `MemoryModel` entry point that
    /// collapsed the `enqueue_mem` / `enqueue_mem_mapped` duo). VILLA
    /// translation applies to the pre-mapped address. Returns false
    /// (rejecting the request) when the target queue is full.
    // lint: mutates-channel-state
    pub fn enqueue(&mut self, access: Access) -> bool {
        let Access { id, core, mut addr, .. } = access;
        let is_write = access.is_write();
        if !self.can_accept(addr.channel, is_write) {
            return false;
        }
        if let Some(v) = self.villa.as_mut() {
            // Backpressure: only start new fills when the copy engine
            // on this channel is idle.
            let allow_fill = {
                let c = &self.chans[addr.channel];
                c.copy_q.is_empty() && c.active_copy.is_none() && c.active_memcpy.is_none()
            };
            let (redirected, copies) =
                v.on_access(&addr, is_write, self.now, core, allow_fill);
            addr = redirected;
            for c in copies {
                self.stats.villa_copies += 1;
                let cch = c.src.channel;
                if self.observing() {
                    self.observe(copy_enq_event(&c, self.now));
                }
                self.chans[cch].copy_q.push_back(c);
                self.invalidate_horizon(cch);
            }
        }
        let ch = addr.channel;
        let req = MemRequest {
            id,
            core,
            addr,
            is_write,
            arrive: self.now,
            done: None,
            copy_id: None,
        };
        if is_write {
            self.chans[ch].write_q.push_back(req);
        } else {
            self.chans[ch].read_q.push_back(req);
        }
        self.invalidate_horizon(ch);
        if self.observing() {
            let c = &self.chans[ch];
            let depth = if is_write { c.write_q.len() } else { c.read_q.len() };
            let mut ev = TraceEvent::new(TraceKind::Enq, self.now, ch, addr.rank);
            ev.bank = addr.bank as i64;
            ev.sa = addr.subarray(&self.cfg.dram) as i64;
            ev.row = addr.row as i64;
            ev.col = addr.col as i64;
            ev.id = id as i64;
            ev.arrive = self.now;
            ev.val = depth as i64;
            self.observe(ev);
        }
        true
    }

    /// Enqueue a bulk copy. The destination row is invalidated in the
    /// VILLA cache (its cached copy would go stale).
    // lint: mutates-channel-state
    pub fn enqueue_copy(&mut self, req: CopyRequest) {
        if let Some(v) = self.villa.as_mut() {
            for r in 0..req.rows {
                let mut a = req.dst;
                a.row += r;
                v.invalidate(&a);
            }
        }
        let ch = req.src.channel;
        if self.observing() {
            self.observe(copy_enq_event(&req, self.now));
        }
        self.chans[ch].copy_q.push_back(req);
        self.invalidate_horizon(ch);
    }

    /// Enqueue a page-granularity copy from the OS layer. Requests
    /// park in the page-copy queue and are released into the target
    /// channel's copy queue as the copy engine drains (so a bulk
    /// zero/checkpoint of hundreds of pages cannot swamp a channel).
    pub fn enqueue_page_copy(&mut self, req: CopyRequest) {
        self.page_copy_q.push_back(req);
    }

    /// Release parked page copies into their channels while the head's
    /// channel has room. Head-of-line order is preserved (completion
    /// order of a bulk op's pages is what the OS stall path expects).
    fn drain_page_copies(&mut self) {
        while let Some(req) = self.page_copy_q.front() {
            if self.copies_pending(req.src.channel) >= PAGE_COPY_WINDOW {
                break;
            }
            // lint: allow(panic) reason=front() returned Some above and nothing popped since
            let req = self.page_copy_q.pop_front().expect("head present");
            self.enqueue_copy(req);
        }
    }

    /// Take completed requests (reads and copies).
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Advance one DRAM cycle: deliver due events, then let every
    /// channel issue at most one command.
    // lint: mutates-channel-state
    pub fn tick(&mut self) -> Result<()> {
        let now = self.now;
        // Deliver due events. swap_remove keeps this O(n) per tick.
        let mut i = 0;
        let mut delivered = false;
        while i < self.inflight.len() {
            if self.inflight[i].0 <= now {
                let (_, ev) = self.inflight.swap_remove(i);
                self.handle_event(ev)?;
                delivered = true;
            } else {
                i += 1;
            }
        }
        if delivered {
            // Event delivery can mutate any channel's queues / copy
            // state; events are rare relative to ticks, so a blanket
            // drop is cheaper than tracking the channels touched.
            for h in &self.horizon {
                h.set(None);
            }
        }
        if let Some(v) = self.villa.as_mut() {
            v.tick(now);
        }
        self.drain_page_copies();
        for ch in 0..self.chans.len() {
            self.tick_channel(ch)?;
        }
        self.now += 1;
        Ok(())
    }

    fn handle_event(&mut self, ev: Event) -> Result<()> {
        match ev {
            Event::ReadDone(c) => {
                // Latency stats were recorded at issue time.
                self.completions.push(c);
            }
            Event::WriteDone { copy_id, ch } => {
                self.stats.writes_done += 1;
                if let Some(id) = copy_id {
                    self.memcpy_write_done(ch, id)?;
                }
            }
            Event::MemcpyReadDone { ch, col, row_idx } => {
                // The CPU turns the line around and writes it to dst.
                let (dst, copy_id) = {
                    let m = self.chans[ch]
                        .active_memcpy
                        .as_ref()
                        .context("memcpy read completed with no live memcpy")?;
                    let mut d = m.req.dst;
                    d.row += row_idx;
                    d.col = col;
                    (d, m.req.id)
                };
                let req = MemRequest {
                    id: copy_id,
                    core: 0,
                    addr: dst,
                    is_write: true,
                    arrive: self.now,
                    done: None,
                    copy_id: Some(copy_id),
                };
                self.chans[ch].write_q.push_back(req);
            }
            Event::CopyDone(c) => {
                self.finish_copy(c);
            }
        }
        Ok(())
    }

    fn finish_copy(&mut self, c: Completion) {
        self.stats.copies_done += 1;
        if let Some(v) = self.villa.as_mut() {
            if v.owns_copy(c.id) {
                v.on_copy_done(c.id);
                return; // villa-internal; no core completion
            }
        }
        self.completions.push(c);
    }

    fn memcpy_write_done(&mut self, ch: usize, copy_id: u64) -> Result<()> {
        let finished = {
            let Some(m) = self.chans[ch].active_memcpy.as_mut() else {
                return Ok(());
            };
            if m.req.id != copy_id {
                return Ok(());
            }
            m.writes_done += 1;
            if m.writes_done == self.cfg.dram.columns {
                // Row complete: move the content tag.
                let (src, dst) = {
                    let mut s = m.req.src;
                    s.row += m.row_idx;
                    let mut d = m.req.dst;
                    d.row += m.row_idx;
                    (s, d)
                };
                let tag = self.dev.row_tag(src.channel, src.rank, src.bank, src.row);
                self.dev.set_row_tag(dst.channel, dst.rank, dst.bank, dst.row, tag);
                // lint: allow(panic) reason=checked Some at fn entry and not mutated since
                let m = self.chans[ch].active_memcpy.as_mut().unwrap();
                m.row_idx += 1;
                m.writes_done = 0;
                m.reads_issued = 0;
                m.row_idx >= m.req.rows
            } else {
                false
            }
        };
        if finished {
            // lint: allow(panic) reason=finished implies the memcpy was live this tick
            let m = self.chans[ch].active_memcpy.take().unwrap();
            self.stats
                .sum_copy_latency
                .checked_add(self.now - m.req.arrive)
                .map(|v| self.stats.sum_copy_latency = v);
            if self.observing() {
                let mut ev =
                    TraceEvent::new(TraceKind::CopyDone, self.now, ch, m.req.src.rank);
                ev.bank = m.req.src.bank as i64;
                ev.id = m.req.id as i64;
                ev.arrive = m.req.arrive;
                ev.copy = true;
                self.observe(ev);
            }
            self.finish_copy(Completion {
                id: m.req.id,
                core: m.req.core,
                at: self.now,
                was_copy: true,
            });
        }
        Ok(())
    }

    /// Issue at most one command on channel `ch` this cycle.
    // lint: mutates-channel-state
    fn tick_channel(&mut self, ch: usize) -> Result<()> {
        let now = self.now;

        // 1. Refresh has absolute priority once due.
        for rank in 0..self.cfg.dram.ranks {
            if now >= self.chans[ch].next_refresh[rank]
                && !self.chans[ch].refresh_pending[rank]
            {
                self.chans[ch].refresh_pending[rank] = true;
                self.invalidate_horizon(ch);
                if self.observing() {
                    self.observe(TraceEvent::new(TraceKind::RefPend, now, ch, rank));
                }
            }
            if self.chans[ch].refresh_pending[rank] {
                let cmd = Command::Ref { rank };
                if let Ok(e) = self.dev.earliest(ch, cmd, now) {
                    if e <= now {
                        let issued = self.dev.issue(ch, cmd, now)?;
                        self.chans[ch].refresh_pending[rank] = false;
                        self.chans[ch].next_refresh[rank] += self.dev.timing.t_refi;
                        self.invalidate_horizon(ch);
                        if self.observing() {
                            self.observe_cmd(ch, &cmd, issued.done_at, false, -1, 0);
                        }
                        return Ok(());
                    }
                } else {
                    // Some bank open: close banks first.
                    for bank in 0..self.cfg.dram.banks {
                        if !self.dev.bank(ch, rank, bank).all_precharged() {
                            let pre = Command::Pre { rank, bank };
                            if let Ok(e) = self.dev.earliest(ch, pre, now) {
                                if e <= now {
                                    let issued = self.dev.issue(ch, pre, now)?;
                                    self.invalidate_horizon(ch);
                                    if self.observing() {
                                        self.observe_cmd(
                                            ch,
                                            &pre,
                                            issued.done_at,
                                            false,
                                            -1,
                                            0,
                                        );
                                    }
                                    return Ok(());
                                }
                            }
                        }
                    }
                }
                // Refresh pending but cannot progress: stall new ACTs
                // on this rank by simply not scheduling ACTs below.
            }
        }

        // 2. Copy engine. Pause it entirely while a refresh is pending
        // on its rank: the copy sequence keeps re-opening banks, which
        // would otherwise livelock against refresh's all-banks-
        // precharged requirement (REF then never issues and demand
        // traffic starves behind the pending refresh).
        let copy_paused = {
            let c = &self.chans[ch];
            let rank_of = |r: usize| c.refresh_pending.get(r).copied().unwrap_or(false);
            c.active_copy.as_ref().map(|op| rank_of(op.req.src.rank)).unwrap_or(false)
                || c.pending_cmd.map(|cmd| rank_of(cmd.rank())).unwrap_or(false)
        };
        self.activate_next_copy(ch);
        if !copy_paused && self.chans[ch].pending_cmd.is_none() {
            if let Some(mut op) = self.chans[ch].active_copy.take() {
                match op.next_command(&self.dev) {
                    Some(cmd) => {
                        self.chans[ch].pending_cmd = Some(cmd);
                        self.chans[ch].active_copy = Some(op);
                    }
                    None => {
                        // Sequence complete; completion at last step end.
                        let done_at = op.last_done.max(now);
                        self.stats.sum_copy_latency += done_at - op.req.arrive;
                        if self.observing() {
                            let rank = op.req.src.rank;
                            let id = op.req.id as i64;
                            for b in op.banks(&self.cfg.dram).into_iter().flatten() {
                                let mut ev =
                                    TraceEvent::new(TraceKind::CopyRelease, now, ch, rank);
                                ev.bank = b as i64;
                                ev.id = id;
                                ev.copy = true;
                                self.observe(ev);
                            }
                            let mut ev = TraceEvent::new(TraceKind::CopyDone, now, ch, rank);
                            ev.done = done_at;
                            ev.bank = op.req.src.bank as i64;
                            ev.id = id;
                            ev.arrive = op.req.arrive;
                            ev.copy = true;
                            self.observe(ev);
                        }
                        self.inflight.push((
                            done_at,
                            Event::CopyDone(Completion {
                                id: op.req.id,
                                core: op.req.core,
                                at: done_at,
                                was_copy: true,
                            }),
                        ));
                    }
                }
                // Both arms mutate the copy engine state (sequence
                // advanced + command staged, or the op retired).
                self.invalidate_horizon(ch);
            }
        }
        if copy_paused {
            // Let the refresh machinery close the copy's banks; the
            // copy's (idempotent) row sequence restarts afterwards.
            self.generate_memcpy_reads(ch);
            return self.schedule_requests(ch);
        }
        if let Some(cmd) = self.chans[ch].pending_cmd {
            match self.dev.earliest(ch, cmd, now) {
                Ok(e) if e <= now => {
                    let issued = self.dev.issue(ch, cmd, now)?;
                    if let Some(op) = self.chans[ch].active_copy.as_mut() {
                        op.on_issued(issued.done_at);
                    }
                    self.chans[ch].pending_cmd = None;
                    self.invalidate_horizon(ch);
                    if self.observing() {
                        let (id, arrive) = self.chans[ch]
                            .active_copy
                            .as_ref()
                            .map(|op| (op.req.id as i64, op.req.arrive))
                            .unwrap_or((-1, 0));
                        self.observe_cmd(ch, &cmd, issued.done_at, true, id, arrive);
                    }
                    return Ok(());
                }
                Ok(_) => {}
                Err(_) => {
                    // Structurally blocked. Two causes:
                    // (a) normal traffic re-opened the bank after the
                    //     copy's precharge phase -> close it;
                    // (b) a refresh-forced precharge wiped the latched
                    //     state a later step depended on -> restart the
                    //     row's (idempotent) sequence.
                    let mut recovered = false;
                    if let Some(bank) = cmd.bank() {
                        let rank = cmd.rank();
                        if !self.dev.bank(ch, rank, bank).all_precharged() {
                            recovered = true;
                            let pre = Command::Pre { rank, bank };
                            if let Ok(e) = self.dev.earliest(ch, pre, now) {
                                if e <= now {
                                    let issued = self.dev.issue(ch, pre, now)?;
                                    self.invalidate_horizon(ch);
                                    if self.observing() {
                                        self.observe_cmd(
                                            ch,
                                            &pre,
                                            issued.done_at,
                                            true,
                                            -1,
                                            0,
                                        );
                                    }
                                    return Ok(());
                                }
                            }
                        }
                    }
                    if !recovered {
                        if let Some(op) = self.chans[ch].active_copy.as_mut() {
                            op.restart_row();
                        }
                        self.chans[ch].pending_cmd = None;
                        self.invalidate_horizon(ch);
                    }
                }
            }
            // Copy command not ready; fall through so other banks can
            // still be served (LISA keeps the channel free!).
        }

        // 3. Memcpy read generation (reads go through the normal queue).
        self.generate_memcpy_reads(ch);

        // 4. Normal FR-FCFS scheduling.
        self.schedule_requests(ch)
    }

    // lint: mutates-channel-state
    fn activate_next_copy(&mut self, ch: usize) {
        let c = &mut self.chans[ch];
        if c.active_copy.is_some() || c.active_memcpy.is_some() {
            return;
        }
        let Some(req) = c.copy_q.pop_front() else {
            return;
        };
        let start = (req.id, req.src, req.arrive, req.rows);
        if req.mechanism == CopyMechanism::MemcpyChannel {
            c.active_memcpy = Some(MemcpyState {
                req,
                row_idx: 0,
                reads_issued: 0,
                writes_done: 0,
            });
        } else {
            c.active_copy = Some(CopyOp::new(req, &self.cfg.dram));
        }
        self.invalidate_horizon(ch);
        if self.observing() {
            let (id, src, arrive, rows) = start;
            let banks = self.chans[ch]
                .active_copy
                .as_ref()
                .map(|op| op.banks(&self.cfg.dram));
            let mut ev = TraceEvent::new(TraceKind::CopyStart, self.now, ch, src.rank);
            ev.bank = src.bank as i64;
            ev.row = src.row as i64;
            ev.id = id as i64;
            ev.arrive = arrive;
            ev.val = rows as i64;
            ev.copy = true;
            self.observe(ev);
            // A CopyOp owns its banks for the whole sequence (the
            // scheduler parks row preparation there); a memcpy uses
            // the normal queues and owns nothing.
            for b in banks.into_iter().flatten().flatten() {
                let mut ev = TraceEvent::new(TraceKind::CopyOwn, self.now, ch, src.rank);
                ev.bank = b as i64;
                ev.id = id as i64;
                ev.copy = true;
                self.observe(ev);
            }
        }
    }

    // lint: mutates-channel-state
    fn generate_memcpy_reads(&mut self, ch: usize) {
        let cols = self.cfg.dram.columns;
        let c = &mut self.chans[ch];
        let Some(m) = c.active_memcpy.as_mut() else {
            return;
        };
        let mut pushed = false;
        while m.reads_issued < cols && c.read_q.len() < READ_Q_CAP {
            let mut a = m.req.src;
            a.row += m.row_idx;
            a.col = m.reads_issued;
            c.read_q.push_back(MemRequest {
                id: m.req.id,
                core: m.req.core,
                addr: a,
                is_write: false,
                arrive: self.now,
                done: None,
                copy_id: Some(m.req.id),
            });
            m.reads_issued += 1;
            pushed = true;
        }
        if pushed {
            self.invalidate_horizon(ch);
        }
    }

    /// FR-FCFS: row hits first, then oldest-first; writes drain in
    /// batches between read bursts.
    fn schedule_requests(&mut self, ch: usize) -> Result<()> {
        let now = self.now;
        // Hysteretic write drain.
        {
            let c = &mut self.chans[ch];
            if c.write_q.len() >= DRAIN_HI {
                c.drain_mode = true;
            }
            if c.write_q.len() <= DRAIN_LO {
                c.drain_mode = false;
            }
            if c.read_q.is_empty() && !c.write_q.is_empty() {
                c.drain_mode = true;
            }
        }
        let serve_writes = self.chans[ch].drain_mode;

        if let Some((loc, cmd)) = self.pick_request(ch, serve_writes, now) {
            self.issue_for_request(ch, serve_writes, loc, cmd)?;
            return Ok(());
        }
        // Nothing issuable in the preferred queue: try the other one.
        if let Some((loc, cmd)) = self.pick_request(ch, !serve_writes, now) {
            self.issue_for_request(ch, !serve_writes, loc, cmd)?;
        }
        Ok(())
    }

    /// Find the oldest schedulable (queue location, command) pair
    /// under FR-FCFS: pass 1 row hits, pass 2 oldest-first
    /// preparation. Under SALP modes pass 1 sees the open row of the
    /// *request's own subarray* (so hits in distinct subarrays of one
    /// bank coexist) and pass 2 prepares rows per subarray via
    /// `prep_command`.
    ///
    /// Both passes walk the per-(rank, bank) buckets instead of the
    /// flat queue: bank-level rejects (busy bank, refresh-parked rank,
    /// copy-owned bank) skip whole buckets, and a bucket stops being
    /// scanned as soon as a candidate older than its remaining entries
    /// is in hand. Selection is identical to the flat oldest-first
    /// scan: the winner is the ready candidate with the minimum
    /// arrival `seq` over all buckets.
    fn pick_request(&self, ch: usize, writes: bool, now: u64) -> Option<(QueueLoc, Command)> {
        let c = &self.chans[ch];
        let q: &BankedQueue = if writes { &c.write_q } else { &c.read_q };
        if q.is_empty() {
            return None;
        }
        // Cheap per-pass gating (hot path): the channel data bus is a
        // global constraint — if it is not ready, no RD/WR can issue
        // this cycle and pass 1 can be skipped entirely.
        let chan_dev = &self.dev.channels[ch];
        let bus_ready_rd = chan_dev.next_rd <= now;
        let bus_ready_wr = chan_dev.next_wr <= now;

        // Pass 1: the oldest row hit ready to go.
        if bus_ready_rd || bus_ready_wr {
            let mut best: Option<(u64, QueueLoc, Command)> = None;
            for (bucket, rank, bank_i, entries) in q.banks_with_work() {
                let bank = self.dev.bank(ch, rank, bank_i);
                // Fast reject for the whole bucket.
                if bank.busy_until > now {
                    continue;
                }
                for (pos, e) in entries.iter().enumerate() {
                    // Bucket entries are seq-ascending: nothing below
                    // can beat an older candidate already in hand.
                    if best.as_ref().is_some_and(|(s, ..)| *s < e.seq) {
                        break;
                    }
                    let a = &e.req.addr;
                    let sa = a.subarray(&self.cfg.dram);
                    if bank.subarrays[sa].next_rdwr > now {
                        continue;
                    }
                    let w = writes || e.req.is_write;
                    if (w && !bus_ready_wr) || (!w && !bus_ready_rd) {
                        continue;
                    }
                    if bank.subarrays[sa].open_row() == Some(a.row) {
                        let cmd = if w {
                            Command::Wr { rank: a.rank, bank: a.bank, sa, col: a.col }
                        } else {
                            Command::Rd { rank: a.rank, bank: a.bank, sa, col: a.col }
                        };
                        if let Ok(e_cyc) = self.dev.earliest(ch, cmd, now) {
                            if e_cyc <= now {
                                best = Some((e.seq, QueueLoc { bucket, pos }, cmd));
                                break;
                            }
                        }
                    }
                }
            }
            if let Some((_, loc, cmd)) = best {
                return Some((loc, cmd));
            }
        }
        // Banks owned by the active copy: don't open new rows there,
        // or the copy never makes progress (livelock). Other banks
        // keep serving — LISA's bank-level parallelism is preserved.
        let copy_rank = c.active_copy.as_ref().map(|op| op.req.src.rank);
        let copy_banks: [Option<usize>; 3] = c
            .active_copy
            .as_ref()
            .map(|op| op.banks(&self.cfg.dram))
            .unwrap_or([None; 3]);
        // Pass 2: oldest-first, prepare the row (PRE / PRE_SA or ACT).
        let mut best: Option<(u64, QueueLoc, Command)> = None;
        for (bucket, rank, bank_i, entries) in q.banks_with_work() {
            // Don't prepare rows for ranks with refresh pending, nor
            // for banks the active copy owns; a busy bank can take
            // neither ACT nor PRE. All three park the whole bucket.
            if c.refresh_pending[rank] {
                continue;
            }
            if copy_rank == Some(rank) && copy_banks.contains(&Some(bank_i)) {
                continue;
            }
            let bank = self.dev.bank(ch, rank, bank_i);
            if bank.busy_until > now {
                continue;
            }
            for (pos, e) in entries.iter().enumerate() {
                if best.as_ref().is_some_and(|(s, ..)| *s < e.seq) {
                    break;
                }
                let a = &e.req.addr;
                let sa = a.subarray(&self.cfg.dram);
                if bank.subarrays[sa].open_row() == Some(a.row) {
                    continue; // hit not ready yet (bus or tRCD); keep order
                }
                let cmd = self.prep_command(bank, a, sa);
                // Cheap per-command register gates before the full check.
                let ready = match cmd {
                    Command::Act { .. } => {
                        bank.next_act <= now && bank.subarrays[sa].next_act <= now
                    }
                    Command::Pre { .. } => bank.next_pre <= now,
                    Command::PreSa { sa: victim, .. } => {
                        bank.subarrays[victim].next_pre <= now
                    }
                    _ => true,
                };
                if !ready {
                    continue;
                }
                if let Ok(e_cyc) = self.dev.earliest(ch, cmd, now) {
                    if e_cyc <= now {
                        best = Some((e.seq, QueueLoc { bucket, pos }, cmd));
                        break;
                    }
                }
            }
        }
        best.map(|(_, loc, cmd)| (loc, cmd))
    }

    /// The row-preparation command pass 2 (oldest-first) would issue
    /// for a request to `a` under the current bank state. The baseline
    /// closes/opens whole banks; the SALP modes operate per subarray —
    /// precharge the target subarray on a row conflict, activate while
    /// under the mode's open-subarray cap, and otherwise evict the
    /// lowest-indexed non-precharged subarray (a deterministic victim,
    /// never `sa` itself, which is precharged in that branch). Shared
    /// by the scheduler and the fast-forward horizon so both always
    /// agree on the candidate command.
    fn prep_command(&self, bank: &Bank, a: &Address, sa: usize) -> Command {
        let mode = self.cfg.dram.salp;
        if !mode.per_subarray() {
            return if bank.all_precharged() {
                Command::Act { rank: a.rank, bank: a.bank, row: a.row }
            } else {
                Command::Pre { rank: a.rank, bank: a.bank }
            };
        }
        if !bank.subarrays[sa].is_precharged() {
            Command::PreSa { rank: a.rank, bank: a.bank, sa }
        } else if bank.open_count() < mode.open_cap(bank.subarrays.len()) {
            Command::Act { rank: a.rank, bank: a.bank, row: a.row }
        } else {
            let victim = bank
                .subarrays
                .iter()
                .position(|s| !s.is_precharged())
                // lint: allow(panic) reason=open_subarrays() == cap implies one is open
                .expect("bank at cap has a non-precharged subarray");
            Command::PreSa { rank: a.rank, bank: a.bank, sa: victim }
        }
    }

    // lint: mutates-channel-state
    fn issue_for_request(
        &mut self,
        ch: usize,
        writes: bool,
        loc: QueueLoc,
        cmd: Command,
    ) -> Result<()> {
        let now = self.now;
        let issued = self.dev.issue(ch, cmd, now)?;
        match cmd {
            Command::Rd { .. } => {
                self.stats.row_hits += 1;
                let req = self.chans[ch]
                    .read_q
                    .remove(loc)
                    .context("issued Rd for a read no longer at its queue slot")?;
                let lat = issued.done_at - req.arrive;
                if let Some(copy_id) = req.copy_id {
                    let m = self.chans[ch]
                        .active_memcpy
                        .as_ref()
                        .context("memcpy read issued with no live memcpy")?;
                    let _ = copy_id;
                    self.inflight.push((
                        issued.done_at,
                        Event::MemcpyReadDone {
                            ch,
                            col: req.addr.col,
                            row_idx: m.row_idx,
                        },
                    ));
                } else {
                    self.stats.sum_read_latency += lat;
                    self.stats.read_latency.add(lat);
                    self.stats.reads_done += 1;
                    self.inflight.push((
                        issued.done_at,
                        Event::ReadDone(Completion {
                            id: req.id,
                            core: req.core,
                            at: issued.done_at,
                            was_copy: false,
                        }),
                    ));
                }
                if self.observing() {
                    self.observe_cmd(
                        ch,
                        &cmd,
                        issued.done_at,
                        req.copy_id.is_some(),
                        req.id as i64,
                        req.arrive,
                    );
                }
            }
            Command::Wr { .. } => {
                self.stats.row_hits += 1;
                let q = if writes {
                    &mut self.chans[ch].write_q
                } else {
                    &mut self.chans[ch].read_q
                };
                let req = q
                    .remove(loc)
                    .context("issued Wr for a write no longer at its queue slot")?;
                debug_assert!(req.is_write);
                self.inflight.push((
                    issued.done_at,
                    Event::WriteDone { copy_id: req.copy_id, ch },
                ));
                if self.observing() {
                    self.observe_cmd(
                        ch,
                        &cmd,
                        issued.done_at,
                        req.copy_id.is_some(),
                        req.id as i64,
                        req.arrive,
                    );
                }
            }
            Command::Act { .. } | Command::Pre { .. } | Command::PreSa { .. } => {
                self.stats.row_misses += 1;
                if self.observing() {
                    self.observe_cmd(ch, &cmd, issued.done_at, false, -1, 0);
                }
            }
            _ => {}
        }
        self.invalidate_horizon(ch);
        Ok(())
    }

    /// Advance the controller clock across a provably idle gap (the
    /// fast-forward engine established — via `next_event_cycle` — that
    /// no event, state transition or command issue can happen in it).
    pub fn fast_forward(&mut self, cycles: u64) {
        self.now += cycles;
    }

    /// Earliest cycle >= `self.now` at which this controller could do
    /// *anything* — deliver an in-flight event, cross a refresh or
    /// VILLA epoch deadline, advance a copy sequence, or issue any
    /// command for the currently queued requests — assuming no new
    /// requests arrive in the meantime.
    ///
    /// This is a cycle-exact **lower bound**: the per-cycle reference
    /// loop performs no state change at any cycle strictly before the
    /// returned one, so the engine may jump `now` straight to it.
    /// Returning `self.now` means "possibly active right now; do not
    /// skip". `u64::MAX` means nothing will ever happen again.
    ///
    /// The per-channel component is cached (`self.horizon`) and only
    /// recomputed after a mutation of that channel's state; the cheap
    /// global terms (in-flight events, the VILLA epoch boundary, the
    /// parked page-copy head) are evaluated fresh on every call.
    pub fn next_event_cycle(&self) -> u64 {
        self.next_event_cycle_inner(true)
    }

    /// `next_event_cycle` with the per-channel horizon cache bypassed
    /// (neither consulted nor filled). The two must agree at every
    /// cycle; the lower-bound property test pins them against each
    /// other so a stale cache is a loud failure, not a silent slowdown
    /// (or worse, a skipped event).
    pub fn next_event_cycle_uncached(&self) -> u64 {
        self.next_event_cycle_inner(false)
    }

    fn next_event_cycle_inner(&self, use_cache: bool) -> u64 {
        let now = self.now;
        let mut h = u64::MAX;
        for (t, _) in &self.inflight {
            h = h.min((*t).max(now));
        }
        if let Some(v) = self.villa.as_ref() {
            // Epoch maintenance re-arms relative to the observed cycle;
            // jumping past the boundary would shift every later epoch.
            h = h.min(v.next_epoch_cycle().max(now));
        }
        if h <= now {
            return now;
        }
        // A releasable parked page copy mutates state on the next tick
        // (`drain_page_copies`); a blocked head stays blocked until a
        // copy completes, which is itself a horizon event.
        if let Some(req) = self.page_copy_q.front() {
            if self.copies_pending(req.src.channel) < PAGE_COPY_WINDOW {
                return now;
            }
        }
        for ch in 0..self.chans.len() {
            let hc = if use_cache {
                match self.horizon[ch].get() {
                    Some(v) => v,
                    None => {
                        let v = self.channel_horizon(ch);
                        self.horizon[ch].set(Some(v));
                        v
                    }
                }
            } else {
                self.channel_horizon(ch)
            };
            // Every term of `channel_horizon` is `max(now, f(state))`
            // for a pure `f` of the channel's frozen state, so a
            // cached value computed at an earlier `now` stays exact
            // under the clamp below until the state mutates (which
            // drops the cache).
            h = h.min(hc.max(now));
            if h <= now {
                return now;
            }
        }
        h
    }

    /// The per-channel horizon component: refresh deadlines and
    /// pending-refresh progress, the copy engine, memcpy read
    /// generation, and every FR-FCFS candidate — against the channel's
    /// current (frozen) controller + device state.
    fn channel_horizon(&self, ch: usize) -> u64 {
        let now = self.now;
        let c = &self.chans[ch];
        let mut h = u64::MAX;
        // Refresh deadlines and pending-refresh progress.
        for rank in 0..self.cfg.dram.ranks {
            if c.refresh_pending[rank] {
                match self.dev.earliest(ch, Command::Ref { rank }, now) {
                    Ok(e) => h = h.min(e),
                    Err(_) => {
                        // REF blocked on open banks: the tick loop
                        // closes them one PRE at a time.
                        for bank in 0..self.cfg.dram.banks {
                            if !self.dev.bank(ch, rank, bank).all_precharged() {
                                let pre = Command::Pre { rank, bank };
                                if let Ok(e) = self.dev.earliest(ch, pre, now) {
                                    h = h.min(e);
                                }
                            }
                        }
                    }
                }
            } else {
                h = h.min(c.next_refresh[rank].max(now));
            }
        }
        // Copy engine: activation and sequence advancement mutate
        // state on the very next tick — never skip across them.
        if c.active_copy.is_none() && c.active_memcpy.is_none() && !c.copy_q.is_empty() {
            return now;
        }
        if let Some(cmd) = c.pending_cmd {
            match self.dev.earliest(ch, cmd, now) {
                Ok(e) => h = h.min(e),
                // Structurally blocked: the tick loop's recovery
                // path (close bank / restart row) mutates state.
                Err(_) => return now,
            }
        } else if c.active_copy.is_some() {
            return now; // next_command() advances the sequence
        }
        if let Some(m) = c.active_memcpy.as_ref() {
            if m.reads_issued < self.cfg.dram.columns && c.read_q.len() < READ_Q_CAP {
                return now; // read generation runs this tick
            }
        }
        // FR-FCFS candidates: per-bank earliest() for every queued
        // request (both queues are consulted every tick regardless
        // of drain mode, so both bound the horizon).
        let copy_rank = c.active_copy.as_ref().map(|op| op.req.src.rank);
        let copy_banks: [Option<usize>; 3] = c
            .active_copy
            .as_ref()
            .map(|op| op.banks(&self.cfg.dram))
            .unwrap_or([None; 3]);
        for req in c.read_q.iter().chain(c.write_q.iter()) {
            h = h.min(self.request_ready_cycle(ch, c, req, copy_rank, &copy_banks, now));
            if h <= now {
                return now;
            }
        }
        h
    }

    /// Earliest cycle the scheduler could legally serve `req`,
    /// mirroring `pick_request`'s command selection against the
    /// current (frozen) bank state — including pass 2's exclusions:
    /// row preparation (ACT/PRE) is parked for ranks with a refresh
    /// pending and for banks owned by the active copy. Those parked
    /// requests stay parked until a refresh / copy state change, which
    /// is itself a horizon event, so they never bound the horizon.
    fn request_ready_cycle(
        &self,
        ch: usize,
        c: &ChannelState,
        req: &MemRequest,
        copy_rank: Option<usize>,
        copy_banks: &[Option<usize>; 3],
        now: u64,
    ) -> u64 {
        let a = &req.addr;
        let bank = self.dev.bank(ch, a.rank, a.bank);
        let sa = a.subarray(&self.cfg.dram);
        let cmd = if bank.subarrays[sa].open_row() == Some(a.row) {
            // Pass 1 (row hits) has no rank/bank exclusions.
            if req.is_write {
                Command::Wr { rank: a.rank, bank: a.bank, sa, col: a.col }
            } else {
                Command::Rd { rank: a.rank, bank: a.bank, sa, col: a.col }
            }
        } else if c.refresh_pending[a.rank]
            || (copy_rank == Some(a.rank) && copy_banks.contains(&Some(a.bank)))
        {
            return u64::MAX;
        } else {
            self.prep_command(bank, a, sa)
        };
        // A structural Err is stable until some other command issues
        // (which is itself a horizon event), so it never bounds h.
        self.dev.earliest(ch, cmd, now).unwrap_or(u64::MAX)
    }

    /// All queues empty and nothing in flight?
    pub fn idle(&self) -> bool {
        self.inflight.is_empty()
            && self.page_copy_q.is_empty()
            && self.chans.iter().all(|c| {
                c.read_q.is_empty()
                    && c.write_q.is_empty()
                    && c.copy_q.is_empty()
                    && c.active_copy.is_none()
                    && c.active_memcpy.is_none()
                    && c.pending_cmd.is_none()
            })
    }

    /// Total queued + inflight copies (for backpressure decisions).
    pub fn copies_pending(&self, ch: usize) -> usize {
        let c = &self.chans[ch];
        c.copy_q.len()
            + c.active_copy.is_some() as usize
            + c.active_memcpy.is_some() as usize
    }

    /// Everything `Simulation::report` needs from the memory side (the
    /// `MemoryModel` report hook; the engine no longer reaches into
    /// `stats` / `dev` / `villa` directly).
    pub fn report_parts(&self, cycles: u64) -> ReportParts {
        let energy_model = EnergyModel::from_calibration(&self.cfg.calibration);
        let tck = self.dev.timing.tck_ns;
        ReportParts {
            reads: self.stats.reads_done,
            writes: self.stats.writes_done,
            copies: self.stats.copies_done,
            avg_read_latency_cycles: self.stats.avg_read_latency(),
            row_hit_rate: self.stats.row_hit_rate(),
            villa_hit_rate: self
                .villa
                .as_ref()
                .map(|v| v.stats.hit_rate())
                .unwrap_or(0.0),
            lip_coverage: lip_coverage(&self.dev.stats),
            energy: energy_model.breakdown_uj(&self.dev.stats, cycles, tck),
            obs: self.obs_report(cycles),
        }
    }
}

/// The cycle-exact controller is the ground-truth `MemoryModel`
/// implementation. Pure delegation to the inherent methods — behavior
/// through the trait is bit-identical to direct calls.
impl MemoryModel for Controller {
    fn cfg(&self) -> &SimConfig {
        &self.cfg
    }

    fn now(&self) -> u64 {
        self.now
    }

    fn tck_ns(&self) -> f64 {
        self.dev.timing.tck_ns
    }

    fn map(&self, byte_addr: u64) -> Address {
        self.mapper.map(byte_addr)
    }

    fn can_accept(&self, ch: usize, is_write: bool) -> bool {
        Controller::can_accept(self, ch, is_write)
    }

    fn enqueue(&mut self, access: Access) -> bool {
        Controller::enqueue(self, access)
    }

    fn enqueue_copy(&mut self, req: CopyRequest) {
        Controller::enqueue_copy(self, req)
    }

    fn enqueue_page_copy(&mut self, req: CopyRequest) {
        Controller::enqueue_page_copy(self, req)
    }

    fn tick(&mut self) -> Result<()> {
        Controller::tick(self)
    }

    fn fast_forward(&mut self, cycles: u64) {
        Controller::fast_forward(self, cycles)
    }

    fn next_event_cycle(&self) -> u64 {
        Controller::next_event_cycle(self)
    }

    fn drain_completions(&mut self) -> Vec<Completion> {
        Controller::drain_completions(self)
    }

    fn idle(&self) -> bool {
        Controller::idle(self)
    }

    fn command_stats(&self) -> &CommandStats {
        &self.dev.stats
    }

    fn report_parts(&self, cycles: u64) -> ReportParts {
        Controller::report_parts(self, cycles)
    }

    fn enable_attribution(&mut self) {
        Controller::enable_attribution(self)
    }

    fn set_probe(&mut self, probe: Box<dyn Probe>) {
        Controller::set_probe(self, probe)
    }

    fn obs_report(&self, cycles: u64) -> Option<ObsReport> {
        Controller::obs_report(self, cycles)
    }
}

/// The COPY_ENQ event for a copy request entering a channel queue.
fn copy_enq_event(req: &CopyRequest, now: u64) -> TraceEvent {
    let mut ev = TraceEvent::new(TraceKind::CopyEnq, now, req.src.channel, req.src.rank);
    ev.bank = req.src.bank as i64;
    ev.row = req.src.row as i64;
    ev.id = req.id as i64;
    ev.arrive = req.arrive;
    ev.val = req.rows as i64;
    ev.copy = true;
    ev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn ctrl(mut f: impl FnMut(&mut SimConfig)) -> Controller {
        let mut cfg = SimConfig::default();
        f(&mut cfg);
        Controller::new(cfg)
    }

    fn run_until_idle(c: &mut Controller, max: u64) -> Vec<Completion> {
        let mut out = vec![];
        for _ in 0..max {
            c.tick().unwrap();
            out.extend(c.drain_completions());
            if c.idle() {
                break;
            }
        }
        out
    }

    /// Map a byte address and enqueue through the typed entry point.
    fn enq(c: &mut Controller, id: u64, byte_addr: u64, is_write: bool) -> bool {
        let a = c.mapper.map(byte_addr);
        let access =
            if is_write { Access::write(id, 0, a) } else { Access::read(id, 0, a) };
        c.enqueue(access)
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_wrappers_delegate_to_enqueue() {
        // The old duo must stay exact aliases of map() + enqueue().
        let mut a = ctrl(|_| {});
        let mut b = ctrl(|_| {});
        assert!(a.enqueue_mem(1, 0, 0x10040, false));
        let mapped = b.mapper.map(0x10040);
        assert!(b.enqueue_mem_mapped(1, 0, mapped, false));
        assert!(enq(&mut a, 2, 0x200c0, true));
        assert!(b.enqueue_mem(2, 0, 0x200c0, true));
        let da = run_until_idle(&mut a, 100_000);
        let db = run_until_idle(&mut b, 100_000);
        assert_eq!(da, db);
        assert_eq!(a.stats.reads_done, b.stats.reads_done);
        assert_eq!(a.stats.writes_done, b.stats.writes_done);
    }

    #[test]
    fn single_read_completes_with_act_latency() {
        let mut c = ctrl(|_| {});
        assert!(enq(&mut c, 1, 0x10000, false));
        let done = run_until_idle(&mut c, 10_000);
        assert_eq!(done.len(), 1);
        let t = &c.dev.timing;
        // ACT + tRCD + tCL + tBL (plus a cycle or two of scheduling).
        let expect = t.t_rcd + t.t_cl + t.t_bl;
        assert!(done[0].at >= expect && done[0].at <= expect + 4,
                "at={} expect~{}", done[0].at, expect);
        assert_eq!(c.stats.reads_done, 1);
    }

    #[test]
    fn row_hits_are_prioritized() {
        let mut c = ctrl(|_| {});
        // Two requests to the same row + one to a different row of the
        // same bank, arriving together: the same-row pair must both be
        // served before the conflicting one forces a PRE.
        assert!(enq(&mut c, 1, 0x0, false)); // row R col 0
        assert!(enq(&mut c, 2, 0x40000, false)); // same bank, diff row
        assert!(enq(&mut c, 3, 0x40, false)); // row R col 1
        let done = run_until_idle(&mut c, 100_000);
        assert_eq!(done.len(), 3);
        let pos =
            |id: u64| done.iter().position(|c| c.id == id).unwrap();
        assert!(pos(3) < pos(2), "row hit must bypass the row conflict");
        assert!(c.stats.row_hit_rate() > 0.0);
    }

    #[test]
    fn writes_drain_and_complete() {
        let mut c = ctrl(|_| {});
        for i in 0..30 {
            assert!(enq(&mut c, i, i * 64, true));
        }
        run_until_idle(&mut c, 100_000);
        assert_eq!(c.stats.writes_done, 30);
    }

    #[test]
    fn refresh_happens_periodically() {
        let mut c = ctrl(|_| {});
        let trefi = c.dev.timing.t_refi;
        for _ in 0..(trefi * 3 + 100) {
            c.tick().unwrap();
        }
        assert!(c.dev.stats.n_ref >= 2, "refreshes: {}", c.dev.stats.n_ref);
    }

    #[test]
    fn lisa_risc_copy_through_controller_moves_tag() {
        let mut c = ctrl(|cfg| {
            cfg.lisa.risc = true;
            cfg.copy_mechanism = CopyMechanism::LisaRisc;
        });
        let src = Address { channel: 0, rank: 0, bank: 0, row: 100, col: 0 };
        let dst = Address { channel: 0, rank: 0, bank: 0, row: 5 * 512 + 7, col: 0 };
        c.dev.set_row_tag(0, 0, 0, 100, 0xABCD);
        c.enqueue_copy(CopyRequest {
            id: 77,
            core: 1,
            src,
            dst,
            rows: 1,
            mechanism: CopyMechanism::LisaRisc,
            arrive: 0,
        });
        let done = run_until_idle(&mut c, 100_000);
        assert_eq!(done.len(), 1);
        assert!(done[0].was_copy);
        assert_eq!(done[0].id, 77);
        assert_eq!(c.dev.row_tag(0, 0, 0, dst.row), 0xABCD);
        assert!(c.dev.stats.n_rbm_hops >= 5);
    }

    #[test]
    fn memcpy_copy_through_controller_moves_tag() {
        let mut c = ctrl(|_| {});
        let src = Address { channel: 0, rank: 0, bank: 0, row: 100, col: 0 };
        let dst = Address { channel: 0, rank: 0, bank: 1, row: 200, col: 0 };
        c.dev.set_row_tag(0, 0, 0, 100, 0x1234);
        c.enqueue_copy(CopyRequest {
            id: 9,
            core: 0,
            src,
            dst,
            rows: 1,
            mechanism: CopyMechanism::MemcpyChannel,
            arrive: 0,
        });
        let done = run_until_idle(&mut c, 200_000);
        assert_eq!(done.len(), 1, "copy should complete");
        assert_eq!(c.dev.row_tag(0, 0, 1, 200), 0x1234);
        // 128 reads + 128 writes crossed the channel.
        assert_eq!(c.dev.stats.n_rd, 128);
        assert_eq!(c.dev.stats.n_wr, 128);
    }

    #[test]
    fn reads_proceed_during_lisa_copy_on_other_bank() {
        // LISA's bank-level parallelism claim: a LISA-RISC copy in bank
        // 0 must not block reads to bank 1 (unlike RC-InterSA, whose
        // Transfer occupies the internal/IO bus).
        let mut c = ctrl(|cfg| {
            cfg.lisa.risc = true;
        });
        let src = Address { channel: 0, rank: 0, bank: 0, row: 100, col: 0 };
        let dst = Address { channel: 0, rank: 0, bank: 0, row: 15 * 512, col: 0 };
        c.enqueue_copy(CopyRequest {
            id: 1,
            core: 0,
            src,
            dst,
            rows: 1,
            mechanism: CopyMechanism::LisaRisc,
            arrive: 0,
        });
        // Read to bank 1 (address 0x2000 has bank bits -> bank 1).
        assert!(c.enqueue(Access::read(
            2,
            0,
            Address { channel: 0, rank: 0, bank: 1, row: 40, col: 0 },
        )));
        let done = run_until_idle(&mut c, 100_000);
        let read_done = done.iter().find(|c| c.id == 2).unwrap().at;
        let copy_done = done.iter().find(|c| c.id == 1).unwrap().at;
        assert!(
            read_done < copy_done,
            "read {read_done} should finish before copy {copy_done}"
        );
        let t = &c.dev.timing;
        assert!(read_done <= t.t_rcd + t.t_cl + t.t_bl + 8);
    }

    #[test]
    fn page_copy_queue_windows_releases_and_drains() {
        let mut c = ctrl(|cfg| {
            cfg.lisa.risc = true;
            cfg.copy_mechanism = CopyMechanism::LisaRisc;
        });
        // 8 page copies; only PAGE_COPY_WINDOW may be in a channel at
        // once, yet all must complete in order.
        for i in 0..8 {
            c.enqueue_page_copy(CopyRequest {
                id: 100 + i,
                core: 0,
                src: Address { channel: 0, rank: 0, bank: 0, row: 600 + i as usize, col: 0 },
                dst: Address {
                    channel: 0,
                    rank: 0,
                    bank: 0,
                    row: 3 * 512 + i as usize,
                    col: 0,
                },
                rows: 1,
                mechanism: CopyMechanism::LisaRisc,
                arrive: 0,
            });
        }
        assert!(!c.idle(), "parked page copies must keep the controller live");
        let mut done = vec![];
        for _ in 0..500_000u64 {
            c.tick().unwrap();
            assert!(c.copies_pending(0) <= PAGE_COPY_WINDOW);
            done.extend(c.drain_completions());
            if c.idle() {
                break;
            }
        }
        assert_eq!(done.len(), 8);
        let ids: Vec<u64> = done.iter().map(|d| d.id).collect();
        assert_eq!(ids, (100u64..108).collect::<Vec<_>>(), "page order preserved");
        assert_eq!(c.stats.copies_done, 8);
    }

    #[test]
    fn horizon_cache_tracks_enqueues_and_issues() {
        let mut c = ctrl(|_| {});
        // Warm the cache on an idle controller: the horizon is the
        // first refresh deadline, well in the future.
        let h0 = c.next_event_cycle();
        assert_eq!(h0, c.next_event_cycle_uncached());
        assert!(h0 > c.now, "idle controller horizon must be ahead");
        // An enqueue must drop the cached horizon on the spot: a fresh
        // request to a precharged bank is schedulable immediately.
        assert!(enq(&mut c, 1, 0x10000, false));
        let h1 = c.next_event_cycle();
        assert_eq!(h1, c.next_event_cycle_uncached(), "stale cache after enqueue");
        assert_eq!(h1, c.now, "a fresh request is schedulable now");
        // Every subsequent issue/completion keeps cache and fresh
        // recomputation in lock-step until the controller drains.
        for _ in 0..10_000u64 {
            c.tick().unwrap();
            c.drain_completions();
            assert_eq!(
                c.next_event_cycle(),
                c.next_event_cycle_uncached(),
                "cache diverged at cycle {}",
                c.now
            );
            if c.idle() {
                break;
            }
        }
        assert!(c.idle());
        // And a copy enqueue invalidates its channel too.
        c.enqueue_copy(CopyRequest {
            id: 7,
            core: 0,
            src: Address { channel: 0, rank: 0, bank: 0, row: 10, col: 0 },
            dst: Address { channel: 0, rank: 0, bank: 1, row: 20, col: 0 },
            rows: 1,
            mechanism: CopyMechanism::MemcpyChannel,
            arrive: 0,
        });
        let h2 = c.next_event_cycle();
        assert_eq!(h2, c.next_event_cycle_uncached(), "stale cache after copy enqueue");
        assert_eq!(h2, c.now, "copy activation runs on the next tick");
    }

    /// Fingerprint of every behaviorally relevant piece of controller
    /// + device state the tick loop can mutate, EXCEPT the clock and
    /// the `drain_mode` hysteresis bit (recomputed from queue lengths
    /// before every use, so it cannot alter behavior on its own). The
    /// horizon cache is deliberately excluded: it is a derived value,
    /// pinned against fresh recomputation separately.
    fn fingerprint(c: &Controller) -> String {
        let mut s = format!("{:?}|{:?}|{:?}", c.inflight, c.stats, c.dev.stats);
        for (ch, cs) in c.chans.iter().enumerate() {
            // (seq, id) pairs bucket-major: a dropped, duplicated or
            // misfiled per-bank index entry changes the fingerprint.
            let ids = |q: &BankedQueue| {
                q.iter_entries().map(|e| (e.seq, e.req.id)).collect::<Vec<_>>()
            };
            s += &format!(
                "|{:?}{:?}{:?}{:?}{:?}{:?}{:?}{:?}",
                ids(&cs.read_q),
                ids(&cs.write_q),
                cs.copy_q.iter().map(|r| r.id).collect::<Vec<_>>(),
                cs.active_copy.as_ref().map(|op| (op.req.id, op.done, op.last_done)),
                cs.active_memcpy
                    .as_ref()
                    .map(|m| (m.req.id, m.row_idx, m.reads_issued, m.writes_done)),
                cs.pending_cmd,
                cs.refresh_pending,
                cs.next_refresh,
            );
            for rank in 0..c.cfg.dram.ranks {
                for bank in 0..c.cfg.dram.banks {
                    let b = c.dev.bank(ch, rank, bank);
                    // `subarrays` Debug covers every per-subarray
                    // register, buffer state and tag.
                    s += &format!(
                        "|{},{},{},{:?},{:?}",
                        b.busy_until,
                        b.next_act,
                        b.next_pre,
                        b.last_sa,
                        b.subarrays,
                    );
                }
            }
        }
        s += &format!("|{}", self_page_q_ids(c));
        s
    }

    fn self_page_q_ids(c: &Controller) -> String {
        format!("{:?}", c.page_copy_q.iter().map(|r| r.id).collect::<Vec<_>>())
    }

    #[test]
    fn prop_next_event_cycle_is_a_lower_bound() {
        // The fast-forward contract: for randomized request mixes, no
        // tick strictly before `next_event_cycle()` changes any state
        // (the per-cycle reference loop would be a pure no-op there).
        // Previously this was only checked end-to-end by the engine
        // equivalence suite; here it is checked directly per state.
        use crate::config::SalpMode;
        use crate::util::proptest::check;
        check("next_event_cycle lower bound", 8, |g| {
            let mut c = ctrl(|cfg| {
                // Per-subarray open rows must not break the bound: draw
                // the SALP mode alongside the LISA switches.
                cfg.dram.salp = *g.pick(&SalpMode::ALL);
                cfg.lisa.risc = g.bool();
                cfg.lisa.lip = g.bool();
                cfg.copy_mechanism = if cfg.lisa.risc {
                    CopyMechanism::LisaRisc
                } else {
                    *g.pick(&[CopyMechanism::MemcpyChannel, CopyMechanism::RowCloneInterSa])
                };
            });
            for i in 0..(1 + g.usize(16)) {
                let addr = g.u64(32 << 20) & !63;
                let _ = enq(&mut c, i as u64 + 1, addr, g.chance(0.3));
            }
            if g.chance(0.7) {
                let src = g.usize(4000);
                c.enqueue_copy(CopyRequest {
                    id: 0x9000,
                    core: 0,
                    src: Address { channel: 0, rank: 0, bank: 0, row: src, col: 0 },
                    dst: Address {
                        channel: 0,
                        rank: 0,
                        bank: 0,
                        row: 4096 + g.usize(3000),
                        col: 0,
                    },
                    rows: 1 + g.usize(2),
                    mechanism: c.cfg.copy_mechanism,
                    arrive: 0,
                });
            }
            for k in 0..(1 + g.usize(4)) {
                c.enqueue_page_copy(CopyRequest {
                    id: 0xA000 + k as u64,
                    core: 0,
                    src: Address { channel: 0, rank: 0, bank: 1, row: g.usize(3000), col: 0 },
                    dst: Address {
                        channel: 0,
                        rank: 0,
                        bank: 1 + g.usize(7),
                        row: 4096 + g.usize(3000),
                        col: 0,
                    },
                    rows: 1,
                    mechanism: c.cfg.copy_mechanism,
                    arrive: 0,
                });
            }
            // Per-case tick budget keeps the fingerprint cost bounded.
            let mut budget = 12_000u64;
            while budget > 0 && !c.idle() {
                let h = c.next_event_cycle();
                // The cached horizon must agree with a fresh, cache-
                // bypassing recomputation at every probe — a missed
                // invalidation fails here, not as a silent slowdown.
                assert_eq!(
                    h,
                    c.next_event_cycle_uncached(),
                    "stale per-channel horizon cache at cycle {}",
                    c.now
                );
                if h <= c.now {
                    c.tick().unwrap();
                    c.drain_completions();
                    budget -= 1;
                    continue;
                }
                // Every tick strictly before the horizon must be a
                // no-op: identical state, no completions delivered.
                let fp = fingerprint(&c);
                let span = (h - c.now).min(budget);
                for _ in 0..span {
                    c.tick().unwrap();
                    assert!(
                        c.drain_completions().is_empty(),
                        "completion delivered before horizon {h}"
                    );
                    assert_eq!(
                        fingerprint(&c),
                        fp,
                        "state changed at cycle {} before horizon {h}",
                        c.now - 1
                    );
                    assert_eq!(
                        c.next_event_cycle(),
                        c.next_event_cycle_uncached(),
                        "horizon cache diverged mid-gap at cycle {}",
                        c.now
                    );
                }
                budget -= span;
            }
        });
    }

    #[test]
    fn prop_refresh_is_never_starved_with_salp_open_rows() {
        // A due refresh must reach the device within a bounded window
        // no matter how many per-subarray open rows, copies and page
        // copies the scheduler is juggling — SALP keeps more rows open
        // per bank, so refresh has strictly more closing work to do.
        use crate::config::SalpMode;
        use crate::util::proptest::check;
        check("refresh not starved", 6, |g| {
            let mode = *g.pick(&SalpMode::ALL);
            let mut c = ctrl(|cfg| {
                cfg.dram.salp = mode;
                cfg.lisa.risc = g.bool();
                cfg.copy_mechanism = if cfg.lisa.risc {
                    CopyMechanism::LisaRisc
                } else {
                    CopyMechanism::MemcpyChannel
                };
            });
            let t_refi = c.dev.timing.t_refi;
            let bound = 2 * t_refi;
            let mut next_id = 1u64;
            let mut pending_since: Option<u64> = None;
            while c.now < 4 * t_refi {
                // Keep request and copy pressure up so refresh really
                // competes with open-row traffic.
                if c.now % 131 == 0 {
                    let addr = g.u64(32 << 20) & !63;
                    let _ = enq(&mut c, next_id, addr, g.chance(0.3));
                    next_id += 1;
                }
                if c.now % 977 == 0 && g.chance(0.5) {
                    c.enqueue_copy(CopyRequest {
                        id: 0x8000 + next_id,
                        core: 0,
                        src: Address { channel: 0, rank: 0, bank: 0, row: g.usize(4000), col: 0 },
                        dst: Address {
                            channel: 0,
                            rank: 0,
                            bank: 0,
                            row: 4096 + g.usize(3000),
                            col: 0,
                        },
                        rows: 1 + g.usize(2),
                        mechanism: c.cfg.copy_mechanism,
                        arrive: 0,
                    });
                    next_id += 1;
                }
                c.tick().unwrap();
                c.drain_completions();
                let pending = c.chans[0].refresh_pending[0];
                match (pending, pending_since) {
                    (true, None) => pending_since = Some(c.now),
                    (true, Some(t0)) => assert!(
                        c.now - t0 < bound,
                        "refresh pending for {} cycles under {:?}",
                        c.now - t0,
                        mode
                    ),
                    (false, _) => pending_since = None,
                }
            }
            assert!(c.dev.stats.n_ref >= 2, "refreshes: {}", c.dev.stats.n_ref);
        });
    }

    #[test]
    fn masa_serves_conflicting_subarrays_without_thrashing() {
        // Two request streams hammering different subarrays of ONE
        // bank: the baseline must precharge back and forth, MASA keeps
        // both rows open after the first conflict resolution.
        use crate::config::SalpMode;
        let run = |mode: SalpMode| {
            let mut c = ctrl(|cfg| cfg.dram.salp = mode);
            let mut id = 0u64;
            let mut done = 0usize;
            // One request at a time, alternating between rows in
            // subarray 0 and subarray 1 of bank 0 — drained before the
            // next arrives, so FR-FCFS cannot batch same-row hits and
            // the baseline genuinely ping-pongs the bank.
            for round in 0..16usize {
                for row in [10usize, 700usize] {
                    id += 1;
                    assert!(c.enqueue(Access::read(
                        id,
                        0,
                        Address { channel: 0, rank: 0, bank: 0, row, col: round },
                    )));
                    for _ in 0..10_000u64 {
                        c.tick().unwrap();
                        done += c.drain_completions().len();
                        if c.idle() {
                            break;
                        }
                    }
                }
            }
            assert_eq!(done, 32, "{mode:?}: all requests complete");
            (c.dev.stats.n_act, c.stats.row_hit_rate())
        };
        let (act_none, hit_none) = run(SalpMode::None);
        let (act_masa, hit_masa) = run(SalpMode::Masa);
        assert!(act_masa < act_none, "MASA acts {act_masa} vs baseline {act_none}");
        assert!(hit_masa > hit_none, "MASA hit rate {hit_masa} vs baseline {hit_none}");
        assert_eq!(act_masa, 2, "MASA opens each conflicting row exactly once");
    }

    #[test]
    fn villa_caches_hot_row_and_serves_fast() {
        let mut c = ctrl(|cfg| {
            cfg.lisa.villa = true;
            cfg.lisa.risc = true;
            cfg.lisa.villa_epoch_cycles = 2000;
        });
        // Hammer one row; after an epoch it should be cached.
        let addr = Address { channel: 0, rank: 0, bank: 0, row: 1000, col: 0 };
        let mut id = 0;
        for round in 0..60 {
            id += 1;
            c.enqueue(Access::read(id, 0, addr));
            for _ in 0..100 {
                c.tick().unwrap();
            }
            c.drain_completions();
            let _ = round;
        }
        let v = c.villa.as_ref().unwrap();
        assert!(v.stats.fills >= 1, "hot row never cached");
        assert!(v.stats.hits >= 1, "cached row never hit");
        assert!(c.dev.stats.n_act_fast >= 1, "no fast-subarray activation");
    }
}
