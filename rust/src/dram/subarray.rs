//! Per-subarray row-buffer state. LISA is fundamentally a subarray-
//! level substrate, so the device model tracks each subarray's row
//! buffer individually (the baseline non-SALP configuration simply
//! enforces at most one non-precharged subarray per bank).

/// State of one subarray's row buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaState {
    /// Bitlines precharged to VDD/2; buffer holds nothing.
    Precharged,
    /// A row is open (activated) in this subarray.
    Open { row: usize },
    /// The row buffer holds latched data but no wordline is raised —
    /// the state RBM leaves destination/intermediate subarrays in.
    LatchedOnly,
}

/// One subarray: buffer state plus the content tag used to verify
/// data-movement semantics (tags stand in for 8 KB of row data).
#[derive(Debug, Clone)]
pub struct Subarray {
    pub state: SaState,
    /// Content tag of whatever the row buffer currently holds.
    pub buffer_tag: Option<u64>,
}

impl Default for Subarray {
    fn default() -> Self {
        Self {
            state: SaState::Precharged,
            buffer_tag: None,
        }
    }
}

impl Subarray {
    pub fn is_precharged(&self) -> bool {
        self.state == SaState::Precharged
    }

    pub fn open_row(&self) -> Option<usize> {
        match self.state {
            SaState::Open { row } => Some(row),
            _ => None,
        }
    }

    /// Precharge: closes the wordline and clears the buffer.
    pub fn precharge(&mut self) {
        self.state = SaState::Precharged;
        self.buffer_tag = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut sa = Subarray::default();
        assert!(sa.is_precharged());
        assert_eq!(sa.open_row(), None);

        sa.state = SaState::Open { row: 7 };
        sa.buffer_tag = Some(0xAB);
        assert_eq!(sa.open_row(), Some(7));
        assert!(!sa.is_precharged());

        sa.state = SaState::LatchedOnly;
        assert_eq!(sa.open_row(), None);
        assert!(!sa.is_precharged());

        sa.precharge();
        assert!(sa.is_precharged());
        assert_eq!(sa.buffer_tag, None);
    }
}
