"""Layer-2 JAX model: DRAM analog experiments composed from the L1 kernel.

Each public entry point is a jax function over concrete f32 arrays that
`aot.py` lowers ONCE to HLO text under `artifacts/`. The rust
coordinator (rust/src/runtime/) loads and executes those artifacts via
PJRT to *calibrate* the cycle-accurate simulator's LISA timing and
energy parameters (tRBM per hop, tRP with linked precharge, fast-
subarray latencies, per-op energies). Python never runs at simulation
time.

Entry points (all vectorized over N_LANES bitlines with per-bitline
process variation):

  activate_sense    — cell/bitline charge sharing + sense amplification
                      + cell restoration  (tRCD / tRAS / activation energy)
  rbm_hop           — LISA row buffer movement across one inter-subarray
                      link              (tRBM / RBM energy)
  precharge_single  — ordinary precharge (tRP / precharge energy)
  precharge_linked  — LISA-LIP: two precharge units + neighbor bitline
                      reservoir         (tRP_LIP)
  copy_energy       — full LISA-RISC copy: activation + masked scan of
                      up to MAX_HOPS RBM hops + destination activation
                      (per-hop energy accounting for Table 1)

Physical constants (PhysParams) were tuned — see
python/compile/tune_params.py — so the model reproduces the paper's
SPICE anchor points on nominal bitlines:

  tRP        ~ 13 ns      (paper §3.3: baseline precharge 13 ns)
  tRP_LIP    ~  5 ns      (paper §3.3: linked precharge 5 ns, 2.6x)
  tRBM(raw)  ~  5 ns      (paper §2: ~8 ns per hop after the 60% margin)
  tRCD-class sense latency and tRAS-class restoration consistent with
  DDR3-1600 (13.75 / 35 ns) once the worst-bitline + margin methodology
  of the paper is applied by the rust calibration driver.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import bitline as bl
from .kernels.bitline import NSCALARS

# Number of bitlines simulated per phase call. A DDR3 chip row buffer is
# 8K bits (65536 per rank); 4096 lanes keeps AOT artifacts fast on the
# CPU PJRT client while still giving a meaningful Monte-Carlo
# population for worst-case (paper: +60% guard band) analysis.
N_LANES = 4096

# Step counts (static, baked into the HLO). dt lives in the scalar
# vector so the rust side can refine resolution without re-lowering.
STEPS_ACTIVATE = 4000   # 40 ns window at dt = 0.01 ns
STEPS_RBM = 1500        # 15 ns window
STEPS_PRECHARGE = 2500  # 25 ns window
MAX_HOPS = 15           # 16 subarrays/bank => at most 15 hops (paper §3.1.1)


@dataclasses.dataclass(frozen=True)
class PhysParams:
    """Nominal circuit constants (units: V, fF, uS, ns).

    Tuned by tune_params.py against the paper's SPICE anchor points;
    see module docstring. tau = C/g is in ns for these units.
    """
    vdd: float = 1.2
    dt: float = 0.01
    c_bitline: float = 85.0     # fF, long (512-row) bitline
    c_bitline_fast: float = 38.0  # fF, short bitline in a VILLA fast subarray
    c_cell: float = 22.0        # fF storage capacitor
    g_access: float = 6.0       # uS access transistor (wordline on)
    g_line: float = 30.0        # uS lumped conductance between the two
                                #    halves of the distributed bitline
                                #    (2-segment line model for precharge)
    gm_sense: float = 20.0      # uS regenerative sense-amp strength
    gm_hold: float = 400.0      # uS: latched row buffer holding the rails
    g_precharge: float = 25.0   # uS precharge unit drive
    g_iso: float = 12.0         # uS LISA isolation transistor (RBM path)
    sense_threshold: float = 0.075  # V swing needed to latch
    settle_tol: float = 0.03    # V tolerance for "settled"
    variation_sigma: float = 0.05  # lognormal-ish sigma used by callers


DEFAULT_PARAMS = PhysParams()


def _scalars(p: PhysParams, kw) -> jnp.ndarray:
    """Build a scalar parameter vector with slot-index overrides."""
    s = [0.0] * NSCALARS
    s[bl.S_DT] = p.dt
    s[bl.S_VDD] = p.vdd
    s[bl.S_SENSE_THR] = p.sense_threshold
    s[bl.S_SETTLE_TOL] = p.settle_tol
    s[bl.S_C_A] = p.c_bitline
    s[bl.S_C_B] = p.c_cell
    s[bl.S_SETTLE_TGT] = p.vdd * 0.5
    s[bl.S_SETTLE_TGT_B] = p.vdd * 0.5
    for key, val in kw.items():
        s[key] = val
    return jnp.asarray(s, dtype=jnp.float32)


# --------------------------------------------------------------------------
# Scalar-vector builders. These encode WHICH circuit each phase is; the
# rust calibration driver builds the same vectors (runtime inputs), so
# changing a constant here does not require re-lowering.
# --------------------------------------------------------------------------

def scalars_activate(p: PhysParams = DEFAULT_PARAMS, fast: bool = False):
    """Activation: node a = bitline (sense amp on, starts at VDD/2),
    node b = cell (starts at a rail), coupled by the access transistor.
    t_sense ~ tRCD class; t_settle (cell back at rail) ~ tRAS class."""
    return _scalars(
        p,
        {bl.S_GM_A: p.gm_sense,
           bl.S_G_LINK: p.g_access,
           bl.S_C_A: p.c_bitline_fast if fast else p.c_bitline,
           bl.S_C_B: p.c_cell,
           bl.S_SETTLE_B: 1.0,
           bl.S_SETTLE_TGT: p.vdd,      # bitline restored high (storing 1)
           bl.S_SETTLE_TGT_B: p.vdd})   # cell restored high


def scalars_rbm(p: PhysParams = DEFAULT_PARAMS, fast: bool = False):
    """RBM: node a = destination bitline (precharged, own sense amp on),
    node b = source row buffer (latched full swing, strong hold),
    coupled by the LISA isolation transistor.

    tRBM = t_settle: the hop completes when the destination bitline has
    fully latched at the rail (it must, before it can drive the next
    hop or the destination activation)."""
    return _scalars(
        p,
        {bl.S_GM_A: p.gm_sense,
           bl.S_GM_B: p.gm_hold,
           bl.S_G_LINK: p.g_iso,
           bl.S_C_A: p.c_bitline_fast if fast else p.c_bitline,
           bl.S_C_B: p.c_bitline,
           bl.S_SETTLE_TGT: p.vdd,
           bl.S_SETTLE_TGT_B: p.vdd})


def scalars_precharge(p: PhysParams = DEFAULT_PARAMS, linked: bool = False,
                      fast: bool = False):
    """Precharge, 2-segment distributed-line model.

    The bitline is a distributed RC line; what makes LISA-LIP fast is
    driving it from BOTH ends (Elmore delay of a line driven from both
    ends is ~4x lower). Discretize into two halves:

      node a = far half of the bitline (C/2) — in the baseline it is
               only reached through the line conductance g_line;
      node b = near half (C/2), driven by the local precharge unit.

    linked (LISA-LIP): the neighboring subarray's precharge unit also
    drives node a through the (wide, low-resistance) isolation switch —
    modeled as a direct g_precharge drive on the far end, plus the
    neighbor's already-precharged bitline acting as a charge reservoir
    at exactly VDD/2 (folded into the same driver).

    t_settle requires BOTH halves within tolerance of VDD/2 ~ tRP."""
    c_half = (p.c_bitline_fast if fast else p.c_bitline) * 0.5
    return _scalars(
        p,
        {bl.S_G_EXT_A: p.g_precharge if linked else 0.0,
           bl.S_V_EXT_A: p.vdd * 0.5,
           bl.S_G_EXT_B: p.g_precharge,
           bl.S_V_EXT_B: p.vdd * 0.5,
           bl.S_G_LINK: p.g_line,
           bl.S_C_A: c_half,
           bl.S_C_B: c_half,
           bl.S_SETTLE_B: 1.0,
           bl.S_SETTLE_TGT: p.vdd * 0.5,
           bl.S_SETTLE_TGT_B: p.vdd * 0.5})


# --------------------------------------------------------------------------
# AOT entry points. Uniform leading signature (va0, vb0, gmul, cmul,
# scalars) -> 5 x f32[n]; copy_energy appends extra operands.
# --------------------------------------------------------------------------

def activate_sense(va0, vb0, gmul, cmul, scalars):
    return bl.phase(va0, vb0, gmul, cmul, scalars, n_steps=STEPS_ACTIVATE)


def rbm_hop(va0, vb0, gmul, cmul, scalars):
    return bl.phase(va0, vb0, gmul, cmul, scalars, n_steps=STEPS_RBM)


def precharge_single(va0, vb0, gmul, cmul, scalars):
    return bl.phase(va0, vb0, gmul, cmul, scalars, n_steps=STEPS_PRECHARGE)


def precharge_linked(va0, vb0, gmul, cmul, scalars):
    return bl.phase(va0, vb0, gmul, cmul, scalars, n_steps=STEPS_PRECHARGE)


def copy_energy(va0, vb0, gmul, cmul, s_act, s_rbm, hops):
    """Full LISA-RISC copy energy: source activation, `hops` RBM hops
    (masked scan over MAX_HOPS), destination activation (restore).

    Args:
      va0, vb0, gmul, cmul: as in the other entries (f32[n]).
      s_act, s_rbm: scalar vectors for the activation and RBM phases.
      hops: f32[1], number of hops actually used (1..MAX_HOPS).

    Returns:
      (e_total, e_act, e_rbm_per_hop, t_act_settle, t_rbm_sense),
      each f32[n] per-bitline; e_total already includes both
      activations plus `hops` RBM hops.
    """
    vdd = s_act[bl.S_VDD]
    vmid = vdd * 0.5

    _, _, _, t_act, e_act = bl.phase(va0, vb0, gmul, cmul, s_act,
                                     n_steps=STEPS_ACTIVATE)

    # One RBM hop in steady state: destination bitlines precharged,
    # source row buffer latched at the value the data encodes (use the
    # sign of va0 - vmid to pick the rail, so the data pattern flows in).
    rail = jnp.where(va0 >= vmid, vdd, 0.0)
    dst0 = jnp.full_like(va0, vmid)

    def hop_body(carry, k):
        e_sum, t_last = carry
        _, _, t_s, _, e_h = bl.phase(dst0, rail, gmul, cmul, s_rbm,
                                     n_steps=STEPS_RBM)
        live = (k.astype(jnp.float32) < hops[0])
        e_sum = e_sum + jnp.where(live, e_h, 0.0)
        t_last = jnp.where(live, t_s, t_last)
        return (e_sum, t_last), e_h

    (e_rbm_sum, t_rbm), e_hops = jax.lax.scan(
        hop_body, (jnp.zeros_like(va0), jnp.zeros_like(va0)),
        jnp.arange(MAX_HOPS))
    e_rbm_per_hop = e_hops[0]

    e_total = 2.0 * e_act + e_rbm_sum
    return e_total, e_act, e_rbm_per_hop, t_act, t_rbm


# Registry consumed by aot.py: name -> (fn, extra-operand builder).
def example_args(n: int = N_LANES):
    """Example (shape-defining) arguments for lowering."""
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    sca = jax.ShapeDtypeStruct((NSCALARS,), jnp.float32)
    one = jax.ShapeDtypeStruct((1,), jnp.float32)
    return {
        "activate_sense": (activate_sense, (vec, vec, vec, vec, sca)),
        "rbm_hop": (rbm_hop, (vec, vec, vec, vec, sca)),
        "precharge_single": (precharge_single, (vec, vec, vec, vec, sca)),
        "precharge_linked": (precharge_linked, (vec, vec, vec, vec, sca)),
        "copy_energy": (copy_energy, (vec, vec, vec, vec, sca, sca, one)),
    }
