//! Row buffer movement (RBM) bandwidth analytics — experiment E2
//! (paper §2: RBM moves a row's worth of data at 26x the bandwidth of
//! a DDR4-2400 channel, 500 GB/s vs 19.2 GB/s).

use crate::config::Calibration;
use crate::dram::timing::{SpeedBin, Timing};

/// RBM effective bandwidth for moving one rank-level row.
#[derive(Debug, Clone)]
pub struct RbmBandwidth {
    /// Row size moved per hop, bytes (rank-level row: all chips in
    /// parallel).
    pub row_bytes: usize,
    /// One margined hop, nanoseconds (ceil'd to the bus clock).
    pub hop_ns: f64,
    /// Effective GB/s (bytes/ns).
    pub gbps: f64,
    /// Channel peak bandwidth for comparison.
    pub channel_gbps: f64,
    /// The headline ratio.
    pub speedup: f64,
}

/// Compute the RBM bandwidth claim for a speed bin.
pub fn rbm_bandwidth(speed: SpeedBin, cal: &Calibration, row_bytes: usize) -> RbmBandwidth {
    let t = Timing::new(speed, cal);
    let hop_ns = t.ns(t.t_rbm);
    let gbps = row_bytes as f64 / hop_ns; // bytes per ns == GB/s
    let channel_gbps = speed.channel_gbps();
    RbmBandwidth {
        row_bytes,
        hop_ns,
        gbps,
        channel_gbps,
        speedup: gbps / channel_gbps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbm_bandwidth_far_exceeds_channel() {
        let r = rbm_bandwidth(SpeedBin::Ddr4_2400, &Calibration::default(), 8192);
        // Paper: 26x. Our calibrated hop is slightly faster, so we land
        // higher; the claim's shape is ">= an order of magnitude".
        assert!(r.speedup > 10.0, "speedup {}", r.speedup);
        assert!(r.gbps > 400.0, "gbps {}", r.gbps);
    }

    #[test]
    fn per_chip_row_is_still_faster_than_channel() {
        // Even counting only a single chip's 1 KB row slice (no rank
        // parallelism), RBM beats the channel.
        let r = rbm_bandwidth(SpeedBin::Ddr4_2400, &Calibration::default(), 1024);
        assert!(r.speedup > 1.0, "speedup {}", r.speedup);
    }

    #[test]
    fn hop_time_uses_margined_calibration() {
        let cal = Calibration::default();
        let r = rbm_bandwidth(SpeedBin::Ddr3_1600, &cal, 8192);
        // hop >= the raw calibrated value (ceil to clock can only add).
        assert!(r.hop_ns >= cal.t_rbm_ns - 1e-9, "{} < {}", r.hop_ns, cal.t_rbm_ns);
    }
}
