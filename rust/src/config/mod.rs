//! Configuration: a TOML-subset parser (`minitoml`, built in-tree —
//! no serde offline), the typed simulator configuration tree, and the
//! `SimConfigBuilder` every experiment derives its configs from.

pub mod builder;
pub mod minitoml;

pub use builder::{LisaPreset, SimConfigBuilder};

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::dram::timing::SpeedBin;
use minitoml::Document;

/// Which bulk-copy mechanism the system uses for copy requests.
/// These are the rows of Table 1 / Fig. 2 of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CopyMechanism {
    /// Baseline: data crosses the memory channel through the CPU.
    MemcpyChannel,
    /// RowClone, source and destination rows in the same subarray.
    RowCloneIntraSa,
    /// RowClone pipelined serial mode across banks (internal 64-bit bus).
    RowCloneInterBank,
    /// RowClone between subarrays of the same bank (two inter-bank
    /// transfers via a temporary bank).
    RowCloneInterSa,
    /// LISA-RISC: row buffer movement across linked subarrays.
    LisaRisc,
}

impl CopyMechanism {
    /// All mechanisms, in Table 1 order.
    pub const ALL: [CopyMechanism; 5] = [
        CopyMechanism::MemcpyChannel,
        CopyMechanism::RowCloneIntraSa,
        CopyMechanism::RowCloneInterBank,
        CopyMechanism::RowCloneInterSa,
        CopyMechanism::LisaRisc,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "memcpy" => Self::MemcpyChannel,
            "rc-intra" => Self::RowCloneIntraSa,
            "rc-bank" => Self::RowCloneInterBank,
            "rc-inter" => Self::RowCloneInterSa,
            "lisa-risc" => Self::LisaRisc,
            _ => bail!(
                "unknown copy mechanism '{s}' (memcpy|rc-intra|rc-bank|rc-inter|lisa-risc)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::MemcpyChannel => "memcpy",
            Self::RowCloneIntraSa => "rc-intra",
            Self::RowCloneInterBank => "rc-bank",
            Self::RowCloneInterSa => "rc-inter",
            Self::LisaRisc => "lisa-risc",
        }
    }
}

/// Subarray-level-parallelism mode of the bank state machine (Kim et
/// al., "Exploiting the DRAM Microarchitecture to Increase
/// Memory-Level Parallelism" — SALP-1 / SALP-2 / MASA), composable
/// with the LISA substrate: LISA links subarrays for *data movement*,
/// SALP exposes their independent *activation* state to the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SalpMode {
    /// Baseline: at most one non-precharged subarray per bank, and a
    /// whole-bank precharge charges the full tRP before the next ACT.
    None,
    /// SALP-1: still one open row at a time, but precharge is a
    /// per-subarray operation, so an ACT to a *different* subarray
    /// overlaps with the previous subarray's tRP.
    Salp1,
    /// SALP-2: per-subarray sense-amp latches let two subarrays stay
    /// open concurrently (the designated-subarray approximation: the
    /// global-bitline select costs `t_sa_sel` on a subarray switch).
    Salp2,
    /// MASA: every subarray may hold an open row; RD/WR steers the
    /// global bitlines by subarray-select (again `t_sa_sel` per
    /// switch). The scheduler exploits open rows in distinct
    /// subarrays of the same bank.
    Masa,
}

impl SalpMode {
    /// All modes, in increasing parallelism order.
    pub const ALL: [SalpMode; 4] =
        [SalpMode::None, SalpMode::Salp1, SalpMode::Salp2, SalpMode::Masa];

    /// Parse a mode name (`none|salp1|salp2|masa`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => Self::None,
            "salp1" => Self::Salp1,
            "salp2" => Self::Salp2,
            "masa" => Self::Masa,
            _ => bail!("unknown SALP mode '{s}' (none|salp1|salp2|masa)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Salp1 => "salp1",
            Self::Salp2 => "salp2",
            Self::Masa => "masa",
        }
    }

    /// Maximum number of concurrently non-precharged subarrays per
    /// bank under this mode.
    pub fn open_cap(&self, subarrays_per_bank: usize) -> usize {
        match self {
            Self::None | Self::Salp1 => 1,
            Self::Salp2 => 2,
            Self::Masa => subarrays_per_bank,
        }
    }

    /// Does the mode track activation state (and schedule precharges)
    /// per subarray rather than per bank?
    pub fn per_subarray(&self) -> bool {
        *self != Self::None
    }

    /// Does the mode pay the subarray-select latch cost on RD/WR
    /// subarray switches (the modes with >1 concurrently open row)?
    pub fn has_sa_select(&self) -> bool {
        matches!(self, Self::Salp2 | Self::Masa)
    }
}

/// Physical frame placement policy of the OS-layer frame allocator
/// (`os/frame_alloc.rs`). Placement decides where bulk-copy pairs land
/// relative to each other, which in turn decides how many page copies
/// the in-DRAM mechanisms can serve without leaving the bank — the
/// RISC hit rate is itself an evaluable knob of experiment E9.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlacementPolicy {
    /// Uniform-random frame from the free pool (no locality).
    Random,
    /// Fill subarray groups in order: maximal co-location (dense
    /// same-bank placement, minimal bank-level parallelism).
    SubarrayPacked,
    /// Round-robin across subarray groups: maximal bank parallelism,
    /// minimal copy-pair locality.
    SubarraySpread,
    /// Level-major across banks: pack the subarrays nearest the fast
    /// (VILLA) subarray first while round-robining banks — co-location
    /// with bank parallelism and short promotion hops.
    VillaAware,
}

impl PlacementPolicy {
    pub const ALL: [PlacementPolicy; 4] = [
        PlacementPolicy::Random,
        PlacementPolicy::SubarrayPacked,
        PlacementPolicy::SubarraySpread,
        PlacementPolicy::VillaAware,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "random" => Self::Random,
            "packed" | "subarray-packed" => Self::SubarrayPacked,
            "spread" | "subarray-spread" => Self::SubarraySpread,
            "villa" | "villa-aware" => Self::VillaAware,
            _ => bail!(
                "unknown placement policy '{s}' (random|packed|spread|villa-aware)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Random => "random",
            Self::SubarrayPacked => "packed",
            Self::SubarraySpread => "spread",
            Self::VillaAware => "villa-aware",
        }
    }
}

/// Which memory-model backend executes a configuration's grid point.
/// The engine↔memory boundary is the `MemoryModel` trait
/// (`backend/mod.rs`); this enum selects the implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// Cycle-exact controller + device model (`controller::Controller`).
    /// The ground truth every other backend is calibrated against.
    #[default]
    Cycle,
    /// Calibrated analytical event-count model (`backend/analytical.rs`):
    /// orders of magnitude faster per point, validated against the
    /// cycle backend within a stated tolerance (tests/backend_twin.rs).
    Analytical,
}

impl BackendKind {
    pub const ALL: [BackendKind; 2] = [BackendKind::Cycle, BackendKind::Analytical];

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "cycle" => Self::Cycle,
            "analytical" => Self::Analytical,
            _ => bail!("unknown backend '{s}' (cycle|analytical)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Cycle => "cycle",
            Self::Analytical => "analytical",
        }
    }
}

/// OS-layer (virtual memory + bulk-operation subsystem) configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct OsConfig {
    /// Frame placement policy for the subarray-aware allocator.
    pub placement: PlacementPolicy,
}

impl Default for OsConfig {
    fn default() -> Self {
        Self { placement: PlacementPolicy::SubarrayPacked }
    }
}

/// DRAM organization. Defaults mirror the paper's configuration:
/// DDR3-1600, 1 channel, 1 rank, 8 banks, 16 subarrays/bank,
/// 512 rows/subarray, 8 KB rows (128 cache lines of 64 B).
#[derive(Debug, Clone, PartialEq)]
pub struct DramConfig {
    pub channels: usize,
    pub ranks: usize,
    pub banks: usize,
    pub subarrays_per_bank: usize,
    pub rows_per_subarray: usize,
    /// Cache lines (64 B) per row; 8 KB row => 128.
    pub columns: usize,
    pub speed: SpeedBin,
    /// Subarray-level parallelism mode — the paper's baseline is
    /// `SalpMode::None`; the device model always keeps per-subarray
    /// row-buffer state, the mode decides how much of it the bank
    /// state machine (and therefore the scheduler) may exploit.
    pub salp: SalpMode,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self {
            channels: 1,
            ranks: 1,
            banks: 8,
            subarrays_per_bank: 16,
            rows_per_subarray: 512,
            columns: 128,
            speed: SpeedBin::Ddr3_1600,
            salp: SalpMode::None,
        }
    }
}

impl DramConfig {
    pub fn rows_per_bank(&self) -> usize {
        self.subarrays_per_bank * self.rows_per_subarray
    }

    /// Row size in bytes (columns * 64 B cache lines).
    pub fn row_bytes(&self) -> usize {
        self.columns * 64
    }

    /// Total capacity in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.channels * self.ranks * self.banks * self.rows_per_bank() * self.row_bytes()
    }
}

/// LISA feature switches (the paper's three applications).
#[derive(Debug, Clone, PartialEq)]
pub struct LisaConfig {
    /// LISA-RISC: inter-subarray copies use RBM.
    pub risc: bool,
    /// LISA-VILLA: heterogeneous subarrays + hot-row caching.
    pub villa: bool,
    /// LISA-LIP: linked precharge.
    pub lip: bool,
    /// Number of fast subarrays per bank for VILLA (paper: 1 fast
    /// subarray of 32 rows per bank class designs; we default 1).
    pub fast_subarrays_per_bank: usize,
    /// Rows per fast subarray (short bitlines => fewer rows).
    pub fast_rows_per_subarray: usize,
    /// VILLA epoch length in DRAM cycles.
    pub villa_epoch_cycles: u64,
    /// Hot-row counters per bank (paper: 1024 saturating counters).
    pub villa_counters: usize,
    /// Rows marked hot per epoch (paper: 16).
    pub villa_hot_per_epoch: usize,
}

impl Default for LisaConfig {
    fn default() -> Self {
        Self {
            risc: false,
            villa: false,
            lip: false,
            fast_subarrays_per_bank: 1,
            fast_rows_per_subarray: 32,
            villa_epoch_cycles: 100_000,
            villa_counters: 1024,
            villa_hot_per_epoch: 16,
        }
    }
}

/// CPU / cache hierarchy configuration (quad-core, paper §9 setup).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuConfig {
    pub cores: usize,
    /// CPU clock as a multiple of the DRAM bus clock (3.2 GHz / 800 MHz).
    pub clock_ratio: u64,
    /// Reorder-buffer (instruction window) entries per core.
    pub rob_size: usize,
    /// Maximum outstanding L1 misses per core.
    pub mshrs: usize,
    /// Retire width (instructions per CPU cycle).
    pub issue_width: u64,
    pub l1_kb: usize,
    pub l1_ways: usize, // lint: allow(config-coverage) reason=fixed cache geometry, no TOML surface
    pub l1_latency: u64, // lint: allow(config-coverage) reason=fixed cache geometry, no TOML surface
    pub l2_kb: usize,
    pub l2_ways: usize, // lint: allow(config-coverage) reason=fixed cache geometry, no TOML surface
    pub l2_latency: u64, // lint: allow(config-coverage) reason=fixed cache geometry, no TOML surface
    pub llc_kb: usize,
    pub llc_ways: usize, // lint: allow(config-coverage) reason=fixed cache geometry, no TOML surface
    pub llc_latency: u64, // lint: allow(config-coverage) reason=fixed cache geometry, no TOML surface
}

impl Default for CpuConfig {
    fn default() -> Self {
        Self {
            cores: 4,
            clock_ratio: 4,
            rob_size: 128,
            mshrs: 16,
            issue_width: 4,
            l1_kb: 32,
            l1_ways: 8,
            l1_latency: 4,
            l2_kb: 256,
            l2_ways: 8,
            l2_latency: 12,
            llc_kb: 8192,
            llc_ways: 16,
            llc_latency: 38,
        }
    }
}

/// Calibrated LISA timing/energy parameters. Normally produced by
/// `lisa calibrate` (rust/src/runtime/calibrate.rs) executing the
/// JAX/Pallas circuit artifacts through PJRT; the defaults below are
/// the same values the checked-in circuit model yields, so the
/// simulator is usable (and the test suite hermetic) without artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Row buffer movement latency per hop, ns (raw circuit time x the
    /// paper's 60% process/temperature guard band).
    pub t_rbm_ns: f64,
    /// Precharge latency with linked precharge units, ns.
    pub t_rp_lip_ns: f64,
    /// Baseline precharge latency from the same circuit model, ns
    /// (used to scale JEDEC tRP for LIP rather than absolute ns).
    pub t_rp_circuit_ns: f64,
    /// Fast-subarray latency ratios (fast/slow) for ACT / restore / PRE.
    pub fast_act_ratio: f64,
    pub fast_ras_ratio: f64,
    pub fast_rp_ratio: f64,
    /// Per-bitline op energies from the circuit model, fJ.
    pub e_act_fj: f64,
    pub e_pre_fj: f64,
    pub e_rbm_fj: f64,
    /// True when values came from executing the artifacts (vs. the
    /// built-in analytic fallback).
    pub from_artifacts: bool,
}

impl Default for Calibration {
    fn default() -> Self {
        // Matches python/compile/tune_params.py on the checked-in
        // PhysParams (see EXPERIMENTS.md §Calibration).
        Self {
            t_rbm_ns: 5.21 * 1.6,
            t_rp_lip_ns: 5.07 * 1.6,
            t_rp_circuit_ns: 13.32 * 1.6,
            fast_act_ratio: 0.40,
            fast_ras_ratio: 0.62,
            fast_rp_ratio: 0.45,
            e_act_fj: 55.2,
            e_pre_fj: 61.0,
            e_rbm_fj: 35.9,
            from_artifacts: false,
        }
    }
}

/// Top-level simulator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    pub dram: DramConfig,
    pub lisa: LisaConfig,
    pub cpu: CpuConfig,
    pub os: OsConfig,
    pub calibration: Calibration,
    /// Memory-model backend executing this configuration.
    pub backend: BackendKind,
    pub copy_mechanism: CopyMechanism,
    /// Memory requests simulated per core before the run ends.
    pub requests_per_core: u64,
    /// Warmup fraction excluded from stats.
    pub warmup_frac: f64,
    /// Hard cap on simulated DRAM cycles (safety).
    pub max_cycles: u64,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            dram: DramConfig::default(),
            lisa: LisaConfig::default(),
            cpu: CpuConfig::default(),
            os: OsConfig::default(),
            calibration: Calibration::default(),
            backend: BackendKind::Cycle,
            copy_mechanism: CopyMechanism::MemcpyChannel,
            requests_per_core: 50_000,
            warmup_frac: 0.1,
            max_cycles: 200_000_000,
            seed: 1,
        }
    }
}

impl SimConfig {
    /// Enable all three LISA applications (paper Fig. 4 "All").
    pub fn with_all_lisa(mut self) -> Self {
        self.lisa.risc = true;
        self.lisa.villa = true;
        self.lisa.lip = true;
        self.copy_mechanism = CopyMechanism::LisaRisc;
        self
    }

    /// Load overrides from a TOML file on top of the defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Apply a TOML document on top of the defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = Document::parse(text)?;
        let mut cfg = Self::default();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    /// Apply a parsed document's overrides in place.
    pub fn apply(&mut self, doc: &Document) -> Result<()> {
        macro_rules! set {
            ($field:expr, $get:ident, $sec:expr, $key:expr) => {
                if let Some(v) = doc.$get($sec, $key)? {
                    $field = v;
                }
            };
        }
        set!(self.dram.channels, get_usize, "dram", "channels");
        set!(self.dram.ranks, get_usize, "dram", "ranks");
        set!(self.dram.banks, get_usize, "dram", "banks");
        set!(self.dram.subarrays_per_bank, get_usize, "dram", "subarrays_per_bank");
        set!(self.dram.rows_per_subarray, get_usize, "dram", "rows_per_subarray");
        set!(self.dram.columns, get_usize, "dram", "columns");
        // `salp` accepts either a mode name ("none"|"salp1"|"salp2"|
        // "masa") or, for older configs, a boolean (true == masa).
        match doc.get_str("dram", "salp") {
            Ok(Some(s)) => self.dram.salp = SalpMode::parse(&s)?,
            Ok(None) => {}
            Err(_) => {
                if let Some(b) = doc.get_bool("dram", "salp")? {
                    self.dram.salp = if b { SalpMode::Masa } else { SalpMode::None };
                }
            }
        }
        if let Some(s) = doc.get_str("dram", "speed")? {
            self.dram.speed = SpeedBin::parse(&s)?;
        }

        set!(self.lisa.risc, get_bool, "lisa", "risc");
        set!(self.lisa.villa, get_bool, "lisa", "villa");
        set!(self.lisa.lip, get_bool, "lisa", "lip");
        set!(self.lisa.fast_subarrays_per_bank, get_usize, "lisa", "fast_subarrays_per_bank");
        set!(self.lisa.fast_rows_per_subarray, get_usize, "lisa", "fast_rows_per_subarray");
        set!(self.lisa.villa_epoch_cycles, get_u64, "lisa", "villa_epoch_cycles");
        set!(self.lisa.villa_counters, get_usize, "lisa", "villa_counters");
        set!(self.lisa.villa_hot_per_epoch, get_usize, "lisa", "villa_hot_per_epoch");

        set!(self.cpu.cores, get_usize, "cpu", "cores");
        set!(self.cpu.clock_ratio, get_u64, "cpu", "clock_ratio");
        set!(self.cpu.rob_size, get_usize, "cpu", "rob_size");
        set!(self.cpu.mshrs, get_usize, "cpu", "mshrs");
        set!(self.cpu.issue_width, get_u64, "cpu", "issue_width");
        set!(self.cpu.l1_kb, get_usize, "cpu", "l1_kb");
        set!(self.cpu.l2_kb, get_usize, "cpu", "l2_kb");
        set!(self.cpu.llc_kb, get_usize, "cpu", "llc_kb");

        if let Some(s) = doc.get_str("os", "placement")? {
            self.os.placement = PlacementPolicy::parse(&s)?;
        }

        set!(self.calibration.t_rbm_ns, get_f64, "calibration", "t_rbm_ns");
        set!(self.calibration.t_rp_lip_ns, get_f64, "calibration", "t_rp_lip_ns");
        set!(self.calibration.t_rp_circuit_ns, get_f64, "calibration", "t_rp_circuit_ns");
        set!(self.calibration.fast_act_ratio, get_f64, "calibration", "fast_act_ratio");
        set!(self.calibration.fast_ras_ratio, get_f64, "calibration", "fast_ras_ratio");
        set!(self.calibration.fast_rp_ratio, get_f64, "calibration", "fast_rp_ratio");
        set!(self.calibration.e_act_fj, get_f64, "calibration", "e_act_fj");
        set!(self.calibration.e_pre_fj, get_f64, "calibration", "e_pre_fj");
        set!(self.calibration.e_rbm_fj, get_f64, "calibration", "e_rbm_fj");
        set!(self.calibration.from_artifacts, get_bool, "calibration", "from_artifacts");

        if let Some(s) = doc.get_str("backend", "kind")? {
            self.backend = BackendKind::parse(&s)?;
        }

        if let Some(s) = doc.get_str("sim", "copy_mechanism")? {
            self.copy_mechanism = CopyMechanism::parse(&s)?;
        }
        set!(self.requests_per_core, get_u64, "sim", "requests_per_core");
        set!(self.warmup_frac, get_f64, "sim", "warmup_frac");
        set!(self.max_cycles, get_u64, "sim", "max_cycles");
        set!(self.seed, get_u64, "sim", "seed");
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.dram.channels == 0
            || self.dram.ranks == 0
            || self.dram.banks == 0
            || self.dram.subarrays_per_bank == 0
        {
            bail!("dram geometry must be non-zero");
        }
        if !self.dram.banks.is_power_of_two()
            || !self.dram.subarrays_per_bank.is_power_of_two()
            || !self.dram.rows_per_subarray.is_power_of_two()
            || !self.dram.columns.is_power_of_two()
        {
            bail!("dram geometry fields must be powers of two (address mapping)");
        }
        if self.cpu.cores == 0 {
            bail!("need at least one core");
        }
        if self.cpu.clock_ratio == 0 {
            // `Simulation::drive` steps each core `clock_ratio` times
            // per DRAM cycle; zero would never step a core and the run
            // would silently spin to the max_cycles cap.
            bail!("cpu.clock_ratio must be >= 1");
        }
        if self.cpu.issue_width == 0 {
            bail!("cpu.issue_width must be >= 1 (cores could neither issue nor retire)");
        }
        if self.cpu.rob_size == 0 || self.cpu.mshrs == 0 {
            bail!("cpu.rob_size and cpu.mshrs must be >= 1");
        }
        // The OS layer carries frame numbers — and the trace format /
        // bulk ops carry page counts — as u32, and workload generators
        // multiply geometry fields before casting down. Reject
        // configurations whose products leave u32 range instead of
        // letting them wrap into silent address aliasing.
        let rows_per_bank = self.dram.subarrays_per_bank as u128
            * self.dram.rows_per_subarray as u128;
        if rows_per_bank > u32::MAX as u128 {
            bail!(
                "subarrays_per_bank * rows_per_subarray = {rows_per_bank} \
                 exceeds u32 (row indices would wrap)"
            );
        }
        let frames = rows_per_bank
            * self.dram.channels as u128
            * self.dram.ranks as u128
            * self.dram.banks as u128;
        if frames > u32::MAX as u128 {
            bail!(
                "total row count {frames} exceeds u32 (OS frame numbers \
                 and bulk-op page counts are u32)"
            );
        }
        if self.dram.columns as u128 * 64 > u32::MAX as u128 {
            bail!("columns = {} makes a row wider than u32 bytes", self.dram.columns);
        }
        if frames * (self.dram.columns as u128 * 64) > usize::MAX as u128 {
            bail!("dram capacity overflows usize on this platform");
        }
        if self.lisa.villa
            && self.lisa.fast_subarrays_per_bank >= self.dram.subarrays_per_bank
        {
            bail!("fast subarrays must be a strict subset of subarrays");
        }
        if !(0.0..1.0).contains(&self.warmup_frac) {
            bail!("warmup_frac must be in [0,1)");
        }
        Ok(())
    }

    /// Serialize the full configuration as minitoml text. Covers every
    /// key `apply` reads, so `SimConfig::from_toml(&cfg.to_toml())`
    /// round-trips to an equal config for any builder-constructed
    /// value (property-tested in `config/builder.rs`). Fields `apply`
    /// cannot read (e.g. cache way counts/latencies) are intentionally
    /// not serialized — the builder exposes no setters for them, so
    /// they always carry their defaults.
    pub fn to_toml(&self) -> String {
        format!(
            "[dram]\n\
             channels = {}\n\
             ranks = {}\n\
             banks = {}\n\
             subarrays_per_bank = {}\n\
             rows_per_subarray = {}\n\
             columns = {}\n\
             speed = \"{}\"\n\
             salp = \"{}\"\n\
             \n[lisa]\n\
             risc = {}\n\
             villa = {}\n\
             lip = {}\n\
             fast_subarrays_per_bank = {}\n\
             fast_rows_per_subarray = {}\n\
             villa_epoch_cycles = {}\n\
             villa_counters = {}\n\
             villa_hot_per_epoch = {}\n\
             \n[cpu]\n\
             cores = {}\n\
             clock_ratio = {}\n\
             rob_size = {}\n\
             mshrs = {}\n\
             issue_width = {}\n\
             l1_kb = {}\n\
             l2_kb = {}\n\
             llc_kb = {}\n\
             \n[os]\n\
             placement = \"{}\"\n\
             \n[backend]\n\
             kind = \"{}\"\n\
             \n{}\
             \n[sim]\n\
             copy_mechanism = \"{}\"\n\
             requests_per_core = {}\n\
             warmup_frac = {}\n\
             max_cycles = {}\n\
             seed = {}\n",
            self.dram.channels,
            self.dram.ranks,
            self.dram.banks,
            self.dram.subarrays_per_bank,
            self.dram.rows_per_subarray,
            self.dram.columns,
            self.dram.speed.name(),
            self.dram.salp.name(),
            self.lisa.risc,
            self.lisa.villa,
            self.lisa.lip,
            self.lisa.fast_subarrays_per_bank,
            self.lisa.fast_rows_per_subarray,
            self.lisa.villa_epoch_cycles,
            self.lisa.villa_counters,
            self.lisa.villa_hot_per_epoch,
            self.cpu.cores,
            self.cpu.clock_ratio,
            self.cpu.rob_size,
            self.cpu.mshrs,
            self.cpu.issue_width,
            self.cpu.l1_kb,
            self.cpu.l2_kb,
            self.cpu.llc_kb,
            self.os.placement.name(),
            self.backend.name(),
            Self::calibration_toml(&self.calibration),
            self.copy_mechanism.name(),
            self.requests_per_core,
            self.warmup_frac,
            self.max_cycles,
            self.seed,
        )
    }

    /// Stable 128-bit content hash (32 hex chars) of the canonical
    /// TOML serialization — the config half of the campaign result-
    /// cache key (`sim/cache.rs`). Two configs hash equal iff their
    /// `to_toml` documents are byte-equal, which the builder round-
    /// trip property pins to "equal configurations": every knob
    /// `apply` can read is covered, so any behavioral config change
    /// moves the hash and invalidates cached results.
    pub fn content_hash(&self) -> String {
        crate::util::hash::content_key(&self.to_toml())
    }

    /// Serialize the calibration section (written by `lisa calibrate`).
    pub fn calibration_toml(c: &Calibration) -> String {
        format!(
            "# Generated by `lisa calibrate` from the JAX/Pallas circuit artifacts.\n\
             [calibration]\n\
             t_rbm_ns = {}\n\
             t_rp_lip_ns = {}\n\
             t_rp_circuit_ns = {}\n\
             fast_act_ratio = {}\n\
             fast_ras_ratio = {}\n\
             fast_rp_ratio = {}\n\
             e_act_fj = {}\n\
             e_pre_fj = {}\n\
             e_rbm_fj = {}\n\
             from_artifacts = {}\n",
            c.t_rbm_ns,
            c.t_rp_lip_ns,
            c.t_rp_circuit_ns,
            c.fast_act_ratio,
            c.fast_ras_ratio,
            c.fast_rp_ratio,
            c.e_act_fj,
            c.e_pre_fj,
            c.e_rbm_fj,
            c.from_artifacts,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides_apply() {
        let cfg = SimConfig::from_toml(
            "[dram]\nbanks = 16\nspeed = \"ddr4-2400\"\nsalp = true\n\
             [lisa]\nrisc = true\nvilla = true\n\
             [cpu]\ncores = 8\n\
             [sim]\ncopy_mechanism = \"lisa-risc\"\nseed = 99\n",
        )
        .unwrap();
        assert_eq!(cfg.dram.banks, 16);
        assert_eq!(cfg.dram.speed, SpeedBin::Ddr4_2400);
        // Legacy boolean form maps true -> masa.
        assert_eq!(cfg.dram.salp, SalpMode::Masa);
        assert!(cfg.lisa.risc && cfg.lisa.villa && !cfg.lisa.lip);
        assert_eq!(cfg.cpu.cores, 8);
        assert_eq!(cfg.copy_mechanism, CopyMechanism::LisaRisc);
        assert_eq!(cfg.seed, 99);
    }

    #[test]
    fn content_hash_tracks_the_canonical_form() {
        // Equal configs hash equal (the cache key must be stable) ...
        let a = SimConfig::default();
        assert_eq!(a.content_hash(), SimConfig::default().content_hash());
        assert_eq!(a.content_hash().len(), 32);
        // ... and every cache-relevant knob moves it, including the
        // ones that silently shared config *names* before PR 4.
        let edits: [fn(&mut SimConfig); 6] = [
            |c| c.seed = 2,
            |c| c.requests_per_core += 1,
            |c| c.dram.salp = SalpMode::Masa,
            |c| c.os.placement = PlacementPolicy::SubarrayPacked,
            |c| c.calibration.t_rbm_ns += 0.5,
            // Journal/cache keys must never mix backends.
            |c| c.backend = BackendKind::Analytical,
        ];
        for (i, edit) in edits.iter().enumerate() {
            let mut cfg = SimConfig::default();
            edit(&mut cfg);
            assert_ne!(cfg.content_hash(), a.content_hash(), "edit {i}");
        }
    }

    #[test]
    fn invalid_geometry_rejected() {
        assert!(SimConfig::from_toml("[dram]\nbanks = 7\n").is_err());
        assert!(SimConfig::from_toml("[cpu]\ncores = 0\n").is_err());
    }

    #[test]
    fn u32_overflowing_geometry_rejected_at_the_boundary() {
        // Frame numbers and bulk-op page counts are u32 throughout the
        // OS layer and the trace format; geometry products past u32
        // used to wrap silently in the generators. The largest
        // power-of-two grid that still fits must validate, one doubling
        // past it must not.
        let mut cfg = SimConfig::default();
        cfg.dram.subarrays_per_bank = 1 << 16;
        cfg.dram.rows_per_subarray = 1 << 16;
        // rows_per_bank = 2^32 > u32::MAX: rejected.
        assert!(cfg.validate().is_err());
        cfg.dram.rows_per_subarray = 1 << 15;
        cfg.dram.channels = 1;
        cfg.dram.ranks = 1;
        cfg.dram.banks = 1;
        cfg.dram.columns = 1;
        // rows_per_bank = 2^31, total frames = 2^31: fits.
        cfg.validate().unwrap();
        // One more doubling anywhere pushes the *total* past u32.
        cfg.dram.banks = 4;
        assert!(cfg.validate().is_err());
        // Row wider than u32 bytes is rejected independently.
        let mut cfg = SimConfig::default();
        cfg.dram.columns = 1 << 27;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn zero_cpu_and_timing_fields_rejected() {
        // clock_ratio = 0 used to validate, making `Simulation::drive`
        // never step a core (`for _ in 0..ratio`) and silently spin to
        // the max_cycles cap. The sibling per-cycle quantities have the
        // same never-progress failure mode.
        let cases: [(&str, fn(&mut SimConfig)); 6] = [
            ("clock_ratio", |c| c.cpu.clock_ratio = 0),
            ("issue_width", |c| c.cpu.issue_width = 0),
            ("rob_size", |c| c.cpu.rob_size = 0),
            ("mshrs", |c| c.cpu.mshrs = 0),
            ("channels", |c| c.dram.channels = 0),
            ("ranks", |c| c.dram.ranks = 0),
        ];
        for (name, poison) in cases {
            let mut cfg = SimConfig::default();
            poison(&mut cfg);
            assert!(cfg.validate().is_err(), "zero {name} must be rejected");
        }
        // The TOML path runs the same validation.
        assert!(SimConfig::from_toml("[cpu]\nclock_ratio = 0\n").is_err());
        assert!(SimConfig::from_toml("[cpu]\nissue_width = 0\n").is_err());
    }

    #[test]
    fn calibration_round_trip() {
        let c = Calibration {
            t_rbm_ns: 8.5,
            from_artifacts: true,
            ..Calibration::default()
        };
        let toml = SimConfig::calibration_toml(&c);
        let cfg = SimConfig::from_toml(&toml).unwrap();
        assert!((cfg.calibration.t_rbm_ns - 8.5).abs() < 1e-9);
        assert!(cfg.calibration.from_artifacts);
    }

    #[test]
    fn copy_mechanism_parse_round_trip() {
        for m in [
            CopyMechanism::MemcpyChannel,
            CopyMechanism::RowCloneIntraSa,
            CopyMechanism::RowCloneInterBank,
            CopyMechanism::RowCloneInterSa,
            CopyMechanism::LisaRisc,
        ] {
            assert_eq!(CopyMechanism::parse(m.name()).unwrap(), m);
        }
        assert!(CopyMechanism::parse("bogus").is_err());
    }

    #[test]
    fn salp_mode_parse_round_trip() {
        for m in SalpMode::ALL {
            assert_eq!(SalpMode::parse(m.name()).unwrap(), m);
        }
        assert!(SalpMode::parse("salp3").is_err());
        // String form in TOML.
        let cfg = SimConfig::from_toml("[dram]\nsalp = \"salp2\"\n").unwrap();
        assert_eq!(cfg.dram.salp, SalpMode::Salp2);
        let cfg = SimConfig::from_toml("[dram]\nsalp = false\n").unwrap();
        assert_eq!(cfg.dram.salp, SalpMode::None);
        assert!(SimConfig::from_toml("[dram]\nsalp = \"bogus\"\n").is_err());
        // Caps: none/salp1 serialize, salp2 pairs, masa is unbounded.
        assert_eq!(SalpMode::None.open_cap(16), 1);
        assert_eq!(SalpMode::Salp1.open_cap(16), 1);
        assert_eq!(SalpMode::Salp2.open_cap(16), 2);
        assert_eq!(SalpMode::Masa.open_cap(16), 16);
        assert!(!SalpMode::None.per_subarray());
        assert!(SalpMode::Salp1.per_subarray());
        assert!(!SalpMode::Salp1.has_sa_select());
        assert!(SalpMode::Masa.has_sa_select());
    }

    #[test]
    fn backend_kind_parse_round_trip() {
        for b in BackendKind::ALL {
            assert_eq!(BackendKind::parse(b.name()).unwrap(), b);
        }
        let err = BackendKind::parse("quantum").unwrap_err().to_string();
        assert!(err.contains("cycle|analytical"), "error lists choices: {err}");
        let cfg = SimConfig::from_toml("[backend]\nkind = \"analytical\"\n").unwrap();
        assert_eq!(cfg.backend, BackendKind::Analytical);
        // The default is (and must stay) the cycle-exact controller.
        assert_eq!(SimConfig::default().backend, BackendKind::Cycle);
    }

    #[test]
    fn placement_policy_parse_round_trip() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(PlacementPolicy::parse("nope").is_err());
        let cfg = SimConfig::from_toml("[os]\nplacement = \"spread\"\n").unwrap();
        assert_eq!(cfg.os.placement, PlacementPolicy::SubarraySpread);
        assert_eq!(
            SimConfig::default().os.placement,
            PlacementPolicy::SubarrayPacked
        );
    }

    #[test]
    fn capacity_math() {
        let d = DramConfig::default();
        // 1ch * 1rk * 8 banks * 16 SA * 512 rows * 8 KB = 512 MiB.
        assert_eq!(d.capacity_bytes(), 512 << 20);
        assert_eq!(d.row_bytes(), 8192);
    }
}
