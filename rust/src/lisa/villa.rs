//! LISA-VILLA: in-DRAM caching using heterogeneous (fast) subarrays
//! (paper §3.2).
//!
//! Per bank: `villa_counters` saturating access counters (paper: 1024,
//! 6 KB of controller storage), halved every epoch to prevent
//! staleness. At each epoch boundary the `villa_hot_per_epoch` most
//! frequently accessed row groups are marked hot (paper: 16); a hot
//! row is cached into a fast-subarray slot *the next time it is
//! accessed*, by issuing an in-DRAM copy (LISA-RISC — or RC-InterSA
//! for the paper's Fig. 3 comparison, which shows RowClone's slow
//! movement makes the whole scheme lose 52.3%).
//!
//! Replacement is the benefit-based policy of Lee et al. [TL-DRAM,
//! HPCA 2013]: each slot counts hits since insertion (halved each
//! epoch); the minimum-benefit slot is evicted. Dirty slots are
//! written back (another in-DRAM copy) before the slot is reused.
//!
//! Fast-subarray rows are reserved out of the OS-visible address space
//! (see `controller::mapping::Mapper::with_reserved`), so cache fills
//! never clobber application data.

use std::collections::HashMap;

use crate::config::{CopyMechanism, SimConfig};
use crate::controller::request::CopyRequest;
use crate::dram::geometry::Address;

/// Villa copy ids live in a reserved high range so they never collide
/// with application request ids.
pub const VILLA_ID_BASE: u64 = 1 << 62;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    Empty,
    /// Fill copy in flight; translation not active yet.
    Filling,
    Valid,
    /// Dirty eviction writeback in flight.
    WritingBack,
}

#[derive(Debug, Clone)]
struct Slot {
    app_row: Option<usize>,
    state: SlotState,
    benefit: u32,
    dirty: bool,
}

#[derive(Debug, Clone)]
struct VillaBank {
    counters: Vec<u16>,
    hot: Vec<bool>,
    slots: Vec<Slot>,
    /// app row -> slot index (present for Filling and Valid slots).
    cached: HashMap<usize, usize>,
}

/// Aggregate statistics (Fig. 3's hit rate series).
#[derive(Debug, Clone, Default)]
pub struct VillaStats {
    pub accesses: u64,
    pub hits: u64,
    pub fills: u64,
    pub writebacks: u64,
    pub evictions: u64,
    pub epochs: u64,
}

impl VillaStats {
    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// The LISA-VILLA cache manager (one per memory controller).
#[derive(Debug, Clone)]
pub struct VillaManager {
    mech: CopyMechanism,
    counters_len: usize,
    hot_per_epoch: usize,
    epoch_cycles: u64,
    fast_rows_per_subarray: usize,
    rows_per_subarray: usize,
    slots_per_bank: usize,
    ranks: usize,
    banks_per_rank: usize,
    banks: Vec<VillaBank>,
    next_epoch: u64,
    next_copy_id: u64,
    /// Copy id -> (bank index, slot, what completes).
    inflight: HashMap<u64, (usize, usize, SlotState)>,
    pub stats: VillaStats,
}

impl VillaManager {
    /// `mech` is the movement mechanism for fills/writebacks: LISA-RISC
    /// normally, RC-InterSA for the paper's comparison configuration.
    pub fn new(cfg: &SimConfig, mech: CopyMechanism) -> Self {
        let slots_per_bank =
            cfg.lisa.fast_subarrays_per_bank * cfg.lisa.fast_rows_per_subarray;
        let n_banks = cfg.dram.channels * cfg.dram.ranks * cfg.dram.banks;
        let bank = VillaBank {
            counters: vec![0; cfg.lisa.villa_counters],
            hot: vec![false; cfg.lisa.villa_counters],
            slots: vec![
                Slot { app_row: None, state: SlotState::Empty, benefit: 0, dirty: false };
                slots_per_bank
            ],
            cached: HashMap::new(),
        };
        Self {
            mech,
            counters_len: cfg.lisa.villa_counters,
            hot_per_epoch: cfg.lisa.villa_hot_per_epoch,
            epoch_cycles: cfg.lisa.villa_epoch_cycles,
            fast_rows_per_subarray: cfg.lisa.fast_rows_per_subarray,
            rows_per_subarray: cfg.dram.rows_per_subarray,
            slots_per_bank,
            ranks: cfg.dram.ranks,
            banks_per_rank: cfg.dram.banks,
            banks: vec![bank; n_banks],
            next_epoch: cfg.lisa.villa_epoch_cycles,
            next_copy_id: VILLA_ID_BASE,
            inflight: HashMap::new(),
            stats: VillaStats::default(),
        }
    }

    /// Number of rows per bank that must be reserved from the address
    /// map (the whole fast subarrays).
    pub fn reserved_rows(cfg: &SimConfig) -> usize {
        if cfg.lisa.villa {
            cfg.lisa.fast_subarrays_per_bank * cfg.dram.rows_per_subarray
        } else {
            0
        }
    }

    fn bank_idx(&self, a: &Address) -> usize {
        (a.channel * self.ranks + a.rank) * self.banks_per_rank + a.bank
    }

    /// Physical row of slot `i` (slots fill the usable rows of each
    /// fast subarray; fast subarrays sit at the low subarray indices).
    fn slot_row(&self, i: usize) -> usize {
        (i / self.fast_rows_per_subarray) * self.rows_per_subarray
            + (i % self.fast_rows_per_subarray)
    }

    /// Observe an access; returns the (possibly redirected) address
    /// plus any cache-management copies to enqueue.
    ///
    /// `allow_fill` is the controller's backpressure signal: cache
    /// fills are best-effort background work and are skipped (to be
    /// retried on a later access) while the copy engine is busy —
    /// otherwise a slow movement mechanism (RC-InterSA) accumulates an
    /// unbounded fill queue and starves demand traffic entirely.
    pub fn on_access(
        &mut self,
        addr: &Address,
        is_write: bool,
        now: u64,
        core: usize,
        allow_fill: bool,
    ) -> (Address, Vec<CopyRequest>) {
        self.stats.accesses += 1;
        let bi = self.bank_idx(addr);
        let cidx = addr.row % self.counters_len;
        let counters_len = self.counters_len;
        let _ = counters_len;
        {
            let b = &mut self.banks[bi];
            b.counters[cidx] = b.counters[cidx].saturating_add(1);
        }

        // Served from the cache?
        if let Some(&slot_idx) = self.banks[bi].cached.get(&addr.row) {
            if self.banks[bi].slots[slot_idx].state == SlotState::Valid {
                let slot_row = self.slot_row(slot_idx);
                let b = &mut self.banks[bi];
                let s = &mut b.slots[slot_idx];
                s.benefit = s.benefit.saturating_add(1);
                s.dirty |= is_write;
                self.stats.hits += 1;
                let mut redirected = *addr;
                redirected.row = slot_row;
                return (redirected, vec![]);
            }
            // Fill still in flight: serve from the original location.
            return (*addr, vec![]);
        }

        // Hot and uncached: insert on this access (paper: "cache them
        // when they are accessed the next time").
        let mut copies = vec![];
        if allow_fill && self.banks[bi].hot[cidx] {
            copies = self.try_insert(addr, now, core);
        }
        (*addr, copies)
    }

    fn try_insert(&mut self, addr: &Address, now: u64, core: usize) -> Vec<CopyRequest> {
        // Pick a victim: an empty slot, else the min-benefit Valid one.
        let bi = self.bank_idx(addr);
        let slot_idx = {
            let b = &self.banks[bi];
            match b.slots.iter().position(|s| s.state == SlotState::Empty) {
                Some(i) => Some(i),
                None => b
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.state == SlotState::Valid)
                    .min_by_key(|(_, s)| s.benefit)
                    .map(|(i, _)| i),
            }
        };
        let Some(slot_idx) = slot_idx else {
            return vec![]; // everything in transition; retry later
        };
        let slot_row = self.slot_row(slot_idx);
        let mk_addr = |row: usize| Address { row, col: 0, ..*addr };

        let b = &mut self.banks[bi];
        let victim = &mut b.slots[slot_idx];
        match victim.state {
            SlotState::Valid if victim.dirty => {
                // Write the dirty slot back first; the insert will be
                // retried on a later access.
                let old_row = victim.app_row.expect("valid slot has a row");
                victim.state = SlotState::WritingBack;
                let id = self.next_copy_id;
                self.next_copy_id += 1;
                self.inflight.insert(id, (bi, slot_idx, SlotState::WritingBack));
                self.stats.writebacks += 1;
                vec![CopyRequest {
                    id,
                    core,
                    src: mk_addr(slot_row),
                    dst: mk_addr(old_row),
                    rows: 1,
                    mechanism: self.mech,
                    arrive: now,
                }]
            }
            SlotState::Valid | SlotState::Empty => {
                if let Some(old) = victim.app_row.take() {
                    b.cached.remove(&old);
                    self.stats.evictions += 1;
                }
                let b = &mut self.banks[bi];
                b.slots[slot_idx] = Slot {
                    app_row: Some(addr.row),
                    state: SlotState::Filling,
                    benefit: 0,
                    dirty: false,
                };
                b.cached.insert(addr.row, slot_idx);
                let id = self.next_copy_id;
                self.next_copy_id += 1;
                self.inflight.insert(id, (bi, slot_idx, SlotState::Filling));
                self.stats.fills += 1;
                vec![CopyRequest {
                    id,
                    core,
                    src: mk_addr(addr.row),
                    dst: mk_addr(slot_row),
                    rows: 1,
                    mechanism: self.mech,
                    arrive: now,
                }]
            }
            _ => vec![],
        }
    }

    /// A villa-issued copy completed.
    pub fn on_copy_done(&mut self, copy_id: u64) {
        let Some((bi, slot_idx, kind)) = self.inflight.remove(&copy_id) else {
            return;
        };
        let b = &mut self.banks[bi];
        let s = &mut b.slots[slot_idx];
        match kind {
            SlotState::Filling => {
                if s.state == SlotState::Filling {
                    s.state = SlotState::Valid;
                }
            }
            SlotState::WritingBack => {
                if let Some(old) = s.app_row.take() {
                    b.cached.remove(&old);
                    self.stats.evictions += 1;
                }
                *s = Slot {
                    app_row: None,
                    state: SlotState::Empty,
                    benefit: 0,
                    dirty: false,
                };
            }
            _ => {}
        }
    }

    /// Is a villa copy id?
    pub fn owns_copy(&self, id: u64) -> bool {
        id >= VILLA_ID_BASE
    }

    /// Drop a row's cached copy without writeback (used when a bulk
    /// copy overwrites the row: the cached data would go stale).
    pub fn invalidate(&mut self, addr: &Address) {
        let bi = self.bank_idx(addr);
        let b = &mut self.banks[bi];
        if let Some(slot_idx) = b.cached.remove(&addr.row) {
            b.slots[slot_idx] = Slot {
                app_row: None,
                state: SlotState::Empty,
                benefit: 0,
                dirty: false,
            };
            self.stats.evictions += 1;
        }
    }

    /// Epoch maintenance; call every cycle (cheap when not due).
    pub fn tick(&mut self, now: u64) {
        if now < self.next_epoch {
            return;
        }
        self.next_epoch = now + self.epoch_cycles;
        self.stats.epochs += 1;
        for b in self.banks.iter_mut() {
            // Mark the top-N counters hot, then halve everything.
            let mut idx: Vec<usize> = (0..b.counters.len()).collect();
            idx.sort_unstable_by_key(|&i| std::cmp::Reverse(b.counters[i]));
            for h in b.hot.iter_mut() {
                *h = false;
            }
            for &i in idx.iter().take(self.hot_per_epoch) {
                if b.counters[i] > 0 {
                    b.hot[i] = true;
                }
            }
            for c in b.counters.iter_mut() {
                *c >>= 1;
            }
            for s in b.slots.iter_mut() {
                s.benefit >>= 1;
            }
        }
    }

    /// Slots per bank (for reports).
    pub fn slots_per_bank(&self) -> usize {
        self.slots_per_bank
    }

    /// Next cycle at which `tick` will run epoch maintenance (the
    /// fast-forward engine must not jump past it: `next_epoch` is
    /// re-armed relative to the cycle the boundary is observed at).
    pub fn next_epoch_cycle(&self) -> u64 {
        self.next_epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn villa() -> (VillaManager, SimConfig) {
        let mut cfg = SimConfig::default();
        cfg.lisa.villa = true;
        cfg.lisa.risc = true;
        cfg.lisa.villa_epoch_cycles = 1000;
        // Fewer slots than hot-rows-per-epoch so replacement tests can
        // fill the cache within one epoch.
        cfg.lisa.fast_rows_per_subarray = 4;
        (VillaManager::new(&cfg, CopyMechanism::LisaRisc), cfg)
    }

    fn addr(row: usize) -> Address {
        Address { channel: 0, rank: 0, bank: 0, row, col: 0 }
    }

    #[test]
    fn cold_rows_are_not_cached() {
        let (mut v, _) = villa();
        let (a, copies) = v.on_access(&addr(600), false, 0, 0, true);
        assert_eq!(a.row, 600);
        assert!(copies.is_empty());
        assert_eq!(v.stats.hits, 0);
    }

    #[test]
    fn hot_row_cached_after_epoch_and_hits_redirect() {
        let (mut v, _) = villa();
        // Make row 600 hot during epoch 0.
        for _ in 0..50 {
            v.on_access(&addr(600), false, 10, 0, true);
        }
        v.tick(1000); // epoch boundary: row 600's counter marked hot
        // Next access triggers the fill copy.
        let (_, copies) = v.on_access(&addr(600), false, 1001, 0, true);
        assert_eq!(copies.len(), 1);
        let c = &copies[0];
        assert_eq!(c.src.row, 600);
        assert!(c.dst.row < 32, "slot must be in the fast subarray");
        assert_eq!(c.mechanism, CopyMechanism::LisaRisc);
        // Until the copy completes, accesses still go to the slow row.
        let (a, _) = v.on_access(&addr(600), false, 1002, 0, true);
        assert_eq!(a.row, 600);
        // Completion activates the translation.
        v.on_copy_done(c.id);
        let (a, _) = v.on_access(&addr(600), false, 1003, 0, true);
        assert_eq!(a.row, c.dst.row);
        assert_eq!(v.stats.hits, 1);
        assert!(v.stats.hit_rate() > 0.0);
    }

    #[test]
    fn dirty_eviction_writes_back_first() {
        let (mut v, cfg) = villa();
        let slots = v.slots_per_bank();
        // Fill every slot with a distinct hot row, dirty them.
        for s in 0..slots {
            let row = 600 + s * 7;
            for _ in 0..50 {
                v.on_access(&addr(row), false, 10, 0, true);
            }
        }
        v.tick(1000);
        let mut ids = vec![];
        for s in 0..slots {
            let row = 600 + s * 7;
            let (_, copies) = v.on_access(&addr(row), false, 1001, 0, true);
            assert_eq!(copies.len(), 1, "slot {s}");
            ids.push(copies[0].id);
        }
        for id in ids {
            v.on_copy_done(id);
        }
        // Dirty them via writes (now redirected).
        for s in 0..slots {
            let row = 600 + s * 7;
            let (a, _) = v.on_access(&addr(row), true, 1100, 0, true);
            assert!(a.row < VillaManager::reserved_rows(&cfg));
        }
        // Make a NEW row hot; inserting it must evict -> writeback.
        for _ in 0..200 {
            v.on_access(&addr(5000), false, 1200, 0, true);
        }
        v.tick(2000);
        let (_, copies) = v.on_access(&addr(5000), false, 2001, 0, true);
        assert_eq!(copies.len(), 1);
        let wb = &copies[0];
        // Writeback goes fast-slot -> app row.
        assert!(wb.src.row < 32);
        assert!(wb.dst.row >= 512);
        assert_eq!(v.stats.writebacks, 1);
        // After the writeback completes, the next access inserts.
        v.on_copy_done(wb.id);
        let (_, copies) = v.on_access(&addr(5000), false, 2002, 0, true);
        assert_eq!(copies.len(), 1);
        assert_eq!(copies[0].src.row, 5000);
    }

    #[test]
    fn benefit_based_replacement_picks_least_useful() {
        let (mut v, _) = villa();
        let slots = v.slots_per_bank();
        // Insert `slots` rows, give them different hit counts.
        for s in 0..slots {
            let row = 600 + s;
            for _ in 0..50 {
                v.on_access(&addr(row), false, 10, 0, true);
            }
        }
        v.tick(1000);
        let mut ids = vec![];
        for s in 0..slots {
            let (_, c) = v.on_access(&addr(600 + s), false, 1001, 0, true);
            ids.push(c[0].id);
        }
        for id in ids {
            v.on_copy_done(id);
        }
        // Row 600 gets many hits; 601 gets none.
        for _ in 0..20 {
            v.on_access(&addr(600), false, 1100, 0, true);
        }
        // New hot row must evict the zero-benefit victim (clean).
        for _ in 0..200 {
            v.on_access(&addr(9000), false, 1200, 0, true);
        }
        v.tick(2000);
        let (_, copies) = v.on_access(&addr(9000), false, 2001, 0, true);
        assert_eq!(copies.len(), 1);
        v.on_copy_done(copies[0].id);
        // 600 must still hit; 601 must miss.
        let (a600, _) = v.on_access(&addr(600), false, 2100, 0, true);
        assert!(a600.row < 32, "high-benefit row evicted");
        let (a601, _) = v.on_access(&addr(601), false, 2100, 0, true);
        assert_eq!(a601.row, 601, "zero-benefit row should have been evicted");
    }

    #[test]
    fn counters_halve_each_epoch() {
        let (mut v, _) = villa();
        for _ in 0..40 {
            v.on_access(&addr(600), false, 1, 0, true);
        }
        let bi = 0;
        let cidx = 600 % 1024;
        assert_eq!(v.banks[bi].counters[cidx], 40);
        v.tick(1000);
        assert_eq!(v.banks[bi].counters[cidx], 20);
        assert_eq!(v.stats.epochs, 1);
    }

    #[test]
    fn reserved_rows_matches_fast_geometry() {
        let (_, cfg) = villa();
        assert_eq!(VillaManager::reserved_rows(&cfg), 512);
        let mut off = cfg.clone();
        off.lisa.villa = false;
        assert_eq!(VillaManager::reserved_rows(&off), 0);
    }
}
