//! Perf bench: the simulator's own hot path (EXPERIMENTS.md §Perf).
//! Measures controller tick throughput and end-to-end simulated
//! DRAM-cycles/second on representative workloads.

use std::time::Instant;

use lisa::config::SimConfig;
use lisa::sim::engine::Simulation;
use lisa::util::bench::Table;
use lisa::workloads::mixes;

fn bench_workload(name: &str, requests: u64) -> (f64, u64) {
    let mut cfg = SimConfig::default().with_all_lisa();
    cfg.requests_per_core = requests;
    let wl = mixes::workload_by_name(name, &cfg).unwrap();
    let mut sim = Simulation::new(cfg, wl);
    let t0 = Instant::now();
    let r = sim.run();
    let dt = t0.elapsed().as_secs_f64();
    (r.dram_cycles as f64 / dt, r.dram_cycles)
}

fn main() {
    println!("=== Simulator hot-path throughput ===\n");
    let mut t = Table::new(&["workload", "sim cycles", "Mcycles/s"]);
    for name in ["stream4", "random4", "hotspot4", "fork4"] {
        let (rate, cycles) = bench_workload(name, 5_000);
        t.row(&[
            name.to_string(),
            format!("{cycles}"),
            format!("{:.2}", rate / 1e6),
        ]);
    }
    t.print();
    println!("\ntarget (DESIGN.md §Perf): > 10 Mcycles/s single channel");
}
