//! Cycle-stamped trace events, the `Probe` sink trait, a bounded ring
//! buffer, and the two export formats (JSONL and Chrome trace-event
//! JSON, viewable in Perfetto / `chrome://tracing`).

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Arc, Mutex};

use crate::dram::command::Command;

/// What happened. Command kinds mirror [`Command::name`]; the rest are
/// controller-internal transitions (queue admission, copy sequencing,
/// refresh windows) that a command-only trace cannot show.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceKind {
    Act,
    ActCopy,
    ActStore,
    Pre,
    PreSa,
    PreAll,
    Rd,
    Wr,
    Ref,
    Rbm,
    Transfer,
    /// Refresh became due on a rank (queues park until REF completes).
    RefPend,
    /// A demand request entered its read/write queue.
    Enq,
    /// A bulk copy entered a channel's copy queue.
    CopyEnq,
    /// The copy engine picked up a queued copy.
    CopyStart,
    /// The active copy took ownership of a bank (scheduler pass 2
    /// parks row preparation there until `CopyRelease`).
    CopyOwn,
    CopyRelease,
    /// The copy's full command sequence retired.
    CopyDone,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Act => "ACT",
            TraceKind::ActCopy => "ACT_COPY",
            TraceKind::ActStore => "ACT_STORE",
            TraceKind::Pre => "PRE",
            TraceKind::PreSa => "PRE_SA",
            TraceKind::PreAll => "PREA",
            TraceKind::Rd => "RD",
            TraceKind::Wr => "WR",
            TraceKind::Ref => "REF",
            TraceKind::Rbm => "RBM",
            TraceKind::Transfer => "TRANSFER",
            TraceKind::RefPend => "REF_PEND",
            TraceKind::Enq => "ENQ",
            TraceKind::CopyEnq => "COPY_ENQ",
            TraceKind::CopyStart => "COPY_START",
            TraceKind::CopyOwn => "COPY_OWN",
            TraceKind::CopyRelease => "COPY_RELEASE",
            TraceKind::CopyDone => "COPY_DONE",
        }
    }
}

/// One flat, `Copy` trace record. `-1` marks "not applicable" for the
/// signed fields so every kind shares one layout (the ring buffer
/// stays a flat `Vec`, no per-kind allocation on the hot path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub kind: TraceKind,
    /// Cycle the event was observed (command issue cycle).
    pub cycle: u64,
    /// Cycle the operation completes (`== cycle` for instantaneous
    /// transitions like queue admission).
    pub done: u64,
    pub ch: usize,
    pub rank: usize,
    /// Bank, or -1 for rank-scope events (REF, PREA, REF_PEND).
    pub bank: i64,
    /// Subarray, or -1 for bank-/rank-scope events.
    pub sa: i64,
    pub row: i64,
    pub col: i64,
    /// Owning request or copy id (-1 when none is associated).
    pub id: i64,
    /// Arrival cycle of the owning request (0 when not applicable).
    pub arrive: u64,
    /// Kind-specific payload: queue depth after ENQ, `to_sa` for RBM,
    /// destination bank for TRANSFER, row count for COPY_ENQ/START.
    pub val: i64,
    /// True when the event belongs to a bulk-copy operation.
    pub copy: bool,
}

impl TraceEvent {
    /// A bare event; fill the applicable fields at the emit site.
    pub fn new(kind: TraceKind, cycle: u64, ch: usize, rank: usize) -> Self {
        TraceEvent {
            kind,
            cycle,
            done: cycle,
            ch,
            rank,
            bank: -1,
            sa: -1,
            row: -1,
            col: -1,
            id: -1,
            arrive: 0,
            val: -1,
            copy: false,
        }
    }

    /// Map an issued DRAM command to its trace event. `rows_per_sa`
    /// locates the subarray of row-addressed commands (rows are
    /// bank-relative, subarray-major).
    pub fn from_command(
        ch: usize,
        cmd: &Command,
        cycle: u64,
        done: u64,
        rows_per_sa: usize,
    ) -> Self {
        let sa_of = |row: usize| (row / rows_per_sa.max(1)) as i64;
        let mut ev = TraceEvent::new(TraceKind::Act, cycle, ch, cmd.rank());
        ev.done = done;
        match *cmd {
            Command::Act { bank, row, .. } => {
                ev.kind = TraceKind::Act;
                ev.bank = bank as i64;
                ev.sa = sa_of(row);
                ev.row = row as i64;
            }
            Command::ActCopy { bank, row, .. } => {
                ev.kind = TraceKind::ActCopy;
                ev.bank = bank as i64;
                ev.sa = sa_of(row);
                ev.row = row as i64;
                ev.copy = true;
            }
            Command::ActStore { bank, row, .. } => {
                ev.kind = TraceKind::ActStore;
                ev.bank = bank as i64;
                ev.sa = sa_of(row);
                ev.row = row as i64;
                ev.copy = true;
            }
            Command::Pre { bank, .. } => {
                ev.kind = TraceKind::Pre;
                ev.bank = bank as i64;
            }
            Command::PreSa { bank, sa, .. } => {
                ev.kind = TraceKind::PreSa;
                ev.bank = bank as i64;
                ev.sa = sa as i64;
            }
            Command::PreAll { .. } => ev.kind = TraceKind::PreAll,
            Command::Rd { bank, sa, col, .. } => {
                ev.kind = TraceKind::Rd;
                ev.bank = bank as i64;
                ev.sa = sa as i64;
                ev.col = col as i64;
            }
            Command::Wr { bank, sa, col, .. } => {
                ev.kind = TraceKind::Wr;
                ev.bank = bank as i64;
                ev.sa = sa as i64;
                ev.col = col as i64;
            }
            Command::Ref { .. } => ev.kind = TraceKind::Ref,
            Command::Rbm { bank, from_sa, to_sa, .. } => {
                ev.kind = TraceKind::Rbm;
                ev.bank = bank as i64;
                ev.sa = from_sa as i64;
                ev.val = to_sa as i64;
                ev.copy = true;
            }
            Command::Transfer { src_bank, dst_bank, cols, .. } => {
                ev.kind = TraceKind::Transfer;
                ev.bank = src_bank as i64;
                ev.val = dst_bank as i64;
                ev.col = cols as i64;
                ev.copy = true;
            }
        }
        ev
    }

    /// One JSON object (a JSONL line, minus the newline).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"kind\":\"{}\",\"cycle\":{},\"done\":{},\"ch\":{},\"rank\":{},\
             \"bank\":{},\"sa\":{},\"row\":{},\"col\":{},\"id\":{},\
             \"arrive\":{},\"val\":{},\"copy\":{}}}",
            self.kind.name(),
            self.cycle,
            self.done,
            self.ch,
            self.rank,
            self.bank,
            self.sa,
            self.row,
            self.col,
            self.id,
            self.arrive,
            self.val,
            self.copy,
        )
    }
}

/// A sink for trace events. Implementations must be cheap: the
/// controller calls `record` on every observable transition while a
/// probe is attached (and never when none is).
pub trait Probe: Send {
    fn record(&mut self, ev: &TraceEvent);
}

/// Bounded ring buffer of trace events: the newest `cap` events are
/// kept, older ones are dropped (counted, so exports can say so).
#[derive(Debug)]
pub struct TraceRing {
    cap: usize,
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// Default ring capacity (~1M events; a flat 120-byte record each).
pub const DEFAULT_RING_CAP: usize = 1 << 20;

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing { cap: cap.max(1), events: VecDeque::new(), dropped: 0 }
    }

    pub fn push(&mut self, ev: TraceEvent) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events oldest-first (the order they were recorded).
    pub fn to_vec(&self) -> Vec<TraceEvent> {
        self.events.iter().copied().collect()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

impl Probe for TraceRing {
    fn record(&mut self, ev: &TraceEvent) {
        self.push(*ev);
    }
}

/// A `TraceRing` behind `Arc<Mutex>`: hand one clone to the simulation
/// as its probe, keep the other to snapshot the events afterwards.
#[derive(Clone)]
pub struct SharedTraceRing(Arc<Mutex<TraceRing>>);

impl SharedTraceRing {
    pub fn new(cap: usize) -> Self {
        SharedTraceRing(Arc::new(Mutex::new(TraceRing::new(cap))))
    }

    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.0.lock().expect("trace ring lock").to_vec()
    }

    pub fn dropped(&self) -> u64 {
        self.0.lock().expect("trace ring lock").dropped()
    }
}

impl Probe for SharedTraceRing {
    fn record(&mut self, ev: &TraceEvent) {
        self.0.lock().expect("trace ring lock").push(*ev);
    }
}

/// One JSON object per line, oldest event first.
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&ev.to_json());
        out.push('\n');
    }
    out
}

/// Encode a track id: one Perfetto "thread" per (rank, bank,
/// subarray), with bank/sa = -1 collapsing to the enclosing scope's
/// track (rank-wide REF, bank-wide PRE).
fn track_id(ev: &TraceEvent) -> i64 {
    ev.rank as i64 * 4096 + (ev.bank + 1) * 64 + (ev.sa + 1)
}

fn track_name(ev: &TraceEvent) -> String {
    match (ev.bank, ev.sa) {
        (-1, _) => format!("r{}", ev.rank),
        (b, -1) => format!("r{} b{}", ev.rank, b),
        (b, s) => format!("r{} b{} sa{}", ev.rank, b, s),
    }
}

/// Chrome trace-event JSON (the `{"traceEvents":[...]}` object form):
/// one process per channel, one thread per rank/bank/subarray track,
/// every event a complete (`"ph":"X"`) slice at its issue cycle with
/// its occupancy as the duration. Open the file in
/// <https://ui.perfetto.dev> or `chrome://tracing`.
pub fn to_chrome_trace(events: &[TraceEvent]) -> String {
    let mut pids: BTreeSet<usize> = BTreeSet::new();
    let mut tracks: BTreeMap<(usize, i64), String> = BTreeMap::new();
    for ev in events {
        pids.insert(ev.ch);
        tracks.entry((ev.ch, track_id(ev))).or_insert_with(|| track_name(ev));
    }
    let mut lines: Vec<String> = Vec::with_capacity(events.len() + tracks.len() + 1);
    for pid in &pids {
        lines.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\
             \"args\":{{\"name\":\"ch{pid}\"}}}}"
        ));
    }
    for ((pid, tid), name) in &tracks {
        lines.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }
    for ev in events {
        lines.push(format!(
            "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\
             \"tid\":{},\"args\":{{\"row\":{},\"col\":{},\"id\":{},\"val\":{},\
             \"copy\":{}}}}}",
            ev.kind.name(),
            ev.cycle,
            ev.done.saturating_sub(ev.cycle),
            ev.ch,
            track_id(ev),
            ev.row,
            ev.col,
            ev.id,
            ev.val,
            ev.copy,
        ));
    }
    format!("{{\"traceEvents\":[\n{}\n]}}\n", lines.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: TraceKind, cycle: u64, bank: i64, sa: i64) -> TraceEvent {
        let mut e = TraceEvent::new(kind, cycle, 0, 0);
        e.bank = bank;
        e.sa = sa;
        e.done = cycle + 10;
        e
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let mut r = TraceRing::new(3);
        for i in 0..5 {
            r.push(ev(TraceKind::Act, i, 0, 0));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let v = r.to_vec();
        assert_eq!(v[0].cycle, 2, "oldest surviving event first");
        assert_eq!(v[2].cycle, 4);
    }

    #[test]
    fn command_mapping_locates_subarray() {
        let cmd = Command::Act { rank: 1, bank: 2, row: 700 };
        let e = TraceEvent::from_command(0, &cmd, 5, 20, 512);
        assert_eq!(e.kind, TraceKind::Act);
        assert_eq!((e.rank, e.bank, e.sa, e.row), (1, 2, 1, 700));
        let rbm = Command::Rbm { rank: 0, bank: 0, from_sa: 1, to_sa: 4 };
        let e = TraceEvent::from_command(0, &rbm, 5, 30, 512);
        assert_eq!((e.sa, e.val, e.copy), (1, 4, true));
        let r = Command::Ref { rank: 1 };
        let e = TraceEvent::from_command(0, &r, 5, 500, 512);
        assert_eq!((e.bank, e.sa), (-1, -1));
    }

    #[test]
    fn chrome_export_separates_tracks_and_parses() {
        let events = vec![
            ev(TraceKind::Act, 0, 0, 0),
            ev(TraceKind::Act, 5, 0, 1),
            ev(TraceKind::Ref, 9, -1, -1),
        ];
        let out = to_chrome_trace(&events);
        let v = crate::util::json::parse(&out).expect("well-formed JSON");
        let arr = v.get("traceEvents").and_then(|t| t.as_array()).unwrap();
        // 1 process + 3 distinct tracks + 3 events.
        assert_eq!(arr.len(), 7);
        let tids: std::collections::BTreeSet<i64> = events.iter().map(track_id).collect();
        assert_eq!(tids.len(), 3, "distinct (bank, sa) tracks");
        assert!(out.contains("\"name\":\"r0 b0 sa1\""), "{out}");
        assert!(out.contains("\"name\":\"r0\""), "{out}");
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line() {
        let events = vec![ev(TraceKind::Enq, 1, 3, -1), ev(TraceKind::Rd, 2, 3, 0)];
        let out = to_jsonl(&events);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            let v = crate::util::json::parse(l).unwrap();
            assert!(v.get("kind").is_some());
            assert!(v.get("cycle").and_then(|c| c.as_u64()).is_some());
        }
    }
}
