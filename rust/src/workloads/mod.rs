//! Synthetic workload substrate: trace generators reproducing the
//! memory-behaviour classes of the paper's Pin-based SPEC/TBB/copy
//! workloads (DESIGN.md substitution map row 3), and the 50 four-core
//! mixes the evaluation sweeps over.

pub mod generators;
pub mod mixes;
pub mod os_scenarios;

pub use generators::{CoreSpec, WorkloadKind};
pub use mixes::{all_mixes, workload_by_name, Workload};
pub use os_scenarios::OsScenario;
