//! Parallel experiment campaigns: shard independent `Simulation` runs
//! across OS threads with deterministic result ordering.
//!
//! The paper's evaluation sweeps {mechanism × workload × config} grids
//! through the simulator; every point is an independent, deterministic
//! run, so the campaign layer is embarrassingly parallel. Jobs are
//! claimed from an atomic cursor and their results written back by
//! index, so the same campaign at 1, 2 or N threads yields identical
//! ordered results — only wall-clock time changes. Used by the
//! weighted-speedup helper (the N alone runs + 1 shared run) and the
//! declarative experiment grids (`sim/spec.rs`), which expand every
//! `ExperimentSpec` into the jobs sharded here.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::SimConfig;
use crate::metrics::RunReport;
use crate::sim::engine::Simulation;
use crate::workloads::Workload;

/// Default worker count: one per available hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a user-supplied `--threads` value: absent or `0` means
/// "auto-detect the available parallelism" (like `make -j` semantics),
/// anything else is taken literally. Shared by every campaign-backed
/// CLI subcommand.
pub fn resolve_threads(requested: Option<usize>) -> usize {
    match requested {
        None | Some(0) => default_threads(),
        Some(n) => n,
    }
}

/// Run `jobs` across up to `threads` workers; results come back in
/// job order regardless of scheduling. Panics in a job propagate.
pub fn run_jobs<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    let slots: Vec<Mutex<Option<F>>> =
        jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = slots[i].lock().expect("job slot").take().expect("claimed once");
                let result = job();
                *out[i].lock().expect("result slot") = Some(result);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().expect("result lock").expect("job completed"))
        .collect()
}

/// Run a batch of (config, workload) simulations in parallel,
/// preserving input order.
pub fn run_reports(points: Vec<(SimConfig, Workload)>, threads: usize) -> Vec<RunReport> {
    let jobs: Vec<_> = points
        .into_iter()
        .map(|(cfg, wl)| move || Simulation::new(cfg, wl).run())
        .collect();
    run_jobs(jobs, threads)
}

/// Alone-run IPCs for every core of a workload (the denominator of
/// weighted speedup), sharded across `threads` workers.
pub fn alone_ipcs(cfg: &SimConfig, workload: &Workload, threads: usize) -> Vec<f64> {
    let jobs: Vec<_> = (0..workload.cores.len())
        .map(|i| {
            let cfg = cfg.clone();
            move || Simulation::new_alone(cfg, workload, i).run().ipc[0]
        })
        .collect();
    run_jobs(jobs, threads)
}

/// Weighted speedup of a workload on a config: the N alone runs and
/// the shared run are independent, so all N+1 go through the campaign
/// runner together.
pub fn weighted_speedup(
    cfg: &SimConfig,
    workload: &Workload,
    threads: usize,
) -> (f64, RunReport) {
    let n = workload.cores.len();
    let jobs: Vec<Box<dyn FnOnce() -> RunReport + Send + '_>> = (0..=n)
        .map(|i| {
            let cfg = cfg.clone();
            let job: Box<dyn FnOnce() -> RunReport + Send + '_> = if i < n {
                Box::new(move || Simulation::new_alone(cfg, workload, i).run())
            } else {
                Box::new(move || Simulation::new(cfg, workload.clone()).run())
            };
            job
        })
        .collect();
    let mut reports = run_jobs(jobs, threads);
    let shared = reports.pop().expect("shared run present");
    let alone: Vec<f64> = reports.iter().map(|r| r.ipc[0]).collect();
    (shared.weighted_speedup(&alone), shared)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mixes;

    #[test]
    fn threads_zero_autodetects() {
        let auto = default_threads();
        assert!(auto >= 1);
        assert_eq!(resolve_threads(None), auto);
        assert_eq!(resolve_threads(Some(0)), auto);
        assert_eq!(resolve_threads(Some(3)), 3);
        // And a campaign driven by the resolved value still works.
        let jobs: Vec<_> = (0..4u64).map(|i| move || i * 2).collect();
        assert_eq!(run_jobs(jobs, resolve_threads(Some(0))), vec![0, 2, 4, 6]);
    }

    #[test]
    fn run_jobs_preserves_order_across_thread_counts() {
        // Jobs finish in scrambled wall-clock order (varying work), but
        // results must always come back in submission order.
        fn mk_jobs() -> Vec<impl FnOnce() -> (u64, u64) + Send> {
            (0..32u64)
                .map(|i| {
                    move || {
                        // Unequal work so threads interleave.
                        let mut acc = i;
                        for k in 0..((i % 7) * 1000) {
                            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
                        }
                        (i, acc)
                    }
                })
                .collect()
        }
        let serial = run_jobs(mk_jobs(), 1);
        for threads in [2, 4, 8] {
            let parallel = run_jobs(mk_jobs(), threads);
            assert_eq!(serial, parallel, "threads={threads}");
        }
        assert_eq!(run_jobs(Vec::<fn() -> u8>::new(), 4), Vec::<u8>::new());
    }

    #[test]
    fn run_reports_preserves_point_order() {
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 300;
        let wl_a = mixes::workload_by_name("stream4", &cfg).unwrap();
        let wl_b = mixes::workload_by_name("fork4", &cfg).unwrap();
        let points =
            vec![(cfg.clone(), wl_a.clone()), (cfg.clone(), wl_b.clone())];
        let serial = run_reports(points.clone(), 1);
        assert_eq!(serial[0].workload, "stream4");
        assert_eq!(serial[1].workload, "fork4");
        assert_eq!(serial, run_reports(points, 4));
    }

    #[test]
    fn indexed_scheduler_state_is_thread_migration_safe() {
        // The controller's per-channel horizon cache is interior-
        // mutable state private to each Simulation; campaigns move
        // Simulations across worker threads. A SALP + copy-heavy grid
        // (the configs with the most per-bank bucket and cache churn)
        // must stay byte-identical at 1, 2 and 8 threads.
        use crate::config::{CopyMechanism, SalpMode};
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 300;
        cfg.dram.salp = SalpMode::Masa;
        cfg.lisa.risc = true;
        cfg.copy_mechanism = CopyMechanism::LisaRisc;
        let points: Vec<(SimConfig, Workload)> =
            ["salp-shared-bank4", "salp-copy-conflict4", "fork4"]
                .iter()
                .map(|w| (cfg.clone(), mixes::workload_by_name(w, &cfg).unwrap()))
                .collect();
        let serial = run_reports(points.clone(), 1);
        assert_eq!(serial, run_reports(points.clone(), 2));
        assert_eq!(serial, run_reports(points, 8));
    }

    #[test]
    fn parallel_weighted_speedup_matches_serial_engine() {
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 800;
        let wl = mixes::workload_by_name("random4", &cfg).unwrap();
        let (ws_serial, rep_serial) = crate::sim::engine::weighted_speedup(&cfg, &wl);
        let (ws_par, rep_par) = weighted_speedup(&cfg, &wl, 4);
        assert_eq!(rep_serial, rep_par);
        assert!((ws_serial - ws_par).abs() < 1e-12, "{ws_serial} vs {ws_par}");
        let alone = alone_ipcs(&cfg, &wl, 8);
        assert_eq!(alone, crate::sim::engine::alone_ipcs(&cfg, &wl));
    }
}
