//! The three LISA applications (paper §3):
//!
//! * `rbm` — row buffer movement analytics (bandwidth model, §2);
//! * `villa` — LISA-VILLA in-DRAM caching with heterogeneous
//!   subarrays (§3.2): hot-row tracking, benefit-based replacement,
//!   cache-fill copies through LISA-RISC (or RC-InterSA for the
//!   paper's comparison point);
//! * `lip` — LISA-LIP linked precharge analytics (§3.3); the timing
//!   substitution itself lives in the device model
//!   (`dram::bank`, PRE path).

pub mod lip;
pub mod rbm;
pub mod villa;
