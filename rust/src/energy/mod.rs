//! DRAM energy model: per-command dynamic energies plus background
//! power, IDD-style accounting. Row-level op energies derive from the
//! calibrated circuit model (per-bitline fJ from the Pallas/JAX
//! artifacts × bitlines per rank-row × a peripheral factor covering
//! wordline decode, drivers and control — the parts outside the
//! bitline SPICE scope). Column/IO energies are fit to the paper's
//! Table 1 anchors (memcpy 6.2 µJ, RC-InterSA 4.33 µJ, RC-Bank
//! 2.08 µJ per 8 KB row).

use crate::config::Calibration;
use crate::dram::bank::CommandStats;

/// Bitlines driven per rank-level row operation (8 chips x 8K cells).
pub const BITLINES_PER_ROW: f64 = 65536.0;

/// Peripheral multipliers: total op energy / pure-bitline energy.
/// Fit once against Table 1's RC-IntraSA (ACT/PRE) and LISA slope
/// (RBM) anchors; see EXPERIMENTS.md §Energy-Calibration.
const PERIPH_ACT: f64 = 5.36;
const PERIPH_PRE: f64 = 5.36;
const PERIPH_RBM: f64 = 2.42;

/// Per-operation energies in nanojoules.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub e_act_nj: f64,
    pub e_pre_nj: f64,
    pub e_rbm_hop_nj: f64,
    /// Column read/write (array + internal datapath), per 64 B line.
    pub e_rd_col_nj: f64,
    pub e_wr_col_nj: f64,
    /// Off-chip I/O + termination per 64 B line.
    pub e_io_col_nj: f64,
    /// Internal inter-bank transfer per 64 B line (RowClone PSM).
    pub e_transfer_col_nj: f64,
    pub e_ref_nj: f64,
    /// Background power in watts (per rank, active-standby average).
    pub p_background_w: f64,
}

impl EnergyModel {
    /// Build from the circuit-model calibration.
    pub fn from_calibration(cal: &Calibration) -> Self {
        let fj_to_nj = 1e-6;
        Self {
            e_act_nj: cal.e_act_fj * BITLINES_PER_ROW * PERIPH_ACT * fj_to_nj,
            e_pre_nj: cal.e_pre_fj * BITLINES_PER_ROW * PERIPH_PRE * fj_to_nj,
            e_rbm_hop_nj: cal.e_rbm_fj * BITLINES_PER_ROW * PERIPH_RBM * fj_to_nj,
            e_rd_col_nj: 9.0,
            e_wr_col_nj: 9.0,
            e_io_col_nj: 15.0,
            e_transfer_col_nj: 15.6,
            e_ref_nj: 110.0,
            p_background_w: 0.15,
        }
    }

    /// Total DRAM energy for a run, in microjoules.
    pub fn total_uj(&self, stats: &CommandStats, cycles: u64, tck_ns: f64) -> f64 {
        self.breakdown_uj(stats, cycles, tck_ns).total
    }

    pub fn breakdown_uj(&self, s: &CommandStats, cycles: u64, tck_ns: f64) -> EnergyBreakdown {
        let acts = (s.n_act + s.n_act_copy + s.n_act_store) as f64 * self.e_act_nj;
        // `n_pre` counts whole-bank PREs and per-subarray PRE_SAs
        // alike at one e_pre each: the calibrated e_pre reflects a
        // single subarray's bitlines (the baseline's typical case), so
        // a SALP path closed by N PRE_SAs charges the same energy as N
        // subarrays physically precharging; whole-bank PREs over
        // multiple open subarrays undercount correspondingly (a
        // pre-existing simplification of the baseline model).
        let pres = s.n_pre as f64 * self.e_pre_nj;
        let rbm = s.n_rbm_hops as f64 * self.e_rbm_hop_nj;
        let rd = s.n_rd as f64 * (self.e_rd_col_nj + self.e_io_col_nj);
        let wr = s.n_wr as f64 * (self.e_wr_col_nj + self.e_io_col_nj);
        let transfer = s.n_transfer_cols as f64 * self.e_transfer_col_nj;
        let refresh = s.n_ref as f64 * self.e_ref_nj;
        let background = cycles as f64 * tck_ns * self.p_background_w; // ns * W = nJ
        let dynamic = acts + pres + rbm + rd + wr + transfer + refresh;
        EnergyBreakdown {
            act_uj: acts / 1000.0,
            pre_uj: pres / 1000.0,
            rbm_uj: rbm / 1000.0,
            rdwr_uj: (rd + wr) / 1000.0,
            transfer_uj: transfer / 1000.0,
            refresh_uj: refresh / 1000.0,
            background_uj: background / 1000.0,
            total: (dynamic + background) / 1000.0,
        }
    }
}

/// Energy breakdown in microjoules. `PartialEq` is exact (bitwise)
/// float equality: two runs over the same command stream produce
/// identical breakdowns, which the engine-equivalence tests assert.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub act_uj: f64,
    pub pre_uj: f64,
    pub rbm_uj: f64,
    pub rdwr_uj: f64,
    pub transfer_uj: f64,
    pub refresh_uj: f64,
    pub background_uj: f64,
    pub total: f64,
}

impl EnergyBreakdown {
    pub fn dynamic_uj(&self) -> f64 {
        self.total - self.background_uj
    }

    /// Rebuild from the three components `RunReport::to_json` carries
    /// (`total`, `background`, `rbm`) — the campaign journal / result
    /// cache read path. The per-op components are not serialized and
    /// come back zero; re-serialization through the same three fields
    /// stays byte-identical, which is all the campaign layer compares.
    pub fn from_serialized(total: f64, background_uj: f64, rbm_uj: f64) -> Self {
        Self { total, background_uj, rbm_uj, ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Calibration, CopyMechanism};
    use crate::copy::isolated_copy;
    use crate::dram::timing::SpeedBin;

    fn model() -> EnergyModel {
        EnergyModel::from_calibration(&Calibration::default())
    }

    fn copy_energy_uj(mech: CopyMechanism, hops: usize) -> f64 {
        let r = isolated_copy(mech, hops, SpeedBin::Ddr3_1600, &Calibration::default())
            .unwrap();
        // Dynamic energy only (Table 1 reports per-op DRAM energy).
        model().breakdown_uj(&r.stats, 0, 1.25).total
    }

    #[test]
    fn table1_rc_intra_anchor() {
        // Paper: 0.06 uJ for an intra-subarray RowClone copy.
        let e = copy_energy_uj(CopyMechanism::RowCloneIntraSa, 0);
        assert!((e - 0.06).abs() < 0.02, "RC-IntraSA energy {e}");
    }

    #[test]
    fn table1_lisa_energies() {
        // Paper: 0.09 / 0.12 / 0.17 uJ at 1 / 7 / 15 hops.
        let e1 = copy_energy_uj(CopyMechanism::LisaRisc, 1);
        let e7 = copy_energy_uj(CopyMechanism::LisaRisc, 7);
        let e15 = copy_energy_uj(CopyMechanism::LisaRisc, 15);
        assert!((e1 - 0.09).abs() < 0.03, "1 hop {e1}");
        assert!((e7 - 0.12).abs() < 0.04, "7 hops {e7}");
        assert!((e15 - 0.17).abs() < 0.05, "15 hops {e15}");
        assert!(e1 < e7 && e7 < e15);
    }

    #[test]
    fn table1_memcpy_and_rowclone_anchors() {
        let memcpy = copy_energy_uj(CopyMechanism::MemcpyChannel, 7);
        let bank = copy_energy_uj(CopyMechanism::RowCloneInterBank, 0);
        let inter = copy_energy_uj(CopyMechanism::RowCloneInterSa, 7);
        assert!((memcpy - 6.2).abs() < 0.8, "memcpy {memcpy}");
        assert!((bank - 2.08).abs() < 0.4, "rc-bank {bank}");
        assert!((inter - 4.33).abs() < 0.8, "rc-inter {inter}");
    }

    #[test]
    fn lisa_vs_rowclone_energy_ratio() {
        // Paper: copying between subarrays with LISA reduces energy 48x
        // vs RowClone (RC-InterSA 4.33 vs LISA-RISC-1 0.09).
        let lisa = copy_energy_uj(CopyMechanism::LisaRisc, 1);
        let rc = copy_energy_uj(CopyMechanism::RowCloneInterSa, 7);
        let ratio = rc / lisa;
        assert!(ratio > 20.0, "energy ratio {ratio}");
    }

    #[test]
    fn background_energy_scales_with_time() {
        let m = model();
        let s = CommandStats::default();
        let e1 = m.total_uj(&s, 1_000_000, 1.25);
        let e2 = m.total_uj(&s, 2_000_000, 1.25);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }
}
