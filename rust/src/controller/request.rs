//! Memory controller request types.

use crate::config::CopyMechanism;
use crate::dram::geometry::Address;

/// A single cache-line read or write.
#[derive(Debug, Clone)]
pub struct MemRequest {
    pub id: u64,
    pub core: usize,
    pub addr: Address,
    pub is_write: bool,
    /// DRAM cycle the request entered the controller.
    pub arrive: u64,
    /// Set when the data burst completes.
    pub done: Option<u64>,
    /// When this request is internal traffic of a memcpy-over-channel
    /// copy operation, the id of that copy.
    pub copy_id: Option<u64>,
}

/// A bulk row-to-row copy (memcpy/memmove of one or more 8 KB rows).
#[derive(Debug, Clone)]
pub struct CopyRequest {
    pub id: u64,
    pub core: usize,
    /// Source row (col field ignored).
    pub src: Address,
    /// Destination row.
    pub dst: Address,
    /// Number of consecutive rows to copy.
    pub rows: usize,
    pub mechanism: CopyMechanism,
    pub arrive: u64,
}

/// Completion record handed back to the CPU model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Completion {
    pub id: u64,
    pub core: usize,
    pub at: u64,
    pub was_copy: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = MemRequest {
            id: 1,
            core: 0,
            addr: Address { channel: 0, rank: 0, bank: 2, row: 77, col: 3 },
            is_write: false,
            arrive: 100,
            done: None,
            copy_id: None,
        };
        assert!(r.done.is_none());
        assert_eq!(r.addr.bank, 2);
    }
}
