//! OS-layer virtual memory and bulk-operation subsystem.
//!
//! LISA's headline applications only pay off when system software
//! routes bulk work to them (RowClone's fork/zeroing/checkpoint
//! consumers; the PIM-adoption surveys name the OS interface as the
//! main barrier). This layer supplies that system software for the
//! simulator:
//!
//! * `page_table` — a flat per-process page table with copy-on-write;
//! * `frame_alloc` — a subarray-aware physical frame allocator whose
//!   placement policy (`config::PlacementPolicy`) decides how often
//!   copy pairs land within LISA-RISC reach;
//! * `bulk` — the engine translating `TraceOp::Bulk` primitives
//!   (memcpy / zero / fork / touch / checkpoint / promote) into
//!   page-granular copy requests on the controller's page-copy queue,
//!   with fault-triggered copies stalling the issuing core.
//!
//! The layer is constructed per `Simulation` only when a trace carries
//! bulk ops, so non-OS workloads are bit-identical to before.

pub mod bulk;
pub mod frame_alloc;
pub mod page_table;

pub use bulk::{OsLayer, OsOutcome, OS_ID_BASE};
pub use frame_alloc::FrameAlloc;
pub use page_table::{PageEntry, PageTable};
