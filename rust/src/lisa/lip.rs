//! LISA-LIP (linked precharge) analytics — experiment E3 (paper §3.3:
//! SPICE shows 2.6x faster precharge, 5 ns vs 13 ns; system evaluation
//! shows +10.3% average performance).
//!
//! The timing substitution itself is implemented in the device model:
//! `dram::bank` selects `t_rp_lip` whenever LIP is enabled and the
//! subarray being precharged has an idle (precharged) neighbor whose
//! precharge units can be linked. This module provides the analytic
//! summary used by the bench targets.

use crate::config::Calibration;
use crate::dram::bank::CommandStats;
use crate::dram::timing::{SpeedBin, Timing};

/// The E3 report: circuit-level precharge latencies.
#[derive(Debug, Clone)]
pub struct LipReport {
    /// Baseline tRP from the circuit model (ns, margined).
    pub t_rp_circuit_ns: f64,
    /// Linked-precharge latency (ns, margined).
    pub t_rp_lip_ns: f64,
    /// The paper's headline ratio (2.6x).
    pub speedup: f64,
    /// JEDEC-scaled values used by the simulator (cycles).
    pub t_rp_cycles: u64,
    pub t_rp_lip_cycles: u64,
}

pub fn lip_report(speed: SpeedBin, cal: &Calibration) -> LipReport {
    let t = Timing::new(speed, cal);
    LipReport {
        t_rp_circuit_ns: cal.t_rp_circuit_ns,
        t_rp_lip_ns: cal.t_rp_lip_ns,
        speedup: cal.t_rp_circuit_ns / cal.t_rp_lip_ns,
        t_rp_cycles: t.t_rp,
        t_rp_lip_cycles: t.t_rp_lip,
    }
}

/// Fraction of precharges that managed to link a neighbor's precharge
/// units in a simulated run.
pub fn lip_coverage(stats: &CommandStats) -> f64 {
    if stats.n_pre == 0 {
        0.0
    } else {
        stats.n_pre_lip as f64 / stats.n_pre as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_2_6x() {
        let r = lip_report(SpeedBin::Ddr3_1600, &Calibration::default());
        assert!(r.speedup > 2.0 && r.speedup < 3.2, "speedup {}", r.speedup);
        assert!(r.t_rp_lip_cycles < r.t_rp_cycles);
    }

    #[test]
    fn coverage_math() {
        let mut s = CommandStats::default();
        assert_eq!(lip_coverage(&s), 0.0);
        s.n_pre = 10;
        s.n_pre_lip = 9;
        assert!((lip_coverage(&s) - 0.9).abs() < 1e-12);
    }
}
