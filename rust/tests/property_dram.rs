//! Property-based tests over the DRAM device, controller and copy
//! engines (using the in-tree proptest harness; replay failures with
//! LISA_PROPTEST_SEED=<seed> cargo test).

use lisa::backend::Access;
use lisa::config::{Calibration, CopyMechanism, DramConfig, LisaConfig, SalpMode, SimConfig};
use lisa::controller::request::CopyRequest;
use lisa::controller::Controller;
use lisa::copy::CopyOp;
use lisa::dram::bank::DramDevice;
use lisa::dram::command::Command;
use lisa::dram::geometry::Address;
use lisa::dram::timing::{SpeedBin, Timing};
use lisa::util::proptest::check;

fn device(salp: SalpMode, lip: bool) -> DramDevice {
    let mut cfg = DramConfig::default();
    cfg.salp = salp;
    let mut lisa_cfg = LisaConfig::default();
    lisa_cfg.risc = true;
    lisa_cfg.lip = lip;
    let timing = Timing::new(SpeedBin::Ddr3_1600, &Calibration::default());
    DramDevice::new(cfg, lisa_cfg, timing)
}

#[test]
fn prop_earliest_is_idempotent_and_issue_at_earliest_succeeds() {
    // For random legal command sequences: earliest() twice gives the
    // same answer, and issuing exactly at earliest never fails.
    check("earliest/issue consistency", 60, |g| {
        let mode = *g.pick(&SalpMode::ALL);
        let mut dev = device(mode, g.bool());
        let mut now = 0u64;
        let mut last_row: Option<(usize, usize)> = None; // (bank, row)
        for _ in 0..40 {
            let bank = g.usize(8);
            let cmd = match (last_row, g.u64(4)) {
                (None, _) | (_, 0) => {
                    // Activate somewhere precharged if possible.
                    let row = g.usize(8192);
                    let c = Command::Act { rank: 0, bank, row };
                    if dev.earliest(0, c, now).is_err() {
                        // Bank open (or at the mode's open-subarray
                        // cap): precharge instead.
                        Command::Pre { rank: 0, bank }
                    } else {
                        last_row = Some((bank, row));
                        c
                    }
                }
                (Some((b, r)), 1) => {
                    Command::Rd { rank: 0, bank: b, sa: r / 512, col: g.usize(128) }
                }
                (Some((b, r)), 2) => {
                    Command::Wr { rank: 0, bank: b, sa: r / 512, col: g.usize(128) }
                }
                (Some((b, _)), _) => {
                    last_row = None;
                    Command::Pre { rank: 0, bank: b }
                }
            };
            let Ok(e1) = dev.earliest(0, cmd, now) else {
                continue;
            };
            let e2 = dev.earliest(0, cmd, now).unwrap();
            assert_eq!(e1, e2, "earliest not idempotent for {cmd:?}");
            dev.issue(0, cmd, e1).unwrap_or_else(|err| {
                panic!("issue at earliest failed for {cmd:?}: {err}")
            });
            now = e1;
            // The incrementally maintained per-bank open count must
            // match a scan of subarray state after every transition.
            for b in 0..8 {
                let bank = dev.bank(0, 0, b);
                assert_eq!(
                    bank.open_count(),
                    bank.open_count_scan(),
                    "open count drifted on bank {b} after {cmd:?}"
                );
            }
        }
    });
}

#[test]
fn prop_issue_before_earliest_always_rejected() {
    check("early issue rejected", 40, |g| {
        let mut dev = device(SalpMode::None, false);
        let row = g.usize(8192);
        dev.issue(0, Command::Act { rank: 0, bank: 0, row }, 0).unwrap();
        let rd = Command::Rd { rank: 0, bank: 0, sa: row / 512, col: g.usize(128) };
        let e = dev.earliest(0, rd, 0).unwrap();
        if e > 0 {
            let early = g.u64(e);
            assert!(dev.issue(0, rd, early).is_err(), "issued at {early} < {e}");
        }
    });
}

#[test]
fn prop_copy_engine_always_moves_the_tag() {
    // Any (mechanism, src, dst) pair: driving the CopyOp to completion
    // on an idle device moves the source tag to the destination.
    check("copy moves tag", 50, |g| {
        let cfg = DramConfig::default();
        let mut dev = device(*g.pick(&SalpMode::ALL), false);
        let mech = *g.pick(&[
            CopyMechanism::LisaRisc,
            CopyMechanism::RowCloneIntraSa,
            CopyMechanism::RowCloneInterSa,
            CopyMechanism::RowCloneInterBank,
        ]);
        let src_bank = g.usize(8);
        let src_row = g.usize(8190);
        let (dst_bank, dst_row) = if mech == CopyMechanism::RowCloneInterBank {
            ((src_bank + 1 + g.usize(6)) % 8, g.usize(8190))
        } else {
            let d = g.usize(8190);
            // Avoid the reserved temp row and identical src/dst.
            (src_bank, if d == src_row { d + 1 } else { d })
        };
        let tag = 0xAB00 + g.u64(1000);
        dev.set_row_tag(0, 0, src_bank, src_row, tag);
        let req = CopyRequest {
            id: 1,
            core: 0,
            src: Address { channel: 0, rank: 0, bank: src_bank, row: src_row, col: 0 },
            dst: Address { channel: 0, rank: 0, bank: dst_bank, row: dst_row, col: 0 },
            rows: 1,
            mechanism: mech,
            arrive: 0,
        };
        let mut op = CopyOp::new(req, &cfg);
        let mut now = 0u64;
        let mut steps = 0;
        while let Some(cmd) = op.next_command(&dev) {
            let at = dev.earliest(0, cmd, now).expect("legal step");
            dev.issue(0, cmd, at).expect("issue");
            now = at + 1;
            steps += 1;
            assert!(steps < 64, "copy sequence does not terminate");
        }
        assert_eq!(
            dev.row_tag(0, 0, dst_bank, dst_row),
            tag,
            "{mech:?} src=({src_bank},{src_row}) dst=({dst_bank},{dst_row})"
        );
        // Source unharmed.
        assert_eq!(dev.row_tag(0, 0, src_bank, src_row), tag);
    });
}

#[test]
fn prop_controller_never_stalls_forever() {
    // Random small request soups must always drain (bounded cycles).
    check("controller liveness", 12, |g| {
        let mut cfg = SimConfig::default();
        cfg.dram.salp = *g.pick(&SalpMode::ALL);
        cfg.lisa.risc = g.bool();
        cfg.lisa.lip = g.bool();
        cfg.copy_mechanism = if cfg.lisa.risc {
            CopyMechanism::LisaRisc
        } else {
            CopyMechanism::MemcpyChannel
        };
        let mut ctrl = Controller::new(cfg);
        let n_req = 1 + g.usize(24);
        let mut expected = 0;
        for i in 0..n_req {
            let addr = g.u64(64 << 20) & !63;
            let is_write = g.chance(0.3);
            let mapped = ctrl.mapper.map(addr);
            let access = if is_write {
                Access::write(i as u64 + 1, 0, mapped)
            } else {
                Access::read(i as u64 + 1, 0, mapped)
            };
            if ctrl.enqueue(access) && !is_write {
                expected += 1;
            }
        }
        if g.chance(0.7) {
            let src_row = g.usize(4000);
            let dst_row = 4000 + g.usize(3000);
            ctrl.enqueue_copy(CopyRequest {
                id: 0x9000,
                core: 0,
                src: Address { channel: 0, rank: 0, bank: 0, row: src_row, col: 0 },
                dst: Address { channel: 0, rank: 0, bank: 0, row: dst_row, col: 0 },
                rows: 1 + g.usize(3),
                mechanism: ctrl.cfg.copy_mechanism,
                arrive: 0,
            });
            expected += 1;
        }
        let mut done = 0;
        for t in 0..2_000_000u64 {
            ctrl.tick().unwrap();
            done += ctrl.drain_completions().len();
            // Periodically pin the cached horizon against a fresh
            // recomputation (every tick would dominate the runtime).
            if t % 64 == 0 {
                assert_eq!(
                    ctrl.next_event_cycle(),
                    ctrl.next_event_cycle_uncached(),
                    "stale horizon cache at cycle {}",
                    ctrl.now
                );
            }
            if ctrl.idle() {
                break;
            }
        }
        assert!(ctrl.idle(), "controller failed to drain ({done}/{expected} done)");
        assert_eq!(done, expected, "lost or duplicated completions");
    });
}

#[test]
fn prop_timing_invariants_from_stats() {
    // After any run: #ACTs >= #row-misses implied, every RBM hop count
    // consistent, LIP count <= PRE count.
    check("stats invariants", 10, |g| {
        let mut cfg = SimConfig::default();
        cfg.lisa.lip = true;
        cfg.lisa.risc = true;
        cfg.copy_mechanism = CopyMechanism::LisaRisc;
        cfg.requests_per_core = 300 + g.u64(500);
        let wl = lisa::workloads::mixes::copy_mixes(4)[g.usize(50)].clone();
        let mut sim = lisa::sim::engine::Simulation::new(cfg, wl);
        let r = sim.run();
        let s = sim.memory().command_stats();
        assert!(s.n_pre_lip <= s.n_pre);
        assert!(s.n_act >= 1);
        assert!(r.dram_cycles > 0);
        // Row buffer hygiene: every ACT eventually paired with a PRE
        // (within one outstanding open row per bank).
        assert!(s.n_pre + 8 * 2 >= s.n_act, "ACT {} vs PRE {}", s.n_act, s.n_pre);
    });
}
