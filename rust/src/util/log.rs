//! Minimal leveled logger (stderr). The simulator hot path never logs;
//! logging is for the CLI driver, calibration and experiment harnesses.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            eprintln!("[warn] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
