//! GC / heap-traversal trace generators: the E11 workload family.
//!
//! Garbage collection is the data-movement-bound pattern the paper's
//! bulk-copy substrate targets: long dependent pointer chases over a
//! large heap (MLP = 1, raw DRAM latency on the critical path)
//! punctuated by bulk evacuation phases that move whole pages. Every
//! access is a `BulkOp` at the *virtual* address level, so the OS
//! layer's frame placement policy decides how evacuation copies land
//! on subarrays — the knob E11 sweeps against the copy mechanism.
//!
//! * `Traverse`       — pure marking chase, no collection: the
//!                      low-MLP baseline.
//! * `Semispace`      — chase then bulk evacuation: live pages are
//!                      `Memcpy`d from-space to-space each cycle and
//!                      the spaces swap (Cheney-style copying GC).
//! * `ConcurrentMark` — `Fork` snapshots the heap for the marker;
//!                      mutator writes break CoW pages one
//!                      fault-copy at a time during the mark phase.
//! * `Generational`   — nursery chase; minor collections `Memcpy`
//!                      survivors into the old generation and
//!                      `Promote` the hottest survivor page into the
//!                      bank's fast zone (tenuring as migration).
//!
//! Allocation-site locality is the shared layout knob: the heap is
//! partitioned into `sites` equal regions (objects allocated together
//! sit together), and a chase step stays inside its current site
//! unless it follows a cross-site pointer. More sites = smaller,
//! tighter clusters; `CROSS_SITE` controls how often the chase leaves
//! one.

use crate::config::SimConfig;
use crate::cpu::trace::{BulkOp, TraceOp};
use crate::util::rng::Pcg32;

/// Syscall-ish overheads, matching the E9 scenarios' scale.
const GC_CALL_NONMEM: u32 = 20;
const FORK_NONMEM: u32 = 60;
/// Probability a chase step follows a pointer out of its site.
const CROSS_SITE: f64 = 0.25;
/// Mutation writes interleaved with the chase (forwarding pointers,
/// mark bits); kept read-mostly so the chase stays latency-bound.
const CHASE_WRITE: f64 = 0.1;
/// Pages zeroed per `Zero` call in heap prologues: large heaps are
/// mapped in syscall-sized chunks, not one giant op.
const ZERO_CHUNK: u32 = 64;
/// Pages moved per `Memcpy` call in evacuation phases.
const EVAC_CHUNK: u32 = 16;

/// One core's GC scenario (sizes in pages of one DRAM row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GcScenario {
    /// Dependent pointer chase over a `pages`-page heap laid out
    /// across `sites` allocation sites; no collection.
    Traverse { pages: u32, sites: u32 },
    /// Two `pages`-page semispaces: `period` chase ops in from-space,
    /// then `evac_pages` survivors are bulk-copied to to-space and
    /// the spaces swap.
    Semispace {
        pages: u32,
        sites: u32,
        period: u32,
        evac_pages: u32,
    },
    /// Snapshot-at-the-beginning marking: `Fork` pins the snapshot,
    /// then `period` ops of marker chase mixed with mutator writes
    /// that break CoW pages.
    ConcurrentMark { pages: u32, sites: u32, period: u32 },
    /// Nursery chase with minor collections: every `period` ops,
    /// `survivors` nursery pages are evacuated into the old
    /// generation and the hottest one is promoted to the fast zone.
    Generational {
        nursery_pages: u32,
        old_pages: u32,
        period: u32,
        survivors: u32,
    },
}

/// Dependent-chase cursor over a sited heap region.
struct Chase {
    base_page: u64,
    pages: u64,
    pages_per_site: u64,
    cur: u64,
}

impl Chase {
    fn new(base_page: u64, pages: u32, sites: u32) -> Self {
        let pages = pages.max(1) as u64;
        let sites = (sites.max(1) as u64).min(pages);
        Self {
            base_page,
            pages,
            pages_per_site: (pages / sites).max(1),
            cur: 0,
        }
    }

    /// Follow one pointer: within the current allocation site, or a
    /// cross-site edge. Returns the heap-relative page index.
    fn step(&mut self, rng: &mut Pcg32) -> u64 {
        self.cur = if rng.chance(CROSS_SITE) {
            rng.below(self.pages)
        } else {
            let site_base = self.cur - self.cur % self.pages_per_site;
            (site_base + rng.below(self.pages_per_site)) % self.pages
        };
        self.cur
    }

    /// A chase touch: a dependent read (or a rare mutation write) at
    /// a random line of the next pointed-to page.
    fn touch(&mut self, rng: &mut Pcg32, page_bytes: u64, nonmem: u32) -> TraceOp {
        let page = self.base_page + self.step(rng);
        let is_write = rng.chance(CHASE_WRITE);
        TraceOp::Bulk {
            nonmem,
            op: BulkOp::Touch {
                va: page * page_bytes + rng.below(page_bytes / 64) * 64,
                is_write,
                // Mutation writes are off the chase's critical path.
                dependent: !is_write,
            },
        }
    }
}

/// Map `[base_page, base_page + pages)` with chunked demand-zero calls.
fn zero_region(ops: &mut Vec<TraceOp>, base_page: u64, pages: u32, page_bytes: u64) {
    let mut done = 0u32;
    while done < pages {
        let chunk = ZERO_CHUNK.min(pages - done);
        ops.push(TraceOp::Bulk {
            nonmem: GC_CALL_NONMEM,
            op: BulkOp::Zero {
                va: (base_page + done as u64) * page_bytes,
                pages: chunk,
            },
        });
        done += chunk;
    }
}

/// Evacuate `pages` pages `src_page -> dst_page` in syscall-sized
/// bulk copies.
fn evacuate(ops: &mut Vec<TraceOp>, src_page: u64, dst_page: u64, pages: u32, page_bytes: u64) {
    let mut done = 0u32;
    while done < pages {
        let chunk = EVAC_CHUNK.min(pages - done);
        ops.push(TraceOp::Bulk {
            nonmem: GC_CALL_NONMEM,
            op: BulkOp::Memcpy {
                src_va: (src_page + done as u64) * page_bytes,
                dst_va: (dst_page + done as u64) * page_bytes,
                pages: chunk,
            },
        });
        done += chunk;
    }
}

/// Generate `n_ops` trace operations for one core. Deterministic in
/// (scenario, seed, core); virtual addresses are process-local (each
/// core is its own process, like the E9 scenarios).
pub fn generate(
    scn: GcScenario,
    cfg: &SimConfig,
    core: usize,
    n_ops: usize,
    seed: u64,
    nonmem: u32,
) -> Vec<TraceOp> {
    let page = cfg.dram.row_bytes() as u64;
    let mut rng = Pcg32::new(seed, core as u64 + 0x6C_0000);
    let mut ops = Vec::with_capacity(n_ops + 128);
    match scn {
        GcScenario::Traverse { pages, sites } => {
            zero_region(&mut ops, 0, pages, page);
            let mut chase = Chase::new(0, pages, sites);
            while ops.len() < n_ops {
                ops.push(chase.touch(&mut rng, page, nonmem));
            }
        }
        GcScenario::Semispace { pages, sites, period, evac_pages } => {
            let pages = pages.max(1);
            let evac = evac_pages.min(pages);
            // Map both spaces up front; `from` flips each cycle.
            zero_region(&mut ops, 0, pages, page);
            zero_region(&mut ops, pages as u64, pages, page);
            let mut from = 0u64;
            while ops.len() < n_ops {
                let mut chase = Chase::new(from, pages, sites);
                for _ in 0..period.max(1) {
                    ops.push(chase.touch(&mut rng, page, nonmem));
                }
                // Survivors start at a random offset: evacuation
                // source pages vary cycle to cycle.
                let to = pages as u64 - from;
                let start = rng.below((pages - evac + 1) as u64);
                evacuate(&mut ops, from + start, to + start, evac, page);
                from = to;
            }
        }
        GcScenario::ConcurrentMark { pages, sites, period } => {
            zero_region(&mut ops, 0, pages, page);
            let mut chase = Chase::new(0, pages, sites);
            while ops.len() < n_ops {
                ops.push(TraceOp::Bulk { nonmem: FORK_NONMEM, op: BulkOp::Fork });
                for _ in 0..period.max(1) {
                    if rng.chance(0.3) {
                        // Mutator write during the mark: breaks the
                        // snapshot's CoW page.
                        let p = rng.below(pages.max(1) as u64);
                        ops.push(TraceOp::Bulk {
                            nonmem,
                            op: BulkOp::Touch {
                                va: p * page + rng.below(page / 64) * 64,
                                is_write: true,
                                dependent: false,
                            },
                        });
                    } else {
                        ops.push(chase.touch(&mut rng, page, nonmem));
                    }
                }
            }
        }
        GcScenario::Generational { nursery_pages, old_pages, period, survivors } => {
            let nursery = nursery_pages.max(1);
            let old = old_pages.max(1);
            let survivors = survivors.min(nursery);
            // Layout: nursery at 0, old generation above it.
            zero_region(&mut ops, 0, nursery, page);
            zero_region(&mut ops, nursery as u64, old, page);
            let mut young = Chase::new(0, nursery, 4);
            let mut tenured = Chase::new(nursery as u64, old, 8);
            let mut old_cursor = 0u64;
            while ops.len() < n_ops {
                for _ in 0..period.max(1) {
                    // Young-generation hypothesis: most traffic stays
                    // in the nursery.
                    let c = if rng.chance(0.8) { &mut young } else { &mut tenured };
                    ops.push(c.touch(&mut rng, page, nonmem));
                }
                // Minor collection: copy survivors into the old gen
                // and promote the first (hottest) one to the fast zone.
                if survivors > 0 {
                    let start = rng.below((nursery - survivors + 1) as u64);
                    let dst = nursery as u64 + old_cursor;
                    evacuate(&mut ops, start, dst, survivors, page);
                    old_cursor = (old_cursor + survivors as u64) % old as u64;
                    ops.push(TraceOp::Bulk {
                        nonmem: GC_CALL_NONMEM,
                        op: BulkOp::Promote { va: dst * page },
                    });
                }
            }
        }
    }
    ops.truncate(n_ops.max(1));
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    const ALL: [GcScenario; 4] = [
        GcScenario::Traverse { pages: 192, sites: 12 },
        GcScenario::Semispace { pages: 96, sites: 8, period: 96, evac_pages: 24 },
        GcScenario::ConcurrentMark { pages: 128, sites: 8, period: 96 },
        GcScenario::Generational {
            nursery_pages: 48,
            old_pages: 96,
            period: 96,
            survivors: 8,
        },
    ];

    #[test]
    fn scenarios_are_deterministic_and_bulk_bearing() {
        let c = cfg();
        for scn in ALL {
            let a = generate(scn, &c, 0, 900, 7, 4);
            let b = generate(scn, &c, 0, 900, 7, 4);
            assert_eq!(a, b, "{scn:?} not deterministic");
            assert_eq!(a.len(), 900);
            let d = generate(scn, &c, 0, 900, 8, 4);
            assert_ne!(a, d, "{scn:?} ignores the seed");
            assert!(
                a.iter().all(|o| matches!(o, TraceOp::Bulk { .. })),
                "{scn:?}: everything routes through the OS layer"
            );
        }
    }

    #[test]
    fn chases_are_dominated_by_dependent_reads() {
        let c = cfg();
        for scn in ALL {
            let ops = generate(scn, &c, 0, 1000, 3, 4);
            let dep = ops
                .iter()
                .filter(|o| {
                    matches!(
                        o,
                        TraceOp::Bulk { op: BulkOp::Touch { dependent: true, .. }, .. }
                    )
                })
                .count();
            assert!(dep > 500, "{scn:?}: only {dep}/1000 dependent touches");
        }
    }

    #[test]
    fn semispace_evacuates_between_the_spaces() {
        let c = cfg();
        let pages = 96u64;
        let scn = GcScenario::Semispace {
            pages: pages as u32,
            sites: 8,
            period: 40,
            evac_pages: 24,
        };
        let ops = generate(scn, &c, 0, 1200, 1, 4);
        let mut copies = 0usize;
        for o in &ops {
            if let TraceOp::Bulk { op: BulkOp::Memcpy { src_va, dst_va, pages: p }, .. } = o {
                copies += 1;
                assert!(*p > 0 && *p as u64 <= pages);
                // Every evacuation crosses the semispace boundary.
                let boundary = pages * 8192;
                assert_ne!(*src_va < boundary, *dst_va < boundary, "copy stayed in-space");
            }
        }
        assert!(copies >= 10, "{copies} evacuation copies in 1200 ops");
    }

    #[test]
    fn concurrent_mark_forks_and_writes() {
        let ops = generate(
            GcScenario::ConcurrentMark { pages: 64, sites: 8, period: 50 },
            &cfg(),
            1,
            800,
            2,
            4,
        );
        let forks = ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Bulk { op: BulkOp::Fork, .. }))
            .count();
        assert!((10..=20).contains(&forks), "{forks} forks in 800 ops");
        assert!(ops.iter().any(|o| {
            matches!(
                o,
                TraceOp::Bulk { op: BulkOp::Touch { is_write: true, .. }, .. }
            )
        }));
    }

    #[test]
    fn generational_promotes_into_the_old_generation() {
        let nursery = 48u64;
        let old = 96u64;
        let ops = generate(
            GcScenario::Generational {
                nursery_pages: nursery as u32,
                old_pages: old as u32,
                period: 60,
                survivors: 8,
            },
            &cfg(),
            0,
            900,
            5,
            4,
        );
        let mut promotes = 0usize;
        for o in &ops {
            if let TraceOp::Bulk { op: BulkOp::Promote { va }, .. } = o {
                promotes += 1;
                let p = va / 8192;
                assert!(
                    p >= nursery && p < nursery + old,
                    "promote target page {p} outside the old generation"
                );
            }
        }
        assert!(promotes >= 5, "{promotes} promotions in 900 ops");
    }

    #[test]
    fn site_locality_keeps_most_steps_within_a_site() {
        let c = cfg();
        let pages = 192u64;
        let sites = 12u64;
        let ops = generate(
            GcScenario::Traverse { pages: pages as u32, sites: sites as u32 },
            &c,
            0,
            2000,
            9,
            4,
        );
        let per_site = pages / sites;
        let mut same = 0usize;
        let mut total = 0usize;
        let mut prev: Option<u64> = None;
        for o in &ops {
            if let TraceOp::Bulk { op: BulkOp::Touch { va, .. }, .. } = o {
                let site = (va / 8192) / per_site;
                if let Some(p) = prev {
                    total += 1;
                    if p == site {
                        same += 1;
                    }
                }
                prev = Some(site);
            }
        }
        // CROSS_SITE = 0.25, and a cross-site jump sometimes lands in
        // the same site anyway: well over half the steps stay local.
        assert!(
            same * 100 > total * 60,
            "only {same}/{total} steps stayed within an allocation site"
        );
    }
}
