//! E10 bench target: subarray-level-parallelism modes composed with
//! LISA on the intra-bank-conflict workloads. Prints one table row per
//! {workload x mode} with the structural counters that explain the
//! cycle differences (activations avoided, per-subarray precharges,
//! subarray-select switches).
//!
//! Usage: `cargo bench --bench salp_modes [-- REQUESTS]`

use lisa::config::{CopyMechanism, SalpMode, SimConfig};
use lisa::sim::engine::Simulation;
use lisa::util::bench::Table;
use lisa::workloads::mixes;

fn main() {
    let requests: u64 = std::env::args()
        .skip(1)
        .find_map(|s| s.parse().ok())
        .unwrap_or(3_000);
    println!("=== SALP/MASA modes x LISA (E10, {requests} requests/core) ===\n");
    let mut t = Table::new(&[
        "workload",
        "mode",
        "cycles",
        "IPC sum",
        "row-hit %",
        "ACTs",
        "PRE_SA",
        "sa-switch",
    ]);
    for wl_name in ["salp-pingpong4", "salp-shared-bank4", "salp-copy-conflict4"] {
        for mode in SalpMode::ALL {
            let mut cfg = SimConfig::default();
            cfg.requests_per_core = requests;
            cfg.dram.salp = mode;
            cfg.lisa.risc = true;
            cfg.copy_mechanism = CopyMechanism::LisaRisc;
            let wl = mixes::workload_by_name(wl_name, &cfg).unwrap();
            let mut sim = Simulation::new(cfg, wl);
            let r = sim.run();
            let s = sim.memory().command_stats();
            t.row(&[
                wl_name.to_string(),
                mode.name().to_string(),
                format!("{}", r.dram_cycles),
                format!("{:.3}", r.ipc_sum()),
                format!("{:.1}", r.row_hit_rate * 100.0),
                format!("{}", s.n_act),
                format!("{}", s.n_pre_sa),
                format!("{}", s.n_sa_switch),
            ]);
        }
    }
    t.print();
    println!("\n(none serializes; salp1 overlaps tRP; salp2 keeps 2 rows; masa keeps all)");
}
