//! Cycle-accurate DRAM device model: geometry, JEDEC timing, command
//! set (including the RowClone and LISA extensions), and the
//! bank/subarray state machines with a full timing-constraint checker.
//!
//! This is the substrate the paper evaluates on (their Ramulator
//! configuration), built from scratch — see DESIGN.md inventory S4-S7.

pub mod area;
pub mod bank;
pub mod command;
pub mod geometry;
pub mod subarray;
pub mod timing;

pub use bank::{Bank, Rank};
pub use command::Command;
pub use geometry::Address;
pub use timing::{SpeedBin, Timing};
