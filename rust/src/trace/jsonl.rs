//! JSONL ⇄ binary trace conversion.
//!
//! The JSONL form is the human-readable / toolable view: a header
//! line followed by one op per line. The binary→JSONL direction
//! streams (one op resident at a time); JSONL→binary groups ops per
//! core in memory before writing — acceptable because only the binary
//! reader carries the memory-bounded contract.
//!
//! The binary encoder is canonical (minimal varints, fixed field
//! order), so binary → JSONL → binary reproduces the original file
//! byte for byte.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::cpu::trace::{BulkOp, Trace, TraceOp};
use crate::metrics::json::string as jstr;
use crate::trace::format::{MAX_CORES, VERSION};
use crate::trace::reader::TraceReader;
use crate::trace::writer::write_trace;
use crate::util::json::{self, Value};

fn op_line(core: usize, op: &TraceOp) -> String {
    match *op {
        TraceOp::Mem { nonmem, addr, is_write, dependent } => format!(
            "{{\"core\":{core},\"op\":\"mem\",\"nonmem\":{nonmem},\"addr\":{addr},\"write\":{is_write},\"dep\":{dependent}}}"
        ),
        TraceOp::Copy { nonmem, src, dst, rows } => format!(
            "{{\"core\":{core},\"op\":\"copy\",\"nonmem\":{nonmem},\"src\":{src},\"dst\":{dst},\"rows\":{rows}}}"
        ),
        TraceOp::Bulk { nonmem, op } => match op {
            BulkOp::Memcpy { src_va, dst_va, pages } => format!(
                "{{\"core\":{core},\"op\":\"memcpy\",\"nonmem\":{nonmem},\"src_va\":{src_va},\"dst_va\":{dst_va},\"pages\":{pages}}}"
            ),
            BulkOp::Zero { va, pages } => format!(
                "{{\"core\":{core},\"op\":\"zero\",\"nonmem\":{nonmem},\"va\":{va},\"pages\":{pages}}}"
            ),
            BulkOp::Fork => {
                format!("{{\"core\":{core},\"op\":\"fork\",\"nonmem\":{nonmem}}}")
            }
            BulkOp::Touch { va, is_write, dependent } => format!(
                "{{\"core\":{core},\"op\":\"touch\",\"nonmem\":{nonmem},\"va\":{va},\"write\":{is_write},\"dep\":{dependent}}}"
            ),
            BulkOp::Checkpoint => {
                format!("{{\"core\":{core},\"op\":\"checkpoint\",\"nonmem\":{nonmem}}}")
            }
            BulkOp::Promote { va } => format!(
                "{{\"core\":{core},\"op\":\"promote\",\"nonmem\":{nonmem},\"va\":{va}}}"
            ),
        },
    }
}

/// Convert a binary trace file to JSONL, streaming op by op.
pub fn to_jsonl(src: &Path, dst: &Path) -> Result<()> {
    let mut rd = TraceReader::open(src)?;
    let out = File::create(dst)
        .with_context(|| format!("creating {}", dst.display()))?;
    let mut w = BufWriter::new(out);
    writeln!(
        w,
        "{{\"trace\":{},\"version\":{VERSION},\"cores\":{}}}",
        jstr(&rd.header().name),
        rd.header().streams.len()
    )?;
    let cores = rd.header().streams.len();
    for core in 0..cores {
        let mut it = rd.ops(core)?;
        let mut prev = 0u64;
        while let Some(op) = it.next_op(&mut prev) {
            let op = op?;
            writeln!(w, "{}", op_line(core, &op))?;
        }
    }
    w.into_inner()
        .map_err(|e| anyhow!("flushing {}: {e}", dst.display()))?;
    Ok(())
}

fn field<'a>(v: &'a Value, key: &str, line_no: usize) -> Result<&'a Value> {
    v.get(key)
        .ok_or_else(|| anyhow!("line {line_no}: missing field \"{key}\""))
}

fn field_u64(v: &Value, key: &str, line_no: usize) -> Result<u64> {
    field(v, key, line_no)?
        .as_u64()
        .ok_or_else(|| anyhow!("line {line_no}: field \"{key}\" is not a u64"))
}

fn field_u32(v: &Value, key: &str, line_no: usize) -> Result<u32> {
    let n = field_u64(v, key, line_no)?;
    u32::try_from(n).map_err(|_| anyhow!("line {line_no}: field \"{key}\" = {n} exceeds u32"))
}

fn field_bool(v: &Value, key: &str, line_no: usize) -> Result<bool> {
    field(v, key, line_no)?
        .as_bool()
        .ok_or_else(|| anyhow!("line {line_no}: field \"{key}\" is not a bool"))
}

fn parse_op(v: &Value, line_no: usize) -> Result<TraceOp> {
    let kind = field(v, "op", line_no)?
        .as_str()
        .ok_or_else(|| anyhow!("line {line_no}: field \"op\" is not a string"))?;
    let nonmem = field_u32(v, "nonmem", line_no)?;
    let op = match kind {
        "mem" => TraceOp::Mem {
            nonmem,
            addr: field_u64(v, "addr", line_no)?,
            is_write: field_bool(v, "write", line_no)?,
            dependent: field_bool(v, "dep", line_no)?,
        },
        "copy" => TraceOp::Copy {
            nonmem,
            src: field_u64(v, "src", line_no)?,
            dst: field_u64(v, "dst", line_no)?,
            rows: field_u32(v, "rows", line_no)?,
        },
        "memcpy" => TraceOp::Bulk {
            nonmem,
            op: BulkOp::Memcpy {
                src_va: field_u64(v, "src_va", line_no)?,
                dst_va: field_u64(v, "dst_va", line_no)?,
                pages: field_u32(v, "pages", line_no)?,
            },
        },
        "zero" => TraceOp::Bulk {
            nonmem,
            op: BulkOp::Zero {
                va: field_u64(v, "va", line_no)?,
                pages: field_u32(v, "pages", line_no)?,
            },
        },
        "fork" => TraceOp::Bulk { nonmem, op: BulkOp::Fork },
        "touch" => TraceOp::Bulk {
            nonmem,
            op: BulkOp::Touch {
                va: field_u64(v, "va", line_no)?,
                is_write: field_bool(v, "write", line_no)?,
                dependent: field_bool(v, "dep", line_no)?,
            },
        },
        "checkpoint" => TraceOp::Bulk { nonmem, op: BulkOp::Checkpoint },
        "promote" => TraceOp::Bulk {
            nonmem,
            op: BulkOp::Promote { va: field_u64(v, "va", line_no)? },
        },
        other => bail!("line {line_no}: unknown op kind \"{other}\""),
    };
    Ok(op)
}

/// Convert a JSONL trace to the binary format.
pub fn from_jsonl(src: &Path, dst: &Path) -> Result<()> {
    let file = File::open(src)
        .with_context(|| format!("opening {}", src.display()))?;
    let mut lines = BufReader::new(file).lines();

    let header_line = lines
        .next()
        .ok_or_else(|| anyhow!("{}: empty file (expected a header line)", src.display()))?
        .context("reading JSONL header line")?;
    let header = json::parse(&header_line)
        .with_context(|| format!("{}: line 1 is not valid JSON", src.display()))?;
    let name = field(&header, "trace", 1)?
        .as_str()
        .ok_or_else(|| anyhow!("line 1: field \"trace\" is not a string"))?
        .to_string();
    let version = field_u64(&header, "version", 1)?;
    if version != VERSION as u64 {
        bail!("{}: unsupported trace version {version} (this build reads {VERSION})", src.display());
    }
    let cores = field_u64(&header, "cores", 1)?;
    if cores == 0 || cores > MAX_CORES as u64 {
        bail!("{}: implausible core count {cores} (limit {MAX_CORES})", src.display());
    }

    let mut per_core: Vec<Vec<TraceOp>> = vec![Vec::new(); cores as usize];
    for (i, line) in lines.enumerate() {
        let line_no = i + 2;
        let line = line.with_context(|| format!("reading line {line_no}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(&line)
            .with_context(|| format!("{}: line {line_no} is not valid JSON", src.display()))?;
        let core = field_u64(&v, "core", line_no)? as usize;
        if core >= per_core.len() {
            bail!("line {line_no}: core {core} out of range (header declares {cores})");
        }
        per_core[core].push(parse_op(&v, line_no)?);
    }

    let traces: Vec<Trace> = per_core.into_iter().map(Trace::new).collect();
    for (core, t) in traces.iter().enumerate() {
        if t.ops.is_empty() {
            bail!("{}: core {core} has no ops", src.display());
        }
    }
    write_trace(dst, &name, &traces)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("lisa-trace-jsonl-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_jsonl_binary_is_byte_identical() {
        let t0 = Trace::new(vec![
            TraceOp::Mem { nonmem: 4, addr: 1 << 33, is_write: false, dependent: true },
            TraceOp::Bulk {
                nonmem: 20,
                op: BulkOp::Memcpy { src_va: 0, dst_va: 1 << 20, pages: 8 },
            },
            TraceOp::Bulk { nonmem: 60, op: BulkOp::Fork },
            TraceOp::Bulk { nonmem: 20, op: BulkOp::Checkpoint },
        ]);
        let t1 = Trace::new(vec![
            TraceOp::Copy { nonmem: 10, src: 8192, dst: 16384, rows: 2 },
            TraceOp::Bulk { nonmem: 4, op: BulkOp::Promote { va: 1 << 21 } },
            TraceOp::Bulk { nonmem: 4, op: BulkOp::Zero { va: 0, pages: 64 } },
            TraceOp::Bulk {
                nonmem: 4,
                op: BulkOp::Touch { va: 4096, is_write: true, dependent: false },
            },
        ]);
        let bin1 = tmp("a.trc");
        let jsonl = tmp("a.jsonl");
        let bin2 = tmp("a2.trc");
        write_trace(&bin1, "mix \"quoted\"", &[t0, t1]).unwrap();
        to_jsonl(&bin1, &jsonl).unwrap();
        from_jsonl(&jsonl, &bin2).unwrap();
        let b1 = std::fs::read(&bin1).unwrap();
        let b2 = std::fs::read(&bin2).unwrap();
        assert_eq!(b1, b2, "binary -> jsonl -> binary changed bytes");
        for p in [&bin1, &jsonl, &bin2] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn malformed_jsonl_is_a_contextual_error() {
        let p = tmp("bad.jsonl");
        std::fs::write(
            &p,
            "{\"trace\":\"x\",\"version\":1,\"cores\":1}\n{\"core\":0,\"op\":\"warp\",\"nonmem\":1}\n",
        )
        .unwrap();
        let err = format!("{:#}", from_jsonl(&p, &tmp("bad.trc")).unwrap_err());
        assert!(err.contains("unknown op kind"), "{err}");
        std::fs::write(&p, "{\"trace\":\"x\",\"version\":7,\"cores\":1}\n").unwrap();
        let err = format!("{:#}", from_jsonl(&p, &tmp("bad.trc")).unwrap_err());
        assert!(err.contains("version 7"), "{err}");
        std::fs::remove_file(&p).ok();
    }
}
