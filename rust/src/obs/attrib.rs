//! Latency attribution: decompose each demand request's end-to-end
//! latency into queueing / bank-conflict / refresh-blocked /
//! copy-blocked / service components, plus per-bank utilization and
//! queue-depth percentiles. Fed by the same [`TraceEvent`] stream the
//! probe sees; only active under `--obs`.

use anyhow::{anyhow, Result};

use super::trace::{TraceEvent, TraceKind};
use crate::metrics::json;
use crate::util::json::Value;
use crate::util::stats::percentile;

/// Closed, non-overlapping, start-sorted windows plus at most one
/// still-open window (refresh pending, copy ownership, open row —
/// all strictly sequential per resource).
#[derive(Debug, Clone, Default)]
struct Spans {
    done: Vec<(u64, u64)>,
    open: Option<u64>,
}

impl Spans {
    fn open_at(&mut self, t: u64) {
        if self.open.is_none() {
            self.open = Some(t);
        }
    }

    fn close_at(&mut self, t: u64) {
        if let Some(s) = self.open.take() {
            if t > s {
                self.done.push((s, t));
            }
        }
    }

    /// Is `t` inside a window? (half-open `[start, end)`.)
    fn covers(&self, t: u64) -> bool {
        if self.open.is_some_and(|s| s <= t) {
            return true;
        }
        let i = self.done.partition_point(|&(s, _)| s <= t);
        i > 0 && self.done[i - 1].1 > t
    }

    /// Push every window boundary that falls inside `[a, b)`.
    fn boundaries_into(&self, a: u64, b: u64, cuts: &mut Vec<u64>) {
        if let Some(s) = self.open {
            if s < b {
                cuts.push(s.max(a));
            }
        }
        let start = self.done.partition_point(|&(_, e)| e <= a);
        for &(s, e) in &self.done[start..] {
            if s >= b {
                break;
            }
            cuts.push(s.max(a));
            cuts.push(e.min(b));
        }
    }
}

/// Like [`Spans`] but each window remembers which row was open, so a
/// conflict query can ignore windows where the requested row itself
/// was the open one (those are hits, not conflicts).
#[derive(Debug, Clone, Default)]
struct RowSpans {
    done: Vec<(u64, u64, i64)>,
    open: Option<(u64, i64)>,
}

impl RowSpans {
    fn open_at(&mut self, t: u64, row: i64) {
        // Defensive: an ACT over a still-open row (VILLA fast rows,
        // copy restarts) closes the previous window first.
        self.close_at(t);
        self.open = Some((t, row));
    }

    fn close_at(&mut self, t: u64) {
        if let Some((s, row)) = self.open.take() {
            if t > s {
                self.done.push((s, t, row));
            }
        }
    }

    /// Was a row *other than* `req_row` open at `t`?
    fn conflicts_at(&self, t: u64, req_row: i64) -> bool {
        if let Some((s, row)) = self.open {
            if s <= t {
                return row != req_row;
            }
        }
        let i = self.done.partition_point(|&(s, _, _)| s <= t);
        i > 0 && self.done[i - 1].1 > t && self.done[i - 1].2 != req_row
    }

    fn conflict_boundaries_into(&self, a: u64, b: u64, req_row: i64, cuts: &mut Vec<u64>) {
        if let Some((s, row)) = self.open {
            if s < b && row != req_row {
                cuts.push(s.max(a));
            }
        }
        let start = self.done.partition_point(|&(_, e, _)| e <= a);
        for &(s, e, row) in &self.done[start..] {
            if s >= b {
                break;
            }
            if row != req_row {
                cuts.push(s.max(a));
                cuts.push(e.min(b));
            }
        }
    }
}

/// Merge-accumulator for per-bank busy time. Events arrive in issue
/// order, so overlapping occupancies (e.g. pipelined column bursts)
/// only count the uncovered tail.
#[derive(Debug, Clone, Copy, Default)]
struct Busy {
    acc: u64,
    last_end: u64,
}

impl Busy {
    fn merge(&mut self, start: u64, end: u64) {
        let s = start.max(self.last_end);
        if end > s {
            self.acc += end - s;
        }
        self.last_end = self.last_end.max(end);
    }
}

/// One demand request's latency decomposition. The five components sum
/// exactly to `done - arrive` by construction (the wait window is
/// partitioned by a single boundary sweep; the property test in
/// `tests/observability.rs` pins this).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestLatency {
    pub id: u64,
    pub arrive: u64,
    /// Cycle the RD/WR column command issued.
    pub issue: u64,
    pub done: u64,
    /// Wait not explained by any blocker below (scheduler order, bus
    /// contention, row preparation of the request's own row).
    pub queueing: u64,
    /// Wait while a *different* row was open in the request's subarray.
    pub bank_conflict: u64,
    /// Wait while the request's rank had a refresh pending/in flight.
    pub refresh_blocked: u64,
    /// Wait while the active copy owned the request's bank.
    pub copy_blocked: u64,
    /// Issue to data-burst completion.
    pub service: u64,
}

impl RequestLatency {
    pub fn total(&self) -> u64 {
        self.done - self.arrive
    }

    pub fn components_sum(&self) -> u64 {
        self.queueing
            + self.bank_conflict
            + self.refresh_blocked
            + self.copy_blocked
            + self.service
    }
}

/// The attribution engine: replays the probe event stream into
/// blocker windows and decomposes each demand RD/WR at issue time
/// (all windows overlapping `[arrive, issue)` are already final).
#[derive(Debug)]
pub struct Attribution {
    ranks: usize,
    banks: usize,
    sas: usize,
    refresh: Vec<Spans>,
    copy_own: Vec<Spans>,
    rows: Vec<RowSpans>,
    busy: Vec<Busy>,
    queue_depth: Vec<f64>,
    latency: Vec<f64>,
    /// Per-request decompositions, in completion-issue order.
    pub requests: Vec<RequestLatency>,
    sums: [u64; 5],
}

impl Attribution {
    pub fn new(channels: usize, ranks: usize, banks: usize, subarrays: usize) -> Self {
        let nr = channels * ranks;
        let nb = nr * banks;
        Attribution {
            ranks,
            banks,
            sas: subarrays,
            refresh: vec![Spans::default(); nr],
            copy_own: vec![Spans::default(); nb],
            rows: vec![RowSpans::default(); nb * subarrays],
            busy: vec![Busy::default(); nb],
            queue_depth: Vec::new(),
            latency: Vec::new(),
            requests: Vec::new(),
            sums: [0; 5],
        }
    }

    fn rank_idx(&self, ev: &TraceEvent) -> usize {
        ev.ch * self.ranks + ev.rank
    }

    fn bank_idx(&self, ev: &TraceEvent, bank: i64) -> usize {
        self.rank_idx(ev) * self.banks + bank.max(0) as usize
    }

    fn sa_idx(&self, ev: &TraceEvent) -> usize {
        self.bank_idx(ev, ev.bank) * self.sas + ev.sa.max(0) as usize
    }

    pub fn observe(&mut self, ev: &TraceEvent) {
        match ev.kind {
            TraceKind::Enq => self.queue_depth.push(ev.val as f64),
            TraceKind::RefPend => {
                let ri = self.rank_idx(ev);
                self.refresh[ri].open_at(ev.cycle);
            }
            TraceKind::Ref => {
                let ri = self.rank_idx(ev);
                self.refresh[ri].close_at(ev.done);
                for b in 0..self.banks {
                    let bi = self.bank_idx(ev, b as i64);
                    self.busy[bi].merge(ev.cycle, ev.done);
                }
            }
            TraceKind::CopyOwn => {
                let bi = self.bank_idx(ev, ev.bank);
                self.copy_own[bi].open_at(ev.cycle);
            }
            TraceKind::CopyRelease => {
                let bi = self.bank_idx(ev, ev.bank);
                self.copy_own[bi].close_at(ev.cycle);
            }
            TraceKind::Act | TraceKind::ActCopy | TraceKind::ActStore => {
                let si = self.sa_idx(ev);
                self.rows[si].open_at(ev.cycle, ev.row);
                let bi = self.bank_idx(ev, ev.bank);
                self.busy[bi].merge(ev.cycle, ev.done);
            }
            TraceKind::Pre => {
                let bi = self.bank_idx(ev, ev.bank);
                for sa in 0..self.sas {
                    self.rows[bi * self.sas + sa].close_at(ev.cycle);
                }
                self.busy[bi].merge(ev.cycle, ev.done);
            }
            TraceKind::PreSa => {
                let si = self.sa_idx(ev);
                self.rows[si].close_at(ev.cycle);
                let bi = self.bank_idx(ev, ev.bank);
                self.busy[bi].merge(ev.cycle, ev.done);
            }
            TraceKind::PreAll => {
                for b in 0..self.banks {
                    let bi = self.bank_idx(ev, b as i64);
                    for sa in 0..self.sas {
                        self.rows[bi * self.sas + sa].close_at(ev.cycle);
                    }
                    self.busy[bi].merge(ev.cycle, ev.done);
                }
            }
            TraceKind::Rd | TraceKind::Wr => {
                let bi = self.bank_idx(ev, ev.bank);
                self.busy[bi].merge(ev.cycle, ev.done);
                if !ev.copy && ev.id >= 0 {
                    self.decompose(ev);
                }
            }
            TraceKind::Rbm => {
                let bi = self.bank_idx(ev, ev.bank);
                self.busy[bi].merge(ev.cycle, ev.done);
            }
            TraceKind::Transfer => {
                let src = self.bank_idx(ev, ev.bank);
                self.busy[src].merge(ev.cycle, ev.done);
                let dst = self.bank_idx(ev, ev.val);
                self.busy[dst].merge(ev.cycle, ev.done);
            }
            TraceKind::CopyEnq
            | TraceKind::CopyStart
            | TraceKind::CopyDone => {}
        }
    }

    /// Partition the wait window `[arrive, issue)` by a boundary sweep
    /// with blocker priority refresh > copy > conflict; the remainder
    /// is queueing. The row that the request itself needed does not
    /// count as a conflict.
    fn decompose(&mut self, ev: &TraceEvent) {
        let (a, b) = (ev.arrive, ev.cycle);
        let ri = self.rank_idx(ev);
        let bi = self.bank_idx(ev, ev.bank);
        let si = self.sa_idx(ev);
        let mut refresh_blocked = 0u64;
        let mut copy_blocked = 0u64;
        let mut bank_conflict = 0u64;
        let mut queueing = 0u64;
        if b > a {
            let mut cuts = vec![a, b];
            self.refresh[ri].boundaries_into(a, b, &mut cuts);
            self.copy_own[bi].boundaries_into(a, b, &mut cuts);
            self.rows[si].conflict_boundaries_into(a, b, ev.row, &mut cuts);
            cuts.sort_unstable();
            cuts.dedup();
            for w in cuts.windows(2) {
                let (s, e) = (w[0], w[1]);
                let len = e - s;
                if self.refresh[ri].covers(s) {
                    refresh_blocked += len;
                } else if self.copy_own[bi].covers(s) {
                    copy_blocked += len;
                } else if self.rows[si].conflicts_at(s, ev.row) {
                    bank_conflict += len;
                } else {
                    queueing += len;
                }
            }
        }
        let service = ev.done.saturating_sub(ev.cycle);
        self.sums[0] += queueing;
        self.sums[1] += bank_conflict;
        self.sums[2] += refresh_blocked;
        self.sums[3] += copy_blocked;
        self.sums[4] += service;
        self.latency.push((ev.done - ev.arrive) as f64);
        self.requests.push(RequestLatency {
            id: ev.id as u64,
            arrive: a,
            issue: b,
            done: ev.done,
            queueing,
            bank_conflict,
            refresh_blocked,
            copy_blocked,
            service,
        });
    }

    /// Aggregate into the report block attached under `"obs"`.
    pub fn finalize(&self, cycles: u64) -> ObsReport {
        let pct = |xs: &[f64], p: f64| if xs.is_empty() { 0.0 } else { percentile(xs, p) };
        let maxf = |xs: &[f64]| xs.iter().copied().fold(0.0f64, f64::max);
        let denom = cycles.max(1) as f64;
        ObsReport {
            requests: self.requests.len() as u64,
            sum_queueing: self.sums[0],
            sum_bank_conflict: self.sums[1],
            sum_refresh_blocked: self.sums[2],
            sum_copy_blocked: self.sums[3],
            sum_service: self.sums[4],
            lat_p50: pct(&self.latency, 50.0),
            lat_p90: pct(&self.latency, 90.0),
            lat_p99: pct(&self.latency, 99.0),
            lat_max: maxf(&self.latency),
            qd_p50: pct(&self.queue_depth, 50.0),
            qd_p90: pct(&self.queue_depth, 90.0),
            qd_p99: pct(&self.queue_depth, 99.0),
            qd_max: maxf(&self.queue_depth),
            bank_util: self
                .busy
                .iter()
                .map(|b| (b.acc as f64 / denom).min(1.0))
                .collect(),
        }
    }
}

/// The `"obs"` block of a `RunReport`: aggregate latency attribution.
/// Deterministic for a given run, so it participates in the campaign
/// byte-identity contracts (journal/cache round trips, N-thread vs 1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsReport {
    pub requests: u64,
    pub sum_queueing: u64,
    pub sum_bank_conflict: u64,
    pub sum_refresh_blocked: u64,
    pub sum_copy_blocked: u64,
    pub sum_service: u64,
    pub lat_p50: f64,
    pub lat_p90: f64,
    pub lat_p99: f64,
    pub lat_max: f64,
    pub qd_p50: f64,
    pub qd_p90: f64,
    pub qd_p99: f64,
    pub qd_max: f64,
    /// Busy fraction per (channel, rank, bank), bank-minor.
    pub bank_util: Vec<f64>,
}

impl ObsReport {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"requests\":{},\"components\":{{\"queueing\":{},\
             \"bank_conflict\":{},\"refresh_blocked\":{},\"copy_blocked\":{},\
             \"service\":{}}},\"read_latency\":{{\"p50\":{},\"p90\":{},\
             \"p99\":{},\"max\":{}}},\"queue_depth\":{{\"p50\":{},\"p90\":{},\
             \"p99\":{},\"max\":{}}},\"bank_util\":[{}]}}",
            self.requests,
            self.sum_queueing,
            self.sum_bank_conflict,
            self.sum_refresh_blocked,
            self.sum_copy_blocked,
            self.sum_service,
            json::number(self.lat_p50),
            json::number(self.lat_p90),
            json::number(self.lat_p99),
            json::number(self.lat_max),
            json::number(self.qd_p50),
            json::number(self.qd_p90),
            json::number(self.qd_p99),
            json::number(self.qd_max),
            self.bank_util
                .iter()
                .map(|&x| json::number(x))
                .collect::<Vec<_>>()
                .join(","),
        )
    }

    /// Rebuild from the object [`Self::to_json`] emits (campaign
    /// journal / result-cache read path; byte-stable round trip).
    pub fn from_json(v: &Value) -> Result<Self> {
        let num = |o: &Value, k: &str| -> Result<f64> {
            o.get(k)
                .and_then(Value::as_f64_or_nan)
                .ok_or_else(|| anyhow!("obs field '{k}' is not a number"))
        };
        let int = |o: &Value, k: &str| -> Result<u64> {
            o.get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| anyhow!("obs field '{k}' is not a u64"))
        };
        let comp = v
            .get("components")
            .ok_or_else(|| anyhow!("obs block missing 'components'"))?;
        let lat = v
            .get("read_latency")
            .ok_or_else(|| anyhow!("obs block missing 'read_latency'"))?;
        let qd = v
            .get("queue_depth")
            .ok_or_else(|| anyhow!("obs block missing 'queue_depth'"))?;
        let bank_util = v
            .get("bank_util")
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("obs block missing 'bank_util'"))?
            .iter()
            .map(|x| {
                x.as_f64_or_nan()
                    .ok_or_else(|| anyhow!("non-numeric bank_util entry"))
            })
            .collect::<Result<Vec<f64>>>()?;
        Ok(ObsReport {
            requests: int(v, "requests")?,
            sum_queueing: int(comp, "queueing")?,
            sum_bank_conflict: int(comp, "bank_conflict")?,
            sum_refresh_blocked: int(comp, "refresh_blocked")?,
            sum_copy_blocked: int(comp, "copy_blocked")?,
            sum_service: int(comp, "service")?,
            lat_p50: num(lat, "p50")?,
            lat_p90: num(lat, "p90")?,
            lat_p99: num(lat, "p99")?,
            lat_max: num(lat, "max")?,
            qd_p50: num(qd, "p50")?,
            qd_p90: num(qd, "p90")?,
            qd_p99: num(qd, "p99")?,
            qd_max: num(qd, "max")?,
            bank_util,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd_ev(kind: TraceKind, cycle: u64, done: u64, bank: i64, sa: i64) -> TraceEvent {
        let mut e = TraceEvent::new(kind, cycle, 0, 0);
        e.done = done;
        e.bank = bank;
        e.sa = sa;
        e
    }

    #[test]
    fn spans_cover_and_cut_half_open() {
        let mut s = Spans::default();
        s.open_at(10);
        s.close_at(20);
        assert!(!s.covers(9));
        assert!(s.covers(10));
        assert!(s.covers(19));
        assert!(!s.covers(20));
        s.open_at(30);
        assert!(s.covers(35), "open window extends to the query point");
        let mut cuts = vec![];
        s.boundaries_into(0, 100, &mut cuts);
        cuts.sort_unstable();
        assert_eq!(cuts, vec![10, 20, 30]);
    }

    #[test]
    fn conflict_ignores_own_row() {
        let mut r = RowSpans::default();
        r.open_at(0, 7);
        r.close_at(50);
        assert!(r.conflicts_at(10, 9), "other row open = conflict");
        assert!(!r.conflicts_at(10, 7), "own row open = hit, not conflict");
        assert!(!r.conflicts_at(60, 9), "closed window");
    }

    #[test]
    fn decomposition_partitions_the_window() {
        let mut a = Attribution::new(1, 1, 2, 2);
        // Refresh pending [5, 40), a conflicting row open [0, 30) in
        // the request's subarray, copy owning the bank [20, 60).
        let mut act = cmd_ev(TraceKind::Act, 0, 15, 0, 0);
        act.row = 99;
        a.observe(&act);
        a.observe(&TraceEvent::new(TraceKind::RefPend, 5, 0, 0));
        a.observe(&cmd_ev(TraceKind::Ref, 38, 40, -1, -1));
        a.observe(&cmd_ev(TraceKind::CopyOwn, 20, 20, 0, -1));
        a.observe(&cmd_ev(TraceKind::PreSa, 30, 42, 0, 0));
        a.observe(&cmd_ev(TraceKind::CopyRelease, 60, 60, 0, -1));
        // Request to row 7 of (bank 0, sa 0): arrived 0, issued 70,
        // done 85.
        let mut rd = cmd_ev(TraceKind::Rd, 70, 85, 0, 0);
        rd.id = 1;
        rd.arrive = 0;
        rd.row = 7;
        a.observe(&rd);
        let r = a.requests[0];
        assert_eq!(r.components_sum(), r.total(), "exact partition");
        assert_eq!(r.service, 15);
        // [5,40) refresh wins over both overlapping blockers.
        assert_eq!(r.refresh_blocked, 35);
        // Copy owns [20,60); refresh already claimed up to 40.
        assert_eq!(r.copy_blocked, 20);
        // Conflict [0,30) minus refresh [5,40) leaves [0,5).
        assert_eq!(r.bank_conflict, 5);
        // Remainder: [60,70).
        assert_eq!(r.queueing, 10);
    }

    #[test]
    fn busy_merge_ignores_overlap() {
        let mut b = Busy::default();
        b.merge(0, 10);
        b.merge(5, 12);
        b.merge(20, 25);
        assert_eq!(b.acc, 17);
    }

    #[test]
    fn obs_report_round_trips_byte_identically() {
        let r = ObsReport {
            requests: 3,
            sum_queueing: 10,
            sum_bank_conflict: 5,
            sum_refresh_blocked: 2,
            sum_copy_blocked: 0,
            sum_service: 45,
            lat_p50: 18.0,
            lat_p90: 30.5,
            lat_p99: 31.0,
            lat_max: 31.0,
            qd_p50: 1.0,
            qd_p90: 2.0,
            qd_p99: 2.0,
            qd_max: 2.0,
            bank_util: vec![0.25, 0.0],
        };
        let emitted = r.to_json();
        let parsed = crate::util::json::parse(&emitted).unwrap();
        let back = ObsReport::from_json(&parsed).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.to_json(), emitted);
        assert!(ObsReport::from_json(&Value::Null).is_err());
    }

    #[test]
    fn empty_run_finalizes_without_nan() {
        let a = Attribution::new(1, 1, 1, 1);
        let rep = a.finalize(0);
        assert_eq!(rep.requests, 0);
        assert_eq!(rep.lat_p50, 0.0);
        assert_eq!(rep.qd_max, 0.0);
        assert!(rep.bank_util.iter().all(|u| u.is_finite()));
        // And it still round-trips.
        let parsed = crate::util::json::parse(&rep.to_json()).unwrap();
        assert_eq!(ObsReport::from_json(&parsed).unwrap(), rep);
    }
}
