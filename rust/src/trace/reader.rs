//! Streaming trace reader.
//!
//! The reader never materializes a stream: ops are decoded out of a
//! fixed-size chunk buffer (64 KiB) refilled from the file on demand,
//! so a million-op trace costs the same resident memory as a
//! hundred-op one. `high_water()` reports the largest number of bytes
//! the reader ever held at once (header + chunk buffer) and is what
//! the memory-bound regression test asserts on.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cpu::trace::TraceOp;
use crate::trace::format::{self, ByteSource, StreamDesc, TraceHeader, FIXED_HEADER_BYTES};

/// Chunk buffer size. The memory-bound contract: resident bytes never
/// exceed the header plus one chunk.
pub const CHUNK_BYTES: usize = 64 << 10;

pub struct TraceReader {
    file: File,
    header: TraceHeader,
    /// Largest resident byte count (header + chunk buffer) observed.
    high_water: usize,
    header_bytes: usize,
}

impl TraceReader {
    pub fn open(path: &Path) -> Result<TraceReader> {
        let mut file = File::open(path)
            .with_context(|| format!("opening trace file {}", path.display()))?;
        let file_len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        let mut fixed = [0u8; FIXED_HEADER_BYTES as usize];
        file.read_exact(&mut fixed).with_context(|| {
            format!("truncated trace file {} (no header)", path.display())
        })?;
        let (core_count, name_len) = TraceHeader::decode_fixed(&fixed)
            .with_context(|| format!("reading {}", path.display()))?;
        let tail_len = TraceHeader::byte_len("", core_count as usize) as usize
            - FIXED_HEADER_BYTES as usize
            + name_len as usize;
        let mut tail = vec![0u8; tail_len];
        file.read_exact(&mut tail).with_context(|| {
            format!("truncated trace file {} (header cut short)", path.display())
        })?;
        let header = TraceHeader::decode_tail(core_count, name_len, &tail, file_len)
            .with_context(|| format!("reading {}", path.display()))?;
        let header_bytes = FIXED_HEADER_BYTES as usize + tail_len;
        Ok(TraceReader { file, header, high_water: header_bytes, header_bytes })
    }

    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Iterate core `core`'s ops, decoding out of a bounded chunk
    /// buffer. The iterator yields exactly `op_count` results (fusing
    /// after the first error) and verifies the stream consumes
    /// exactly its directory-declared byte length.
    pub fn ops(&mut self, core: usize) -> Result<OpIter<'_>> {
        let n = self.header.streams.len();
        if core >= n {
            bail!("core {core} out of range (trace has {n} streams)");
        }
        let desc = self.header.streams[core];
        self.file
            .seek(SeekFrom::Start(desc.offset))
            .with_context(|| format!("seeking to core {core} stream"))?;
        Ok(OpIter {
            rd: self,
            desc,
            core,
            buf: Vec::new(),
            pos: 0,
            consumed: 0,
            emitted: 0,
            failed: false,
        })
    }
}

pub struct OpIter<'a> {
    rd: &'a mut TraceReader,
    desc: StreamDesc,
    core: usize,
    buf: Vec<u8>,
    /// Cursor within `buf`.
    pos: usize,
    /// Stream bytes consumed so far (across all refills), including
    /// the unread remainder of the current buffer's fill.
    consumed: u64,
    emitted: u64,
    failed: bool,
}

impl OpIter<'_> {
    /// Address-delta state lives in the iterator between ops.
    fn decode_next(&mut self, prev: &mut u64) -> Result<TraceOp> {
        format::decode_op(self, prev)
    }
}

impl ByteSource for OpIter<'_> {
    fn next_byte(&mut self) -> Result<u8> {
        if self.pos == self.buf.len() {
            let remaining = self.desc.len - self.consumed;
            if remaining == 0 {
                bail!(
                    "core {} stream truncated: op {} of {} cut short",
                    self.core,
                    self.emitted + 1,
                    self.desc.op_count
                );
            }
            let take = remaining.min(CHUNK_BYTES as u64) as usize;
            self.buf.resize(take, 0);
            self.rd.file.read_exact(&mut self.buf).with_context(|| {
                format!("reading core {} stream (file shorter than its directory claims)", self.core)
            })?;
            self.consumed += take as u64;
            self.pos = 0;
            let resident = self.rd.header_bytes + self.buf.len();
            if resident > self.rd.high_water {
                self.rd.high_water = resident;
            }
        }
        let b = self.buf[self.pos];
        self.pos += 1;
        Ok(b)
    }
}

impl OpIter<'_> {
    /// Pull the next op; `None` once `op_count` ops have been decoded
    /// and the stream verified to end exactly on its declared length.
    pub fn next_op(&mut self, prev: &mut u64) -> Option<Result<TraceOp>> {
        if self.failed {
            return None;
        }
        if self.emitted == self.desc.op_count {
            // Exact-length check: no trailing bytes allowed.
            let left_in_buf = (self.buf.len() - self.pos) as u64;
            let unread = self.desc.len - self.consumed + left_in_buf;
            if unread > 0 {
                self.failed = true;
                return Some(Err(anyhow::anyhow!(
                    "core {} stream has {unread} trailing bytes after its {} declared ops",
                    self.core,
                    self.desc.op_count
                )));
            }
            return None;
        }
        let idx = self.emitted;
        match self.decode_next(prev) {
            Ok(op) => {
                self.emitted += 1;
                Some(Ok(op))
            }
            Err(e) => {
                self.failed = true;
                Some(Err(e.context(format!(
                    "decoding core {} op {idx} (of {})",
                    self.core, self.desc.op_count
                ))))
            }
        }
    }

    /// Convenience: drain the whole stream into a Vec (used by the
    /// replay loader, which needs materialized per-core traces
    /// anyway — the simulator's cores cycle over them).
    pub fn collect_ops(mut self) -> Result<Vec<TraceOp>> {
        let mut out = Vec::with_capacity(self.desc.op_count.min(1 << 20) as usize);
        let mut prev = 0u64;
        while let Some(op) = self.next_op(&mut prev) {
            out.push(op?);
        }
        Ok(out)
    }
}
