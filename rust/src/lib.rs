//! # LISA: Low-Cost Inter-Linked Subarrays — full-system reproduction
//!
//! This crate reproduces the system described in *"LISA: Increasing
//! Internal Connectivity in DRAM for Fast Data Movement and Low
//! Latency"* (Chang et al., HPCA 2016 / CS.AR 2018 retrospective) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — a cycle-accurate DRAM + memory
//!   controller + multi-core simulator (the paper's Ramulator-based
//!   methodology, built from scratch), with the LISA substrate
//!   (row-buffer movement), LISA-RISC bulk copy, LISA-VILLA in-DRAM
//!   caching and LISA-LIP linked precharge as first-class features.
//! * **Layer 2/1 (python, build-time only)** — a JAX/Pallas circuit
//!   model of the DRAM bitline analog dynamics (the paper's SPICE
//!   substitute), AOT-lowered to HLO text artifacts.
//! * **runtime** — loads those artifacts through PJRT (the `xla`
//!   crate) and *calibrates* the simulator's LISA timing and energy
//!   parameters from them. Python never runs on the simulation path.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment
//! index mapping every table/figure of the paper to modules and bench
//! targets, and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```
//! use lisa::config::SimConfig;
//! use lisa::sim::engine::Simulation;
//! use lisa::workloads::mixes;
//!
//! let mut cfg = SimConfig::default().with_all_lisa();
//! cfg.requests_per_core = 500; // keep the demo quick
//! let wl = mixes::workload_by_name("stream4", &cfg).unwrap();
//! let mut sim = Simulation::new(cfg, wl);
//! let report = sim.run();
//! assert_eq!(report.ipc.len(), 4);
//! println!("IPC sum: {:.3} over {} DRAM cycles", report.ipc_sum(), report.dram_cycles);
//! ```

// Curated `clippy::pedantic` subset (ISSUE 10): each lint here was
// audited against the tree and either passes or had its hits fixed.
// Complementary to `lisa lint`, which checks project conventions
// clippy cannot see. Extend this list one audited lint at a time —
// do not blanket-enable `clippy::pedantic`.
#![warn(
    clippy::bool_to_int_with_if,
    clippy::cloned_instead_of_copied,
    clippy::empty_enum,
    clippy::filter_map_next,
    clippy::flat_map_option,
    clippy::macro_use_imports,
    clippy::manual_string_new,
    clippy::mut_mut,
    clippy::needless_continue,
    clippy::redundant_else
)]

pub mod backend;
pub mod cli;
pub mod config;
pub mod controller;
pub mod copy;
pub mod cpu;
pub mod dram;
pub mod energy;
pub mod lint;
pub mod lisa;
pub mod metrics;
pub mod obs;
pub mod os;
#[cfg(feature = "runtime")]
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workloads;
