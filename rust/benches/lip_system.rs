//! Bench E7 (paper §3.3): LISA-LIP alone at the system level
//! (paper: +10.3% average across 50 four-core workloads).
//!
//! Env knobs: LISA_REQUESTS (default 2000), LISA_MIXES (default 15).

use lisa::sim::campaign::default_threads;
use lisa::sim::experiments::lip_system;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(d)
}

fn main() {
    let requests = env_u64("LISA_REQUESTS", 2_000);
    let n = env_u64("LISA_MIXES", 15) as usize;
    println!("=== E7: LISA-LIP system-level ({requests} reqs/core, {n} mixes) ===\n");
    let c = lip_system(requests, n, default_threads());
    for (wl, imp) in c.ws_improvements.iter().enumerate() {
        println!("copy-mix-{wl:02}: {:+.1}%", imp * 100.0);
    }
    println!(
        "\nmean WS improvement: {:+.1}% (paper: +10.3%)",
        c.mean_ws_improvement() * 100.0
    );
}
