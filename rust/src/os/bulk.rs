//! The OS bulk-operation engine: translates OS-level primitives —
//! `memcpy`, bulk page zeroing, `fork` (lazy CoW with fault-triggered
//! copies), checkpointing and hot-page migration — into page-granular
//! copy requests dispatched through the controller's page-copy queue,
//! choosing the best in-DRAM mechanism each page pair's geometry
//! allows (RowClone intra-SA / LISA-RISC / RowClone-PSM) and falling
//! back to memcpy-over-channel when none applies.
//!
//! This is the system-software half the paper's applications need
//! (RowClone's fork/zeroing consumers; the PIM-survey's OS-interface
//! barrier): the simulator's cores execute `TraceOp::Bulk` records at
//! *virtual* addresses, and everything physical — frames, placement,
//! mechanism dispatch, fault-triggered copies — happens here at run
//! time, so frame placement is a simulation knob, not a trace artifact.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::{CopyMechanism, SimConfig};
use crate::controller::mapping::{Mapper, MappingScheme};
use crate::controller::request::CopyRequest;
use crate::backend::MemoryModel;
use crate::copy::effective_mechanism;
use crate::cpu::trace::BulkOp;
use crate::lisa::villa::VillaManager;
use crate::metrics::OsSummary;
use crate::os::frame_alloc::FrameAlloc;
use crate::os::page_table::PageTable;

/// OS copy ids live in their own high range: below VILLA's (1 << 62)
/// and far above the per-core id spaces ((core + 1) << 32).
pub const OS_ID_BASE: u64 = 1 << 61;

/// What a bulk primitive resolved to; the core model acts on this.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OsOutcome {
    /// Pure bookkeeping (fork, no-op promote): the instruction retires.
    Done,
    /// Page copies were enqueued; the core stalls until every listed
    /// copy completes (synchronous bulk-op semantics).
    Stall(Vec<u64>),
    /// The primitive is a translated memory access: issue it at the
    /// returned physical address. `dependent` carries the trace's
    /// pointer-chase marker through translation so the core stalls its
    /// window on the access exactly as it would for a dependent load.
    Access {
        addr: u64,
        is_write: bool,
        dependent: bool,
    },
    /// A fault (CoW break / demand-zero fill): stall on the copies,
    /// then perform the access at the returned physical address.
    FaultThenAccess {
        copies: Vec<u64>,
        addr: u64,
        is_write: bool,
        dependent: bool,
    },
}

/// Per-process (= per-core) OS state.
#[derive(Debug, Clone, Default)]
struct Proc {
    pt: PageTable,
    /// Frames referenced by the (implicit) forked child; replaced —
    /// and released — wholesale by the next fork.
    child: Vec<u32>,
    /// Pages dirtied since the last checkpoint (vpn order).
    dirty: BTreeSet<u64>,
    /// Checkpoint shadow frames per vpn.
    shadow: BTreeMap<u64, u32>,
}

/// The OS layer: one flat page table per core, the subarray-aware
/// frame allocator, per-bank zero rows, and the bulk engine.
#[derive(Debug, Clone)]
pub struct OsLayer {
    frames: FrameAlloc,
    procs: Vec<Proc>,
    /// One pre-zeroed row per (channel, rank, bank): the in-DRAM
    /// zeroing source (RowClone-style), always a same-bank copy.
    zero_frames: Vec<u32>,
    mech: CopyMechanism,
    mapper: Mapper,
    dram: crate::config::DramConfig,
    page_bytes: u64,
    next_copy_id: u64,
    /// Frames whose last reference is dropped only when the listed
    /// copy completes (migration sources: freeing at dispatch would
    /// let the frame be reallocated while the copy still reads it).
    pending_free: Vec<(u64, u32)>,
    pub stats: OsSummary,
}

impl OsLayer {
    pub fn new(cfg: &SimConfig) -> Self {
        let reserved = VillaManager::reserved_rows(cfg);
        let mapper =
            Mapper::with_reserved(&cfg.dram, MappingScheme::RowRankBankColCh, reserved);
        let mut frames =
            FrameAlloc::new(&cfg.dram, reserved, cfg.os.placement, cfg.seed);
        let banks_total = cfg.dram.channels * cfg.dram.ranks * cfg.dram.banks;
        let zero_frames = (0..banks_total)
            .map(|gb| frames.alloc_top(gb).expect("zero row per bank"))
            .collect();
        Self {
            frames,
            procs: vec![Proc::default(); cfg.cpu.cores],
            zero_frames,
            mech: cfg.copy_mechanism,
            mapper,
            dram: cfg.dram.clone(),
            page_bytes: cfg.dram.row_bytes() as u64,
            next_copy_id: OS_ID_BASE,
            pending_free: Vec::new(),
            stats: OsSummary::default(),
        }
    }

    /// A page copy completed: drop any frame reference that was kept
    /// alive for it (the engine calls this for every copy completion).
    pub fn on_copy_complete(&mut self, copy_id: u64) {
        if let Some(i) = self.pending_free.iter().position(|&(id, _)| id == copy_id) {
            let (_, frame) = self.pending_free.swap_remove(i);
            self.frames.release(frame);
        }
    }

    /// Snapshot of the aggregate statistics for the run report.
    pub fn summary(&self) -> OsSummary {
        self.stats.clone()
    }

    /// Physical byte address of `va` through `frame` (same cache line
    /// offset within the 8 KB page/row).
    fn phys(&self, frame: u32, va: u64) -> u64 {
        let off = va % self.page_bytes;
        let mut a = self.frames.addr_of(frame);
        a.col = (off / 64) as usize;
        self.mapper.unmap(&a) + (off % 64)
    }

    /// Enqueue one page copy `src_frame -> dst_frame` on the
    /// controller's page-copy queue, dispatched with the system copy
    /// mechanism (the controller's sequencer picks the effective
    /// in-DRAM mechanism the pair's geometry allows). Returns the copy
    /// id the core must wait on.
    fn dispatch(
        &mut self,
        core: usize,
        src_frame: u32,
        dst_frame: u32,
        zero: bool,
        mem: &mut dyn MemoryModel,
    ) -> u64 {
        let src = self.frames.addr_of(src_frame);
        let dst = self.frames.addr_of(dst_frame);
        // No in-DRAM mechanism can cross a channel or rank boundary
        // (the inter-bank bus is per-rank): such pairs degrade to
        // memcpy over the channel regardless of the system mechanism.
        let req_mech = if src.channel != dst.channel || src.rank != dst.rank {
            CopyMechanism::MemcpyChannel
        } else {
            self.mech
        };
        let eff = effective_mechanism(req_mech, &src, &dst, &self.dram);
        self.stats.pages_copied += 1;
        if zero {
            self.stats.pages_zeroed += 1;
        }
        self.stats.mech_pages[OsSummary::mech_slot(eff)] += 1;
        if src.same_bank(&dst) {
            self.stats.risc_hits += 1;
        }
        let id = self.next_copy_id;
        self.next_copy_id += 1;
        mem.enqueue_page_copy(CopyRequest {
            id,
            core,
            src,
            dst,
            rows: 1,
            mechanism: req_mech,
            arrive: mem.now(),
        });
        id
    }

    /// Copy the bank-local zero row into `frame` (in-DRAM zeroing).
    fn dispatch_zero(&mut self, core: usize, frame: u32, mem: &mut dyn MemoryModel) -> u64 {
        let z = self.zero_frames[self.frames.bank_of(frame)];
        self.dispatch(core, z, frame, true, mem)
    }

    /// Execute one bulk primitive for `core`. Deterministic in the
    /// (config, op-sequence) pair: every data structure walks in vpn
    /// order and the allocator RNG is seeded from the config.
    pub fn execute(&mut self, core: usize, op: BulkOp, mem: &mut dyn MemoryModel) -> OsOutcome {
        match op {
            BulkOp::Touch { va, is_write, dependent } => {
                self.touch(core, va, is_write, dependent, mem)
            }
            BulkOp::Zero { va, pages } => self.zero(core, va, pages, mem),
            BulkOp::Memcpy { src_va, dst_va, pages } => {
                self.memcpy(core, src_va, dst_va, pages, mem)
            }
            BulkOp::Fork => self.fork(core),
            BulkOp::Checkpoint => self.checkpoint(core, mem),
            BulkOp::Promote { va } => self.promote(core, va, mem),
        }
    }

    fn touch(
        &mut self,
        core: usize,
        va: u64,
        is_write: bool,
        dependent: bool,
        mem: &mut dyn MemoryModel,
    ) -> OsOutcome {
        let vpn = va / self.page_bytes;
        match self.procs[core].pt.translate(vpn) {
            Some(e) if !(is_write && e.cow) => {
                if is_write {
                    self.procs[core].dirty.insert(vpn);
                }
                OsOutcome::Access {
                    addr: self.phys(e.frame, va),
                    is_write,
                    dependent,
                }
            }
            Some(e) => {
                // Write to a CoW page: break the sharing with a page
                // copy into a frame placed near the shared one.
                self.stats.cow_faults += 1;
                let new = match self.frames.alloc_near(e.frame) {
                    Some(f) => f,
                    None => {
                        // Physical memory exhausted: degrade to writing
                        // the shared frame in place, clearing the CoW
                        // bit so the fault is charged exactly once.
                        self.procs[core].pt.remap(vpn, e.frame);
                        self.procs[core].dirty.insert(vpn);
                        return OsOutcome::Access {
                            addr: self.phys(e.frame, va),
                            is_write,
                            dependent,
                        };
                    }
                };
                let id = self.dispatch(core, e.frame, new, false, mem);
                self.frames.release(e.frame);
                self.procs[core].pt.remap(vpn, new);
                self.procs[core].dirty.insert(vpn);
                OsOutcome::FaultThenAccess {
                    copies: vec![id],
                    addr: self.phys(new, va),
                    is_write,
                    dependent,
                }
            }
            None => {
                // Demand-zero fill: allocate + in-DRAM zero.
                self.stats.demand_faults += 1;
                let Some(f) = self.frames.alloc() else {
                    return OsOutcome::Done; // out of memory: drop the access
                };
                let id = self.dispatch_zero(core, f, mem);
                self.procs[core].pt.map(vpn, f, false);
                if is_write {
                    self.procs[core].dirty.insert(vpn);
                }
                OsOutcome::FaultThenAccess {
                    copies: vec![id],
                    addr: self.phys(f, va),
                    is_write,
                    dependent,
                }
            }
        }
    }

    fn zero(&mut self, core: usize, va: u64, pages: u32, mem: &mut dyn MemoryModel) -> OsOutcome {
        let base = va / self.page_bytes;
        let mut ids = Vec::with_capacity(pages as usize);
        for i in 0..pages as u64 {
            let vpn = base + i;
            let frame = match self.procs[core].pt.translate(vpn) {
                Some(e) if e.cow => {
                    // Zeroing a shared page: give the process a fresh
                    // private frame (content is all-zero anyway).
                    let Some(f) = self.frames.alloc() else { continue };
                    self.frames.release(e.frame);
                    self.procs[core].pt.remap(vpn, f);
                    f
                }
                Some(e) => e.frame,
                None => {
                    let Some(f) = self.frames.alloc() else { continue };
                    self.procs[core].pt.map(vpn, f, false);
                    f
                }
            };
            ids.push(self.dispatch_zero(core, frame, mem));
            self.procs[core].dirty.insert(vpn);
        }
        if ids.is_empty() {
            OsOutcome::Done
        } else {
            OsOutcome::Stall(ids)
        }
    }

    fn memcpy(
        &mut self,
        core: usize,
        src_va: u64,
        dst_va: u64,
        pages: u32,
        mem: &mut dyn MemoryModel,
    ) -> OsOutcome {
        let src_base = src_va / self.page_bytes;
        let dst_base = dst_va / self.page_bytes;
        let mut ids = Vec::with_capacity(pages as usize);
        for i in 0..pages as u64 {
            let Some(src_e) = self.procs[core].pt.translate(src_base + i) else {
                continue; // unmapped source page: nothing to copy
            };
            let dst_vpn = dst_base + i;
            let dst_frame = match self.procs[core].pt.translate(dst_vpn) {
                Some(e) if !e.cow => e.frame,
                Some(e) => {
                    let Some(f) = self.frames.alloc_near(src_e.frame) else { continue };
                    self.frames.release(e.frame);
                    self.procs[core].pt.remap(dst_vpn, f);
                    f
                }
                None => {
                    let Some(f) = self.frames.alloc_near(src_e.frame) else { continue };
                    self.procs[core].pt.map(dst_vpn, f, false);
                    f
                }
            };
            ids.push(self.dispatch(core, src_e.frame, dst_frame, false, mem));
            self.procs[core].dirty.insert(dst_vpn);
        }
        if ids.is_empty() {
            OsOutcome::Done
        } else {
            OsOutcome::Stall(ids)
        }
    }

    fn fork(&mut self, core: usize) -> OsOutcome {
        // Retire the previous child first (fork-server steady state:
        // one live child per server process).
        let old = std::mem::take(&mut self.procs[core].child);
        for f in old {
            self.frames.release(f);
        }
        let shared = self.procs[core].pt.mark_all_cow();
        for &f in &shared {
            self.frames.retain(f);
        }
        self.procs[core].child = shared;
        self.stats.forks += 1;
        OsOutcome::Done
    }

    fn checkpoint(&mut self, core: usize, mem: &mut dyn MemoryModel) -> OsOutcome {
        self.stats.checkpoints += 1;
        let dirty: Vec<u64> = std::mem::take(&mut self.procs[core].dirty)
            .into_iter()
            .collect();
        let mut ids = Vec::with_capacity(dirty.len());
        for vpn in dirty {
            let Some(e) = self.procs[core].pt.translate(vpn) else { continue };
            let Some(shadow) = self.frames.alloc_near(e.frame) else { continue };
            if let Some(old) = self.procs[core].shadow.insert(vpn, shadow) {
                self.frames.release(old);
            }
            ids.push(self.dispatch(core, e.frame, shadow, false, mem));
        }
        if ids.is_empty() {
            OsOutcome::Done
        } else {
            OsOutcome::Stall(ids)
        }
    }

    fn promote(&mut self, core: usize, va: u64, mem: &mut dyn MemoryModel) -> OsOutcome {
        let vpn = va / self.page_bytes;
        let Some(e) = self.procs[core].pt.translate(vpn) else {
            return OsOutcome::Done; // nothing mapped to promote
        };
        if self.frames.level_of(e.frame) < crate::os::frame_alloc::ZONE_LEVELS {
            return OsOutcome::Done; // already in the promotion zone
        }
        let Some(zone) = self.frames.alloc_zone(e.frame) else {
            return OsOutcome::Done; // zone full: skip
        };
        let id = self.dispatch(core, e.frame, zone, false, mem);
        // The old frame stays allocated until the copy has read it.
        self.pending_free.push((id, e.frame));
        self.procs[core].pt.remap(vpn, zone);
        self.stats.promotions += 1;
        OsOutcome::Stall(vec![id])
    }

    /// Mapped pages of one process (test/diagnostic hook).
    pub fn mapped_pages(&self, core: usize) -> usize {
        self.procs[core].pt.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlacementPolicy;
    use crate::controller::Controller;

    fn setup(mech: CopyMechanism, placement: PlacementPolicy) -> (OsLayer, Controller) {
        let mut cfg = SimConfig::default();
        cfg.copy_mechanism = mech;
        cfg.lisa.risc = mech == CopyMechanism::LisaRisc;
        cfg.os.placement = placement;
        let ctrl = Controller::new(cfg.clone());
        (OsLayer::new(&cfg), ctrl)
    }

    fn drain(ctrl: &mut Controller) -> Vec<u64> {
        let mut done = vec![];
        for _ in 0..2_000_000u64 {
            ctrl.tick().unwrap();
            done.extend(ctrl.drain_completions().into_iter().map(|c| c.id));
            if ctrl.idle() {
                break;
            }
        }
        assert!(ctrl.idle(), "controller failed to drain OS copies");
        done
    }

    #[test]
    fn touch_demand_zeroes_then_hits() {
        let (mut os, mut ctrl) =
            setup(CopyMechanism::LisaRisc, PlacementPolicy::SubarrayPacked);
        let touch = BulkOp::Touch { va: 8192 * 5 + 64, is_write: false, dependent: false };
        let out = os.execute(0, touch, &mut ctrl);
        let (copies, addr) = match out {
            OsOutcome::FaultThenAccess { copies, addr, .. } => (copies, addr),
            other => panic!("first touch must demand-fault, got {other:?}"),
        };
        assert_eq!(copies.len(), 1);
        assert_eq!(os.stats.pages_zeroed, 1);
        assert_eq!(os.stats.demand_faults, 1);
        let done = drain(&mut ctrl);
        assert_eq!(done, copies);
        // Second touch to the same page: plain access, same line.
        let out2 = os.execute(0, touch, &mut ctrl);
        assert_eq!(out2, OsOutcome::Access { addr, is_write: false, dependent: false });
        assert_eq!(os.mapped_pages(0), 1);
    }

    #[test]
    fn fork_then_write_breaks_cow_once() {
        let (mut os, mut ctrl) =
            setup(CopyMechanism::LisaRisc, PlacementPolicy::SubarrayPacked);
        // Map 4 pages via zeroing.
        let out = os.execute(0, BulkOp::Zero { va: 0, pages: 4 }, &mut ctrl);
        assert!(matches!(out, OsOutcome::Stall(ref v) if v.len() == 4));
        drain(&mut ctrl);
        assert_eq!(os.execute(0, BulkOp::Fork, &mut ctrl), OsOutcome::Done);
        assert_eq!(os.stats.forks, 1);
        // Read: no fault.
        assert!(matches!(
            os.execute(
                0,
                BulkOp::Touch { va: 0, is_write: false, dependent: false },
                &mut ctrl
            ),
            OsOutcome::Access { .. }
        ));
        // Write: one CoW copy; the repeat write does not fault again.
        let w = os.execute(
            0,
            BulkOp::Touch { va: 0, is_write: true, dependent: false },
            &mut ctrl,
        );
        assert!(matches!(w, OsOutcome::FaultThenAccess { .. }), "{w:?}");
        assert_eq!(os.stats.cow_faults, 1);
        drain(&mut ctrl);
        assert!(matches!(
            os.execute(
                0,
                BulkOp::Touch { va: 0, is_write: true, dependent: false },
                &mut ctrl
            ),
            OsOutcome::Access { .. }
        ));
        assert_eq!(os.stats.cow_faults, 1);
    }

    #[test]
    fn checkpoint_copies_exactly_the_dirty_pages() {
        let (mut os, mut ctrl) =
            setup(CopyMechanism::LisaRisc, PlacementPolicy::SubarrayPacked);
        os.execute(0, BulkOp::Zero { va: 0, pages: 8 }, &mut ctrl);
        drain(&mut ctrl);
        // Zeroing dirtied all 8; first checkpoint shadows them.
        let out = os.execute(0, BulkOp::Checkpoint, &mut ctrl);
        assert!(matches!(out, OsOutcome::Stall(ref v) if v.len() == 8), "{out:?}");
        drain(&mut ctrl);
        // Touch-write 2 pages; next checkpoint copies exactly 2.
        for p in [1u64, 6] {
            os.execute(
                0,
                BulkOp::Touch { va: p * 8192, is_write: true, dependent: false },
                &mut ctrl,
            );
            drain(&mut ctrl);
        }
        let out = os.execute(0, BulkOp::Checkpoint, &mut ctrl);
        assert!(matches!(out, OsOutcome::Stall(ref v) if v.len() == 2), "{out:?}");
        drain(&mut ctrl);
        // Nothing dirty: checkpoint is free.
        assert_eq!(os.execute(0, BulkOp::Checkpoint, &mut ctrl), OsOutcome::Done);
    }

    #[test]
    fn promote_moves_into_zone_once() {
        let (mut os, mut ctrl) =
            setup(CopyMechanism::LisaRisc, PlacementPolicy::SubarrayPacked);
        os.execute(0, BulkOp::Zero { va: 8192, pages: 1 }, &mut ctrl);
        drain(&mut ctrl);
        let out = os.execute(0, BulkOp::Promote { va: 8192 }, &mut ctrl);
        let ids = match out {
            OsOutcome::Stall(v) => v,
            other => panic!("promote must stall on its copy, got {other:?}"),
        };
        assert_eq!(ids.len(), 1);
        drain(&mut ctrl);
        assert_eq!(os.stats.promotions, 1);
        // The migration source frame is freed only once the copy that
        // reads it has completed.
        let before = os.frames.free_frames();
        os.on_copy_complete(ids[0]);
        assert_eq!(os.frames.free_frames(), before + 1, "source freed on completion");
        // Second promote: already in the zone, no copy.
        assert_eq!(os.execute(0, BulkOp::Promote { va: 8192 }, &mut ctrl), OsOutcome::Done);
        assert_eq!(os.stats.promotions, 1);
    }

    #[test]
    fn packed_placement_yields_same_bank_copies_random_does_not() {
        let run = |placement| {
            let (mut os, mut ctrl) = setup(CopyMechanism::LisaRisc, placement);
            os.execute(0, BulkOp::Zero { va: 0, pages: 32 }, &mut ctrl);
            drain(&mut ctrl);
            os.execute(0, BulkOp::Fork, &mut ctrl);
            for p in 0..32u64 {
                os.execute(
                    0,
                    BulkOp::Touch { va: p * 8192, is_write: true, dependent: false },
                    &mut ctrl,
                );
                drain(&mut ctrl);
            }
            // Exclude the 32 (always same-bank) zero fills.
            (os.stats.risc_hits - 32) as f64 / os.stats.cow_faults as f64
        };
        let packed = run(PlacementPolicy::SubarrayPacked);
        let random = run(PlacementPolicy::Random);
        assert!(packed > 0.9, "packed CoW hit rate {packed}");
        assert!(random < 0.6, "random CoW hit rate {random}");
    }

    #[test]
    fn memcpy_bulk_op_copies_pages() {
        let (mut os, mut ctrl) =
            setup(CopyMechanism::MemcpyChannel, PlacementPolicy::SubarraySpread);
        os.execute(0, BulkOp::Zero { va: 0, pages: 4 }, &mut ctrl);
        drain(&mut ctrl);
        let out = os.execute(
            0,
            BulkOp::Memcpy { src_va: 0, dst_va: 64 * 8192, pages: 4 },
            &mut ctrl,
        );
        assert!(matches!(out, OsOutcome::Stall(ref v) if v.len() == 4));
        drain(&mut ctrl);
        assert_eq!(os.mapped_pages(0), 8);
        // All page traffic under the memcpy system crosses the channel.
        assert_eq!(
            os.stats.mech_pages[OsSummary::mech_index("memcpy").unwrap()],
            os.stats.pages_copied
        );
    }
}
