//! Deterministic pseudo-random number generation (SplitMix64 seeding +
//! PCG32 stream), built in-tree because the offline registry has no
//! `rand`. Every stochastic component of the simulator (workload
//! generators, Monte-Carlo variation populations for calibration)
//! derives from these, so whole experiments are reproducible from a
//! single seed.

/// SplitMix64: used to expand a single u64 seed into independent
/// stream seeds. Passes BigCrush as a 64-bit generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and a stream id; distinct stream
    /// ids yield independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.rotate_left(17));
        let mut rng = Self {
            state: 0,
            inc: (sm.next_u64() << 1) | 1,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift; for simulator purposes the tiny
        // residual bias of a single multiply is unacceptable in
        // property tests, so reject the low zone.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box-Muller (we don't need ziggurat speed).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Lognormal multiplier with median 1.0 and the given sigma — the
    /// process-variation model used for calibration populations.
    pub fn lognormal_mul(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// True with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniform element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams look correlated: {same} equal draws");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg32::new(7, 3);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = Pcg32::new(1, 1);
        let mut lo = 0usize;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            if x < 0.5 {
                lo += 1;
            }
        }
        assert!((4_500..5_500).contains(&lo), "biased: {lo}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(9, 2);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut r = Pcg32::new(3, 5);
        let mut xs: Vec<f64> = (0..5001).map(|_| r.lognormal_mul(0.1)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[2500];
        assert!((med - 1.0).abs() < 0.02, "median {med}");
    }
}
