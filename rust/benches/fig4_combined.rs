//! Bench E6 (Fig. 4): combined weighted-speedup improvement of
//! LISA-RISC / +VILLA / +LIP over the memcpy baseline across the copy
//! mixes (paper: +59.6% / +76.1% cumulative / +94.8%; energy -49%).
//!
//! Env knobs: LISA_REQUESTS (default 2000), LISA_MIXES (default 15;
//! set 50 for the paper's full sweep).

use lisa::sim::campaign::default_threads;
use lisa::sim::experiments::fig4;
use lisa::util::bench::Table;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(d)
}

fn main() {
    let requests = env_u64("LISA_REQUESTS", 2_000);
    let n = env_u64("LISA_MIXES", 15) as usize;
    println!("=== E6 / Fig. 4: combined LISA ({requests} reqs/core, {n} mixes) ===\n");
    let cmps = fig4(requests, n, default_threads());
    let mut t = Table::new(&["config", "mean WS +%", "geomean x", "max +%", "energy -%", "paper WS"]);
    let paper = ["+59.6%", "+76.1% cum", "+94.8%"];
    for (c, p) in cmps.iter().zip(paper) {
        t.row(&[
            c.name.clone(),
            format!("{:+.1}", c.mean_ws_improvement() * 100.0),
            format!("{:.3}", c.geomean_speedup()),
            format!("{:+.1}", c.max_ws_improvement() * 100.0),
            format!("{:.1}", c.mean_energy_reduction() * 100.0),
            p.to_string(),
        ]);
    }
    t.print();
    println!("\nshape checks: each row adds benefit; All > RISC+VILLA > RISC > 0.");
}
