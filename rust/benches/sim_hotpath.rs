//! Perf bench: the simulator's own hot path (EXPERIMENTS.md §Perf).
//! Measures end-to-end simulated DRAM-cycles/second on representative
//! workloads, for both the event-driven fast-forward engine (the
//! default `run()`) and the per-cycle reference loop — the ratio is
//! the repo's headline engine-speed metric.
//!
//! Usage: `cargo bench --bench sim_hotpath [-- REQUESTS]
//!             [--json FILE] [--gate BASELINE] [--handicap N]`
//!
//! * REQUESTS defaults to 5000; CI smoke mode passes a small value.
//! * `--json FILE` writes a machine-readable summary (the CI artifact
//!   the perf-regression gate and historical comparisons consume).
//! * `--gate BASELINE` compares the measurement against the checked-in
//!   thresholds (`rust/ci/perf_baseline.toml`) and exits non-zero on a
//!   regression beyond the (deliberately generous) tolerance.
//! * `--handicap N` multiplies the measured fast-forward time by N —
//!   an artificial slowdown for demonstrating that the gate fails
//!   (e.g. `-- 800 --gate ci/perf_baseline.toml --handicap 10`).

use std::time::Instant;

use lisa::config::minitoml::Document;
use lisa::config::{CopyMechanism, SalpMode, SimConfig};
use lisa::metrics::json;
use lisa::sim::engine::Simulation;
use lisa::sim::spec::{self, RunOptions};
use lisa::util::bench::Table;
use lisa::workloads::mixes;

/// Measured configurations. The four all-LISA rows are the historical
/// smoke set; `salp-conflict` drives an intra-bank conflict mix under
/// MASA + LISA-RISC — together with `fork4` (copy-heavy) it anchors
/// the per-class throughput floors of the perf gate, because those two
/// put the most pressure on the scheduler's per-bank index and the
/// cached event horizons.
const CASES: [(&str, &str, SalpMode); 5] = [
    ("stream4", "stream4", SalpMode::None),
    ("random4", "random4", SalpMode::None),
    ("hotspot4", "hotspot4", SalpMode::None),
    ("fork4", "fork4", SalpMode::None),
    ("salp-conflict", "salp-shared-bank4", SalpMode::Masa),
];

struct Measurement {
    name: &'static str,
    cycles: u64,
    ff_secs: f64,
    ref_secs: f64,
}

impl Measurement {
    fn ff_rate(&self) -> f64 {
        self.cycles as f64 / self.ff_secs
    }

    fn ref_rate(&self) -> f64 {
        self.cycles as f64 / self.ref_secs
    }

    fn speedup(&self) -> f64 {
        self.ff_rate() / self.ref_rate()
    }
}

fn bench_workload(
    name: &'static str,
    workload: &str,
    salp: SalpMode,
    requests: u64,
    handicap: f64,
) -> Measurement {
    let mut cfg = if salp == SalpMode::None {
        SimConfig::default().with_all_lisa()
    } else {
        // SALP rows run MASA + LISA-RISC + LIP without VILLA (the
        // composition the E10 equivalence matrix pins).
        let mut c = SimConfig::default();
        c.lisa.risc = true;
        c.lisa.lip = true;
        c.copy_mechanism = CopyMechanism::LisaRisc;
        c.dram.salp = salp;
        c
    };
    cfg.requests_per_core = requests;
    let wl = mixes::workload_by_name(workload, &cfg).unwrap();

    let mut ff = Simulation::new(cfg.clone(), wl.clone());
    let t0 = Instant::now();
    let r_ff = ff.run();
    let ff_secs = t0.elapsed().as_secs_f64() * handicap;

    let mut reference = Simulation::new(cfg, wl);
    let t0 = Instant::now();
    let r_ref = reference.reference_run();
    let ref_secs = t0.elapsed().as_secs_f64();

    assert_eq!(
        r_ff, r_ref,
        "{name}: fast-forward must be cycle-exact vs the reference loop"
    );
    Measurement {
        name,
        cycles: r_ff.dram_cycles,
        ff_secs,
        ref_secs,
    }
}

/// Grid-expansion overhead of the declarative experiment API: how
/// many times per second the FULL built-in registry (every spec's
/// default grid — several hundred `SimConfig`s + workload clones) can
/// be expanded. Expansion happens once per campaign, strictly before
/// any simulation starts, so it must stay off the simulated hot path;
/// the gate floor in `ci/perf_baseline.toml` pins that down.
struct Expansion {
    points_per_registry: usize,
    registries_per_sec: f64,
}

fn bench_grid_expansion() -> Expansion {
    let specs = spec::registry();
    let opts = RunOptions::default();
    // Warm once (builds the workload suite caches, faults in code).
    let mut points_per_registry = 0usize;
    for s in &specs {
        points_per_registry += spec::expand(s, &opts).expect("built-in grid").len();
    }
    const ITERS: usize = 5;
    let t0 = Instant::now();
    let mut total = 0usize;
    for _ in 0..ITERS {
        for s in &specs {
            total += spec::expand(s, &opts).expect("built-in grid").len();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(total, points_per_registry * ITERS);
    Expansion {
        points_per_registry,
        registries_per_sec: ITERS as f64 / secs,
    }
}

/// The two gate-relevant aggregates, computed in exactly one place so
/// the printed table, the JSON artifact and the gate verdict can never
/// diverge: (aggregate fast-forward cycles/sec, worst-case speedup).
fn aggregates(measurements: &[Measurement]) -> (f64, f64) {
    let total_cycles: u64 = measurements.iter().map(|m| m.cycles).sum();
    let total_ff_secs: f64 = measurements.iter().map(|m| m.ff_secs).sum();
    let worst = measurements
        .iter()
        .map(Measurement::speedup)
        .fold(f64::INFINITY, f64::min);
    (total_cycles as f64 / total_ff_secs, worst)
}

fn summary_json(requests: u64, measurements: &[Measurement], exp: &Expansion) -> String {
    let (agg_rate, worst) = aggregates(measurements);
    let rows: Vec<String> = measurements
        .iter()
        .map(|m| {
            format!(
                "{{\"workload\":{},\"sim_cycles\":{},\"ff_cyc_per_sec\":{},\
                 \"ref_cyc_per_sec\":{},\"speedup\":{}}}",
                json::string(m.name),
                m.cycles,
                json::number(m.ff_rate()),
                json::number(m.ref_rate()),
                json::number(m.speedup()),
            )
        })
        .collect();
    format!(
        "{{\"bench\":\"sim_hotpath\",\"schema\":3,\"requests\":{requests},\
         \"workloads\":[\n{}\n],\"aggregate_ff_cyc_per_sec\":{},\
         \"worst_ff_speedup\":{},\"grid_points\":{},\
         \"grid_expansions_per_sec\":{}}}\n",
        rows.join(",\n"),
        json::number(agg_rate),
        json::number(worst),
        exp.points_per_registry,
        json::number(exp.registries_per_sec),
    )
}

/// Apply the checked-in perf baseline; returns Err lines on violation.
fn check_gate(
    path: &str,
    measurements: &[Measurement],
    exp: &Expansion,
) -> Result<(), Vec<String>> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("perf baseline {path}: {e}"));
    let doc = Document::parse(&text).expect("perf baseline parses");
    let min_speedup = doc
        .get_f64("sim_hotpath", "min_ff_speedup")
        .expect("min_ff_speedup type")
        .expect("min_ff_speedup present");
    let min_mcyc = doc
        .get_f64("sim_hotpath", "min_ff_mcyc_per_sec")
        .expect("min_ff_mcyc_per_sec type")
        .expect("min_ff_mcyc_per_sec present");
    let min_expansions = doc
        .get_f64("sim_hotpath", "min_grid_expansions_per_sec")
        .expect("min_grid_expansions_per_sec type")
        .expect("min_grid_expansions_per_sec present");

    let (agg_rate, worst) = aggregates(measurements);
    let agg_mcyc = agg_rate / 1e6;

    let mut violations = Vec::new();
    if worst < min_speedup {
        violations.push(format!(
            "worst-case fast-forward speedup {worst:.2}x < baseline floor {min_speedup:.2}x"
        ));
    }
    if agg_mcyc < min_mcyc {
        violations.push(format!(
            "aggregate fast-forward throughput {agg_mcyc:.2} Mcyc/s < baseline floor \
             {min_mcyc:.2} Mcyc/s"
        ));
    }
    // Per-class floors: the copy-heavy and SALP-conflict rows are the
    // scheduler-index / horizon-cache stress cases the aggregate can
    // average away, so each is gated on its own.
    for (key, wl) in [
        ("min_ff_mcyc_per_sec_copy", "fork4"),
        ("min_ff_mcyc_per_sec_salp", "salp-conflict"),
    ] {
        let floor = doc
            .get_f64("sim_hotpath", key)
            .unwrap_or_else(|e| panic!("{key} type: {e}"))
            .unwrap_or_else(|| panic!("{key} present"));
        let m = measurements
            .iter()
            .find(|m| m.name == wl)
            .unwrap_or_else(|| panic!("gated workload '{wl}' was measured"));
        let rate = m.ff_rate() / 1e6;
        if rate < floor {
            violations.push(format!(
                "{wl} fast-forward throughput {rate:.2} Mcyc/s < class floor \
                 {floor:.2} Mcyc/s ({key})"
            ));
        }
    }
    if exp.registries_per_sec < min_expansions {
        violations.push(format!(
            "registry grid expansion {:.2}/s < baseline floor {min_expansions:.2}/s \
             ({} points) — spec expansion must stay off the simulated hot path",
            exp.registries_per_sec, exp.points_per_registry
        ));
    }
    if violations.is_empty() {
        Ok(())
    } else {
        Err(violations)
    }
}

fn main() {
    // First bare numeric argument = request count; flagged options may
    // appear in any order (cargo bench injects its own `--bench`).
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut requests: u64 = 5_000;
    let mut json_out: Option<String> = None;
    let mut gate: Option<String> = None;
    let mut handicap: f64 = 1.0;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" if i + 1 < argv.len() => {
                json_out = Some(argv[i + 1].clone());
                i += 1;
            }
            "--gate" if i + 1 < argv.len() => {
                gate = Some(argv[i + 1].clone());
                i += 1;
            }
            "--handicap" if i + 1 < argv.len() => {
                handicap = argv[i + 1].parse().expect("numeric --handicap");
                i += 1;
            }
            // cargo injects --bench for harness-style invocations.
            "--bench" => {}
            other => match other.parse() {
                Ok(n) => requests = n,
                // Anything else is a mistyped flag or a flag missing
                // its value — neither may silently disable the gate.
                Err(_) => {
                    eprintln!("sim_hotpath: unknown or valueless argument '{other}'");
                    std::process::exit(2);
                }
            },
        }
        i += 1;
    }

    println!("=== Simulator hot-path throughput ({requests} requests/core) ===\n");
    let mut t = Table::new(&[
        "workload",
        "sim cycles",
        "ff Mcyc/s",
        "ref Mcyc/s",
        "speedup",
    ]);
    let mut measurements = Vec::new();
    for (name, workload, salp) in CASES {
        let m = bench_workload(name, workload, salp, requests, handicap);
        t.row(&[
            name.to_string(),
            format!("{}", m.cycles),
            format!("{:.2}", m.ff_rate() / 1e6),
            format!("{:.2}", m.ref_rate() / 1e6),
            format!("{:.2}x", m.speedup()),
        ]);
        measurements.push(m);
    }
    t.print();
    let (_, worst) = aggregates(&measurements);
    println!("\nworst-case fast-forward speedup: {worst:.2}x");
    println!("target (EXPERIMENTS.md §Perf): >= 3x vs the per-cycle reference loop");
    if handicap != 1.0 {
        println!("NOTE: fast-forward times artificially inflated {handicap}x (--handicap)");
    }

    let expansion = bench_grid_expansion();
    println!(
        "experiment-registry grid expansion: {} points in {:.1} registries/s \
         (off the simulated hot path; gated)",
        expansion.points_per_registry, expansion.registries_per_sec
    );

    if let Some(path) = json_out {
        std::fs::write(&path, summary_json(requests, &measurements, &expansion))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }
    if let Some(path) = gate {
        match check_gate(&path, &measurements, &expansion) {
            Ok(()) => println!("perf gate: PASS ({path})"),
            Err(violations) => {
                eprintln!("perf gate: FAIL ({path})");
                for v in &violations {
                    eprintln!("  {v}");
                }
                eprintln!(
                    "intentional engine change? bump the floors in {path} in the same PR \
                     (one-line edit) and say why in the PR description"
                );
                std::process::exit(1);
            }
        }
    }
}
