//! Perf bench: the simulator's own hot path (EXPERIMENTS.md §Perf).
//! Measures end-to-end simulated DRAM-cycles/second on representative
//! workloads, for both the event-driven fast-forward engine (the
//! default `run()`) and the per-cycle reference loop — the ratio is
//! the repo's headline engine-speed metric.
//!
//! Usage: `cargo bench --bench sim_hotpath [-- REQUESTS]
//!             [--json FILE] [--gate BASELINE] [--handicap N]`
//!
//! * REQUESTS defaults to 5000; CI smoke mode passes a small value.
//! * `--json FILE` writes a machine-readable summary (the CI artifact
//!   the perf-regression gate and historical comparisons consume).
//! * `--gate BASELINE` compares the measurement against the checked-in
//!   thresholds (`rust/ci/perf_baseline.toml`) and exits non-zero on a
//!   regression beyond the (deliberately generous) tolerance.
//! * `--handicap N` multiplies the measured fast-forward time by N —
//!   an artificial slowdown for demonstrating that the gate fails
//!   (e.g. `-- 800 --gate ci/perf_baseline.toml --handicap 10`).

use std::time::Instant;

use lisa::backend::analytical::AnalyticalModel;
use lisa::backend::{Access, MemoryModel};
use lisa::config::minitoml::Document;
use lisa::config::{BackendKind, CopyMechanism, SalpMode, SimConfig};
use lisa::controller::Controller;
use lisa::dram::geometry::Address;
use lisa::metrics::json;
use lisa::sim::engine::{run_workload, Simulation};
use lisa::sim::spec::{self, RunOptions};
use lisa::util::bench::Table;
use lisa::workloads::mixes;

/// Measured configurations. The four all-LISA rows are the historical
/// smoke set; `salp-conflict` drives an intra-bank conflict mix under
/// MASA + LISA-RISC — together with `fork4` (copy-heavy) it anchors
/// the per-class throughput floors of the perf gate, because those two
/// put the most pressure on the scheduler's per-bank index and the
/// cached event horizons.
const CASES: [(&str, &str, SalpMode); 5] = [
    ("stream4", "stream4", SalpMode::None),
    ("random4", "random4", SalpMode::None),
    ("hotspot4", "hotspot4", SalpMode::None),
    ("fork4", "fork4", SalpMode::None),
    ("salp-conflict", "salp-shared-bank4", SalpMode::Masa),
];

struct Measurement {
    name: &'static str,
    cycles: u64,
    ff_secs: f64,
    ref_secs: f64,
}

impl Measurement {
    fn ff_rate(&self) -> f64 {
        self.cycles as f64 / self.ff_secs
    }

    fn ref_rate(&self) -> f64 {
        self.cycles as f64 / self.ref_secs
    }

    fn speedup(&self) -> f64 {
        self.ff_rate() / self.ref_rate()
    }
}

fn bench_workload(
    name: &'static str,
    workload: &str,
    salp: SalpMode,
    requests: u64,
    handicap: f64,
) -> Measurement {
    let mut cfg = if salp == SalpMode::None {
        SimConfig::default().with_all_lisa()
    } else {
        // SALP rows run MASA + LISA-RISC + LIP without VILLA (the
        // composition the E10 equivalence matrix pins).
        let mut c = SimConfig::default();
        c.lisa.risc = true;
        c.lisa.lip = true;
        c.copy_mechanism = CopyMechanism::LisaRisc;
        c.dram.salp = salp;
        c
    };
    cfg.requests_per_core = requests;
    let wl = mixes::workload_by_name(workload, &cfg).unwrap();

    let mut ff = Simulation::new(cfg.clone(), wl.clone());
    let t0 = Instant::now();
    let r_ff = ff.run();
    let ff_secs = t0.elapsed().as_secs_f64() * handicap;

    let mut reference = Simulation::new(cfg, wl);
    let t0 = Instant::now();
    let r_ref = reference.reference_run();
    let ref_secs = t0.elapsed().as_secs_f64();

    assert_eq!(
        r_ff, r_ref,
        "{name}: fast-forward must be cycle-exact vs the reference loop"
    );
    Measurement {
        name,
        cycles: r_ff.dram_cycles,
        ff_secs,
        ref_secs,
    }
}

/// The fleet-sweep economics of the two memory-model backends
/// (DESIGN.md §MemoryModel backends), measured two ways:
///
/// * **Model-level** — both backends driven directly through the
///   `MemoryModel` trait on a serialized same-bank row-conflict read
///   stream (the controller's worst case, and the strongest test of
///   the analytical busy-until chains). The cycle backend runs its
///   cycle-exact semantics — one `tick` per DRAM cycle; the analytical
///   backend event-skips between completions, exactly how campaigns
///   consume it. The ratio is the gated `min_analytical_model_speedup`
///   floor: machine-independent (same process, same stream).
/// * **End-to-end** — one full grid point (`run_workload`, CPU model
///   included) per backend. Informational only: both backends share
///   the identical trace-driven CPU/cache model, so Amdahl bounds this
///   ratio far below the model-level one.
struct BackendDrive {
    reads: u64,
    cycle_req_per_sec: f64,
    analytical_req_per_sec: f64,
    cycle_pts_per_sec: f64,
    analytical_pts_per_sec: f64,
}

impl BackendDrive {
    fn model_speedup(&self) -> f64 {
        self.analytical_req_per_sec / self.cycle_req_per_sec
    }

    fn e2e_speedup(&self) -> f64 {
        self.analytical_pts_per_sec / self.cycle_pts_per_sec
    }
}

/// Push `n` reads through a memory model via the trait interface and
/// return the wall seconds to drain them. `skip` fast-forwards over
/// the gaps below `next_event_cycle` (the analytical backend's natural
/// mode); without it every DRAM cycle is ticked (the cycle backend's
/// cycle-exact semantics). The stream alternates rows within one bank,
/// so every access is a row conflict and the drain is fully
/// serialized.
fn drive_reads(mem: &mut dyn MemoryModel, n: u64, skip: bool) -> f64 {
    const ROWS: usize = 4096;
    let t0 = Instant::now();
    let (mut issued, mut done, mut guard) = (0u64, 0u64, 0u64);
    while done < n {
        while issued < n && mem.can_accept(0, false) {
            issued += 1;
            let addr = Address {
                channel: 0,
                rank: 0,
                bank: 0,
                row: (issued as usize * 3) % ROWS,
                col: issued as usize % 64,
            };
            mem.enqueue(Access::read(issued, 0, addr));
        }
        mem.tick().expect("backend tick");
        done += mem.drain_completions().len() as u64;
        if skip {
            let next = mem.next_event_cycle();
            if next != u64::MAX {
                let gap = next.saturating_sub(mem.now()).saturating_sub(1);
                if gap > 0 {
                    mem.fast_forward(gap);
                }
            }
        }
        guard += 1;
        assert!(guard < 1_000_000_000, "backend drive failed to drain");
    }
    t0.elapsed().as_secs_f64()
}

fn bench_backends(requests: u64) -> BackendDrive {
    let n = requests.max(500);
    let cfg = SimConfig::default();
    let mut ctrl = Controller::new(cfg.clone());
    let cycle_secs = drive_reads(&mut ctrl, n, false);
    // The analytical drive is orders of magnitude faster; average over
    // repeats (fresh model each time) for a measurable interval.
    const ITERS: u64 = 20;
    let mut secs = 0.0;
    for _ in 0..ITERS {
        let mut model = AnalyticalModel::new(cfg.clone());
        secs += drive_reads(&mut model, n, true);
    }
    let analytical_secs = secs / ITERS as f64;

    // End-to-end grid points/sec: identical workload, CPU model and
    // engine — only the backend differs.
    let mut cycle_cfg = cfg;
    cycle_cfg.requests_per_core = n;
    let wl = mixes::workload_by_name("stream4", &cycle_cfg).unwrap();
    let mut analytical_cfg = cycle_cfg.clone();
    analytical_cfg.backend = BackendKind::Analytical;
    let t0 = Instant::now();
    run_workload(&cycle_cfg, &wl);
    let cycle_pt_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    run_workload(&analytical_cfg, &wl);
    let analytical_pt_secs = t0.elapsed().as_secs_f64();

    BackendDrive {
        reads: n,
        cycle_req_per_sec: n as f64 / cycle_secs,
        analytical_req_per_sec: n as f64 / analytical_secs,
        cycle_pts_per_sec: 1.0 / cycle_pt_secs,
        analytical_pts_per_sec: 1.0 / analytical_pt_secs,
    }
}

/// Grid-expansion overhead of the declarative experiment API: how
/// many times per second the FULL built-in registry (every spec's
/// default grid — several hundred `SimConfig`s + workload clones) can
/// be expanded. Expansion happens once per campaign, strictly before
/// any simulation starts, so it must stay off the simulated hot path;
/// the gate floor in `ci/perf_baseline.toml` pins that down.
struct Expansion {
    points_per_registry: usize,
    registries_per_sec: f64,
}

fn bench_grid_expansion() -> Expansion {
    let specs = spec::registry();
    let opts = RunOptions::default();
    // Warm once (builds the workload suite caches, faults in code).
    let mut points_per_registry = 0usize;
    for s in &specs {
        points_per_registry += spec::expand(s, &opts).expect("built-in grid").len();
    }
    const ITERS: usize = 5;
    let t0 = Instant::now();
    let mut total = 0usize;
    for _ in 0..ITERS {
        for s in &specs {
            total += spec::expand(s, &opts).expect("built-in grid").len();
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(total, points_per_registry * ITERS);
    Expansion {
        points_per_registry,
        registries_per_sec: ITERS as f64 / secs,
    }
}

/// The two gate-relevant aggregates, computed in exactly one place so
/// the printed table, the JSON artifact and the gate verdict can never
/// diverge: (aggregate fast-forward cycles/sec, worst-case speedup).
fn aggregates(measurements: &[Measurement]) -> (f64, f64) {
    let total_cycles: u64 = measurements.iter().map(|m| m.cycles).sum();
    let total_ff_secs: f64 = measurements.iter().map(|m| m.ff_secs).sum();
    let worst = measurements
        .iter()
        .map(Measurement::speedup)
        .fold(f64::INFINITY, f64::min);
    (total_cycles as f64 / total_ff_secs, worst)
}

fn summary_json(
    requests: u64,
    measurements: &[Measurement],
    exp: &Expansion,
    bd: &BackendDrive,
) -> String {
    let (agg_rate, worst) = aggregates(measurements);
    let rows: Vec<String> = measurements
        .iter()
        .map(|m| {
            format!(
                "{{\"workload\":{},\"sim_cycles\":{},\"ff_cyc_per_sec\":{},\
                 \"ref_cyc_per_sec\":{},\"speedup\":{}}}",
                json::string(m.name),
                m.cycles,
                json::number(m.ff_rate()),
                json::number(m.ref_rate()),
                json::number(m.speedup()),
            )
        })
        .collect();
    let backend = format!(
        "{{\"reads\":{},\"cycle_req_per_sec\":{},\
         \"analytical_req_per_sec\":{},\"model_speedup\":{},\
         \"cycle_pts_per_sec\":{},\"analytical_pts_per_sec\":{},\
         \"e2e_speedup\":{}}}",
        bd.reads,
        json::number(bd.cycle_req_per_sec),
        json::number(bd.analytical_req_per_sec),
        json::number(bd.model_speedup()),
        json::number(bd.cycle_pts_per_sec),
        json::number(bd.analytical_pts_per_sec),
        json::number(bd.e2e_speedup()),
    );
    format!(
        "{{\"bench\":\"sim_hotpath\",\"schema\":4,\"requests\":{requests},\
         \"workloads\":[\n{}\n],\"aggregate_ff_cyc_per_sec\":{},\
         \"worst_ff_speedup\":{},\"grid_points\":{},\
         \"grid_expansions_per_sec\":{},\"backend\":{}}}\n",
        rows.join(",\n"),
        json::number(agg_rate),
        json::number(worst),
        exp.points_per_registry,
        json::number(exp.registries_per_sec),
        backend,
    )
}

/// Apply the checked-in perf baseline; returns Err lines on violation.
fn check_gate(
    path: &str,
    measurements: &[Measurement],
    exp: &Expansion,
    bd: &BackendDrive,
) -> Result<(), Vec<String>> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("perf baseline {path}: {e}"));
    let doc = Document::parse(&text).expect("perf baseline parses");
    let min_speedup = doc
        .get_f64("sim_hotpath", "min_ff_speedup")
        .expect("min_ff_speedup type")
        .expect("min_ff_speedup present");
    let min_mcyc = doc
        .get_f64("sim_hotpath", "min_ff_mcyc_per_sec")
        .expect("min_ff_mcyc_per_sec type")
        .expect("min_ff_mcyc_per_sec present");
    let min_expansions = doc
        .get_f64("sim_hotpath", "min_grid_expansions_per_sec")
        .expect("min_grid_expansions_per_sec type")
        .expect("min_grid_expansions_per_sec present");

    let (agg_rate, worst) = aggregates(measurements);
    let agg_mcyc = agg_rate / 1e6;

    let mut violations = Vec::new();
    if worst < min_speedup {
        violations.push(format!(
            "worst-case fast-forward speedup {worst:.2}x < baseline floor {min_speedup:.2}x"
        ));
    }
    if agg_mcyc < min_mcyc {
        violations.push(format!(
            "aggregate fast-forward throughput {agg_mcyc:.2} Mcyc/s < baseline floor \
             {min_mcyc:.2} Mcyc/s"
        ));
    }
    // Per-class floors: the copy-heavy and SALP-conflict rows are the
    // scheduler-index / horizon-cache stress cases the aggregate can
    // average away, so each is gated on its own.
    for (key, wl) in [
        ("min_ff_mcyc_per_sec_copy", "fork4"),
        ("min_ff_mcyc_per_sec_salp", "salp-conflict"),
    ] {
        let floor = doc
            .get_f64("sim_hotpath", key)
            .unwrap_or_else(|e| panic!("{key} type: {e}"))
            .unwrap_or_else(|| panic!("{key} present"));
        let m = measurements
            .iter()
            .find(|m| m.name == wl)
            .unwrap_or_else(|| panic!("gated workload '{wl}' was measured"));
        let rate = m.ff_rate() / 1e6;
        if rate < floor {
            violations.push(format!(
                "{wl} fast-forward throughput {rate:.2} Mcyc/s < class floor \
                 {floor:.2} Mcyc/s ({key})"
            ));
        }
    }
    if exp.registries_per_sec < min_expansions {
        violations.push(format!(
            "registry grid expansion {:.2}/s < baseline floor {min_expansions:.2}/s \
             ({} points) — spec expansion must stay off the simulated hot path",
            exp.registries_per_sec, exp.points_per_registry
        ));
    }
    // The analytical backend's whole reason to exist is being orders of
    // magnitude cheaper per request than the cycle-exact controller; the
    // floor pins the ratio (same process, same address stream, so this
    // one is machine-independent).
    let min_model_speedup = doc
        .get_f64("sim_hotpath", "min_analytical_model_speedup")
        .expect("min_analytical_model_speedup type")
        .expect("min_analytical_model_speedup present");
    let model_speedup = bd.model_speedup();
    if model_speedup < min_model_speedup {
        violations.push(format!(
            "analytical backend only {model_speedup:.0}x the cycle-exact model rate \
             < floor {min_model_speedup:.0}x (min_analytical_model_speedup, {} reads)",
            bd.reads
        ));
    }
    if violations.is_empty() {
        Ok(())
    } else {
        Err(violations)
    }
}

fn main() {
    // First bare numeric argument = request count; flagged options may
    // appear in any order (cargo bench injects its own `--bench`).
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut requests: u64 = 5_000;
    let mut json_out: Option<String> = None;
    let mut gate: Option<String> = None;
    let mut handicap: f64 = 1.0;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--json" if i + 1 < argv.len() => {
                json_out = Some(argv[i + 1].clone());
                i += 1;
            }
            "--gate" if i + 1 < argv.len() => {
                gate = Some(argv[i + 1].clone());
                i += 1;
            }
            "--handicap" if i + 1 < argv.len() => {
                handicap = argv[i + 1].parse().expect("numeric --handicap");
                i += 1;
            }
            // cargo injects --bench for harness-style invocations.
            "--bench" => {}
            other => match other.parse() {
                Ok(n) => requests = n,
                // Anything else is a mistyped flag or a flag missing
                // its value — neither may silently disable the gate.
                Err(_) => {
                    eprintln!("sim_hotpath: unknown or valueless argument '{other}'");
                    std::process::exit(2);
                }
            },
        }
        i += 1;
    }

    println!("=== Simulator hot-path throughput ({requests} requests/core) ===\n");
    let mut t = Table::new(&[
        "workload",
        "sim cycles",
        "ff Mcyc/s",
        "ref Mcyc/s",
        "speedup",
    ]);
    let mut measurements = Vec::new();
    for (name, workload, salp) in CASES {
        let m = bench_workload(name, workload, salp, requests, handicap);
        t.row(&[
            name.to_string(),
            format!("{}", m.cycles),
            format!("{:.2}", m.ff_rate() / 1e6),
            format!("{:.2}", m.ref_rate() / 1e6),
            format!("{:.2}x", m.speedup()),
        ]);
        measurements.push(m);
    }
    t.print();
    let (_, worst) = aggregates(&measurements);
    println!("\nworst-case fast-forward speedup: {worst:.2}x");
    println!("target (EXPERIMENTS.md §Perf): >= 3x vs the per-cycle reference loop");
    if handicap != 1.0 {
        println!("NOTE: fast-forward times artificially inflated {handicap}x (--handicap)");
    }

    let expansion = bench_grid_expansion();
    println!(
        "experiment-registry grid expansion: {} points in {:.1} registries/s \
         (off the simulated hot path; gated)",
        expansion.points_per_registry, expansion.registries_per_sec
    );

    let backends = bench_backends(requests);
    println!(
        "\nmemory-model backends ({} serialized row-conflict reads):",
        backends.reads
    );
    println!(
        "  model-level: cycle {:.2} Kreq/s, analytical {:.0} Kreq/s => {:.0}x (gated)",
        backends.cycle_req_per_sec / 1e3,
        backends.analytical_req_per_sec / 1e3,
        backends.model_speedup()
    );
    println!(
        "  end-to-end grid point (stream4): cycle {:.2} pts/s, analytical {:.2} pts/s \
         => {:.1}x (informational; shared CPU model bounds this)",
        backends.cycle_pts_per_sec,
        backends.analytical_pts_per_sec,
        backends.e2e_speedup()
    );

    if let Some(path) = json_out {
        std::fs::write(
            &path,
            summary_json(requests, &measurements, &expansion, &backends),
        )
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }
    if let Some(path) = gate {
        match check_gate(&path, &measurements, &expansion, &backends) {
            Ok(()) => println!("perf gate: PASS ({path})"),
            Err(violations) => {
                eprintln!("perf gate: FAIL ({path})");
                for v in &violations {
                    eprintln!("  {v}");
                }
                eprintln!(
                    "intentional engine change? bump the floors in {path} in the same PR \
                     (one-line edit) and say why in the PR description"
                );
                std::process::exit(1);
            }
        }
    }
}
