//! L5 fixture (no-panic-hot-path): this file sits under `controller/`
//! relative to the fixture root, so the bare `unwrap()` and
//! `expect(..)` below are violations; the annotated unwrap and the
//! `#[cfg(test)]` mod must not fire. Not compiled — lexed only.

pub fn pop_head(q: &mut Vec<u64>) -> u64 {
    q.pop().unwrap()
}

pub fn tagged(v: Option<u64>) -> u64 {
    v.expect("tag present")
}

pub fn checked(q: &mut Vec<u64>) -> u64 {
    if q.is_empty() {
        return 0;
    }
    q.pop().unwrap() // lint: allow(panic) reason=emptiness checked above
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(super::pop_head(&mut vec![1]), 1);
        let x: Option<u64> = Some(2);
        assert_eq!(x.unwrap(), 2);
    }
}
