//! L3 fixture (json-key-drift): `writes` is serialized but never read
//! back, and `latency` is read but never written. The symmetric
//! `reads` key must not fire. Not compiled — lexed by lint tests only.

pub struct Report {
    pub reads: u64,
    pub writes: u64,
    pub latency: u64,
}

impl Report {
    pub fn to_json(&self) -> String {
        format!("{{\"reads\":{},\"writes\":{}}}", self.reads, self.writes)
    }

    pub fn from_json(text: &str) -> Report {
        let reads = field(text, "reads");
        let latency = field(text, "latency");
        Report { reads, writes: 0, latency }
    }
}

fn field(_text: &str, _key: &str) -> u64 {
    0
}
