//! DRAM geometry: the physical coordinates of a cache line and the
//! subarray arithmetic LISA's hop counts are computed from.

use crate::config::DramConfig;

/// Fully decoded physical location of one cache line.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Address {
    pub channel: usize,
    pub rank: usize,
    pub bank: usize,
    /// Bank-relative row index (subarray-major: row / rows_per_subarray
    /// is the subarray id).
    pub row: usize,
    /// Column in cache-line units.
    pub col: usize,
}

impl Address {
    /// Subarray index of this row within its bank.
    pub fn subarray(&self, cfg: &DramConfig) -> usize {
        self.row / cfg.rows_per_subarray
    }

    /// Row index within its subarray.
    pub fn row_in_subarray(&self, cfg: &DramConfig) -> usize {
        self.row % cfg.rows_per_subarray
    }

    /// LISA hop count between this row's subarray and another row's
    /// subarray in the same bank (paper §3.1.1: number of subarrays the
    /// data is copied *across*; adjacent subarrays = 1 hop).
    pub fn hops_to(&self, other: &Address, cfg: &DramConfig) -> usize {
        debug_assert_eq!((self.channel, self.rank, self.bank),
                         (other.channel, other.rank, other.bank));
        self.subarray(cfg).abs_diff(other.subarray(cfg)).max(1)
    }

    /// True if both rows live in the same subarray of the same bank.
    pub fn same_subarray(&self, other: &Address, cfg: &DramConfig) -> bool {
        self.channel == other.channel
            && self.rank == other.rank
            && self.bank == other.bank
            && self.subarray(cfg) == other.subarray(cfg)
    }

    /// True if both rows are in the same bank.
    pub fn same_bank(&self, other: &Address) -> bool {
        self.channel == other.channel
            && self.rank == other.rank
            && self.bank == other.bank
    }

    /// Flat row id within the whole system (for content tags).
    pub fn global_row(&self, cfg: &DramConfig) -> u64 {
        let rows_per_bank = cfg.rows_per_bank() as u64;
        let banks = cfg.banks as u64;
        let ranks = cfg.ranks as u64;
        (((self.channel as u64 * ranks + self.rank as u64) * banks
            + self.bank as u64)
            * rows_per_bank)
            + self.row as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn cfg() -> DramConfig {
        DramConfig::default()
    }

    #[test]
    fn subarray_decomposition() {
        let c = cfg();
        let a = Address { row: 0, ..Default::default() };
        assert_eq!(a.subarray(&c), 0);
        let a = Address { row: 511, ..Default::default() };
        assert_eq!(a.subarray(&c), 0);
        assert_eq!(a.row_in_subarray(&c), 511);
        let a = Address { row: 512, ..Default::default() };
        assert_eq!(a.subarray(&c), 1);
        assert_eq!(a.row_in_subarray(&c), 0);
        let a = Address { row: 512 * 15 + 3, ..Default::default() };
        assert_eq!(a.subarray(&c), 15);
    }

    #[test]
    fn hop_counts_match_paper_definition() {
        let c = cfg();
        let at = |sa: usize| Address { row: sa * 512, ..Default::default() };
        // Adjacent subarrays: 1 hop.
        assert_eq!(at(0).hops_to(&at(1), &c), 1);
        // Opposite ends of a 16-subarray bank: 15 hops (paper max).
        assert_eq!(at(0).hops_to(&at(15), &c), 15);
        assert_eq!(at(15).hops_to(&at(0), &c), 15);
        assert_eq!(at(4).hops_to(&at(11), &c), 7);
    }

    #[test]
    fn global_rows_unique() {
        let c = cfg();
        check("global row uniqueness", 300, |g| {
            let a = Address {
                channel: 0,
                rank: 0,
                bank: g.usize(c.banks),
                row: g.usize(c.rows_per_bank()),
                col: 0,
            };
            let b = Address {
                channel: 0,
                rank: 0,
                bank: g.usize(c.banks),
                row: g.usize(c.rows_per_bank()),
                col: 0,
            };
            if a != b {
                assert_ne!(a.global_row(&c), b.global_row(&c));
            } else {
                assert_eq!(a.global_row(&c), b.global_row(&c));
            }
        });
    }
}
