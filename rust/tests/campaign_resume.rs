//! Resumable/cached campaign invariants, end to end through the
//! public spec API: a journaled run killed at any record boundary (or
//! mid-line) and resumed must be byte-identical to an uninterrupted
//! run at any thread count, and an unchanged cached re-invocation must
//! re-run zero points.

use std::path::PathBuf;

use lisa::sim::spec::{self, CampaignStats, RunOptions};
use lisa::util::rng::Pcg32;

/// Per-test scratch directory under the system temp dir; unique per
/// process so parallel `cargo test` binaries never collide.
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir()
            .join(format!("lisa-campaign-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        Scratch(dir)
    }

    fn path(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// A small raw grid (4 jobs of one point each).
fn raw_opts() -> RunOptions {
    RunOptions::default()
        .requests(120)
        .threads(2)
        .axis("workload", &["salp-pingpong4"])
        .axis("mech", &["memcpy", "lisa-risc"])
        .axis("mode", &["none", "masa"])
        .axis("policy", &["packed"])
}

/// A small WS grid (2 jobs: one per workload, chunking 2 presets).
fn ws_opts() -> RunOptions {
    RunOptions::default()
        .requests(200)
        .threads(2)
        .mixes(2)
        .axis("preset", &["baseline", "risc-villa"])
}

#[test]
fn truncated_journal_resumes_byte_identically_at_any_cut() {
    // Property test: simulate `kill -9` by truncating the journal at a
    // random byte — sometimes a record boundary, sometimes mid-line —
    // and resume. Every cut, at every thread count, must reproduce the
    // uninterrupted JSON byte for byte.
    let scratch = Scratch::new("truncate");
    let spec = spec::spec_by_name("e10-salp").unwrap();
    let clean = spec::run(&spec, &raw_opts()).unwrap().to_json();

    let journal = scratch.path("full.jsonl");
    let full = spec::run(&spec, &raw_opts().journal(&journal)).unwrap();
    assert_eq!(full.to_json(), clean);
    let bytes = std::fs::read(&journal).unwrap();
    let lines = bytes.split_inclusive(|b| *b == b'\n').count();
    assert_eq!(lines, 4, "one journal line per job");

    let mut rng = Pcg32::new(0xC0FFEE, 7);
    for trial in 0..12 {
        let cut = rng.below(bytes.len() as u64 + 1) as usize;
        let threads = *rng.pick(&[1usize, 2, 8]);
        let truncated = scratch.path("truncated.jsonl");
        std::fs::write(&truncated, &bytes[..cut]).unwrap();
        let resumed =
            spec::run(&spec, &raw_opts().threads(threads).resume(&truncated))
                .unwrap();
        assert_eq!(
            resumed.to_json(),
            clean,
            "trial {trial}: cut at byte {cut}/{} with {threads} threads",
            bytes.len()
        );
        // Whole journaled lines resume; the torn tail (if any) re-runs.
        let whole = bytes[..cut].split_inclusive(|b| *b == b'\n').filter(|l| {
            l.last() == Some(&b'\n')
        });
        assert_eq!(resumed.stats.resumed, whole.count(), "cut at byte {cut}");
        assert_eq!(resumed.stats.resumed + resumed.stats.ran, 4);
        // And the resumed journal is itself complete: resuming it
        // again simulates nothing.
        let again = spec::run(&spec, &raw_opts().resume(&truncated)).unwrap();
        assert_eq!(
            again.stats,
            CampaignStats { resumed: 4, cache_hits: 0, ran: 0 }
        );
        assert_eq!(again.to_json(), clean);
    }
}

#[test]
fn ws_campaign_resumes_and_caches_byte_identically() {
    // The WS path journals per-workload jobs (records carry ws values
    // and the alone-run methodology); resume and cache must both
    // reproduce the fresh bytes.
    let scratch = Scratch::new("ws");
    let spec = spec::spec_by_name("fig3").unwrap();
    let clean = spec::run(&spec, &ws_opts()).unwrap();
    assert_eq!(clean.records.len(), 4, "2 workloads x 2 presets");
    assert!(clean.records.iter().all(|r| r.ws.is_some()));

    let journal = scratch.path("ws.jsonl");
    spec::run(&spec, &ws_opts().journal(&journal)).unwrap();
    // Keep only the first of the two job lines: one workload resumes,
    // the other re-runs.
    let text = std::fs::read_to_string(&journal).unwrap();
    let first_line = &text[..text.find('\n').unwrap() + 1];
    let half = scratch.path("half.jsonl");
    for threads in [1, 2, 8] {
        // Resuming keeps journaling into the same file, so each
        // iteration appends the re-run job's entry; restore the
        // one-line journal so every thread count starts equal.
        std::fs::write(&half, first_line).unwrap();
        let resumed =
            spec::run(&spec, &ws_opts().threads(threads).resume(&half)).unwrap();
        assert_eq!(
            resumed.stats,
            CampaignStats { resumed: 1, cache_hits: 0, ran: 1 },
            "threads={threads}"
        );
        assert_eq!(resumed.to_json(), clean.to_json(), "threads={threads}");
    }

    let cache = scratch.path("cache");
    let warmed = spec::run(&spec, &ws_opts().cache_dir(&cache)).unwrap();
    assert_eq!(warmed.stats.ran, 2);
    assert_eq!(warmed.to_json(), clean.to_json());
    for threads in [1, 8] {
        let hit = spec::run(&spec, &ws_opts().threads(threads).cache_dir(&cache))
            .unwrap();
        assert_eq!(
            hit.stats,
            CampaignStats { resumed: 0, cache_hits: 2, ran: 0 },
            "threads={threads}"
        );
        assert_eq!(hit.to_json(), clean.to_json(), "threads={threads}");
    }
}

#[test]
fn resume_journal_and_cache_compose() {
    // A killed journaled+cached run leaves both artifacts; resuming
    // with both adopts journal entries first, cache for the rest, and
    // simulates only what neither covers.
    let scratch = Scratch::new("compose");
    let spec = spec::spec_by_name("e10-salp").unwrap();
    let clean = spec::run(&spec, &raw_opts()).unwrap().to_json();

    let journal = scratch.path("run.jsonl");
    let cache = scratch.path("cache");
    spec::run(
        &spec,
        &raw_opts().journal(&journal).cache_dir(&cache),
    )
    .unwrap();
    // Keep two journal lines; the cache still holds all four jobs.
    let text = std::fs::read_to_string(&journal).unwrap();
    let cut = text.match_indices('\n').nth(1).unwrap().0 + 1;
    std::fs::write(&journal, &text[..cut]).unwrap();
    let mixed = spec::run(
        &spec,
        &raw_opts().resume(&journal).cache_dir(&cache),
    )
    .unwrap();
    assert_eq!(
        mixed.stats,
        CampaignStats { resumed: 2, cache_hits: 2, ran: 0 }
    );
    assert_eq!(mixed.to_json(), clean);

    // A changed grid invalidates the journal positionally but reuses
    // matching points from the cache, and simulates only the new ones.
    let mut wider = raw_opts().resume(&journal).cache_dir(&cache);
    wider.axes.retain(|(n, _)| n != "policy");
    let wider = wider.axis("policy", &["packed", "spread"]);
    let report = spec::run(&spec, &wider).unwrap();
    assert_eq!(report.records.len(), 8);
    assert_eq!(report.stats.cache_hits + report.stats.resumed, 4);
    assert_eq!(report.stats.ran, 4);
}

#[test]
fn missing_resume_file_is_a_fresh_start() {
    let scratch = Scratch::new("fresh");
    let spec = spec::spec_by_name("e10-salp").unwrap();
    let journal = scratch.path("never-written.jsonl");
    let mut opts = raw_opts();
    opts.axes.retain(|(n, _)| n != "mech");
    let opts = opts.axis("mech", &["memcpy"]).resume(&journal);
    let report = spec::run(&spec, &opts).unwrap();
    assert_eq!(
        report.stats,
        CampaignStats { resumed: 0, cache_hits: 0, ran: 2 }
    );
    // ... and the journal now exists (resume implies journaling), so
    // the next invocation adopts everything.
    let resumed = spec::run(&spec, &opts.clone()).unwrap();
    assert_eq!(
        resumed.stats,
        CampaignStats { resumed: 2, cache_hits: 0, ran: 0 }
    );
    assert_eq!(resumed.to_json(), report.to_json());
}
