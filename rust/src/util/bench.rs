//! Hand-rolled bench harness (criterion is not in the offline
//! registry). Provides warmup + timed iterations with mean/stddev, and
//! a table printer used by every `rust/benches/*` target so the bench
//! output mirrors the paper's tables.

use std::time::Instant;

use super::stats::Summary;

/// Time `f` after `warmup` untimed runs; returns per-iteration stats in
/// nanoseconds.
pub fn time_it(warmup: u32, iters: u32, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_nanos() as f64);
    }
    s
}

/// Simple fixed-width table printer for bench/eval output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut out = String::from("|");
            for i in 0..ncol {
                out.push_str(&format!(" {:width$} |", cells[i], width = widths[i]));
            }
            out
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        for row in &self.rows {
            out.push('\n');
            out.push_str(&line(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a nanosecond quantity human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts_iters() {
        let mut n = 0u64;
        let s = time_it(2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(s.count(), 10);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "12345".into()]);
        let r = t.render();
        assert!(r.contains("| name      | value |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.20 s");
    }
}
