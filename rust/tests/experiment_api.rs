//! Integration tests for the declarative experiment API: legacy
//! subcommands are byte-identical aliases of the registry path, the
//! big grids stay deterministic at any thread count, config names
//! cannot alias across the full built-in grid, and a new scenario is
//! one `ExperimentSpec` value — no CLI surgery.

use lisa::cli::Args;
use lisa::config::{PlacementPolicy, SalpMode};
use lisa::sim::engine::config_name;
use lisa::sim::spec::{
    self, AxisDef, AxisKind, Eval, ExperimentSpec, RunOptions, LEGACY_ALIASES,
};

fn args_of(line: &str) -> Args {
    Args::parse(line.split_whitespace().map(str::to_string)).unwrap()
}

/// Run one experiment the way a CLI subcommand does: resolve the spec,
/// extract options from the parsed arguments, run, serialize.
fn json_via(spec: &ExperimentSpec, argv: &str) -> String {
    let args = args_of(argv);
    let opts = RunOptions::from_args(spec, &args).unwrap();
    spec::run(spec, &opts).unwrap().to_json()
}

#[test]
fn every_legacy_subcommand_is_byte_identical_to_its_registry_spec() {
    // The acceptance bar of the API redesign: `lisa <legacy> ...` and
    // `lisa exp <spec> ...` produce byte-identical JSON for the same
    // options — including the legacy flag spellings (`--scenarios`,
    // `--mechs`, `--mixes`).
    let shrunk: &[(&str, &str)] = &[
        ("fig3", "--requests 200 --mixes 1 --threads 2"),
        ("fig4", "--requests 150 --mixes 1 --threads 2 --presets baseline,risc"),
        ("lip-system", "--requests 150 --mixes 1 --threads 2"),
        (
            "os",
            "--requests 200 --threads 2 --mechs memcpy,lisa-risc \
             --policies packed --scenarios os-fork",
        ),
        (
            "salp",
            "--requests 150 --threads 2 --mechs lisa-risc --modes none,masa \
             --policies packed --workloads salp-pingpong4",
        ),
        ("sweep", "--requests 300 --threads 2 --mechs memcpy --workloads stream4"),
    ];
    for (alias, flags) in shrunk {
        let (_, name) = LEGACY_ALIASES
            .iter()
            .find(|(a, _)| a == alias)
            .unwrap_or_else(|| panic!("{alias} missing from LEGACY_ALIASES"));
        let legacy_spec = spec::spec_for_alias(alias).unwrap();
        let exp_spec = spec::spec_by_name(name).unwrap();
        let legacy = json_via(&legacy_spec, &format!("{alias} {flags}"));
        let exp = json_via(&exp_spec, &format!("exp {name} {flags}"));
        assert!(!legacy.is_empty());
        assert_eq!(legacy, exp, "{alias} vs exp {name}");
        // The unified schema is the same document shape everywhere.
        assert!(legacy.contains(&format!("\"experiment\":\"{name}\"")), "{legacy}");
        assert!(legacy.contains("\"schema\":1"), "{legacy}");
        assert!(legacy.contains("\"records\":["), "{legacy}");
    }
}

#[test]
fn e9_grid_is_byte_identical_across_thread_counts() {
    let s = spec::spec_by_name("e9-os").unwrap();
    let opts = RunOptions::default()
        .requests(300)
        .axis("workload", &["os-fork", "os-checkpoint", "os-promote"])
        .axis("mech", &["memcpy", "lisa-risc"])
        .axis("policy", &["packed", "spread"]);
    let serial = spec::run(&s, &opts.clone().threads(1)).unwrap();
    assert_eq!(serial.records.len(), 12);
    // Scenario-major row order, and every record carries the OS layer.
    assert!(serial.records[..4]
        .iter()
        .all(|r| r.axis("workload") == Some("os-fork")));
    assert!(serial
        .records
        .iter()
        .all(|r| r.report.os.as_ref().is_some_and(|o| o.pages_copied > 0)));
    let json1 = serial.to_json();
    for threads in [2, 8] {
        let rows = spec::run(&s, &opts.clone().threads(threads)).unwrap();
        assert_eq!(serial, rows, "threads={threads}");
        assert_eq!(json1, rows.to_json(), "threads={threads}");
    }
}

#[test]
fn e10_grid_is_byte_identical_across_thread_counts() {
    let s = spec::spec_by_name("e10-salp").unwrap();
    let opts = RunOptions::default()
        .requests(150)
        .axis("workload", &["salp-shared-bank4"])
        .axis("mech", &["memcpy", "lisa-risc"])
        .axis("mode", &["none", "masa"])
        .axis("policy", &["packed"]);
    let serial = spec::run(&s, &opts.clone().threads(1)).unwrap();
    assert_eq!(serial.records.len(), 4);
    assert_eq!(serial.records[0].axis("mode"), Some("none"));
    assert_eq!(serial.records[1].axis("mode"), Some("masa"));
    let json1 = serial.to_json();
    for threads in [2, 8] {
        let rows = spec::run(&s, &opts.clone().threads(threads)).unwrap();
        assert_eq!(serial, rows, "threads={threads}");
        assert_eq!(json1, rows.to_json(), "threads={threads}");
    }
}

#[test]
fn config_names_cannot_alias_across_the_full_builtin_grid() {
    // The satellite fix: `config_name` now folds in the SALP mode and
    // the placement policy, so distinct grid points of any built-in
    // experiment never share a label unless their configs agree on
    // every axis-visible knob.
    let mut by_name: std::collections::BTreeMap<
        String,
        (lisa::config::CopyMechanism, bool, bool, SalpMode, PlacementPolicy),
    > = std::collections::BTreeMap::new();
    let mut points = 0usize;
    for s in spec::registry() {
        for p in spec::expand(&s, &RunOptions::default()).unwrap() {
            points += 1;
            let knobs = (
                p.cfg.copy_mechanism,
                p.cfg.lisa.villa,
                p.cfg.lisa.lip,
                p.cfg.dram.salp,
                p.cfg.os.placement,
            );
            let name = config_name(&p.cfg);
            if let Some(prev) = by_name.get(&name) {
                assert_eq!(
                    prev, &knobs,
                    "config name '{name}' aliases two distinct configs"
                );
            } else {
                by_name.insert(name, knobs);
            }
        }
    }
    // The registry actually exercised a non-trivial grid.
    assert!(points > 400, "expected the full built-in grid, saw {points}");
    // Spot checks: the knobs that used to alias are now in the name.
    let salp_cfg = lisa::config::SimConfigBuilder::new()
        .salp(SalpMode::Masa)
        .placement(PlacementPolicy::Random)
        .build()
        .unwrap();
    let name = config_name(&salp_cfg);
    assert!(name.contains("salp:masa"), "{name}");
    assert!(name.contains("place:random"), "{name}");
    // Defaults stay short.
    let default_name = config_name(&lisa::config::SimConfig::default());
    assert_eq!(default_name, "memcpy");
}

#[test]
fn a_new_scenario_is_one_spec_value() {
    // The extension story the redesign exists for: registering a brand
    // new experiment means building one `ExperimentSpec` — the same
    // pipeline expands, runs, tabulates and serializes it without any
    // per-experiment code.
    let custom = ExperimentSpec {
        name: "zero-storm".into(),
        title: "demand-zero pressure across placements".into(),
        requests: 150,
        eval: Eval::Raw,
        axes: vec![
            AxisDef::new(
                "workload",
                "workloads",
                AxisKind::Workload,
                vec!["os-zero".into()],
            ),
            AxisDef::new(
                "policy",
                "policies",
                AxisKind::Placement,
                vec!["packed".into(), "random".into()],
            ),
        ],
    };
    let report = spec::run(&custom, &RunOptions::default().threads(2)).unwrap();
    assert_eq!(report.experiment, "zero-storm");
    assert_eq!(report.records.len(), 2);
    assert_eq!(report.records[0].axis("policy"), Some("packed"));
    let j = report.to_json();
    assert!(j.contains("\"experiment\":\"zero-storm\""), "{j}");
    // And the CLI option extractor understands its flags with zero
    // subcommand plumbing.
    let args = args_of("exp zero-storm --policies random --requests 99");
    let opts = RunOptions::from_args(&custom, &args).unwrap();
    assert_eq!(opts.requests, Some(99));
    let axes = spec::effective_axes(&custom, &opts).unwrap();
    assert_eq!(axes[1].1, vec!["random".to_string()]);
}

#[test]
fn weighted_specs_reject_malformed_axis_shapes() {
    // WeightedSpeedup is only defined for {workload × preset} grids;
    // anything else must fail loudly, not mis-normalize.
    let bad = ExperimentSpec {
        name: "bad-ws".into(),
        title: "ws without a preset axis".into(),
        requests: 100,
        eval: Eval::WeightedSpeedup,
        axes: vec![AxisDef::new(
            "workload",
            "workloads",
            AxisKind::Workload,
            vec!["stream4".into()],
        )],
    };
    assert!(spec::run(&bad, &RunOptions::default().threads(1)).is_err());
}
