//! Trace-driven multi-core CPU frontend: instruction-window core model
//! (Ramulator-style), private L1/L2 + shared LLC cache hierarchy, and
//! the trace format the workload generators produce.

pub mod cache;
pub mod core;
pub mod trace;
