"""Layer-1 Pallas kernel: DRAM bitline analog dynamics.

This is the SPICE-substitute circuit model for the LISA reproduction
(DESIGN.md, substitution map row 1). Every analog quantity the paper
obtains from SPICE — activation/sense latency (tRCD/tRAS), precharge
latency (tRP), linked-precharge latency (LISA-LIP), row-buffer-movement
latency (tRBM, LISA's new operation), and per-operation energy — comes
out of one explicit-Euler integration of a two-node RC network per
bitline:

    node a : the bitline under observation (destination bitline for RBM,
             the bitline being precharged for LIP, the sensing bitline
             for activation)
    node b : the coupled node (the DRAM cell for activation, the
             neighboring subarray's bitline / latched row buffer for
             RBM and LIP)

    dVa/dt = [ g_ext_a (Vext_a - Va) + g_link (Vb - Va) + gm_a (Va - Vmid) ] / Ca
    dVb/dt = [ g_ext_b (Vext_b - Vb) + g_link (Va - Vb) + gm_b (Vb - Vmid) ] / Cb

with both voltages clamped to [0, VDD] after every step. The `gm`
terms model the regenerative sense amplifier (positive feedback away
from VDD/2); `g_ext` models precharge units or supply rails; `g_link`
models the access transistor (activation) or LISA's isolation
transistor (RBM / LIP).

The kernel is vectorized over all bitlines of a subarray (the paper's
8K-bit row buffer) with per-bitline multiplicative process variation on
conductance and capacitance. Outputs, per bitline:

    v_a, v_b     final voltages
    t_sense      first time |Va - Vmid| >= sense threshold (ns)
    t_settle     last time Va was outside the settle tolerance (ns)
    energy       integral of driver + sense-amp current * VDD (fJ)

Units: time ns, capacitance fF, conductance uS  (tau = C/g is then in
ns directly), voltage V, energy fJ.

TPU shape (DESIGN.md §Hardware-Adaptation): the model is embarrassingly
parallel across bitlines — a VPU-friendly elementwise time-scan. The
BlockSpec tiles bitlines into VMEM-resident blocks; the time loop runs
entirely in-block and only O(lanes) results are written back, never the
time series. MXU is not used (no matmul in the physics).

The kernel MUST run with interpret=True: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Layout of the scalar parameter vector (f32[NSCALARS]). Shared with
# ref.py, model.py and the rust calibration driver
# (rust/src/runtime/calibrate.rs) — keep all four in sync.
S_DT = 0          # integration step (ns)
S_VDD = 1         # rail voltage (V)
S_SENSE_THR = 2   # |Va - Vmid| threshold for t_sense (V)
S_SETTLE_TOL = 3  # |Va - settle target| tolerance for t_settle (V)
S_GM_A = 4        # sense-amp transconductance on node a (uS)
S_GM_B = 5        # sense-amp transconductance on node b (uS)
S_G_EXT_A = 6     # external driver conductance on node a (uS)
S_G_EXT_B = 7     # external driver conductance on node b (uS)
S_V_EXT_A = 8     # external driver voltage on node a (V)
S_V_EXT_B = 9     # external driver voltage on node b (V)
S_G_LINK = 10     # coupling conductance a<->b (uS)
S_C_A = 11        # node a capacitance (fF)
S_C_B = 12        # node b capacitance (fF)
S_SETTLE_TGT = 13 # settle target voltage for node a (V)
S_SETTLE_B = 14   # if > 0.5: t_settle also requires node b within tol of its target
S_SETTLE_TGT_B = 15  # settle target voltage for node b (V)
NSCALARS = 16

DEFAULT_BLOCK = 1024


def _phase_kernel(n_steps: int,
                  va_ref, vb_ref, gmul_ref, cmul_ref, s_ref,
                  va_out, vb_out, ts_out, tt_out, en_out):
    """Pallas kernel body: integrate one analog phase for one bitline block."""
    s = s_ref[...]
    dt = s[S_DT]
    vdd = s[S_VDD]
    vmid = vdd * 0.5
    thr = s[S_SENSE_THR]
    tol = s[S_SETTLE_TOL]
    tgt_a = s[S_SETTLE_TGT]
    tgt_b = s[S_SETTLE_TGT_B]
    settle_b = s[S_SETTLE_B] > 0.5

    gmul = gmul_ref[...]
    cmul = cmul_ref[...]
    # Per-bitline parameters: process variation scales every conductance
    # and capacitance multiplicatively (the paper's 60% guard band is
    # applied downstream, over the worst bitline of the population).
    ga = s[S_G_EXT_A] * gmul
    gb = s[S_G_EXT_B] * gmul
    gl = s[S_G_LINK] * gmul
    gma = s[S_GM_A] * gmul
    gmb = s[S_GM_B] * gmul
    inv_ca = 1.0 / (s[S_C_A] * cmul)
    inv_cb = 1.0 / (s[S_C_B] * cmul)

    va0 = va_ref[...]
    vb0 = vb_ref[...]
    zeros = jnp.zeros_like(va0)
    neg = zeros - 1.0

    def body(i, carry):
        va, vb, ts, tt, en = carry
        t = (i.astype(jnp.float32) + 1.0) * dt
        # Currents into each node (uA = uS * V).
        i_a = (ga * (s[S_V_EXT_A] - va)
               + gl * (vb - va)
               + gma * (va - vmid))
        i_b = (gb * (s[S_V_EXT_B] - vb)
               + gl * (va - vb)
               + gmb * (vb - vmid))
        # Sense amps source current only while the node is between the
        # rails — a CMOS latch clamped at a rail is in cutoff and draws
        # no static current (matters for energy accounting of the held
        # source row buffer during RBM).
        act_a = ((va > 0.0) & (va < vdd)).astype(va.dtype)
        act_b = ((vb > 0.0) & (vb < vdd)).astype(vb.dtype)
        # Energy drawn from the rails by drivers and sense amps
        # (fJ = uS * V * V * ns), evaluated pre-update.
        p = (ga * jnp.abs(s[S_V_EXT_A] - va)
             + gb * jnp.abs(s[S_V_EXT_B] - vb)
             + gma * jnp.abs(va - vmid) * act_a
             + gmb * jnp.abs(vb - vmid) * act_b) * vdd
        en = en + p * dt
        va = jnp.clip(va + dt * i_a * inv_ca, 0.0, vdd)
        vb = jnp.clip(vb + dt * i_b * inv_cb, 0.0, vdd)
        # First crossing of the sense threshold.
        crossed = jnp.abs(va - vmid) >= thr
        ts = jnp.where((ts < 0.0) & crossed, t, ts)
        # Last instant outside the settle tolerance.
        out_a = jnp.abs(va - tgt_a) > tol
        out_b = jnp.abs(vb - tgt_b) > tol
        outside = jnp.where(settle_b, out_a | out_b, out_a)
        tt = jnp.where(outside, t, tt)
        return va, vb, ts, tt, en

    va, vb, ts, tt, en = jax.lax.fori_loop(
        0, n_steps, body, (va0, vb0, neg, zeros, zeros))
    horizon = n_steps * dt
    ts = jnp.where(ts < 0.0, horizon, ts)
    va_out[...] = va
    vb_out[...] = vb
    ts_out[...] = ts
    tt_out[...] = tt
    en_out[...] = en


def phase(va0, vb0, gmul, cmul, scalars, *, n_steps: int,
          block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Integrate one analog phase over a population of bitlines.

    Args:
      va0, vb0: initial node voltages, shape (n,) float32.
      gmul, cmul: per-bitline variation multipliers, shape (n,) float32.
      scalars: phase parameter vector, shape (NSCALARS,) float32.
      n_steps: number of Euler steps (static; horizon = n_steps * dt).
      block: bitlines per Pallas block (must divide n).
      interpret: keep True — CPU PJRT cannot run Mosaic custom-calls.

    Returns:
      (v_a, v_b, t_sense, t_settle, energy), each shape (n,) float32.
    """
    n = va0.shape[0]
    if n % block != 0:
        block = n  # small test populations: single block
    grid = (n // block,)
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((NSCALARS,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((n,), jnp.float32)
    return pl.pallas_call(
        functools.partial(_phase_kernel, n_steps),
        grid=grid,
        in_specs=[vec_spec, vec_spec, vec_spec, vec_spec, scalar_spec],
        out_specs=[vec_spec] * 5,
        out_shape=[out] * 5,
        interpret=interpret,
    )(va0, vb0, gmul, cmul, scalars)
