"""Offline tuning utility: checks / tunes PhysParams against the paper's
SPICE anchor points. Not part of the AOT path — run manually:

    cd python && python -m compile.tune_params

Targets (paper values):
  precharge_single  t_settle ~ 13   ns   (§3.3 baseline tRP)
  precharge_linked  t_settle ~  5   ns   (§3.3 LISA-LIP, 2.6x)
  rbm_hop           t_sense  ~  5   ns   (§2: ~8 ns/hop after 60% margin)
  activate_sense    t_sense  ~  9   ns   and t_settle ~ 30 ns
                    (tRCD 13.75 / tRAS 35 on the worst bitline once the
                     population worst case + margin methodology applies)
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import model as m
from .kernels import bitline as bl
from .kernels.ref import phase_ref


def nominal(n=8):
    ones = jnp.ones((n,), jnp.float32)
    return ones


def run(name, scalars, va0, vb0, steps):
    n = va0.shape[0]
    ones = jnp.ones((n,), jnp.float32)
    va, vb, ts, tt, en = phase_ref(va0, vb0, ones, ones, scalars,
                                   n_steps=steps)
    return (float(ts[0]), float(tt[0]), float(en[0]),
            float(va[0]), float(vb[0]))


def report(p: m.PhysParams = m.DEFAULT_PARAMS):
    n = 8
    vdd = p.vdd
    mid = vdd / 2

    # Both halves of the bitline start at the rail (row was open, storing 1).
    s = m.scalars_precharge(p, linked=False)
    ts, tt, en, va, vb = run("pre", s, jnp.full((n,), vdd, jnp.float32),
                             jnp.full((n,), vdd, jnp.float32),
                             m.STEPS_PRECHARGE)
    print(f"precharge_single: t_settle={tt:7.2f} ns  E={en:8.1f} fJ  va={va:.3f}")

    s = m.scalars_precharge(p, linked=True)
    ts, tt2, en, va, vb = run("lip", s, jnp.full((n,), vdd, jnp.float32),
                              jnp.full((n,), vdd, jnp.float32),
                              m.STEPS_PRECHARGE)
    print(f"precharge_linked: t_settle={tt2:7.2f} ns  E={en:8.1f} fJ  "
          f"speedup={tt/max(tt2,1e-9):.2f}x")

    s = m.scalars_rbm(p)
    ts3, tt3, en, va, vb = run("rbm", s, jnp.full((n,), mid, jnp.float32),
                               jnp.full((n,), vdd, jnp.float32),
                               m.STEPS_RBM)
    print(f"rbm_hop:          t_settle={tt3:7.2f} ns  E={en:8.1f} fJ  va={va:.3f}")

    s = m.scalars_activate(p)
    ts4, tt4, en, va, vb = run("act", s, jnp.full((n,), mid, jnp.float32),
                               jnp.full((n,), vdd, jnp.float32),
                               m.STEPS_ACTIVATE)
    print(f"activate_sense:   t_sense ={ts4:7.2f} ns  t_settle={tt4:7.2f} ns  "
          f"E={en:8.1f} fJ  va={va:.3f} vb={vb:.3f}")

    s = m.scalars_activate(p, fast=True)
    ts5, tt5, en, va, vb = run("actf", s, jnp.full((n,), mid, jnp.float32),
                               jnp.full((n,), vdd, jnp.float32),
                               m.STEPS_ACTIVATE)
    print(f"activate (fast):  t_sense ={ts5:7.2f} ns  t_settle={tt5:7.2f} ns")


if __name__ == "__main__":
    report()
