//! Per-(rank, bank) indexed FIFO request queues for the FR-FCFS
//! scheduler.
//!
//! FR-FCFS consults requests *per bank* — whether a request is a row
//! hit and which prepare command it needs are properties of its bank
//! (and subarray) state — so the queue keeps one FIFO bucket per
//! (rank, bank) plus a monotone arrival counter. A full oldest-first
//! scan over N queued requests becomes a scan over only the buckets
//! with pending work, each prunable by bank-level state (busy,
//! refresh-parked, copy-owned) before any per-request timing query,
//! and prunable by sequence number once an older candidate is in hand.
//! The buckets ARE the queue — there is no secondary index that could
//! fall out of sync with it.

use std::collections::VecDeque;

use crate::controller::request::MemRequest;

/// A queue entry: the request plus its arrival sequence number (the
/// global FIFO position, used for oldest-first selection across bank
/// buckets).
#[derive(Debug, Clone)]
pub struct Entry {
    pub seq: u64,
    pub req: MemRequest,
}

/// Position of an entry inside a `BankedQueue`, as returned by the
/// scheduler's scans and consumed by `remove`. Valid only until the
/// queue is next mutated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueLoc {
    pub bucket: usize,
    pub pos: usize,
}

/// FIFO request queue bucketed per (rank, bank).
#[derive(Debug)]
pub struct BankedQueue {
    /// `rank * banks + bank` → FIFO bucket, ascending `seq` within.
    buckets: Vec<VecDeque<Entry>>,
    banks: usize,
    len: usize,
    next_seq: u64,
}

impl BankedQueue {
    pub fn new(ranks: usize, banks: usize) -> Self {
        Self {
            buckets: (0..ranks * banks).map(|_| VecDeque::new()).collect(),
            banks,
            len: 0,
            next_seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append a request. FIFO order within its (rank, bank) bucket;
    /// `seq` preserves the global arrival order across buckets.
    pub fn push_back(&mut self, req: MemRequest) {
        let b = req.addr.rank * self.banks + req.addr.bank;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.buckets[b].push_back(Entry { seq, req });
        self.len += 1;
    }

    /// Remove and return the request at `loc`.
    pub fn remove(&mut self, loc: QueueLoc) -> Option<MemRequest> {
        let e = self.buckets[loc.bucket].remove(loc.pos)?;
        self.len -= 1;
        Some(e.req)
    }

    /// Non-empty buckets as `(bucket, rank, bank, entries)`, in
    /// ascending (rank, bank) order.
    pub fn banks_with_work(
        &self,
    ) -> impl Iterator<Item = (usize, usize, usize, &VecDeque<Entry>)> + '_ {
        let banks = self.banks;
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, q)| !q.is_empty())
            .map(move |(i, q)| (i, i / banks, i % banks, q))
    }

    /// Every queued request, bucket-major. Deterministic, but NOT the
    /// global arrival order — order-sensitive callers must use `seq`.
    pub fn iter(&self) -> impl Iterator<Item = &MemRequest> + '_ {
        self.buckets.iter().flat_map(|q| q.iter().map(|e| &e.req))
    }

    /// Every entry (with its `seq`), bucket-major — fingerprints and
    /// consistency checks.
    pub fn iter_entries(&self) -> impl Iterator<Item = &Entry> + '_ {
        self.buckets.iter().flat_map(|q| q.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::Address;

    fn req(id: u64, rank: usize, bank: usize) -> MemRequest {
        MemRequest {
            id,
            core: 0,
            addr: Address { channel: 0, rank, bank, row: 1, col: 0 },
            is_write: false,
            arrive: 0,
            done: None,
            copy_id: None,
        }
    }

    #[test]
    fn buckets_preserve_fifo_and_len_invariants() {
        let mut q = BankedQueue::new(2, 4);
        assert!(q.is_empty());
        // Interleave two banks and a second rank.
        q.push_back(req(1, 0, 2));
        q.push_back(req(2, 0, 0));
        q.push_back(req(3, 0, 2));
        q.push_back(req(4, 1, 3));
        assert_eq!(q.len(), 4);

        // Entries carry ascending global seq; buckets are per (rank,
        // bank) and FIFO within.
        let entries: Vec<(u64, u64)> =
            q.iter_entries().map(|e| (e.seq, e.req.id)).collect();
        assert_eq!(entries, vec![(1, 2), (0, 1), (2, 3), (3, 4)]);

        let work: Vec<(usize, usize, Vec<u64>)> = q
            .banks_with_work()
            .map(|(_, r, b, es)| (r, b, es.iter().map(|e| e.req.id).collect()))
            .collect();
        assert_eq!(
            work,
            vec![(0, 0, vec![2]), (0, 2, vec![1, 3]), (1, 3, vec![4])]
        );

        // Bucket lengths always sum to len().
        assert_eq!(q.banks_with_work().count(), 3, "three non-empty buckets");
        let total: usize = q.banks_with_work().map(|(.., es)| es.len()).sum();
        assert_eq!(total, q.len());

        // Removal by location keeps order and len coherent.
        let (bucket, ..) = q
            .banks_with_work()
            .find(|(_, r, b, _)| *r == 0 && *b == 2)
            .map(|(i, r, b, _)| (i, r, b))
            .unwrap();
        let removed = q.remove(QueueLoc { bucket, pos: 0 }).unwrap();
        assert_eq!(removed.id, 1);
        assert_eq!(q.len(), 3);
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
        assert!(q.remove(QueueLoc { bucket, pos: 5 }).is_none());
        assert_eq!(q.len(), 3, "failed removal must not corrupt len");
    }

    #[test]
    fn seq_is_monotone_across_interleaved_pushes_and_removals() {
        let mut q = BankedQueue::new(1, 2);
        for i in 0..6 {
            q.push_back(req(i, 0, (i % 2) as usize));
        }
        let bucket0 = 0;
        q.remove(QueueLoc { bucket: bucket0, pos: 0 }).unwrap();
        q.push_back(req(100, 0, 0));
        // New arrivals always get a seq larger than every live entry.
        let max_seq = q.iter_entries().map(|e| e.seq).max().unwrap();
        let new_seq = q
            .iter_entries()
            .find(|e| e.req.id == 100)
            .map(|e| e.seq)
            .unwrap();
        assert_eq!(new_seq, max_seq);
        // Within each bucket seq stays strictly ascending.
        for (.., es) in q.banks_with_work() {
            for w in es.iter().collect::<Vec<_>>().windows(2) {
                assert!(w[0].seq < w[1].seq);
            }
        }
    }
}
