//! L1 fixture (config-coverage): `extra_knob` is declared on the
//! SimConfig tree but never serialized or read back, and `DramConfig`
//! does not derive PartialEq. Not compiled — lexed by lint tests only.

#[derive(Debug, Clone, Default)]
pub struct DramConfig {
    pub channels: usize,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimConfig {
    pub dram: DramConfig,
    pub seed: u64,
    pub extra_knob: u64,
}

impl SimConfig {
    pub fn to_toml(&self) -> String {
        format!("channels = {}\nseed = {}\n", self.dram.channels, self.seed)
    }

    pub fn apply(&mut self, doc: &str) {
        if let Some(v) = doc.strip_prefix("seed = ") {
            self.seed = v.trim().parse().unwrap_or(0);
        }
        self.dram.channels = 1;
    }

    pub fn from_toml(text: &str) -> Self {
        let mut c = Self::default();
        c.apply(text);
        c
    }

    pub fn content_hash(&self) -> u64 {
        self.to_toml().len() as u64
    }
}
