//! Bench E5 (paper §3.1.2): LISA-RISC on the quad-core copy mixes —
//! average performance improvement and memory energy reduction over
//! the memcpy baseline (paper: +66.2% perf, -55.4% energy across 50
//! workloads).
//!
//! Env knobs: LISA_REQUESTS (default 2000), LISA_MIXES (default 15).

use lisa::config::{LisaPreset, SimConfigBuilder};
use lisa::sim::engine::alone_ipcs;
use lisa::sim::experiments::{improvement, ws_point_with};
use lisa::util::bench::Table;
use lisa::workloads::mixes::copy_mixes;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(d)
}

fn main() {
    let requests = env_u64("LISA_REQUESTS", 2_000);
    let n = env_u64("LISA_MIXES", 15) as usize;
    println!("=== E5: LISA-RISC quad-core ({requests} reqs/core, {n} mixes) ===\n");

    let cfg = |p| {
        SimConfigBuilder::new()
            .requests(requests)
            .preset(p)
            .build()
            .expect("preset configs validate")
    };
    let base = cfg(LisaPreset::Baseline);
    let risc = cfg(LisaPreset::Risc);
    let mixes = copy_mixes(base.cpu.cores);

    let mut t = Table::new(&["workload", "WS +%", "energy -%"]);
    let (mut imps, mut ens) = (vec![], vec![]);
    for wl in mixes.iter().take(n) {
        // Paper methodology: alone runs measured once on the baseline.
        let alone = alone_ipcs(&base, wl);
        let b = ws_point_with(&base, wl, &alone);
        let c = ws_point_with(&risc, wl, &alone);
        let (imp, en) = improvement(&b, &c);
        imps.push(imp);
        ens.push(en);
        t.row(&[
            wl.name.clone(),
            format!("{:+.1}", imp * 100.0),
            format!("{:.1}", en * 100.0),
        ]);
    }
    t.print();
    println!(
        "\nmean: WS {:+.1}% (paper +66.2%), energy -{:.1}% (paper -55.4%)",
        imps.iter().sum::<f64>() / imps.len() as f64 * 100.0,
        ens.iter().sum::<f64>() / ens.len() as f64 * 100.0
    );
}
