//! Quickstart: simulate a small four-core workload on the baseline
//! system and on full LISA, and print the comparison.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use lisa::config::{CopyMechanism, SimConfig};
use lisa::sim::engine::run_workload;
use lisa::workloads::mixes;

fn main() -> anyhow::Result<()> {
    let mut base = SimConfig::default();
    base.requests_per_core = 5_000;

    let lisa_cfg = base.clone().with_all_lisa();

    let wl = mixes::workload_by_name("fork4", &base)?;
    println!("workload: {} (4 cores, bulk-copy heavy)", wl.name);

    let r_base = run_workload(&base, &wl);
    let r_lisa = run_workload(&lisa_cfg, &wl);

    println!("\n{:<22} {:>12} {:>12}", "", "baseline", "LISA (all)");
    println!(
        "{:<22} {:>12} {:>12}",
        "copy mechanism",
        CopyMechanism::MemcpyChannel.name(),
        CopyMechanism::LisaRisc.name()
    );
    println!("{:<22} {:>12.3} {:>12.3}", "IPC (sum)", r_base.ipc_sum(), r_lisa.ipc_sum());
    println!("{:<22} {:>12} {:>12}", "DRAM cycles", r_base.dram_cycles, r_lisa.dram_cycles);
    println!("{:<22} {:>12} {:>12}", "copies", r_base.copies, r_lisa.copies);
    println!(
        "{:<22} {:>12.1} {:>12.1}",
        "energy (uJ)", r_base.energy.total, r_lisa.energy.total
    );
    println!(
        "\nLISA speedup: {:.2}x   energy reduction: {:.1}%",
        r_base.dram_cycles as f64 / r_lisa.dram_cycles as f64,
        (1.0 - r_lisa.energy.total / r_base.energy.total) * 100.0
    );
    Ok(())
}
