//! `lisa lint` self-checks (ISSUE 10 acceptance): the shipped tree is
//! clean (pinned by a golden `--json` document), every rule L1–L5
//! catches its seeded fixture violation, and mutating a scratch copy
//! of the real tree — dropping a `SimConfig` field's serialization
//! fold, or an `invalidate_horizon` call — makes the pass fail with a
//! diagnostic naming the field/site and a nonzero CLI exit.

use std::fs;
use std::path::{Path, PathBuf};

use lisa::lint::{self, rules};

fn manifest(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(sub)
}

fn render(diags: &[lint::Diagnostic]) -> String {
    lint::render_text(diags)
}

#[test]
fn shipped_tree_is_clean() {
    let diags = lint::run_dir(&manifest("src"), None).unwrap();
    assert!(diags.is_empty(), "lint errors on the shipped tree:\n{}", render(&diags));
}

#[test]
fn clean_tree_json_matches_golden() {
    let diags = lint::run_dir(&manifest("src"), None).unwrap();
    let got = lint::render_json(&diags);
    let want = fs::read_to_string(manifest("tests/lint_fixtures/lint_clean_golden.json"))
        .expect("golden file present");
    assert_eq!(got, want, "lint --json drifted from the golden clean document");
}

#[test]
fn each_rule_catches_its_seeded_fixture_violation() {
    let root = manifest("tests/lint_fixtures/violations");
    let diags = lint::run_dir(&root, None).unwrap();
    let all = render(&diags);
    for rule in [rules::L1, rules::L2, rules::L3, rules::L4, rules::L5] {
        assert!(
            diags.iter().any(|d| d.rule == rule),
            "rule {rule} caught nothing; findings were:\n{all}"
        );
    }
    // The specific seeded claims, by name.
    assert!(
        diags.iter().any(|d| d.file == "config.rs"
            && d.message.contains("extra_knob")
            && d.message.contains("to_toml")
            && d.message.contains("content_hash")
            && d.message.contains("from_toml")),
        "L1 must name the field and every missing site:\n{all}"
    );
    assert!(
        diags
            .iter()
            .any(|d| d.file == "config.rs" && d.message.contains("DramConfig")),
        "L1 must flag the missing PartialEq derive:\n{all}"
    );
    assert!(
        diags
            .iter()
            .any(|d| d.file == "scheduler.rs" && d.message.contains("push_request")),
        "L2 must name the marked mutator:\n{all}"
    );
    assert!(
        diags
            .iter()
            .any(|d| d.file == "report.rs" && d.message.contains("\"writes\"")),
        "L3 must flag the written-but-unread key:\n{all}"
    );
    assert!(
        diags
            .iter()
            .any(|d| d.file == "report.rs" && d.message.contains("\"latency\"")),
        "L3 must flag the read-but-unwritten key:\n{all}"
    );
    assert_eq!(
        diags.iter().filter(|d| d.rule == rules::L4).count(),
        1,
        "exactly the ungated probe call fires:\n{all}"
    );
    assert_eq!(
        diags.iter().filter(|d| d.file == "controller/bad_l5.rs").count(),
        2,
        "the allowed unwrap and the test mod must not fire:\n{all}"
    );
}

#[test]
fn rule_filter_restricts_findings() {
    let root = manifest("tests/lint_fixtures/violations");
    let diags = lint::run_dir(&root, Some(&[rules::L5])).unwrap();
    assert!(!diags.is_empty());
    assert!(
        diags.iter().all(|d| d.rule == rules::L5),
        "only L5 was enabled:\n{}",
        render(&diags)
    );
}

#[test]
fn clean_fixture_is_clean() {
    let diags = lint::run_dir(&manifest("tests/lint_fixtures/clean"), None).unwrap();
    assert!(diags.is_empty(), "clean fixture must lint clean:\n{}", render(&diags));
}

/// Copy the real `src/` tree (Rust sources only) into a scratch dir.
fn scratch_copy(tag: &str) -> PathBuf {
    let src = manifest("src");
    let dst = std::env::temp_dir().join(format!("lisa_lint_scratch_{tag}_{}", std::process::id()));
    if dst.exists() {
        fs::remove_dir_all(&dst).unwrap();
    }
    for f in lint::collect_rs_files(&src).unwrap() {
        let rel = f.strip_prefix(&src).unwrap();
        let to = dst.join(rel);
        fs::create_dir_all(to.parent().unwrap()).unwrap();
        fs::copy(&f, &to).unwrap();
    }
    dst
}

fn mutate(path: &Path, from: &str, to: &str) {
    let text = fs::read_to_string(path).unwrap();
    let mutated = text.replacen(from, to, 1);
    assert_ne!(text, mutated, "mutation anchor {from:?} not found in {}", path.display());
    fs::write(path, mutated).unwrap();
}

#[test]
fn dropping_a_config_fold_fails_naming_the_field() {
    let root = scratch_copy("l1");
    // Drop `seed` from the to_toml serialization (and therefore from
    // the to_toml-chained content_hash).
    mutate(&root.join("config/mod.rs"), "\n            self.seed,\n", "\n            0,\n");
    let diags = lint::run_dir(&root, Some(&[rules::L1])).unwrap();
    let hit = diags.iter().find(|d| {
        d.file == "config/mod.rs"
            && d.rule == rules::L1
            && d.message.contains("`seed`")
            && d.message.contains("to_toml")
            && d.message.contains("content_hash")
    });
    assert!(hit.is_some(), "expected a diagnostic naming `seed`; got:\n{}", render(&diags));

    // And the CLI exits nonzero on the same scratch tree, with the
    // field name in the JSON stream.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_lisa"))
        .args(["lint", "--root", root.to_str().unwrap(), "--json"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "lint must exit nonzero on a dirty tree");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("seed"), "JSON output must name the field: {stdout}");
    fs::remove_dir_all(&root).unwrap();
}

#[test]
fn dropping_an_invalidate_horizon_call_fails_naming_the_site() {
    let root = scratch_copy("l2");
    mutate(
        &root.join("controller/mod.rs"),
        "        self.chans[ch].copy_q.push_back(req);\n        self.invalidate_horizon(ch);\n",
        "        self.chans[ch].copy_q.push_back(req);\n",
    );
    let diags = lint::run_dir(&root, Some(&[rules::L2])).unwrap();
    let hit = diags.iter().find(|d| {
        d.file == "controller/mod.rs"
            && d.rule == rules::L2
            && d.message.contains("enqueue_copy")
    });
    assert!(
        hit.is_some(),
        "expected a diagnostic naming enqueue_copy; got:\n{}",
        render(&diags)
    );
    fs::remove_dir_all(&root).unwrap();
}
