//! Golden-equivalence tests for the event-driven fast-forward engine:
//! `Simulation::run()` (fast-forward) must produce a `RunReport`
//! bit-identical to `Simulation::reference_run()` (the original
//! per-cycle loop) — cycles, IPC, energy, per-command stats — across
//! the full configuration matrix the paper's evaluation sweeps.

use lisa::config::{CopyMechanism, SalpMode, SimConfig};
use lisa::dram::timing::SpeedBin;
use lisa::metrics::RunReport;
use lisa::obs::SharedTraceRing;
use lisa::sim::engine::Simulation;
use lisa::workloads::mixes;

const ALL_MECHANISMS: [CopyMechanism; 5] = [
    CopyMechanism::MemcpyChannel,
    CopyMechanism::RowCloneIntraSa,
    CopyMechanism::RowCloneInterBank,
    CopyMechanism::RowCloneInterSa,
    CopyMechanism::LisaRisc,
];

fn matrix_cfg(
    mech: CopyMechanism,
    salp: SalpMode,
    lip: bool,
    speed: SpeedBin,
    requests: u64,
) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.requests_per_core = requests;
    cfg.max_cycles = 30_000_000;
    cfg.copy_mechanism = mech;
    cfg.lisa.risc = mech == CopyMechanism::LisaRisc;
    cfg.dram.salp = salp;
    cfg.lisa.lip = lip;
    cfg.dram.speed = speed;
    cfg
}

/// Run both engines on a config + workload and assert identical
/// reports. Returns the (shared) report for extra assertions.
fn assert_equivalent(cfg: &SimConfig, workload: &str) -> RunReport {
    let wl = mixes::workload_by_name(workload, cfg).unwrap();
    let fast = Simulation::new(cfg.clone(), wl.clone()).run();
    let mut reference_sim = Simulation::new(cfg.clone(), wl);
    let reference = reference_sim.reference_run();
    assert_eq!(
        fast, reference,
        "fast-forward diverged from the reference loop: mech={:?} salp={:?} lip={} speed={:?} wl={workload}",
        cfg.copy_mechanism, cfg.dram.salp, cfg.lisa.lip, cfg.dram.speed
    );
    // The per-command device stats feed the energy model; equality of
    // the energy breakdown already covers them, but check the raw
    // counters of the reference sim are self-consistent too.
    assert!(reference.dram_cycles > 0);
    fast
}

#[test]
fn matrix_all_mechanisms_salp_lip_speed_bins() {
    // {5 mechanisms} x {SALP off/full} x {LIP on/off} x {DDR3, DDR4}
    // on a copy-heavy workload (copies exercise every command
    // sequence); the two intermediate SALP modes get their own matrix
    // below.
    for mech in ALL_MECHANISMS {
        for salp in [SalpMode::None, SalpMode::Masa] {
            for lip in [false, true] {
                for speed in [SpeedBin::Ddr3_1600, SpeedBin::Ddr4_2400] {
                    let cfg = matrix_cfg(mech, salp, lip, speed, 250);
                    let r = assert_equivalent(&cfg, "fork4");
                    assert!(r.copies > 0, "{mech:?}: no copies exercised");
                }
            }
        }
    }
}

#[test]
fn matrix_all_salp_modes_on_conflict_workloads() {
    // The E10 acceptance matrix: all four parallelism modes x
    // {memcpy, lisa-risc}, on both an intra-bank-conflict mix (open
    // rows in many subarrays, PRE_SA victim eviction, subarray-select
    // switches) and the copy-vs-open-row conflict mix.
    for mode in SalpMode::ALL {
        for mech in [CopyMechanism::MemcpyChannel, CopyMechanism::LisaRisc] {
            for wl in ["salp-shared-bank4", "salp-copy-conflict4"] {
                let cfg = matrix_cfg(mech, mode, false, SpeedBin::Ddr3_1600, 300);
                let r = assert_equivalent(&cfg, wl);
                assert!(r.reads > 0, "{mode:?}/{mech:?}/{wl}: no reads");
                if wl == "salp-copy-conflict4" {
                    assert!(r.copies > 0, "{mode:?}/{mech:?}: no copies");
                }
            }
        }
    }
}

#[test]
fn equivalence_on_noncopy_behaviour_classes() {
    // Stream / random / pointer-chase / hotspot behaviours hit
    // different stall patterns (row hits, row conflicts, MLP=1).
    for wl in ["stream4", "random4", "chase4", "hotspot4"] {
        let cfg = matrix_cfg(
            CopyMechanism::MemcpyChannel,
            SalpMode::None,
            false,
            SpeedBin::Ddr3_1600,
            400,
        );
        assert_equivalent(&cfg, wl);
    }
}

#[test]
fn equivalence_with_villa_caching() {
    // VILLA adds epoch maintenance + background fill copies — the
    // hardest case for the horizon query (epochs re-arm relative to
    // the cycle they are observed at).
    let mut cfg = matrix_cfg(
        CopyMechanism::LisaRisc,
        SalpMode::None,
        true,
        SpeedBin::Ddr3_1600,
        1_000,
    );
    cfg.lisa.villa = true;
    cfg.lisa.villa_epoch_cycles = 5_000;
    let r = assert_equivalent(&cfg, "hotspot4");
    assert!(r.villa_hit_rate > 0.0, "VILLA never engaged");
}

#[test]
fn equivalence_on_os_scenarios() {
    // The OS layer adds new state the horizon query must respect: the
    // controller's page-copy queue, fault-stalled cores waiting on
    // multiple copies, and the synthetic replay access after a fault.
    // All four scenarios, under both the memcpy baseline and
    // LISA-RISC, must stay bit-identical across engines.
    for wl in ["os-fork", "os-zero", "os-checkpoint", "os-promote"] {
        for mech in [CopyMechanism::MemcpyChannel, CopyMechanism::LisaRisc] {
            let cfg = matrix_cfg(mech, SalpMode::None, false, SpeedBin::Ddr3_1600, 300);
            let r = assert_equivalent(&cfg, wl);
            let os = r.os.expect("OS summary present");
            assert!(os.pages_copied > 0, "{wl}/{mech:?}: no page copies");
        }
    }
}

#[test]
fn equivalence_on_os_scenarios_across_placement_policies() {
    use lisa::config::PlacementPolicy;
    for policy in PlacementPolicy::ALL {
        let mut cfg = matrix_cfg(
            CopyMechanism::LisaRisc,
            SalpMode::None,
            false,
            SpeedBin::Ddr3_1600,
            250,
        );
        cfg.os.placement = policy;
        assert_equivalent(&cfg, "os-fork");
    }
}

#[test]
fn equivalence_on_multi_rank_multi_channel_geometry() {
    let mut cfg = matrix_cfg(
        CopyMechanism::LisaRisc,
        SalpMode::None,
        false,
        SpeedBin::Ddr3_1600,
        300,
    );
    cfg.dram.channels = 2;
    cfg.dram.ranks = 2;
    cfg.validate().unwrap();
    assert_equivalent(&cfg, "fork4");
}

#[test]
fn equivalence_under_indexed_scheduler_stress_geometry() {
    // The per-(rank, bank) request index and the cached per-channel
    // event horizons carry the most state here: two channels x two
    // ranks of buckets, MASA keeping many subarrays open per bank,
    // LIP changing precharge timing, and a LISA-RISC copy engine +
    // refresh invalidating horizons concurrently. A dropped or
    // misfiled bucket entry changes scheduling order, and a stale
    // horizon skips an event — either diverges from the per-cycle
    // reference loop and fails the byte-identical report check.
    for wl in ["fork4", "salp-copy-conflict4", "salp-shared-bank4"] {
        let mut cfg = matrix_cfg(
            CopyMechanism::LisaRisc,
            SalpMode::Masa,
            true,
            SpeedBin::Ddr3_1600,
            250,
        );
        cfg.dram.channels = 2;
        cfg.dram.ranks = 2;
        cfg.validate().unwrap();
        let r = assert_equivalent(&cfg, wl);
        assert!(r.reads > 0, "{wl}: no reads exercised");
    }
}

#[test]
fn observability_never_perturbs_the_simulation() {
    // The whole observability tier is a pure *reader*: attaching a
    // probe and enabling attribution must not change a single byte of
    // the simulated outcome. Run the same point three ways — plain,
    // probe-only, probe+attribution — and compare reports after
    // stripping the optional "obs" block. Also check fast-forward vs
    // the reference loop stay equivalent with observers attached.
    let mut cfg = matrix_cfg(
        CopyMechanism::LisaRisc,
        SalpMode::Masa,
        false,
        SpeedBin::Ddr3_1600,
        250,
    );
    cfg.lisa.lip = true;
    let wl = mixes::workload_by_name("salp-copy-conflict4", &cfg).unwrap();

    let plain = Simulation::new(cfg.clone(), wl.clone()).run();
    assert!(plain.obs.is_none(), "plain runs must not carry an obs block");

    let ring = SharedTraceRing::new(1 << 18);
    let mut probed = Simulation::new(cfg.clone(), wl.clone());
    probed.set_probe(Box::new(ring.clone()));
    let probed_report = probed.run();
    assert!(!ring.snapshot().is_empty(), "probe recorded nothing");
    assert_eq!(
        plain.to_json(),
        probed_report.to_json(),
        "attaching a probe changed the report bytes"
    );

    let mut full = Simulation::new(cfg.clone(), wl.clone());
    full.set_probe(Box::new(SharedTraceRing::new(1 << 18)));
    full.enable_obs();
    let mut full_report = full.run();
    let obs = full_report.obs.take().expect("obs block present with --obs");
    assert!(obs.requests > 0, "attribution saw no requests");
    assert_eq!(
        plain.to_json(),
        full_report.to_json(),
        "attribution changed the report bytes"
    );

    // The reference loop with observers attached still matches the
    // fast-forward engine (both with obs stripped).
    let mut reference = Simulation::new(cfg.clone(), wl);
    reference.enable_obs();
    let mut reference_report = reference.reference_run();
    reference_report.obs = None;
    assert_eq!(plain, reference_report);
}

#[test]
fn fast_forward_respects_the_cycle_cap() {
    // A tiny cycle cap must clip both engines at the same cycle count.
    let mut cfg = matrix_cfg(
        CopyMechanism::MemcpyChannel,
        SalpMode::None,
        false,
        SpeedBin::Ddr3_1600,
        5_000,
    );
    cfg.max_cycles = 10_000;
    let r = assert_equivalent(&cfg, "random4");
    assert_eq!(r.dram_cycles, 10_000);
}
