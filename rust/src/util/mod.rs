//! Utility substrates built in-tree (the offline registry only carries
//! the `xla` closure — no `rand`, `serde`, `clap`, `criterion` or
//! `proptest`; see DESIGN.md §Deviations).

pub mod bench;
pub mod hash;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Integer ceiling division for timing parameter conversion.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Convert a latency in nanoseconds to DRAM clock cycles (round up —
/// JEDEC timing parameters are always ceil'd to the clock).
#[inline]
pub fn ns_to_cycles(ns: f64, tck_ns: f64) -> u64 {
    debug_assert!(tck_ns > 0.0);
    (ns / tck_ns).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn ns_to_cycles_jedec_rounding() {
        // DDR3-1600: tCK = 1.25 ns, tRCD = 13.75 ns -> 11 cycles exact.
        assert_eq!(ns_to_cycles(13.75, 1.25), 11);
        // tRAS = 35 ns -> 28 cycles exact.
        assert_eq!(ns_to_cycles(35.0, 1.25), 28);
        // 8 ns RBM -> ceil(6.4) = 7 cycles.
        assert_eq!(ns_to_cycles(8.0, 1.25), 7);
    }
}
