//! Parallel experiment campaigns: shard independent `Simulation` runs
//! across OS threads with deterministic result ordering.
//!
//! The paper's evaluation sweeps {mechanism × workload × config} grids
//! through the simulator; every point is an independent, deterministic
//! run, so the campaign layer is embarrassingly parallel. Scheduling is
//! work-stealing: the job indices are dealt round-robin into one deque
//! per worker, owners pop their own deque from the front and idle
//! workers steal from the back of a victim's deque, so a straggler job
//! never strands the rest of its deque the way the old atomic-cursor
//! claim loop could strand nothing but *did* funnel every claim through
//! one contended counter. Results are written back by job index, never
//! by completion order, so the same campaign at 1, 2 or N threads
//! yields byte-identical ordered results — only wall-clock changes.
//!
//! A panic in any job poisons the pool: the flag is checked at claim
//! time, so surviving workers finish the job in hand and stop instead
//! of burning through the rest of a doomed campaign. The panic then
//! propagates to the caller via `std::thread::scope`.
//!
//! Used by the weighted-speedup helper (the N alone runs + 1 shared
//! run) and the declarative experiment grids (`sim/spec.rs`), which
//! expand every `ExperimentSpec` into the jobs sharded here;
//! [`run_jobs_sparse`] additionally streams each finished result to a
//! caller-supplied sink — the hook the campaign checkpoint journal and
//! result cache hang off.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::config::SimConfig;
use crate::metrics::RunReport;
use crate::obs::WorkerStats;
use crate::sim::engine::Simulation;
use crate::workloads::Workload;

/// Default worker count: one per available hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a user-supplied `--threads` value: absent or `0` means
/// "auto-detect the available parallelism" (like `make -j` semantics),
/// anything else is taken literally. Shared by every campaign-backed
/// CLI subcommand.
pub fn resolve_threads(requested: Option<usize>) -> usize {
    match requested {
        None | Some(0) => default_threads(),
        Some(n) => n,
    }
}

/// Run `jobs` across up to `threads` workers; results come back in
/// job order regardless of scheduling. Panics in a job propagate.
pub fn run_jobs<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    run_jobs_sparse(jobs.into_iter().enumerate().collect(), threads, |_, _: &T| {})
        .into_iter()
        .map(|(_, t)| t)
        .collect()
}

/// [`run_jobs`] for a sparse slice of a larger campaign: each job
/// carries the caller's index (e.g. its grid position, with resumed or
/// cached positions absent), and `sink` observes every `(index,
/// result)` pair as it completes — the checkpoint-journal hook. The
/// sink runs on worker threads in completion order, so it must carry
/// its own synchronization; results still come back in submission
/// order regardless.
///
/// Scheduling: indices are dealt round-robin into per-worker deques.
/// An owner pops from the front of its own deque; a worker whose deque
/// is empty steals from the back of the next non-empty victim. Stolen
/// or not, a result lands in the slot of the job that produced it, so
/// the output is independent of the schedule.
pub fn run_jobs_sparse<T, F, S>(jobs: Vec<(usize, F)>, threads: usize, sink: S) -> Vec<(usize, T)>
where
    T: Send,
    F: FnOnce() -> T + Send,
    S: Fn(usize, &T) + Sync,
{
    run_jobs_sparse_profiled(jobs, threads, sink).0
}

/// [`run_jobs_sparse`] plus per-worker scheduler counters: how many
/// jobs each worker executed and how many of those it stole from a
/// victim's deque. The counters describe wall-clock scheduling, so —
/// unlike the results — they vary run to run at `threads > 1`; the
/// serial path reports one worker that ran everything and stole
/// nothing.
pub fn run_jobs_sparse_profiled<T, F, S>(
    jobs: Vec<(usize, F)>,
    threads: usize,
    sink: S,
) -> (Vec<(usize, T)>, Vec<WorkerStats>)
where
    T: Send,
    F: FnOnce() -> T + Send,
    S: Fn(usize, &T) + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        // Serial fast path: same order, same sink calls, no pool.
        let out = jobs
            .into_iter()
            .map(|(idx, f)| {
                let t = f();
                sink(idx, &t);
                (idx, t)
            })
            .collect();
        return (out, vec![WorkerStats { ran: n as u64, stolen: 0 }]);
    }
    let slots: Vec<Mutex<Option<(usize, F)>>> =
        jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let out: Vec<Mutex<Option<(usize, T)>>> = (0..n).map(|_| Mutex::new(None)).collect();
    // Deque d owns slots {d, d+threads, d+2*threads, ...}, front first.
    let deques: Vec<Mutex<VecDeque<usize>>> = (0..threads)
        .map(|w| Mutex::new((w..n).step_by(threads).collect()))
        .collect();
    let worker_stats: Vec<Mutex<WorkerStats>> =
        (0..threads).map(|_| Mutex::new(WorkerStats::default())).collect();
    let poisoned = AtomicBool::new(false);
    std::thread::scope(|s| {
        for w in 0..threads {
            let (slots, out, deques) = (&slots, &out, &deques);
            let (poisoned, sink) = (&poisoned, &sink);
            let worker_stats = &worker_stats;
            s.spawn(move || {
                let mut local = WorkerStats::default();
                loop {
                    // Checked at claim time: a panic elsewhere stops
                    // this worker before it starts another (possibly
                    // long) job.
                    if poisoned.load(Ordering::Acquire) {
                        break;
                    }
                    let Some((slot, stole)) = claim(deques, w) else { break };
                    local.ran += 1;
                    local.stolen += stole as u64;
                    let (idx, job) =
                        slots[slot].lock().expect("job slot").take().expect("claimed once");
                    match catch_unwind(AssertUnwindSafe(job)) {
                        Ok(t) => {
                            sink(idx, &t);
                            *out[slot].lock().expect("result slot") = Some((idx, t));
                        }
                        Err(payload) => {
                            poisoned.store(true, Ordering::Release);
                            *worker_stats[w].lock().expect("worker stats") = local;
                            resume_unwind(payload);
                        }
                    }
                }
                *worker_stats[w].lock().expect("worker stats") = local;
            });
        }
    });
    let results = out
        .into_iter()
        .map(|m| m.into_inner().expect("result lock").expect("job completed"))
        .collect();
    let stats = worker_stats
        .into_iter()
        .map(|m| m.into_inner().expect("worker stats lock"))
        .collect();
    (results, stats)
}

/// Claim the next slot for worker `w`: own deque front, else steal
/// from the back of the next victim (cyclic scan). The flag says
/// whether the claim was a steal.
fn claim(deques: &[Mutex<VecDeque<usize>>], w: usize) -> Option<(usize, bool)> {
    if let Some(i) = deques[w].lock().expect("own deque").pop_front() {
        return Some((i, false));
    }
    for step in 1..deques.len() {
        let victim = (w + step) % deques.len();
        if let Some(i) = deques[victim].lock().expect("victim deque").pop_back() {
            return Some((i, true));
        }
    }
    None
}

/// Run a batch of (config, workload) simulations in parallel,
/// preserving input order.
pub fn run_reports(points: Vec<(SimConfig, Workload)>, threads: usize) -> Vec<RunReport> {
    let jobs: Vec<_> = points
        .into_iter()
        .map(|(cfg, wl)| move || Simulation::new(cfg, wl).run())
        .collect();
    run_jobs(jobs, threads)
}

/// Alone-run IPCs for every core of a workload (the denominator of
/// weighted speedup), sharded across `threads` workers.
pub fn alone_ipcs(cfg: &SimConfig, workload: &Workload, threads: usize) -> Vec<f64> {
    let jobs: Vec<_> = (0..workload.cores.len())
        .map(|i| {
            let cfg = cfg.clone();
            move || Simulation::new_alone(cfg, workload, i).run().ipc[0]
        })
        .collect();
    run_jobs(jobs, threads)
}

/// Weighted speedup of a workload on a config: the N alone runs and
/// the shared run are independent, so all N+1 go through the campaign
/// runner together.
pub fn weighted_speedup(
    cfg: &SimConfig,
    workload: &Workload,
    threads: usize,
) -> (f64, RunReport) {
    let n = workload.cores.len();
    let jobs: Vec<Box<dyn FnOnce() -> RunReport + Send + '_>> = (0..=n)
        .map(|i| {
            let cfg = cfg.clone();
            let job: Box<dyn FnOnce() -> RunReport + Send + '_> = if i < n {
                Box::new(move || Simulation::new_alone(cfg, workload, i).run())
            } else {
                Box::new(move || Simulation::new(cfg, workload.clone()).run())
            };
            job
        })
        .collect();
    let mut reports = run_jobs(jobs, threads);
    let shared = reports.pop().expect("shared run present");
    let alone: Vec<f64> = reports.iter().map(|r| r.ipc[0]).collect();
    let ws = shared
        .try_weighted_speedup(&alone)
        .expect("one alone run per core by construction");
    (ws, shared)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::mixes;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn threads_zero_autodetects() {
        let auto = default_threads();
        assert!(auto >= 1);
        assert_eq!(resolve_threads(None), auto);
        assert_eq!(resolve_threads(Some(0)), auto);
        assert_eq!(resolve_threads(Some(3)), 3);
        // And a campaign driven by the resolved value still works.
        let jobs: Vec<_> = (0..4u64).map(|i| move || i * 2).collect();
        assert_eq!(run_jobs(jobs, resolve_threads(Some(0))), vec![0, 2, 4, 6]);
    }

    #[test]
    fn run_jobs_preserves_order_across_thread_counts() {
        // Jobs finish in scrambled wall-clock order (varying work), but
        // results must always come back in submission order.
        fn mk_jobs() -> Vec<impl FnOnce() -> (u64, u64) + Send> {
            (0..32u64)
                .map(|i| {
                    move || {
                        // Unequal work so threads interleave and steal.
                        let mut acc = i;
                        for k in 0..((i % 7) * 1000) {
                            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
                        }
                        (i, acc)
                    }
                })
                .collect()
        }
        let serial = run_jobs(mk_jobs(), 1);
        for threads in [2, 4, 8] {
            let parallel = run_jobs(mk_jobs(), threads);
            assert_eq!(serial, parallel, "threads={threads}");
        }
        assert_eq!(run_jobs(Vec::<fn() -> u8>::new(), 4), Vec::<u8>::new());
    }

    #[test]
    fn stealing_drains_a_stacked_deque() {
        // All the work lands in worker 0's deque positions (indices
        // 0, t, 2t, ... carry the heavy jobs); the other workers go
        // idle immediately and must steal to finish. Every job still
        // runs exactly once and results stay in submission order.
        let threads = 4;
        let executed = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..64usize)
            .map(|i| {
                let executed = &executed;
                move || {
                    executed.fetch_add(1, Ordering::Relaxed);
                    if i % threads == 0 {
                        // Heavy job: worker 0's whole hand.
                        let mut acc = i as u64;
                        for k in 0..20_000u64 {
                            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
                        }
                        std::hint::black_box(acc);
                    }
                    i
                }
            })
            .collect();
        let results = run_jobs(jobs, threads);
        assert_eq!(results, (0..64).collect::<Vec<_>>());
        assert_eq!(executed.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn sparse_jobs_keep_their_indices_and_feed_the_sink() {
        // A resumed campaign runs a sparse subset of the grid: indices
        // are non-contiguous and must come back untouched, and the
        // sink must observe every completion exactly once.
        let jobs: Vec<(usize, _)> =
            [3usize, 7, 12, 40].iter().map(|&i| (i, move || i * 10)).collect();
        let seen = Mutex::new(Vec::new());
        let results = run_jobs_sparse(jobs, 2, |idx, r: &usize| {
            seen.lock().unwrap().push((idx, *r));
        });
        assert_eq!(results, vec![(3, 30), (7, 70), (12, 120), (40, 400)]);
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![(3, 30), (7, 70), (12, 120), (40, 400)]);
    }

    #[test]
    fn profiled_scheduler_accounts_for_every_job() {
        // Serial: one worker, ran == jobs, nothing stolen.
        let jobs: Vec<(usize, _)> = (0..5usize).map(|i| (i, move || i)).collect();
        let (results, stats) = run_jobs_sparse_profiled(jobs, 1, |_, _: &usize| {});
        assert_eq!(results.len(), 5);
        assert_eq!(stats, vec![WorkerStats { ran: 5, stolen: 0 }]);
        // Parallel: per-worker counts vary with scheduling, but they
        // must sum to the job count, with steals a subset of runs.
        let jobs: Vec<(usize, _)> = (0..32usize).map(|i| (i, move || i)).collect();
        let (results, stats) = run_jobs_sparse_profiled(jobs, 4, |_, _: &usize| {});
        assert_eq!(results.len(), 32);
        assert_eq!(stats.len(), 4);
        assert_eq!(stats.iter().map(|s| s.ran).sum::<u64>(), 32);
        assert!(stats.iter().all(|s| s.stolen <= s.ran));
        // The stacked-deque shape from `stealing_drains_a_stacked_deque`
        // forces at least one steal: worker 0's hand is all heavy jobs.
        let threads = 4;
        let jobs: Vec<(usize, _)> = (0..64usize)
            .map(|i| {
                (i, move || {
                    if i % threads == 0 {
                        let mut acc = i as u64;
                        for k in 0..20_000u64 {
                            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
                        }
                        std::hint::black_box(acc);
                    }
                    i
                })
            })
            .collect();
        let (_, stats) = run_jobs_sparse_profiled(jobs, threads, |_, _: &usize| {});
        assert_eq!(stats.iter().map(|s| s.ran).sum::<u64>(), 64);
    }

    #[test]
    fn job_panic_propagates_to_the_caller() {
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom in job")),
            Box::new(|| 3),
        ];
        let r = catch_unwind(AssertUnwindSafe(|| run_jobs(jobs, 2)));
        assert!(r.is_err(), "panic must not be swallowed");
    }

    #[test]
    fn poison_flag_stops_surviving_workers_early() {
        // Job 0 panics immediately; the 15 other jobs sleep. With 2
        // workers the survivor may finish the job already in hand, but
        // the claim-time poison check must keep it from draining the
        // rest of the campaign.
        let executed = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|i| {
                let executed = &executed;
                let job: Box<dyn FnOnce() + Send + '_> = if i == 0 {
                    Box::new(|| panic!("poison"))
                } else {
                    Box::new(move || {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        executed.fetch_add(1, Ordering::Relaxed);
                    })
                };
                job
            })
            .collect();
        let r = catch_unwind(AssertUnwindSafe(|| run_jobs(jobs, 2)));
        assert!(r.is_err());
        let done = executed.load(Ordering::Relaxed);
        assert!(done < 15, "poisoned pool still ran {done}/15 surviving jobs");
    }

    #[test]
    fn run_reports_preserves_point_order() {
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 300;
        let wl_a = mixes::workload_by_name("stream4", &cfg).unwrap();
        let wl_b = mixes::workload_by_name("fork4", &cfg).unwrap();
        let points =
            vec![(cfg.clone(), wl_a.clone()), (cfg.clone(), wl_b.clone())];
        let serial = run_reports(points.clone(), 1);
        assert_eq!(serial[0].workload, "stream4");
        assert_eq!(serial[1].workload, "fork4");
        assert_eq!(serial, run_reports(points, 4));
    }

    #[test]
    fn indexed_scheduler_state_is_thread_migration_safe() {
        // The controller's per-channel horizon cache is interior-
        // mutable state private to each Simulation; campaigns move
        // Simulations across worker threads. A SALP + copy-heavy grid
        // (the configs with the most per-bank bucket and cache churn)
        // must stay byte-identical at 1, 2 and 8 threads.
        use crate::config::{CopyMechanism, SalpMode};
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 300;
        cfg.dram.salp = SalpMode::Masa;
        cfg.lisa.risc = true;
        cfg.copy_mechanism = CopyMechanism::LisaRisc;
        let points: Vec<(SimConfig, Workload)> =
            ["salp-shared-bank4", "salp-copy-conflict4", "fork4"]
                .iter()
                .map(|w| (cfg.clone(), mixes::workload_by_name(w, &cfg).unwrap()))
                .collect();
        let serial = run_reports(points.clone(), 1);
        assert_eq!(serial, run_reports(points.clone(), 2));
        assert_eq!(serial, run_reports(points, 8));
    }

    #[test]
    fn parallel_weighted_speedup_matches_serial_engine() {
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 800;
        let wl = mixes::workload_by_name("random4", &cfg).unwrap();
        let (ws_serial, rep_serial) = crate::sim::engine::weighted_speedup(&cfg, &wl);
        let (ws_par, rep_par) = weighted_speedup(&cfg, &wl, 4);
        assert_eq!(rep_serial, rep_par);
        assert!((ws_serial - ws_par).abs() < 1e-12, "{ws_serial} vs {ws_par}");
        let alone = alone_ipcs(&cfg, &wl, 8);
        assert_eq!(alone, crate::sim::engine::alone_ipcs(&cfg, &wl));
    }
}
