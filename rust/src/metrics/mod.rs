//! System-level metrics: IPC, weighted speedup (the paper's
//! multi-programmed metric [Snavely & Tullsen, Eyerman & Eeckhout]),
//! and the experiment report structures.

use crate::energy::EnergyBreakdown;
use crate::util::stats::geomean;

/// Result of simulating one workload on one configuration.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub workload: String,
    pub config_name: String,
    /// Per-core instructions-per-cycle (CPU cycles).
    pub ipc: Vec<f64>,
    /// DRAM cycles simulated.
    pub dram_cycles: u64,
    pub reads: u64,
    pub writes: u64,
    pub copies: u64,
    pub avg_read_latency_cycles: f64,
    pub row_hit_rate: f64,
    pub villa_hit_rate: f64,
    pub lip_coverage: f64,
    pub energy: EnergyBreakdown,
}

impl RunReport {
    /// Weighted speedup against per-core alone-run IPCs:
    /// WS = sum_i IPC_shared,i / IPC_alone,i.
    pub fn weighted_speedup(&self, alone_ipc: &[f64]) -> f64 {
        self.ipc
            .iter()
            .zip(alone_ipc)
            .map(|(s, a)| if *a > 0.0 { s / a } else { 0.0 })
            .sum()
    }

    pub fn ipc_sum(&self) -> f64 {
        self.ipc.iter().sum()
    }

    /// Convenience for single-config summaries where WS is taken
    /// against itself (== number of cores when alone == shared).
    pub fn weighted_speedup_sum(&self) -> f64 {
        self.ipc_sum()
    }
}

/// Comparison of a mechanism against a baseline across workloads.
#[derive(Debug, Clone, Default)]
pub struct Comparison {
    pub name: String,
    /// Per-workload WS improvement fractions (e.g. 0.25 = +25%).
    pub ws_improvements: Vec<f64>,
    /// Per-workload energy reduction fractions.
    pub energy_reductions: Vec<f64>,
}

impl Comparison {
    pub fn mean_ws_improvement(&self) -> f64 {
        if self.ws_improvements.is_empty() {
            return 0.0;
        }
        self.ws_improvements.iter().sum::<f64>() / self.ws_improvements.len() as f64
    }

    pub fn geomean_speedup(&self) -> f64 {
        let ratios: Vec<f64> = self.ws_improvements.iter().map(|i| 1.0 + i).collect();
        geomean(&ratios)
    }

    pub fn max_ws_improvement(&self) -> f64 {
        self.ws_improvements.iter().cloned().fold(f64::MIN, f64::max)
    }

    pub fn mean_energy_reduction(&self) -> f64 {
        if self.energy_reductions.is_empty() {
            return 0.0;
        }
        self.energy_reductions.iter().sum::<f64>() / self.energy_reductions.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_speedup_math() {
        let r = RunReport { ipc: vec![1.0, 2.0], ..Default::default() };
        let ws = r.weighted_speedup(&[2.0, 2.0]);
        assert!((ws - 1.5).abs() < 1e-12);
        // Degenerate alone IPC contributes zero, not a panic.
        let ws = r.weighted_speedup(&[0.0, 2.0]);
        assert!((ws - 1.0).abs() < 1e-12);
    }

    #[test]
    fn comparison_aggregates() {
        let c = Comparison {
            name: "x".into(),
            ws_improvements: vec![0.10, 0.30],
            energy_reductions: vec![0.5, 0.3],
        };
        assert!((c.mean_ws_improvement() - 0.20).abs() < 1e-12);
        assert!((c.mean_energy_reduction() - 0.40).abs() < 1e-12);
        assert!((c.geomean_speedup() - (1.1f64 * 1.3).sqrt()).abs() < 1e-12);
        assert!((c.max_ws_improvement() - 0.30).abs() < 1e-12);
    }
}
