//! Observability-tier tests: the attribution partition is *exact*
//! (components sum to end-to-end latency, pinned by a property test
//! over random request soups), trace streams are cycle-monotone per
//! track, the MASA copy-conflict point shows copy hops across distinct
//! subarray tracks, and both export formats emit well-formed JSON.

use lisa::config::{CopyMechanism, SalpMode, SimConfig};
use lisa::dram::timing::SpeedBin;
use lisa::obs::{
    to_chrome_trace, to_jsonl, Attribution, SharedTraceRing, TraceEvent, TraceKind,
};
use lisa::sim::engine::Simulation;
use lisa::util::json::{self, Value};
use lisa::util::proptest::check;
use lisa::workloads::mixes;

const BANKS: usize = 4;
const SAS: usize = 4;

#[test]
fn prop_attribution_components_sum_exactly_to_latency() {
    // Random soups of blocker windows (refresh, copy ownership, open
    // rows) interleaved with demand RD/WRs at random offsets: every
    // request's five components must sum *exactly* to `done - arrive`,
    // and the aggregate sums must equal the per-request sums.
    check("attribution exact partition", 100, |g| {
        let mut a = Attribution::new(1, 1, BANKS, SAS);
        let mut now = 0u64;
        let mut expect: Vec<(u64, u64)> = Vec::new(); // (arrive, done)
        let mut sums = [0u64; 5];
        for id in 0..40i64 {
            now += g.u64(25);
            let bank = g.usize(BANKS) as i64;
            let sa = g.usize(SAS) as i64;
            match g.u64(8) {
                0 => {
                    // A refresh window on the rank.
                    a.observe(&TraceEvent::new(TraceKind::RefPend, now, 0, 0));
                    now += g.u64(30);
                    let mut r = TraceEvent::new(TraceKind::Ref, now, 0, 0);
                    r.done = now + 1 + g.u64(50);
                    a.observe(&r);
                    now = r.done;
                }
                1 => {
                    // A copy owning a bank for a while.
                    let mut own = TraceEvent::new(TraceKind::CopyOwn, now, 0, 0);
                    own.bank = bank;
                    a.observe(&own);
                    now += 1 + g.u64(60);
                    let mut rel = TraceEvent::new(TraceKind::CopyRelease, now, 0, 0);
                    rel.bank = bank;
                    a.observe(&rel);
                }
                2 => {
                    // Open a row in some subarray.
                    let mut act = TraceEvent::new(TraceKind::Act, now, 0, 0);
                    act.bank = bank;
                    act.sa = sa;
                    act.row = g.u64(64) as i64;
                    act.done = now + 1 + g.u64(15);
                    a.observe(&act);
                }
                3 => {
                    let mut pre = TraceEvent::new(
                        *g.pick(&[TraceKind::Pre, TraceKind::PreSa, TraceKind::PreAll]),
                        now,
                        0,
                        0,
                    );
                    pre.bank = bank;
                    pre.sa = sa;
                    pre.done = now + 1 + g.u64(10);
                    a.observe(&pre);
                }
                _ => {
                    // A demand access: arrive <= issue <= done.
                    let wait = g.u64(80);
                    let mut rd = TraceEvent::new(
                        *g.pick(&[TraceKind::Rd, TraceKind::Wr]),
                        now,
                        0,
                        0,
                    );
                    rd.bank = bank;
                    rd.sa = sa;
                    rd.row = g.u64(64) as i64;
                    rd.id = id;
                    rd.arrive = now.saturating_sub(wait);
                    rd.done = now + 1 + g.u64(30);
                    a.observe(&rd);
                    expect.push((rd.arrive, rd.done));
                }
            }
        }
        assert_eq!(a.requests.len(), expect.len());
        for (r, &(arrive, done)) in a.requests.iter().zip(&expect) {
            assert_eq!(r.arrive, arrive);
            assert_eq!(r.done, done);
            assert_eq!(
                r.components_sum(),
                r.total(),
                "partition not exact: {r:?}"
            );
            sums[0] += r.queueing;
            sums[1] += r.bank_conflict;
            sums[2] += r.refresh_blocked;
            sums[3] += r.copy_blocked;
            sums[4] += r.service;
        }
        let rep = a.finalize(now.max(1));
        assert_eq!(
            [
                rep.sum_queueing,
                rep.sum_bank_conflict,
                rep.sum_refresh_blocked,
                rep.sum_copy_blocked,
                rep.sum_service,
            ],
            sums,
            "aggregate sums drifted from the per-request decompositions"
        );
        assert!(rep.bank_util.iter().all(|u| u.is_finite() && *u <= 1.0));
    });
}

/// One MASA copy-conflict run with the probe attached; shared by the
/// stream- and export-shape tests below.
fn conflict_trace() -> Vec<TraceEvent> {
    let mut cfg = SimConfig::default();
    cfg.requests_per_core = 200;
    cfg.max_cycles = 30_000_000;
    cfg.copy_mechanism = CopyMechanism::LisaRisc;
    cfg.lisa.risc = true;
    cfg.dram.salp = SalpMode::Masa;
    cfg.dram.speed = SpeedBin::Ddr3_1600;
    let wl = mixes::workload_by_name("salp-copy-conflict4", &cfg).unwrap();
    let ring = SharedTraceRing::new(1 << 20);
    let mut sim = Simulation::new(cfg.clone(), wl);
    sim.set_probe(Box::new(ring.clone()));
    sim.enable_obs();
    let report = sim.run();
    assert_eq!(ring.dropped(), 0, "ring overflowed on a small run");

    // Replaying the probe stream through a fresh Attribution must
    // reproduce the engine's own obs block bit-for-bit: the probe and
    // the attribution engine see the same events, in the same order.
    let events = ring.snapshot();
    let d = &cfg.dram;
    let mut replay = Attribution::new(d.channels, d.ranks, d.banks, d.subarrays_per_bank);
    for ev in &events {
        replay.observe(ev);
    }
    let obs = report.obs.expect("obs enabled");
    assert!(obs.requests > 0, "no demand requests attributed");
    assert_eq!(replay.finalize(report.dram_cycles), obs);
    events
}

#[test]
fn masa_conflict_stream_is_monotone_and_spans_subarray_tracks() {
    let events = conflict_trace();
    assert!(!events.is_empty());
    // Cycle-monotone globally (and therefore per track — a track is a
    // subset of the stream).
    assert!(
        events.windows(2).all(|w| w[0].cycle <= w[1].cycle),
        "trace stream is not cycle-monotone"
    );
    // The interesting kinds of the copy-vs-open-row conflict point are
    // all present: row activity, subarray-scoped precharge (MASA), and
    // LISA-RISC copy hops.
    for kind in [
        TraceKind::Act,
        TraceKind::PreSa,
        TraceKind::Rbm,
        TraceKind::Rd,
        TraceKind::Enq,
        TraceKind::CopyStart,
        TraceKind::CopyDone,
    ] {
        assert!(
            events.iter().any(|e| e.kind == kind),
            "no {} event in the conflict trace",
            kind.name()
        );
    }
    // Copy hops are flagged as copy traffic and land on >= 2 distinct
    // subarray tracks (an RBM moves a row between neighbouring
    // subarrays, so source subarrays vary across hops).
    let hop_tracks: std::collections::BTreeSet<(usize, usize, i64, i64)> = events
        .iter()
        .filter(|e| e.kind == TraceKind::Rbm)
        .inspect(|e| assert!(e.copy, "RBM not flagged as copy traffic"))
        .map(|e| (e.ch, e.rank, e.bank, e.sa))
        .collect();
    let sa_tracks: std::collections::BTreeSet<(usize, usize, i64, i64)> = events
        .iter()
        .filter(|e| e.sa >= 0)
        .map(|e| (e.ch, e.rank, e.bank, e.sa))
        .collect();
    assert!(!hop_tracks.is_empty(), "no RBM hops traced");
    assert!(
        sa_tracks.len() >= 2,
        "expected >= 2 distinct subarray tracks, got {sa_tracks:?}"
    );
}

#[test]
fn chrome_trace_export_is_well_formed_and_monotone_per_track() {
    let events = conflict_trace();
    let doc = json::parse(&to_chrome_trace(&events)).unwrap();
    let slices = doc
        .get("traceEvents")
        .and_then(Value::as_array)
        .expect("traceEvents array");
    assert!(!slices.is_empty());
    let mut last_ts: std::collections::BTreeMap<(u64, u64), f64> =
        std::collections::BTreeMap::new();
    let mut n_slices = 0usize;
    for e in slices {
        let ph = e.get("ph").and_then(Value::as_str).expect("ph");
        match ph {
            "M" => {
                // Metadata: names a process or a thread (track).
                let name = e.get("name").and_then(Value::as_str).unwrap();
                assert!(
                    name == "process_name" || name == "thread_name",
                    "unexpected metadata record {name}"
                );
            }
            "X" => {
                n_slices += 1;
                let pid = e.get("pid").and_then(Value::as_u64).expect("pid");
                let tid = e.get("tid").and_then(Value::as_u64).expect("tid");
                let ts = e.get("ts").and_then(Value::as_f64).expect("ts");
                let dur = e.get("dur").and_then(Value::as_f64).expect("dur");
                assert!(dur >= 0.0);
                assert!(e.get("name").and_then(Value::as_str).is_some());
                let prev = last_ts.insert((pid, tid), ts);
                assert!(
                    prev.map_or(true, |p| p <= ts),
                    "timestamps regressed on track ({pid},{tid})"
                );
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert_eq!(n_slices, events.len(), "every event exports one slice");
}

#[test]
fn jsonl_export_parses_line_by_line() {
    let events = conflict_trace();
    let body = to_jsonl(&events);
    let lines: Vec<&str> = body.lines().collect();
    assert_eq!(lines.len(), events.len());
    for (line, ev) in lines.iter().zip(&events) {
        let v = json::parse(line).unwrap();
        assert_eq!(
            v.get("kind").and_then(Value::as_str),
            Some(ev.kind.name())
        );
        assert_eq!(v.get("cycle").and_then(Value::as_u64), Some(ev.cycle));
    }
}
