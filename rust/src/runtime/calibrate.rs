//! Calibration driver: executes the circuit-model artifacts over a
//! Monte-Carlo process-variation population and derives the
//! simulator's LISA timing/energy parameters, exactly following the
//! paper's methodology:
//!
//! 1. simulate each analog operation across all bitlines with
//!    per-bitline variation;
//! 2. take the WORST bitline (an operation completes only when every
//!    bitline has);
//! 3. apply the paper's 60% process/temperature guard band;
//! 4. quantize to DRAM clock cycles downstream (dram::timing).
//!
//! The scalar parameter vectors mirror python/compile/model.py's
//! PhysParams — both sides document the pairing; drifting them apart
//! is caught by the calibration integration test comparing against
//! the checked-in Calibration defaults.

use anyhow::Result;

use crate::config::Calibration;
use crate::runtime::loader::{Runtime, N_LANES, NSCALARS};
use crate::util::rng::Pcg32;

// Scalar slot indices (bitline.py layout).
const S_DT: usize = 0;
const S_VDD: usize = 1;
const S_SENSE_THR: usize = 2;
const S_SETTLE_TOL: usize = 3;
const S_GM_A: usize = 4;
const S_GM_B: usize = 5;
const S_G_EXT_A: usize = 6;
const S_G_EXT_B: usize = 7;
const S_V_EXT_A: usize = 8;
const S_V_EXT_B: usize = 9;
const S_G_LINK: usize = 10;
const S_C_A: usize = 11;
const S_C_B: usize = 12;
const S_SETTLE_TGT: usize = 13;
const S_SETTLE_B: usize = 14;
const S_SETTLE_TGT_B: usize = 15;

/// Physical constants — MUST mirror python/compile/model.py
/// PhysParams (the authoring side).
#[derive(Debug, Clone)]
pub struct PhysParams {
    pub vdd: f32,
    pub dt: f32,
    pub c_bitline: f32,
    pub c_bitline_fast: f32,
    pub c_cell: f32,
    pub g_access: f32,
    pub g_line: f32,
    pub gm_sense: f32,
    pub gm_hold: f32,
    pub g_precharge: f32,
    pub g_iso: f32,
    pub sense_threshold: f32,
    pub settle_tol: f32,
    pub variation_sigma: f64,
}

impl Default for PhysParams {
    fn default() -> Self {
        Self {
            vdd: 1.2,
            dt: 0.01,
            c_bitline: 85.0,
            c_bitline_fast: 38.0,
            c_cell: 22.0,
            g_access: 6.0,
            g_line: 30.0,
            gm_sense: 20.0,
            gm_hold: 400.0,
            g_precharge: 25.0,
            g_iso: 12.0,
            sense_threshold: 0.075,
            settle_tol: 0.03,
            variation_sigma: 0.05,
        }
    }
}

/// The paper's guard band for process/temperature variation (§2).
pub const GUARD_BAND: f64 = 1.6;

/// Inputs for one calibration run.
#[derive(Debug, Clone)]
pub struct CalibrationInputs {
    pub params: PhysParams,
    pub seed: u64,
}

impl Default for CalibrationInputs {
    fn default() -> Self {
        Self { params: PhysParams::default(), seed: 0xCA11B }
    }
}

fn base_scalars(p: &PhysParams) -> [f32; NSCALARS] {
    let mut s = [0.0f32; NSCALARS];
    s[S_DT] = p.dt;
    s[S_VDD] = p.vdd;
    s[S_SENSE_THR] = p.sense_threshold;
    s[S_SETTLE_TOL] = p.settle_tol;
    s[S_C_A] = p.c_bitline;
    s[S_C_B] = p.c_cell;
    s[S_SETTLE_TGT] = p.vdd * 0.5;
    s[S_SETTLE_TGT_B] = p.vdd * 0.5;
    s
}

/// Mirror of model.scalars_activate.
pub fn scalars_activate(p: &PhysParams, fast: bool) -> [f32; NSCALARS] {
    let mut s = base_scalars(p);
    s[S_GM_A] = p.gm_sense;
    s[S_G_LINK] = p.g_access;
    s[S_C_A] = if fast { p.c_bitline_fast } else { p.c_bitline };
    s[S_C_B] = p.c_cell;
    s[S_SETTLE_B] = 1.0;
    s[S_SETTLE_TGT] = p.vdd;
    s[S_SETTLE_TGT_B] = p.vdd;
    s
}

/// Mirror of model.scalars_rbm.
pub fn scalars_rbm(p: &PhysParams, fast: bool) -> [f32; NSCALARS] {
    let mut s = base_scalars(p);
    s[S_GM_A] = p.gm_sense;
    s[S_GM_B] = p.gm_hold;
    s[S_G_LINK] = p.g_iso;
    s[S_C_A] = if fast { p.c_bitline_fast } else { p.c_bitline };
    s[S_C_B] = p.c_bitline;
    s[S_SETTLE_TGT] = p.vdd;
    s[S_SETTLE_TGT_B] = p.vdd;
    s
}

/// Mirror of model.scalars_precharge (2-segment line model).
pub fn scalars_precharge(p: &PhysParams, linked: bool, fast: bool) -> [f32; NSCALARS] {
    let mut s = base_scalars(p);
    let c_half = if fast { p.c_bitline_fast } else { p.c_bitline } * 0.5;
    s[S_G_EXT_A] = if linked { p.g_precharge } else { 0.0 };
    s[S_V_EXT_A] = p.vdd * 0.5;
    s[S_G_EXT_B] = p.g_precharge;
    s[S_V_EXT_B] = p.vdd * 0.5;
    s[S_G_LINK] = p.g_line;
    s[S_C_A] = c_half;
    s[S_C_B] = c_half;
    s[S_SETTLE_B] = 1.0;
    s[S_SETTLE_TGT] = p.vdd * 0.5;
    s[S_SETTLE_TGT_B] = p.vdd * 0.5;
    s
}

/// Lognormal variation multipliers for the lane population.
fn variation(rng: &mut Pcg32, sigma: f64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.lognormal_mul(sigma) as f32).collect()
}

/// Run the full calibration against the artifacts in `runtime`.
pub fn calibrate(runtime: &Runtime, inputs: &CalibrationInputs) -> Result<Calibration> {
    let p = &inputs.params;
    let mut rng = Pcg32::new(inputs.seed, 7);
    let n = N_LANES;

    let gmul = variation(&mut rng, p.variation_sigma, n);
    let cmul = variation(&mut rng, p.variation_sigma, n);
    let vdd = vec![p.vdd; n];
    let mid = vec![p.vdd * 0.5; n];

    // Precharge: both line halves start at the rail (row stored a 1).
    let pre = runtime.load("precharge_single")?;
    let out_pre = pre.run(&vdd, &vdd, &gmul, &cmul, &scalars_precharge(p, false, false))?;
    let lip = runtime.load("precharge_linked")?;
    let out_lip = lip.run(&vdd, &vdd, &gmul, &cmul, &scalars_precharge(p, true, false))?;

    // RBM: destination precharged, source buffer latched high.
    let rbm = runtime.load("rbm_hop")?;
    let out_rbm = rbm.run(&mid, &vdd, &gmul, &cmul, &scalars_rbm(p, false))?;

    // Activation: bitline at VDD/2, cell at the rail. Slow and fast
    // (VILLA) bitline variants from the same artifact — the capacitance
    // lives in the runtime scalar vector.
    let act = runtime.load("activate_sense")?;
    let out_act = act.run(&mid, &vdd, &gmul, &cmul, &scalars_activate(p, false))?;
    let out_act_fast = act.run(&mid, &vdd, &gmul, &cmul, &scalars_activate(p, true))?;

    // The paper's methodology: nominal SPICE latency + 60% guard band
    // covering process/temperature variation. Our Monte-Carlo
    // population lets us *verify* the band: the worst bitline must
    // fall inside the margined value (otherwise the band is too thin
    // for the configured variation sigma and calibration fails).
    let margined = |o: &crate::runtime::loader::PhaseOutputs, what: &str| -> Result<f64> {
        let mut v: Vec<f32> = o.t_settle.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = v[v.len() / 2] as f64;
        let worst = o.worst_settle_ns();
        let m = median * GUARD_BAND;
        if worst > m {
            anyhow::bail!(
                "{what}: worst bitline {worst:.2} ns exceeds the margined \
                 {m:.2} ns — guard band does not cover variation"
            );
        }
        Ok(m)
    };

    let t_rp_circuit_ns = margined(&out_pre, "precharge")?;
    let t_rp_lip_ns = margined(&out_lip, "linked precharge")?;
    let t_rbm_ns = margined(&out_rbm, "rbm")?;

    // Fast-subarray ratios (margin cancels in the ratio).
    let fast_act_ratio =
        (out_act_fast.worst_sense_ns() / out_act.worst_sense_ns()).clamp(0.05, 1.0);
    let fast_ras_ratio =
        (out_act_fast.worst_settle_ns() / out_act.worst_settle_ns()).clamp(0.05, 1.0);
    // Short-bitline precharge scales ~ with capacitance.
    let fast_rp_ratio = (p.c_bitline_fast / p.c_bitline) as f64;

    Ok(Calibration {
        t_rbm_ns,
        t_rp_lip_ns,
        t_rp_circuit_ns,
        fast_act_ratio,
        fast_ras_ratio,
        fast_rp_ratio,
        e_act_fj: out_act.mean_energy_fj(),
        e_pre_fj: out_pre.mean_energy_fj(),
        e_rbm_fj: out_rbm.mean_energy_fj(),
        from_artifacts: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_vectors_mirror_python_model() {
        // Spot-check the slot layout against the documented values in
        // python/compile/model.py (PhysParams defaults).
        let p = PhysParams::default();
        let s = scalars_precharge(&p, false, false);
        assert_eq!(s[S_G_EXT_B], 25.0); // g_precharge
        assert_eq!(s[S_G_EXT_A], 0.0); // single-ended
        assert_eq!(s[S_G_LINK], 30.0); // g_line
        assert_eq!(s[S_C_A], 42.5); // c_bitline / 2

        let s = scalars_precharge(&p, true, false);
        assert_eq!(s[S_G_EXT_A], 25.0); // neighbor PU linked in

        let s = scalars_rbm(&p, false);
        assert_eq!(s[S_G_LINK], 12.0); // g_iso
        assert_eq!(s[S_GM_B], 400.0); // held source buffer

        let s = scalars_activate(&p, true);
        assert_eq!(s[S_C_A], 38.0); // fast bitline
        assert_eq!(s[S_G_LINK], 6.0); // access transistor
    }

    #[test]
    fn guard_band_is_the_papers_sixty_percent() {
        assert!((GUARD_BAND - 1.6).abs() < 1e-12);
    }
}
