//! `minitoml`: a small TOML-subset parser sufficient for simulator
//! configuration files (sections, key = value with string / integer /
//! float / boolean values, `#` comments). Built in-tree because the
//! offline registry carries no serde/toml.
//!
//! Supported grammar:
//!
//! ```toml
//! # comment
//! top_level_key = 1
//! [section]          # or [a.b] nested names (stored as "a.b")
//! name = "string"    # double-quoted, \" \\ \n \t escapes
//! count = 42         # i64, optional +/-, 0x hex allowed
//! ratio = 0.5        # f64 (also 1e-3 forms)
//! flag = true        # or false
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
        }
    }
}

/// A parsed document: (section, key) -> value. The top level is the
/// empty section "".
#[derive(Debug, Clone, Default)]
pub struct Document {
    entries: BTreeMap<(String, String), Value>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                let name = name.trim();
                if name.is_empty()
                    || !name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
                {
                    bail!("line {}: bad section name '{name}'", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = line[..eq].trim();
            if key.is_empty()
                || !key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                bail!("line {}: bad key '{key}'", lineno + 1);
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            let prev = doc
                .entries
                .insert((section.clone(), key.to_string()), value);
            if prev.is_some() {
                bail!("line {}: duplicate key '{key}' in [{section}]", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries
            .get(&(section.to_string(), key.to_string()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<Option<String>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s.clone())),
            Some(v) => bail!("[{section}].{key}: expected string, got {}", v.type_name()),
        }
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Result<Option<i64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Int(i)) => Ok(Some(*i)),
            Some(v) => bail!("[{section}].{key}: expected integer, got {}", v.type_name()),
        }
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>> {
        match self.get_i64(section, key)? {
            None => Ok(None),
            Some(i) if i >= 0 => Ok(Some(i as u64)),
            Some(i) => bail!("[{section}].{key}: expected non-negative, got {i}"),
        }
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>> {
        Ok(self.get_u64(section, key)?.map(|v| v as usize))
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Float(f)) => Ok(Some(*f)),
            Some(Value::Int(i)) => Ok(Some(*i as f64)),
            Some(v) => bail!("[{section}].{key}: expected float, got {}", v.type_name()),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Bool(b)) => Ok(Some(*b)),
            Some(v) => bail!("[{section}].{key}: expected boolean, got {}", v.type_name()),
        }
    }

    pub fn sections(&self) -> Vec<&str> {
        let mut out: Vec<&str> = self.entries.keys().map(|(s, _)| s.as_str()).collect();
        out.dedup();
        out
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(body) = rest.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(Value::Str(unescape(body)?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Hex integers (underscores between hex digits, TOML-style).
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        if hex.contains('_') && !underscores_between(hex, |c| c.is_ascii_hexdigit()) {
            bail!("bad underscore placement in number '{s}'");
        }
        let cleaned: String = hex.chars().filter(|&c| c != '_').collect();
        return Ok(Value::Int(i64::from_str_radix(&cleaned, 16)?));
    }
    // Underscore separators allowed in numbers, TOML-style: each `_`
    // must sit between two digits. (Stripping them first would accept
    // TOML-invalid spellings like `_1`, `1__2` and `1_`.)
    if s.contains('_') && !underscores_between(s, |c| c.is_ascii_digit()) {
        bail!("bad underscore placement in number '{s}'");
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    } else if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    bail!("cannot parse value '{s}'")
}

/// TOML's underscore rule for numeric literals: every underscore must
/// be surrounded by digits of the literal's radix (so `1_000`,
/// `1e1_0` and `0xdead_beef` pass; `_1`, `1_`, `1__2`, `1_.5`, `-_1`
/// and `0x_ff` do not).
fn underscores_between(s: &str, is_digit: impl Fn(u8) -> bool) -> bool {
    let b = s.as_bytes();
    for (i, &c) in b.iter().enumerate() {
        if c == b'_' {
            let prev_ok = i > 0 && is_digit(b[i - 1]);
            let next_ok = i + 1 < b.len() && is_digit(b[i + 1]);
            if !prev_ok || !next_ok {
                return false;
            }
        }
    }
    true
}

fn unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => bail!("bad escape \\{:?}", other),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(
            "top = 1\n\
             [a]\n\
             s = \"hi # not a comment\"  # real comment\n\
             i = -42\n\
             h = 0xff\n\
             f = 2.5\n\
             e = 1e-3\n\
             b = true\n\
             [a.b]\n\
             nested = 7\n",
        )
        .unwrap();
        assert_eq!(doc.get_i64("", "top").unwrap(), Some(1));
        assert_eq!(doc.get_str("a", "s").unwrap().unwrap(), "hi # not a comment");
        assert_eq!(doc.get_i64("a", "i").unwrap(), Some(-42));
        assert_eq!(doc.get_i64("a", "h").unwrap(), Some(255));
        assert_eq!(doc.get_f64("a", "f").unwrap(), Some(2.5));
        assert_eq!(doc.get_f64("a", "e").unwrap(), Some(1e-3));
        assert_eq!(doc.get_bool("a", "b").unwrap(), Some(true));
        assert_eq!(doc.get_i64("a.b", "nested").unwrap(), Some(7));
    }

    #[test]
    fn underscore_numbers() {
        let doc = Document::parse("n = 1_000_000\n").unwrap();
        assert_eq!(doc.get_i64("", "n").unwrap(), Some(1_000_000));
        // Underscores between digits work in floats and exponents too.
        let doc = Document::parse("f = 1_000.000_1\ne = 1e1_0\nneg = -1_000\n").unwrap();
        assert_eq!(doc.get_f64("", "f").unwrap(), Some(1000.0001));
        assert_eq!(doc.get_f64("", "e").unwrap(), Some(1e10));
        assert_eq!(doc.get_i64("", "neg").unwrap(), Some(-1000));
        // Hex literals take underscores between hex digits.
        let doc = Document::parse("h = 0xdead_beef\n").unwrap();
        assert_eq!(doc.get_i64("", "h").unwrap(), Some(0xdead_beef));
    }

    #[test]
    fn misplaced_underscores_rejected() {
        // TOML requires underscores between digits; stripping them
        // blindly used to accept all of these.
        let bad = [
            "_1", "1_", "1__2", "1_.5", "1._5", "-_1", "1_e3", "1e_3", "0x_ff",
            "0xff_", "0x1__2",
        ];
        for bad in bad {
            assert!(
                Document::parse(&format!("n = {bad}\n")).is_err(),
                "'{bad}' must be rejected"
            );
        }
    }

    #[test]
    fn escapes() {
        let doc = Document::parse("s = \"a\\nb\\\"c\\\\d\"\n").unwrap();
        assert_eq!(doc.get_str("", "s").unwrap().unwrap(), "a\nb\"c\\d");
    }

    #[test]
    fn type_mismatch_is_error_not_none() {
        let doc = Document::parse("x = 5\n").unwrap();
        assert!(doc.get_str("", "x").is_err());
        assert!(doc.get_bool("", "x").is_err());
        // int -> float widening is allowed
        assert_eq!(doc.get_f64("", "x").unwrap(), Some(5.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Document::parse("[unclosed\n").is_err());
        assert!(Document::parse("novalue\n").is_err());
        assert!(Document::parse("k = \n").is_err());
        assert!(Document::parse("k = zzz\n").is_err());
        assert!(Document::parse("k = 1\nk = 2\n").is_err());
        assert!(Document::parse("bad key = 1\n").is_err());
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = Document::parse("[a]\nx = 1\n").unwrap();
        assert_eq!(doc.get_i64("a", "y").unwrap(), None);
        assert_eq!(doc.get_i64("b", "x").unwrap(), None);
    }

    #[test]
    fn prop_int_round_trip() {
        check("minitoml int round trip", 200, |g| {
            let v = g.u64(1 << 62) as i64 - (1 << 61);
            let text = format!("[s]\nk = {v}\n");
            let doc = Document::parse(&text).unwrap();
            assert_eq!(doc.get_i64("s", "k").unwrap(), Some(v));
        });
    }

    #[test]
    fn prop_string_round_trip() {
        check("minitoml string round trip", 200, |g| {
            let n = g.usize(24);
            let s: String = (0..n)
                .map(|_| {
                    let c = *g.pick(&[
                        'a', 'b', 'z', ' ', '#', '=', '[', ']', '\\', '"', '\n', '\t',
                    ]);
                    c
                })
                .collect();
            let escaped = s
                .replace('\\', "\\\\")
                .replace('"', "\\\"")
                .replace('\n', "\\n")
                .replace('\t', "\\t");
            let text = format!("k = \"{escaped}\"\n");
            let doc = Document::parse(&text).unwrap();
            assert_eq!(doc.get_str("", "k").unwrap().unwrap(), s);
        });
    }
}
