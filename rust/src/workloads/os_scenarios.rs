//! OS-scenario trace generators: the four system-level workloads of
//! experiment E9, written at the *virtual* address level. Physical
//! placement, faults and mechanism dispatch all happen at run time in
//! the OS layer, so one trace evaluates every placement policy.
//!
//! * `ForkServer`  — a server forks periodically; post-fork writes
//!                   break CoW pages one fault-copy at a time
//!                   (RowClone's fork consumer).
//! * `BootZero`    — bulk page zeroing sweeps (boot / mmap / security
//!                   clearing) followed by touches of the fresh pages.
//! * `Checkpoint`  — a write-heavy phase, then an epoch checkpoint
//!                   bulk-copying exactly the dirtied pages.
//! * `HotPromote`  — skewed accesses over a drifting hot set; the
//!                   currently hottest page is migrated into its
//!                   bank's promotion zone each period.

use crate::config::SimConfig;
use crate::cpu::trace::{BulkOp, TraceOp};
use crate::util::rng::Pcg32;

/// Syscall-ish instruction overheads charged as non-memory work.
const FORK_NONMEM: u32 = 60;
const BULK_CALL_NONMEM: u32 = 20;

/// One core's OS scenario (parameters in pages of one DRAM row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OsScenario {
    /// `pages` address-space size; fork every `period` ops.
    ForkServer { pages: u32, period: u32 },
    /// Zero `region_pages` at a time, touch for `period` ops, move on.
    BootZero { region_pages: u32, regions: u32, period: u32 },
    /// Write `period` ops over `pages`, then checkpoint the dirty set.
    Checkpoint { pages: u32, period: u32 },
    /// Skewed touches over `pages` with a `hot`-page working set that
    /// drifts each period; promote the newest hot page per period.
    HotPromote { pages: u32, hot: u32, period: u32 },
}

/// Generate `n_ops` trace operations for one core. Deterministic in
/// (scenario, seed, core); virtual addresses are process-local (each
/// core is its own process with its own page table).
pub fn generate(
    scn: OsScenario,
    cfg: &SimConfig,
    core: usize,
    n_ops: usize,
    seed: u64,
    nonmem: u32,
) -> Vec<TraceOp> {
    let page = cfg.dram.row_bytes() as u64;
    let mut rng = Pcg32::new(seed, core as u64 + 0x05_0000);
    let mut ops = Vec::with_capacity(n_ops + 64);
    let touch = |rng: &mut Pcg32, page_idx: u64, write: bool| TraceOp::Bulk {
        nonmem,
        op: BulkOp::Touch {
            va: page_idx * page + rng.below(page / 64) * 64,
            is_write: write,
            dependent: false,
        },
    };
    match scn {
        OsScenario::ForkServer { pages, period } => {
            // Establish the address space once (demand-zeroed).
            ops.push(TraceOp::Bulk {
                nonmem: BULK_CALL_NONMEM,
                op: BulkOp::Zero { va: 0, pages },
            });
            while ops.len() < n_ops {
                ops.push(TraceOp::Bulk { nonmem: FORK_NONMEM, op: BulkOp::Fork });
                for _ in 0..period {
                    let p = rng.below(pages as u64);
                    let w = rng.chance(0.35);
                    ops.push(touch(&mut rng, p, w));
                }
            }
        }
        OsScenario::BootZero { region_pages, regions, period } => {
            let mut region = 0u64;
            while ops.len() < n_ops {
                let base = region * region_pages as u64;
                ops.push(TraceOp::Bulk {
                    nonmem: BULK_CALL_NONMEM,
                    op: BulkOp::Zero { va: base * page, pages: region_pages },
                });
                for _ in 0..period {
                    let p = base + rng.below(region_pages as u64);
                    let w = rng.chance(0.5);
                    ops.push(touch(&mut rng, p, w));
                }
                region = (region + 1) % regions as u64;
            }
        }
        OsScenario::Checkpoint { pages, period } => {
            ops.push(TraceOp::Bulk {
                nonmem: BULK_CALL_NONMEM,
                op: BulkOp::Zero { va: 0, pages },
            });
            while ops.len() < n_ops {
                for _ in 0..period {
                    let p = rng.below(pages as u64);
                    let w = rng.chance(0.6);
                    ops.push(touch(&mut rng, p, w));
                }
                ops.push(TraceOp::Bulk {
                    nonmem: BULK_CALL_NONMEM,
                    op: BulkOp::Checkpoint,
                });
            }
        }
        OsScenario::HotPromote { pages, hot, period } => {
            ops.push(TraceOp::Bulk {
                nonmem: BULK_CALL_NONMEM,
                op: BulkOp::Zero { va: 0, pages },
            });
            let mut hot_base = 0u64;
            while ops.len() < n_ops {
                for _ in 0..period {
                    let p = if rng.chance(0.9) {
                        (hot_base + rng.below(hot as u64)) % pages as u64
                    } else {
                        rng.below(pages as u64)
                    };
                    let w = rng.chance(0.3);
                    ops.push(touch(&mut rng, p, w));
                }
                // The hot window drifts; promote the page that just
                // became hot (OS-level migration toward the fast zone).
                hot_base = (hot_base + 1) % pages as u64;
                let newest = (hot_base + hot as u64 - 1) % pages as u64;
                ops.push(TraceOp::Bulk {
                    nonmem: BULK_CALL_NONMEM,
                    op: BulkOp::Promote { va: newest * page },
                });
            }
        }
    }
    ops.truncate(n_ops.max(1));
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    const ALL: [OsScenario; 4] = [
        OsScenario::ForkServer { pages: 64, period: 48 },
        OsScenario::BootZero { region_pages: 16, regions: 8, period: 32 },
        OsScenario::Checkpoint { pages: 96, period: 64 },
        OsScenario::HotPromote { pages: 128, hot: 8, period: 40 },
    ];

    #[test]
    fn scenarios_are_deterministic_and_bulk_bearing() {
        let c = cfg();
        for scn in ALL {
            let a = generate(scn, &c, 0, 800, 7, 4);
            let b = generate(scn, &c, 0, 800, 7, 4);
            assert_eq!(a, b, "{scn:?} not deterministic");
            assert_eq!(a.len(), 800);
            let d = generate(scn, &c, 0, 800, 8, 4);
            assert_ne!(a, d, "{scn:?} ignores the seed");
            let bulk = a.iter().filter(|o| matches!(o, TraceOp::Bulk { .. })).count();
            assert_eq!(bulk, 800, "{scn:?}: everything routes through the OS");
        }
    }

    #[test]
    fn fork_server_interleaves_forks_and_touches() {
        let ops = generate(OsScenario::ForkServer { pages: 32, period: 20 }, &cfg(), 1, 500, 1, 2);
        let forks = ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Bulk { op: BulkOp::Fork, .. }))
            .count();
        assert!((20..=30).contains(&forks), "{forks} forks in 500 ops");
        assert!(ops
            .iter()
            .any(|o| matches!(o, TraceOp::Bulk { op: BulkOp::Touch { is_write: true, .. }, .. })));
    }

    #[test]
    fn checkpoint_scenario_emits_checkpoints() {
        let ops = generate(OsScenario::Checkpoint { pages: 16, period: 25 }, &cfg(), 0, 300, 1, 2);
        let cps = ops
            .iter()
            .filter(|o| matches!(o, TraceOp::Bulk { op: BulkOp::Checkpoint, .. }))
            .count();
        assert!(cps >= 10, "{cps} checkpoints");
    }

    #[test]
    fn promote_targets_stay_within_the_space() {
        let pages = 64u64;
        let ops = generate(
            OsScenario::HotPromote { pages: pages as u32, hot: 4, period: 10 },
            &cfg(),
            0,
            400,
            3,
            2,
        );
        for o in &ops {
            if let TraceOp::Bulk { op: BulkOp::Promote { va }, .. } = o {
                assert!(*va / 8192 < pages);
            }
        }
    }
}
