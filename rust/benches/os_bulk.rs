//! Perf bench for the OS-layer bulk-operation subsystem: pages/second
//! for the fork (CoW-fault copies) and boot-zeroing scenarios across
//! all five copy mechanisms — the per-page cost each mechanism charges
//! the OS, end to end through page tables, frame allocation, the
//! page-copy queue and the copy sequencer.
//!
//! Usage: `cargo bench --bench os_bulk [-- REQUESTS]`
//! (REQUESTS defaults to 2000; CI smoke mode passes a small value.)

use std::time::Instant;

use lisa::config::{CopyMechanism, PlacementPolicy, SimConfig};
use lisa::sim::engine::Simulation;
use lisa::util::bench::Table;
use lisa::workloads::mixes;

const MECHANISMS: [CopyMechanism; 5] = [
    CopyMechanism::MemcpyChannel,
    CopyMechanism::RowCloneInterBank,
    CopyMechanism::RowCloneInterSa,
    CopyMechanism::RowCloneIntraSa,
    CopyMechanism::LisaRisc,
];

fn main() {
    let requests: u64 = std::env::args()
        .skip(1)
        .find_map(|s| s.parse().ok())
        .unwrap_or(2_000);
    println!("=== OS bulk-operation throughput ({requests} requests/core) ===\n");
    let mut t = Table::new(&[
        "scenario",
        "mechanism",
        "pages",
        "sim cycles",
        "pages/s (sim)",
        "pages/s (wall)",
    ]);
    for scenario in ["os-fork", "os-zero"] {
        for mech in MECHANISMS {
            let mut cfg = SimConfig::default();
            cfg.requests_per_core = requests;
            cfg.copy_mechanism = mech;
            cfg.lisa.risc = mech == CopyMechanism::LisaRisc;
            cfg.os.placement = PlacementPolicy::SubarrayPacked;
            let wl = mixes::workload_by_name(scenario, &cfg).unwrap();
            let mut sim = Simulation::new(cfg, wl);
            let t0 = Instant::now();
            let r = sim.run();
            let wall = t0.elapsed().as_secs_f64();
            let os = r.os.as_ref().expect("OS summary");
            assert!(os.pages_copied > 0, "{scenario}/{mech:?}: no pages copied");
            let sim_secs = r.dram_cycles as f64 * sim.memory().tck_ns() * 1e-9;
            t.row(&[
                scenario.to_string(),
                mech.name().to_string(),
                format!("{}", os.pages_copied),
                format!("{}", r.dram_cycles),
                format!("{:.0}", os.pages_copied as f64 / sim_secs),
                format!("{:.0}", os.pages_copied as f64 / wall),
            ]);
        }
    }
    t.print();
    println!(
        "\n(pages/s (sim) is simulated-time throughput — the number the paper's \
         mechanisms change; pages/s (wall) is host simulation speed)"
    );
}
