//! The DRAM command set: standard DDR commands plus the RowClone and
//! LISA extensions the paper builds on.

/// One DRAM command as issued by the memory controller.
///
/// `row` is always bank-relative (subarray-major). Composite in-DRAM
/// operations (RBM, inter-bank transfer) are modeled as single
/// commands that occupy their resources for their full duration —
/// matching how the paper's controller serializes them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Command {
    /// Activate `row`: latch it into its subarray's row buffer.
    Act { rank: usize, bank: usize, row: usize },
    /// RowClone intra-subarray second activation: write the currently
    /// latched row buffer into `row` (must be in the same subarray).
    ActCopy { rank: usize, bank: usize, row: usize },
    /// LISA: after RBM latched data into `row`'s subarray row buffer,
    /// activate `row` so the buffer contents are restored into it
    /// (paper §3.1: step 3 of LISA-RISC).
    ActStore { rank: usize, bank: usize, row: usize },
    /// Precharge every subarray of the bank (the standard PRE).
    Pre { rank: usize, bank: usize },
    /// Precharge a single subarray of the bank, leaving the others'
    /// open rows / latched buffers intact (SALP's per-subarray PRE;
    /// only legal when the configuration's `SalpMode` tracks
    /// per-subarray state).
    PreSa { rank: usize, bank: usize, sa: usize },
    /// Precharge all banks in the rank (used before refresh).
    PreAll { rank: usize },
    /// Read one cache line (column) from the row open in subarray
    /// `sa` (the subarray-select bits SALP adds to column commands;
    /// with a single open row per bank they are redundant).
    Rd { rank: usize, bank: usize, sa: usize, col: usize },
    /// Write one cache line (column) into the row open in subarray `sa`.
    Wr { rank: usize, bank: usize, sa: usize, col: usize },
    /// Refresh the rank (all banks must be precharged).
    Ref { rank: usize },
    /// LISA row buffer movement: move the latched row buffer of
    /// `from_sa` into the (precharged) row buffers of every subarray
    /// up to and including `to_sa`. Latency = hops * tRBM.
    Rbm { rank: usize, bank: usize, from_sa: usize, to_sa: usize },
    /// RowClone pipelined-serial-mode transfer: stream `cols` cache
    /// lines from `src_bank`'s open row buffer into `dst_bank`'s open
    /// row buffer over the internal 64-bit bus (tCCD per line).
    Transfer { rank: usize, src_bank: usize, dst_bank: usize, cols: usize },
}

impl Command {
    /// The rank this command targets.
    pub fn rank(&self) -> usize {
        match *self {
            Command::Act { rank, .. }
            | Command::ActCopy { rank, .. }
            | Command::ActStore { rank, .. }
            | Command::Pre { rank, .. }
            | Command::PreSa { rank, .. }
            | Command::PreAll { rank }
            | Command::Rd { rank, .. }
            | Command::Wr { rank, .. }
            | Command::Ref { rank }
            | Command::Rbm { rank, .. }
            | Command::Transfer { rank, .. } => rank,
        }
    }

    /// The bank this command targets (None for rank-scope commands).
    pub fn bank(&self) -> Option<usize> {
        match *self {
            Command::Act { bank, .. }
            | Command::ActCopy { bank, .. }
            | Command::ActStore { bank, .. }
            | Command::Pre { bank, .. }
            | Command::PreSa { bank, .. }
            | Command::Rd { bank, .. }
            | Command::Wr { bank, .. }
            | Command::Rbm { bank, .. } => Some(bank),
            Command::Transfer { src_bank, .. } => Some(src_bank),
            Command::PreAll { .. } | Command::Ref { .. } => None,
        }
    }

    /// Does this command use the off-chip data bus?
    pub fn uses_data_bus(&self) -> bool {
        matches!(self, Command::Rd { .. } | Command::Wr { .. })
    }

    /// Is this one of the in-DRAM bulk operations?
    pub fn is_bulk(&self) -> bool {
        matches!(
            self,
            Command::Rbm { .. } | Command::Transfer { .. } | Command::ActCopy { .. }
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            Command::Act { .. } => "ACT",
            Command::ActCopy { .. } => "ACT_COPY",
            Command::ActStore { .. } => "ACT_STORE",
            Command::Pre { .. } => "PRE",
            Command::PreSa { .. } => "PRE_SA",
            Command::PreAll { .. } => "PREA",
            Command::Rd { .. } => "RD",
            Command::Wr { .. } => "WR",
            Command::Ref { .. } => "REF",
            Command::Rbm { .. } => "RBM",
            Command::Transfer { .. } => "TRANSFER",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_helpers() {
        let act = Command::Act { rank: 1, bank: 3, row: 42 };
        assert_eq!(act.rank(), 1);
        assert_eq!(act.bank(), Some(3));
        assert!(!act.uses_data_bus());
        assert!(!act.is_bulk());

        let rd = Command::Rd { rank: 0, bank: 0, sa: 0, col: 5 };
        assert!(rd.uses_data_bus());

        let psa = Command::PreSa { rank: 0, bank: 4, sa: 7 };
        assert_eq!(psa.bank(), Some(4));
        assert_eq!(psa.name(), "PRE_SA");
        assert!(!psa.uses_data_bus() && !psa.is_bulk());

        let rbm = Command::Rbm { rank: 0, bank: 2, from_sa: 1, to_sa: 9 };
        assert!(rbm.is_bulk());
        assert_eq!(rbm.bank(), Some(2));

        let r = Command::Ref { rank: 0 };
        assert_eq!(r.bank(), None);
    }
}
