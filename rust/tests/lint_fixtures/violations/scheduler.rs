//! L2 fixture (horizon-invalidate): `push_request` is marked as a
//! channel-state mutator but never invalidates the horizon cache.
//! The unmarked `peek` must not fire. Not compiled — lexed only.

pub struct Sched {
    q: Vec<u64>,
    horizon: Option<u64>,
}

impl Sched {
    // lint: mutates-channel-state
    pub fn push_request(&mut self, x: u64) {
        self.q.push(x);
    }

    pub fn peek(&self) -> Option<&u64> {
        self.q.first()
    }

    // lint: mutates-channel-state
    pub fn clear(&mut self) {
        self.q.clear();
        self.horizon = None;
        self.invalidate_horizon();
    }

    fn invalidate_horizon(&mut self) {
        self.horizon = None;
    }
}
