//! JEDEC timing parameters (DDR3-1600 / DDR4-2400 speed bins) plus the
//! LISA extensions derived from the calibrated circuit model.
//!
//! All parameters are stored in DRAM bus clock cycles (ceil'd from
//! nanoseconds, as JEDEC does). Table-1-critical values at DDR3-1600
//! (tCK = 1.25 ns): tRCD 11, tRP 11, tRAS 28, tCL 11, tBL 4, tCCD 4.

use anyhow::{bail, Result};

use crate::config::Calibration;
use crate::util::ns_to_cycles;

/// Supported speed bins.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpeedBin {
    Ddr3_1600,
    Ddr4_2400,
}

impl SpeedBin {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "ddr3-1600" => Self::Ddr3_1600,
            "ddr4-2400" => Self::Ddr4_2400,
            _ => bail!("unknown speed bin '{s}' (ddr3-1600|ddr4-2400)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Ddr3_1600 => "ddr3-1600",
            Self::Ddr4_2400 => "ddr4-2400",
        }
    }

    pub fn tck_ns(&self) -> f64 {
        match self {
            Self::Ddr3_1600 => 1.25,  // 800 MHz bus, 1600 MT/s
            Self::Ddr4_2400 => 0.833, // 1200 MHz bus, 2400 MT/s
        }
    }

    /// Peak channel bandwidth in GB/s (64-bit channel, DDR).
    pub fn channel_gbps(&self) -> f64 {
        match self {
            Self::Ddr3_1600 => 12.8,
            Self::Ddr4_2400 => 19.2,
        }
    }
}

/// The full timing parameter set, in cycles.
#[derive(Debug, Clone)]
pub struct Timing {
    pub tck_ns: f64,
    pub t_rcd: u64,
    pub t_rp: u64,
    pub t_ras: u64,
    pub t_rc: u64,
    pub t_cl: u64,
    pub t_cwl: u64,
    pub t_bl: u64,
    pub t_ccd: u64,
    pub t_rtp: u64,
    pub t_wr: u64,
    pub t_wtr: u64,
    pub t_rtw: u64,
    pub t_rrd: u64,
    pub t_faw: u64,
    pub t_refi: u64,
    pub t_rfc: u64,
    // --- SALP extension ---
    /// Subarray-select latch update: the extra latency a RD/WR pays
    /// when it steers the global bitlines to a *different* subarray
    /// than the previous column command used (SALP-2 / MASA designated-
    /// subarray switch). One bus cycle — the select wires are driven in
    /// parallel with column decode, so only the final mux hand-off is
    /// exposed.
    pub t_sa_sel: u64,
    // --- LISA extensions (from the calibrated circuit model) ---
    /// Row buffer movement, per hop.
    pub t_rbm: u64,
    /// Precharge with linked precharge units (LISA-LIP).
    pub t_rp_lip: u64,
    /// Fast (VILLA) subarray variants.
    pub t_rcd_fast: u64,
    pub t_ras_fast: u64,
    pub t_rp_fast: u64,
    pub t_rp_fast_lip: u64,
}

impl Timing {
    /// Build the timing set for a speed bin, with LISA parameters
    /// derived from the circuit-model calibration:
    /// * `t_rbm` is the calibrated (margined) hop latency;
    /// * `t_rp_lip` scales JEDEC tRP by the circuit-model ratio
    ///   (linked/single), matching the paper's methodology of applying
    ///   SPICE-derived deltas to standard timings;
    /// * fast-subarray timings scale tRCD/tRAS/tRP by the calibrated
    ///   short-bitline ratios (VILLA-DRAM heterogeneity).
    pub fn new(bin: SpeedBin, cal: &Calibration) -> Self {
        let tck = bin.tck_ns();
        let c = |ns: f64| ns_to_cycles(ns, tck);
        let (t_rcd_ns, t_rp_ns, t_ras_ns, t_cl_ns, t_cwl_ns) = match bin {
            SpeedBin::Ddr3_1600 => (13.75, 13.75, 35.0, 13.75, 10.0),
            SpeedBin::Ddr4_2400 => (14.16, 14.16, 32.0, 14.16, 12.5),
        };
        let t_rcd = c(t_rcd_ns);
        let t_rp = c(t_rp_ns);
        let t_ras = c(t_ras_ns);

        let lip_ratio = (cal.t_rp_lip_ns / cal.t_rp_circuit_ns).clamp(0.05, 1.0);
        let t_rp_lip = ((t_rp as f64) * lip_ratio).ceil().max(1.0) as u64;
        let t_rp_fast = ((t_rp as f64) * cal.fast_rp_ratio).ceil().max(1.0) as u64;

        Self {
            tck_ns: tck,
            t_rcd,
            t_rp,
            t_ras,
            t_rc: t_ras + t_rp,
            t_cl: c(t_cl_ns),
            t_cwl: c(t_cwl_ns),
            t_bl: 4,
            t_ccd: 4,
            t_rtp: c(7.5),
            t_wr: c(15.0),
            t_wtr: c(7.5),
            t_rtw: c(2.5) + 4, // read-to-write turnaround: tCL - tCWL + tBL + 2
            t_rrd: c(6.0),
            t_faw: c(40.0),
            t_refi: c(7800.0),
            t_rfc: c(260.0),
            t_sa_sel: 1,
            t_rbm: c(cal.t_rbm_ns).max(1),
            t_rp_lip,
            t_rcd_fast: ((t_rcd as f64) * cal.fast_act_ratio).ceil().max(1.0) as u64,
            t_ras_fast: ((t_ras as f64) * cal.fast_ras_ratio).ceil().max(1.0) as u64,
            t_rp_fast,
            t_rp_fast_lip: ((t_rp_fast as f64) * lip_ratio).ceil().max(1.0) as u64,
        }
    }

    /// Convert cycles to nanoseconds.
    pub fn ns(&self, cycles: u64) -> f64 {
        cycles as f64 * self.tck_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Timing {
        Timing::new(SpeedBin::Ddr3_1600, &Calibration::default())
    }

    #[test]
    fn ddr3_1600_jedec_values() {
        let t = t();
        assert_eq!(t.t_rcd, 11);
        assert_eq!(t.t_rp, 11);
        assert_eq!(t.t_ras, 28);
        assert_eq!(t.t_rc, 39);
        assert_eq!(t.t_cl, 11);
        assert_eq!(t.t_bl, 4);
        assert_eq!(t.t_ccd, 4);
        assert_eq!(t.t_faw, 32);
        assert_eq!(t.t_rrd, 5);
    }

    #[test]
    fn lisa_timings_from_calibration() {
        let t = t();
        // Calibrated tRBM = 5.21 * 1.6 = 8.34 ns -> 7 cycles at 1.25 ns.
        assert_eq!(t.t_rbm, 7);
        // LIP ratio = 5.07/13.32 ~ 0.38; tRP 11 -> ceil(4.19) = 5 cycles.
        assert_eq!(t.t_rp_lip, 5);
        assert!(t.t_rp_lip < t.t_rp);
        // Fast subarray strictly faster everywhere.
        assert!(t.t_rcd_fast < t.t_rcd);
        assert!(t.t_ras_fast < t.t_ras);
        assert!(t.t_rp_fast < t.t_rp);
    }

    #[test]
    fn sa_select_is_small_but_nonzero() {
        // The SALP subarray-select hand-off must cost something (it is
        // a real mux switch) but stay well under a column access —
        // otherwise MASA's open-row hits would stop being hits.
        let t = t();
        assert!(t.t_sa_sel >= 1);
        assert!(t.t_sa_sel < t.t_cl);
    }

    #[test]
    fn paper_anchor_rc_intra_latency() {
        // RowClone intra-subarray copy = ACT + ACT + PRE
        // = tRAS + tRAS + tRP = 35 + 35 + 13.75 = 83.75 ns (Table 1).
        let t = t();
        let total = t.ns(t.t_ras + t.t_ras + t.t_rp);
        assert!((total - 83.75).abs() < 0.01, "got {total}");
    }

    #[test]
    fn ddr4_bin_parses_and_is_faster_bus() {
        let t4 = Timing::new(SpeedBin::Ddr4_2400, &Calibration::default());
        assert!(t4.tck_ns < 1.25);
        assert_eq!(SpeedBin::parse("ddr4-2400").unwrap(), SpeedBin::Ddr4_2400);
        assert!(SpeedBin::parse("ddr5-9999").is_err());
    }

    #[test]
    fn rbm_beats_channel_bandwidth() {
        // Paper §2: one RBM moves an 8 KB chip-row's worth per rank at
        // 26x the DDR4-2400 channel. Check the shape: row_bytes / tRBM
        // >> channel bandwidth.
        let t = Timing::new(SpeedBin::Ddr4_2400, &Calibration::default());
        let rbm_gbps = 8192.0 / t.ns(t.t_rbm); // GB/s = bytes/ns
        assert!(
            rbm_gbps > 10.0 * SpeedBin::Ddr4_2400.channel_gbps(),
            "RBM bandwidth {rbm_gbps} GB/s not >> channel"
        );
    }
}
