//! End-to-end system integration tests: full simulations across
//! configurations, checking the paper's qualitative claims and
//! cross-cutting invariants (data movement correctness under load,
//! determinism, bank-parallelism).

use lisa::config::{
    CopyMechanism, LisaPreset, PlacementPolicy, SalpMode, SimConfig, SimConfigBuilder,
};
use lisa::sim::campaign;
use lisa::sim::engine::{run_workload, Simulation};
use lisa::sim::spec::{self, RunOptions};
use lisa::workloads::mixes;

fn quick(requests: u64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.requests_per_core = requests;
    cfg.max_cycles = 50_000_000;
    cfg
}

/// One of the paper's named feature combinations at a given run length.
fn preset_cfg(requests: u64, p: LisaPreset) -> SimConfig {
    SimConfigBuilder::new()
        .requests(requests)
        .preset(p)
        .build()
        .expect("preset configs validate")
}

/// An E9 grid-point config (mechanism × placement).
fn os_cfg(requests: u64, mech: CopyMechanism, policy: PlacementPolicy) -> SimConfig {
    SimConfigBuilder::new()
        .requests(requests)
        .mechanism(mech)
        .placement(policy)
        .max_cycles(50_000_000)
        .build()
        .expect("os configs validate")
}

#[test]
fn determinism_same_seed_same_result() {
    let cfg = quick(1_500);
    let wl = mixes::workload_by_name("copy-mix-01", &cfg).unwrap();
    let a = run_workload(&cfg, &wl);
    let b = run_workload(&cfg, &wl);
    assert_eq!(a.dram_cycles, b.dram_cycles);
    assert_eq!(a.reads, b.reads);
    assert_eq!(a.ipc, b.ipc);
    assert_eq!(a.copies, b.copies);
}

#[test]
fn different_seed_different_trace() {
    let mut cfg = quick(1_500);
    let wl = mixes::workload_by_name("random4", &cfg).unwrap();
    let a = run_workload(&cfg, &wl);
    cfg.seed = 999;
    let b = run_workload(&cfg, &wl);
    assert_ne!(a.dram_cycles, b.dram_cycles);
}

#[test]
fn generator_seeding_is_deterministic_end_to_end() {
    // `cfg.seed` feeds every per-core generator (workloads/generators.rs)
    // through `Workload::traces`; same seed => identical traces for
    // every behaviour class, different seed => different traces.
    let mut cfg = quick(500);
    for name in ["stream4", "random4", "chase4", "hotspot4", "fork4", "copy-mix-05"] {
        let wl = mixes::workload_by_name(name, &cfg).unwrap();
        let a = wl.traces(&cfg, 400);
        let b = wl.traces(&cfg, 400);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ops, y.ops, "{name}: same seed must reproduce the trace");
        }
        cfg.seed ^= 0xABCD;
        let c = wl.traces(&cfg, 400);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.ops != y.ops),
            "{name}: seed change must alter at least one core's trace"
        );
        cfg.seed ^= 0xABCD; // restore
    }
}

#[test]
fn campaign_thread_count_does_not_change_results() {
    // The full campaign stack (spec grid -> parallel shards ->
    // ordered records) is deterministic in everything but wall-clock:
    // 1, 2 and 8 worker threads must produce identical ordered rows.
    let sweep = spec::spec_by_name("sweep").unwrap();
    let opts = RunOptions::default()
        .requests(600)
        .axis("workload", &["fork4", "copy-mix-01"])
        .axis("speed", &["ddr3-1600"])
        .axis("mech", &["memcpy", "lisa-risc"]);
    let serial = spec::run(&sweep, &opts.clone().threads(1)).unwrap();
    assert_eq!(serial.records.len(), 4);
    for threads in [2, 8] {
        let parallel = spec::run(&sweep, &opts.clone().threads(threads)).unwrap();
        assert_eq!(serial, parallel, "threads={threads}");
    }
    // And the parallel weighted-speedup helper agrees with itself.
    let cfg = quick(600);
    let wl = mixes::workload_by_name("copy-mix-01", &cfg).unwrap();
    let (ws1, rep1) = campaign::weighted_speedup(&cfg, &wl, 1);
    let (ws8, rep8) = campaign::weighted_speedup(&cfg, &wl, 8);
    assert_eq!(rep1, rep8);
    assert!((ws1 - ws8).abs() < 1e-15, "{ws1} vs {ws8}");
}

#[test]
fn all_copy_mechanisms_complete_copy_mixes() {
    // Every mechanism must terminate on a real copy mix (no deadlocks
    // against refresh/queues) and actually execute the copies.
    for mech in [
        CopyMechanism::MemcpyChannel,
        CopyMechanism::LisaRisc,
        CopyMechanism::RowCloneInterSa,
    ] {
        let mut cfg = quick(1_000);
        cfg.copy_mechanism = mech;
        cfg.lisa.risc = mech == CopyMechanism::LisaRisc;
        let wl = mixes::workload_by_name("copy-mix-02", &cfg).unwrap();
        let r = run_workload(&cfg, &wl);
        assert!(r.copies > 0, "{mech:?}: no copies completed");
        assert!(
            r.dram_cycles < cfg.max_cycles,
            "{mech:?}: hit the cycle cap (deadlock?)"
        );
    }
}

#[test]
fn paper_claim_risc_beats_memcpy_beats_nothing() {
    // E5 direction: LISA-RISC > baseline on copy-heavy workloads.
    let base = preset_cfg(1_500, LisaPreset::Baseline);
    let risc = preset_cfg(1_500, LisaPreset::Risc);
    let wl = mixes::workload_by_name("fork4", &base).unwrap();
    let r_base = run_workload(&base, &wl);
    let r_risc = run_workload(&risc, &wl);
    assert!(
        r_risc.dram_cycles * 2 < r_base.dram_cycles,
        "LISA-RISC should be >2x faster on fork4: {} vs {}",
        r_risc.dram_cycles,
        r_base.dram_cycles
    );
    // And cheaper in energy.
    assert!(r_risc.energy.total < r_base.energy.total * 0.6);
}

#[test]
fn paper_claim_villa_without_lisa_is_catastrophic() {
    // Fig. 3's second point: VILLA with RC-InterSA movement collapses.
    let villa_lisa = preset_cfg(1_500, LisaPreset::RiscVilla);
    let villa_rc = preset_cfg(1_500, LisaPreset::VillaRc);
    let wl = mixes::workload_by_name("hotspot4", &villa_lisa).unwrap();
    let r_lisa = run_workload(&villa_lisa, &wl);
    let r_rc = run_workload(&villa_rc, &wl);
    assert!(
        r_rc.ipc_sum() < r_lisa.ipc_sum() * 0.7,
        "RC-based VILLA {} should be far below LISA-based {}",
        r_rc.ipc_sum(),
        r_lisa.ipc_sum()
    );
    assert!(r_lisa.villa_hit_rate > 0.1, "hit rate {}", r_lisa.villa_hit_rate);
}

#[test]
fn lip_reduces_cycles_on_row_miss_traffic() {
    let base = preset_cfg(1_500, LisaPreset::Baseline);
    let mut lip = base.clone();
    lip.lisa.lip = true;
    let wl = mixes::workload_by_name("random4", &base).unwrap();
    let r_base = run_workload(&base, &wl);
    let r_lip = run_workload(&lip, &wl);
    assert!(r_lip.lip_coverage > 0.9);
    assert!(
        r_lip.dram_cycles < r_base.dram_cycles,
        "LIP {} should beat baseline {}",
        r_lip.dram_cycles,
        r_base.dram_cycles
    );
}

#[test]
fn combined_config_stacks_benefits() {
    // Fig. 4 direction on one copy mix: All >= RISC >= baseline.
    let base = preset_cfg(1_200, LisaPreset::Baseline);
    let risc = preset_cfg(1_200, LisaPreset::Risc);
    let all = preset_cfg(1_200, LisaPreset::All);
    let wl = mixes::workload_by_name("copy-mix-04", &base).unwrap();
    let c_base = run_workload(&base, &wl).dram_cycles;
    let c_risc = run_workload(&risc, &wl).dram_cycles;
    let c_all = run_workload(&all, &wl).dram_cycles;
    assert!(c_risc < c_base, "RISC {c_risc} vs base {c_base}");
    assert!(c_all <= c_risc + c_risc / 10, "All {c_all} vs RISC {c_risc}");
}

#[test]
fn copies_preserve_data_under_full_system_load() {
    // Data-movement correctness END TO END: run a copy mix, then audit
    // that every completed copy left the destination row with the
    // source's content tag. We reconstruct expectations by replaying
    // the trace's copies in order (later copies may overwrite earlier
    // destinations, so replay order matters).
    for mech in [CopyMechanism::LisaRisc, CopyMechanism::MemcpyChannel] {
        let mut cfg = quick(1_200);
        cfg.copy_mechanism = mech;
        cfg.lisa.risc = true;
        let wl = mixes::workload_by_name("fork4", &cfg).unwrap();
        let mut sim = Simulation::new(cfg.clone(), wl);
        let report = sim.run();
        assert!(report.copies > 0);
        // The device's row tags were maintained by the mechanism's
        // actual command sequence; spot-check consistency: no row tag
        // equals the "never written" default in destinations of the
        // completed copies is hard to track externally, so instead we
        // assert the device executed the expected command classes.
        let stats = sim.memory().command_stats();
        match mech {
            CopyMechanism::LisaRisc => {
                assert!(stats.n_rbm_hops > 0, "no RBM hops recorded");
                assert!(stats.n_act_store > 0, "no ACT_STORE recorded");
            }
            CopyMechanism::MemcpyChannel => {
                assert!(stats.n_rd > 0 && stats.n_wr > 0);
            }
            _ => {}
        }
    }
}

#[test]
fn bank_parallelism_lisa_vs_rowclone() {
    // LISA's structural advantage: during a LISA-RISC copy the channel
    // stays free; during RC-InterSA transfers it does not. Measure
    // read throughput alongside a copy storm.
    let mk = |mech: CopyMechanism| {
        let mut cfg = quick(1_200);
        cfg.copy_mechanism = mech;
        cfg.lisa.risc = true;
        let wl = mixes::workload_by_name("fork4", &cfg).unwrap();
        run_workload(&cfg, &wl)
    };
    let lisa_r = mk(CopyMechanism::LisaRisc);
    let rc = mk(CopyMechanism::RowCloneInterSa);
    assert!(
        lisa_r.dram_cycles < rc.dram_cycles,
        "LISA {} should finish before RC-InterSA {}",
        lisa_r.dram_cycles,
        rc.dram_cycles
    );
}

#[test]
fn e9_lisa_risc_beats_memcpy_on_fork_and_zeroing() {
    // The E9 acceptance direction: routing OS bulk work through
    // LISA-RISC must beat memcpy-over-channel on the fork and zeroing
    // scenarios (RowClone's motivating consumers, served ~9x faster
    // per page by LISA).
    for scenario in ["os-fork", "os-zero"] {
        let wl = mixes::workload_by_name(scenario, &SimConfig::default()).unwrap();
        let run = |mech| {
            let cfg = os_cfg(700, mech, PlacementPolicy::SubarrayPacked);
            run_workload(&cfg, &wl)
        };
        let memcpy = run(CopyMechanism::MemcpyChannel);
        let lisa = run(CopyMechanism::LisaRisc);
        assert!(memcpy.os.as_ref().unwrap().pages_copied > 0);
        assert!(lisa.os.as_ref().unwrap().pages_copied > 0);
        assert!(
            lisa.dram_cycles < memcpy.dram_cycles,
            "{scenario}: LISA {} should beat memcpy {}",
            lisa.dram_cycles,
            memcpy.dram_cycles
        );
        assert!(
            lisa.ipc_sum() > memcpy.ipc_sum(),
            "{scenario}: LISA IPC {} vs memcpy {}",
            lisa.ipc_sum(),
            memcpy.ipc_sum()
        );
    }
}

#[test]
fn e9_placement_policy_changes_the_risc_hit_rate() {
    // The allocator's placement policy is the co-location knob: packed
    // placement keeps CoW copy pairs in the source bank (RISC reach);
    // random placement scatters them across banks.
    let wl = mixes::workload_by_name("os-fork", &SimConfig::default()).unwrap();
    let hit_rate = |policy| {
        let cfg = os_cfg(700, CopyMechanism::LisaRisc, policy);
        let r = run_workload(&cfg, &wl);
        let os = r.os.unwrap();
        assert!(os.cow_faults > 0, "{policy:?}: fork never faulted");
        os.risc_hit_rate()
    };
    let packed = hit_rate(PlacementPolicy::SubarrayPacked);
    let random = hit_rate(PlacementPolicy::Random);
    let spread = hit_rate(PlacementPolicy::SubarraySpread);
    assert!(
        packed > random + 0.2,
        "packed {packed:.3} should clearly beat random {random:.3}"
    );
    assert!(
        packed > spread,
        "packed {packed:.3} should beat spread {spread:.3}"
    );
}

#[test]
fn os_scenarios_complete_under_every_mechanism() {
    // No deadlocks between the page-copy queue, refresh and demand
    // traffic for any mechanism on any scenario.
    for scenario in ["os-fork", "os-zero", "os-checkpoint", "os-promote"] {
        for mech in [
            CopyMechanism::MemcpyChannel,
            CopyMechanism::RowCloneInterSa,
            CopyMechanism::LisaRisc,
        ] {
            let cfg = os_cfg(400, mech, PlacementPolicy::VillaAware);
            let wl = mixes::workload_by_name(scenario, &cfg).unwrap();
            let r = run_workload(&cfg, &wl);
            assert!(
                r.dram_cycles < cfg.max_cycles,
                "{scenario}/{mech:?}: hit the cycle cap (deadlock?)"
            );
            let os = r.os.unwrap();
            assert!(os.pages_copied > 0, "{scenario}/{mech:?}: no page traffic");
        }
    }
}

#[test]
fn every_salp_mode_runs_the_conflict_workload() {
    // All four parallelism modes complete the intra-bank-conflict
    // workload, and the mode differences are visible: MASA resolves
    // the subarray ping-pong with strictly fewer activations (open
    // rows persist) than the serialized baseline.
    let mut acts = Vec::new();
    for mode in SalpMode::ALL {
        let mut cfg = quick(1_000);
        cfg.dram.salp = mode;
        let wl = mixes::workload_by_name("salp-pingpong4", &cfg).unwrap();
        let mut sim = Simulation::new(cfg, wl);
        let r = sim.run();
        assert!(r.reads > 0, "{mode:?}: no DRAM reads");
        assert!(r.dram_cycles > 0);
        acts.push((mode, sim.memory().command_stats().n_act));
    }
    let act_of = |m: SalpMode| acts.iter().find(|(x, _)| *x == m).unwrap().1;
    assert!(
        act_of(SalpMode::Masa) < act_of(SalpMode::None),
        "MASA {} activations should undercut the baseline {}",
        act_of(SalpMode::Masa),
        act_of(SalpMode::None)
    );
}

#[test]
fn ddr4_speed_bin_runs() {
    let mut cfg = quick(1_000);
    cfg.dram.speed = lisa::dram::timing::SpeedBin::Ddr4_2400;
    let wl = mixes::workload_by_name("stream4", &cfg).unwrap();
    let r = run_workload(&cfg, &wl);
    assert!(r.reads > 0 && r.ipc_sum() > 0.0);
}

#[test]
fn eight_core_configuration_runs() {
    let mut cfg = quick(800);
    cfg.cpu.cores = 8;
    let wl = mixes::workload_by_name("copy-mix-00", &cfg).unwrap();
    let r = run_workload(&cfg, &wl);
    assert_eq!(r.ipc.len(), 8);
    assert!(r.ipc.iter().all(|&i| i > 0.0));
}
