//! The engine↔memory boundary: `MemoryModel` is the trait
//! `sim/engine.rs` (and the CPU/OS layers) consume instead of the
//! concrete cycle-exact `controller::Controller`. Two implementations
//! exist:
//!
//! - [`crate::controller::Controller`] — the cycle-exact controller +
//!   device model, the ground truth (`BackendKind::Cycle`, default).
//! - [`analytical::AnalyticalModel`] — a calibrated event-count model
//!   (`BackendKind::Analytical`) that is orders of magnitude faster
//!   per grid point and cross-validated against the cycle backend
//!   within a stated tolerance (`tests/backend_twin.rs`).
//!
//! The boundary is everything the simulation loop actually needs:
//! typed request admission ([`Access`]), copy admission, the clock
//! (`tick`/`fast_forward`/`next_event_cycle`), completion drain, and
//! the report/observability hooks. Anything else on `Controller` is
//! implementation detail the engine can no longer reach.

pub mod analytical;

use anyhow::Result;

use crate::config::{BackendKind, SimConfig};
use crate::controller::request::{Completion, CopyRequest};
use crate::dram::bank::CommandStats;
use crate::dram::geometry::Address;
use crate::metrics::EnergyBreakdown;
use crate::obs::{ObsReport, Probe};

/// Kind of a demand access (the typed replacement for the old
/// `is_write: bool` flags of `enqueue_mem` / `enqueue_mem_mapped`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessKind {
    Read,
    Write,
}

/// One demand access headed into the memory model: the single typed
/// entry point that replaced the `enqueue_mem`/`enqueue_mem_mapped`
/// duo. Addresses arrive pre-mapped (`MemoryModel::map`); VILLA
/// redirection still happens inside the model.
#[derive(Debug, Clone, Copy)]
pub struct Access {
    pub id: u64,
    pub core: usize,
    pub addr: Address,
    pub kind: AccessKind,
}

impl Access {
    pub fn read(id: u64, core: usize, addr: Address) -> Self {
        Self { id, core, addr, kind: AccessKind::Read }
    }

    pub fn write(id: u64, core: usize, addr: Address) -> Self {
        Self { id, core, addr, kind: AccessKind::Write }
    }

    #[inline]
    pub fn is_write(&self) -> bool {
        self.kind == AccessKind::Write
    }
}

/// Everything a run report needs from the memory side, in one hook:
/// `Simulation::report` used to reach into `ctrl.stats` / `ctrl.dev` /
/// `ctrl.villa` directly, which would have pinned the engine to one
/// backend forever.
#[derive(Debug, Clone)]
pub struct ReportParts {
    pub reads: u64,
    pub writes: u64,
    pub copies: u64,
    pub avg_read_latency_cycles: f64,
    pub row_hit_rate: f64,
    pub villa_hit_rate: f64,
    pub lip_coverage: f64,
    pub energy: EnergyBreakdown,
    pub obs: Option<ObsReport>,
}

/// The memory side of the simulation, as the engine sees it. One DRAM
/// cycle per `tick`; `fast_forward(n)` must be exactly equivalent to
/// `n` ticks during which the model provably does nothing (the engine
/// only calls it for gaps below `next_event_cycle`).
pub trait MemoryModel {
    /// The configuration the model was built from.
    fn cfg(&self) -> &SimConfig;

    /// Current DRAM cycle.
    fn now(&self) -> u64;

    /// DRAM clock period in nanoseconds.
    fn tck_ns(&self) -> f64;

    /// Map a physical byte address to device coordinates.
    fn map(&self, byte_addr: u64) -> Address;

    /// Room for another read/write on channel `ch`?
    fn can_accept(&self, ch: usize, is_write: bool) -> bool;

    /// Admit one demand access; false when the target queue is full
    /// (the caller re-sends later).
    fn enqueue(&mut self, access: Access) -> bool;

    /// Admit a bulk copy (trace-level `TraceOp::Copy`).
    fn enqueue_copy(&mut self, req: CopyRequest);

    /// Admit a page-granularity copy from the OS layer (flow-controlled
    /// separately from demand traffic).
    fn enqueue_page_copy(&mut self, req: CopyRequest);

    /// Advance one DRAM cycle.
    fn tick(&mut self) -> Result<()>;

    /// Jump `cycles` ahead in one step (only sound below the horizon).
    fn fast_forward(&mut self, cycles: u64);

    /// Earliest future cycle at which the model could deliver an event
    /// or issue work; `u64::MAX` when fully idle.
    fn next_event_cycle(&self) -> u64;

    /// Take completed requests (reads and copies).
    fn drain_completions(&mut self) -> Vec<Completion>;

    /// Nothing queued or in flight?
    fn idle(&self) -> bool;

    /// Aggregate DRAM command counts (energy accounting, benches).
    fn command_stats(&self) -> &CommandStats;

    /// Everything the run report needs from the memory side.
    fn report_parts(&self, cycles: u64) -> ReportParts;

    /// Turn on latency attribution (`--obs`). Models without a
    /// command-level pipeline may ignore this; their reports simply
    /// carry no `"obs"` block.
    fn enable_attribution(&mut self) {}

    /// Attach an external trace sink. Same opt-out as attribution.
    fn set_probe(&mut self, _probe: Box<dyn Probe>) {}

    /// The aggregated attribution block, when attribution ran.
    fn obs_report(&self, _cycles: u64) -> Option<ObsReport> {
        None
    }
}

/// The one construction path from configuration to memory model: every
/// simulation (including the `run_workload*` free functions and the
/// whole experiment/campaign stack above them) selects its backend
/// here, from `cfg.backend`.
pub fn build(cfg: &SimConfig) -> Box<dyn MemoryModel> {
    match cfg.backend {
        BackendKind::Cycle => Box::new(crate::controller::Controller::new(cfg.clone())),
        BackendKind::Analytical => {
            Box::new(analytical::AnalyticalModel::new(cfg.clone()))
        }
    }
}
