//! Bench E4 (Fig. 3): LISA-VILLA performance improvement + hit rate
//! across hot-region workloads, and the VILLA-with-RC-InterSA
//! comparison (paper: up to +16.1%, geomean +5.1%; RC variant -52.3%).
//!
//! Env knobs: LISA_REQUESTS (default 2000), LISA_MIXES (default 8).

use lisa::sim::campaign::default_threads;
use lisa::sim::experiments::fig3;
use lisa::util::bench::Table;
use lisa::util::stats::geomean;

fn env_u64(k: &str, d: u64) -> u64 {
    std::env::var(k).ok().and_then(|s| s.parse().ok()).unwrap_or(d)
}

fn main() {
    let requests = env_u64("LISA_REQUESTS", 2_000);
    let mixes = env_u64("LISA_MIXES", 8) as usize;
    println!("=== E4 / Fig. 3: LISA-VILLA ({requests} reqs/core, {mixes} mixes) ===\n");
    let rows = fig3(requests, mixes, default_threads());
    let mut t = Table::new(&["workload", "VILLA +%", "hit rate %", "VILLA w/ RC-InterSA +%"]);
    for r in &rows {
        t.row(&[
            r.workload.clone(),
            format!("{:+.1}", r.villa_improvement * 100.0),
            format!("{:.1}", r.villa_hit_rate * 100.0),
            format!("{:+.1}", r.rc_inter_improvement * 100.0),
        ]);
    }
    t.print();

    let geo = geomean(&rows.iter().map(|r| 1.0 + r.villa_improvement).collect::<Vec<_>>());
    let max = rows.iter().map(|r| r.villa_improvement).fold(f64::MIN, f64::max);
    let rc_mean = rows.iter().map(|r| r.rc_inter_improvement).sum::<f64>() / rows.len() as f64;
    println!(
        "\nVILLA: geomean {:+.1}% (paper +5.1%), max {:+.1}% (paper +16.1%)",
        (geo - 1.0) * 100.0,
        max * 100.0
    );
    println!(
        "VILLA w/ RC-InterSA movement: mean {:+.1}% (paper -52.3%)",
        rc_mean * 100.0
    );
}
