//! Statistics helpers: running summaries, histograms, geometric means —
//! the aggregation layer behind `metrics` and the bench harness.

/// Online mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Geometric mean of a sequence of positive values; the paper reports
/// geometric-mean speedups (Fig. 3, Fig. 4).
///
/// Non-positive (or NaN) inputs have no geometric mean. A zero ratio —
/// e.g. a −100% WS "improvement" — makes the whole mean 0.0, returned
/// explicitly so the collapse is surfaced instead of being laundered
/// through `ln(clamp)` into a plausible-looking tiny value (the old
/// behavior clamped to 1e-300 and silently dragged the mean). Negative
/// ratios are a caller bug: debug builds assert, release builds also
/// return 0.0.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(
        xs.iter().all(|x| *x >= 0.0 || x.is_nan()),
        "geomean of negative ratios is undefined: {xs:?}"
    );
    if !xs.iter().all(|x| *x > 0.0) {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Percentile (nearest-rank) of an unsorted slice. NaNs sort last
/// (IEEE total order) instead of panicking the comparator, so a
/// degenerate sample poisons only the top percentiles.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize;
    v[rank.min(v.len()) - 1]
}

/// Fixed-bucket latency histogram (power-of-two buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    total: u64,
    sum: u128,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; 64],
            total: 0,
            sum: 0,
        }
    }

    pub fn add(&mut self, value: u64) {
        let idx = 64 - value.leading_zeros() as usize; // bucket by log2
        self.buckets[idx.min(63)] += 1;
        self.total += 1;
        self.sum += value as u128;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i == 0 { 0 } else { 1u64 << i };
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for x in xs {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        // A zero ratio (a −100% WS improvement) zeroes the mean
        // outright instead of being clamped to 1e-300 and quietly
        // dragging it toward — but not to — zero.
        assert_eq!(geomean(&[0.0, 4.0]), 0.0);
        assert_eq!(geomean(&[2.0, 0.0, 2.0]), 0.0);
        // NaN poison is surfaced the same way, not averaged in.
        assert_eq!(geomean(&[f64::NAN, 2.0]), 0.0);
        // Values below the old clamp still compute honestly.
        assert!(geomean(&[1e-308, 1e-308]) > 0.0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "geomean of negative ratios")]
    fn geomean_rejects_negative_ratios_in_debug() {
        geomean(&[1.0, -0.5]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 1.0), 1.0);
    }

    #[test]
    fn percentile_tolerates_nan_input() {
        // partial_cmp().unwrap() used to panic here.
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 1.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan(), "NaN sorts last");
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn histogram_mean_and_percentile() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 4, 8, 1024] {
            h.add(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 207.8).abs() < 0.1);
        assert!(h.percentile(99.0) >= 1024);
    }
}
