//! PJRT runtime: loads the AOT HLO artifacts produced by the
//! JAX/Pallas circuit model (`python/compile/aot.py`) and executes
//! them through the `xla` crate to calibrate the simulator's LISA
//! timing and energy parameters.
//!
//! Python never runs here — the rust binary is self-contained once
//! `make artifacts` has produced `artifacts/*.hlo.txt`.

pub mod calibrate;
pub mod loader;

pub use calibrate::{calibrate, CalibrationInputs};
pub use loader::{PhaseExecutable, PhaseOutputs, Runtime};
