//! Bench E2 (paper §2): RBM bandwidth vs the DDR4-2400 channel
//! (paper: 500 GB/s vs 19.2 GB/s = 26x, with the 60% guard band).

use lisa::config::Calibration;
use lisa::dram::timing::SpeedBin;
use lisa::lisa::rbm::rbm_bandwidth;
use lisa::util::bench::Table;

fn main() {
    println!("=== E2: RBM bandwidth vs memory channel ===\n");
    let cal = Calibration::default();
    let mut t = Table::new(&[
        "granularity",
        "speed bin",
        "hop ns",
        "RBM GB/s",
        "channel GB/s",
        "speedup",
    ]);
    for (label, bytes) in [("rank row (8 KB)", 8192usize), ("chip row (1 KB)", 1024)] {
        for bin in [SpeedBin::Ddr4_2400, SpeedBin::Ddr3_1600] {
            let r = rbm_bandwidth(bin, &cal, bytes);
            t.row(&[
                label.to_string(),
                bin.name().to_string(),
                format!("{:.2}", r.hop_ns),
                format!("{:.0}", r.gbps),
                format!("{:.1}", r.channel_gbps),
                format!("{:.1}x", r.speedup),
            ]);
        }
    }
    t.print();
    println!("\npaper: 500 GB/s vs 19.2 GB/s = 26x (rank row, DDR4-2400)");
    println!("shape check: RBM exceeds the channel by >= an order of magnitude.");
}
