//! Simulation engine (CPU ⇄ controller ⇄ DRAM binding), the parallel
//! campaign runner, and the experiment drivers that regenerate the
//! paper's tables and figures.

pub mod campaign;
pub mod engine;
pub mod experiments;

pub use engine::Simulation;
