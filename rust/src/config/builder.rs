//! `SimConfigBuilder`: typed, chainable construction of `SimConfig`s.
//!
//! This is the one place configurations are derived from experiment
//! axes — it replaces the per-experiment `cfg_baseline` / `cfg_risc` /
//! `cfg_os` / `cfg_salp` constructors that used to be scattered through
//! `sim/experiments.rs`. Every built config validates, and
//! `build()` → `SimConfig::to_toml()` → `SimConfig::from_toml()`
//! round-trips to an equal config (property-tested below), so a grid
//! point can always be persisted and replayed from a file.

use anyhow::{bail, Result};

use super::{BackendKind, CopyMechanism, PlacementPolicy, SalpMode, SimConfig};
use crate::dram::timing::SpeedBin;

/// The named LISA feature combinations of the paper's system-level
/// evaluation (Figs. 3/4) — the `preset` axis of the WS experiments.
/// A preset fully determines the LISA switch block (risc/villa/lip,
/// copy mechanism, VILLA epoch), so two presets never alias.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LisaPreset {
    /// memcpy over the channel, standard DRAM.
    Baseline,
    /// LISA-RISC only.
    Risc,
    /// LISA-RISC + LISA-VILLA.
    RiscVilla,
    /// All three LISA applications (Fig. 4 "All").
    All,
    /// VILLA with RowClone inter-subarray movement (the Fig. 3
    /// comparison the paper shows LOSING 52.3%).
    VillaRc,
    /// LISA-LIP alone (E7).
    Lip,
}

impl LisaPreset {
    pub const ALL: [LisaPreset; 6] = [
        LisaPreset::Baseline,
        LisaPreset::Risc,
        LisaPreset::RiscVilla,
        LisaPreset::All,
        LisaPreset::VillaRc,
        LisaPreset::Lip,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "baseline" => Self::Baseline,
            "risc" => Self::Risc,
            "risc-villa" => Self::RiscVilla,
            "all" => Self::All,
            "villa-rc" => Self::VillaRc,
            "lip" => Self::Lip,
            _ => bail!(
                "unknown LISA preset '{s}' \
                 (baseline|risc|risc-villa|all|villa-rc|lip)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Baseline => "baseline",
            Self::Risc => "risc",
            Self::RiscVilla => "risc-villa",
            Self::All => "all",
            Self::VillaRc => "villa-rc",
            Self::Lip => "lip",
        }
    }
}

/// Chainable `SimConfig` construction. Setters mirror the experiment
/// axes; `build()` validates. Only fields `SimConfig::to_toml()` can
/// serialize have setters, which is what makes the round-trip
/// guarantee possible.
#[derive(Debug, Clone, Default)]
pub struct SimConfigBuilder {
    cfg: SimConfig,
}

impl SimConfigBuilder {
    pub fn new() -> Self {
        Self { cfg: SimConfig::default() }
    }

    /// Start from an existing configuration (e.g. one loaded from a
    /// file) instead of the defaults.
    pub fn from_config(cfg: SimConfig) -> Self {
        Self { cfg }
    }

    /// Memory requests simulated per core.
    pub fn requests(mut self, n: u64) -> Self {
        self.cfg.requests_per_core = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    pub fn max_cycles(mut self, n: u64) -> Self {
        self.cfg.max_cycles = n;
        self
    }

    pub fn warmup_frac(mut self, f: f64) -> Self {
        self.cfg.warmup_frac = f;
        self
    }

    pub fn speed(mut self, s: SpeedBin) -> Self {
        self.cfg.dram.speed = s;
        self
    }

    /// Select the bulk-copy mechanism. Choosing LISA-RISC implies the
    /// RISC substrate is present (links between subarrays); other
    /// mechanisms leave the substrate switch untouched so a base
    /// config's LISA features survive a mechanism sweep.
    pub fn mechanism(mut self, m: CopyMechanism) -> Self {
        self.cfg.copy_mechanism = m;
        if m == CopyMechanism::LisaRisc {
            self.cfg.lisa.risc = true;
        }
        self
    }

    /// Select the memory-model backend (cycle-exact vs analytical).
    pub fn backend(mut self, b: BackendKind) -> Self {
        self.cfg.backend = b;
        self
    }

    pub fn salp(mut self, mode: SalpMode) -> Self {
        self.cfg.dram.salp = mode;
        self
    }

    pub fn placement(mut self, p: PlacementPolicy) -> Self {
        self.cfg.os.placement = p;
        self
    }

    pub fn risc(mut self, on: bool) -> Self {
        self.cfg.lisa.risc = on;
        self
    }

    pub fn villa(mut self, on: bool) -> Self {
        self.cfg.lisa.villa = on;
        self
    }

    pub fn lip(mut self, on: bool) -> Self {
        self.cfg.lisa.lip = on;
        self
    }

    pub fn villa_epoch_cycles(mut self, n: u64) -> Self {
        self.cfg.lisa.villa_epoch_cycles = n;
        self
    }

    pub fn cores(mut self, n: usize) -> Self {
        self.cfg.cpu.cores = n;
        self
    }

    pub fn banks(mut self, n: usize) -> Self {
        self.cfg.dram.banks = n;
        self
    }

    pub fn subarrays_per_bank(mut self, n: usize) -> Self {
        self.cfg.dram.subarrays_per_bank = n;
        self
    }

    /// Apply a named LISA feature combination. The preset overwrites
    /// the whole LISA switch block (and the copy mechanism), so preset
    /// axis values are order-independent with the other setters as
    /// long as `mechanism()` is not applied after it.
    pub fn preset(mut self, p: LisaPreset) -> Self {
        // The short VILLA epoch matches the bounded run lengths the
        // experiment drivers use (the paper sizes epochs against full
        // SPEC runs; what matters is epochs << run length).
        const BENCH_VILLA_EPOCH: u64 = 5_000;
        let l = &mut self.cfg.lisa;
        match p {
            LisaPreset::Baseline => {
                l.risc = false;
                l.villa = false;
                l.lip = false;
                self.cfg.copy_mechanism = CopyMechanism::MemcpyChannel;
            }
            LisaPreset::Risc => {
                l.risc = true;
                l.villa = false;
                l.lip = false;
                self.cfg.copy_mechanism = CopyMechanism::LisaRisc;
            }
            LisaPreset::RiscVilla => {
                l.risc = true;
                l.villa = true;
                l.lip = false;
                l.villa_epoch_cycles = BENCH_VILLA_EPOCH;
                self.cfg.copy_mechanism = CopyMechanism::LisaRisc;
            }
            LisaPreset::All => {
                l.risc = true;
                l.villa = true;
                l.lip = true;
                l.villa_epoch_cycles = BENCH_VILLA_EPOCH;
                self.cfg.copy_mechanism = CopyMechanism::LisaRisc;
            }
            LisaPreset::VillaRc => {
                // Fills fall back to RC-InterSA movement.
                l.risc = false;
                l.villa = true;
                l.lip = false;
                l.villa_epoch_cycles = BENCH_VILLA_EPOCH;
                self.cfg.copy_mechanism = CopyMechanism::MemcpyChannel;
            }
            LisaPreset::Lip => {
                l.risc = false;
                l.villa = false;
                l.lip = true;
                self.cfg.copy_mechanism = CopyMechanism::MemcpyChannel;
            }
        }
        self
    }

    /// Validate and return the configuration.
    pub fn build(self) -> Result<SimConfig> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn presets_compose_like_the_legacy_constructors() {
        let b = |p| SimConfigBuilder::new().requests(100).preset(p).build().unwrap();
        let base = b(LisaPreset::Baseline);
        assert!(!base.lisa.risc && !base.lisa.villa && !base.lisa.lip);
        assert_eq!(base.copy_mechanism, CopyMechanism::MemcpyChannel);
        assert_eq!(base.requests_per_core, 100);
        let risc = b(LisaPreset::Risc);
        assert!(risc.lisa.risc && !risc.lisa.villa);
        assert_eq!(risc.copy_mechanism, CopyMechanism::LisaRisc);
        let rv = b(LisaPreset::RiscVilla);
        assert!(rv.lisa.risc && rv.lisa.villa && !rv.lisa.lip);
        assert_eq!(rv.lisa.villa_epoch_cycles, 5_000);
        let all = b(LisaPreset::All);
        assert!(all.lisa.risc && all.lisa.villa && all.lisa.lip);
        let rc = b(LisaPreset::VillaRc);
        assert!(rc.lisa.villa && !rc.lisa.risc);
        assert_eq!(rc.copy_mechanism, CopyMechanism::MemcpyChannel);
        let lip = b(LisaPreset::Lip);
        assert!(lip.lisa.lip && !lip.lisa.risc && !lip.lisa.villa);
    }

    #[test]
    fn preset_parse_round_trip() {
        for p in LisaPreset::ALL {
            assert_eq!(LisaPreset::parse(p.name()).unwrap(), p);
        }
        assert!(LisaPreset::parse("turbo").is_err());
    }

    #[test]
    fn mechanism_implies_risc_substrate_only_for_lisa() {
        let c = SimConfigBuilder::new()
            .mechanism(CopyMechanism::LisaRisc)
            .build()
            .unwrap();
        assert!(c.lisa.risc);
        let c = SimConfigBuilder::new()
            .mechanism(CopyMechanism::RowCloneInterSa)
            .build()
            .unwrap();
        assert!(!c.lisa.risc);
        // A base config's substrate survives a non-LISA mechanism.
        let c = SimConfigBuilder::new()
            .preset(LisaPreset::Risc)
            .mechanism(CopyMechanism::MemcpyChannel)
            .build()
            .unwrap();
        assert!(c.lisa.risc);
        assert_eq!(c.copy_mechanism, CopyMechanism::MemcpyChannel);
    }

    #[test]
    fn invalid_geometry_fails_build() {
        assert!(SimConfigBuilder::new().banks(7).build().is_err());
        assert!(SimConfigBuilder::new().cores(0).build().is_err());
        assert!(SimConfigBuilder::new().warmup_frac(1.5).build().is_err());
    }

    #[test]
    fn default_config_round_trips_through_toml() {
        let cfg = SimConfigBuilder::new().build().unwrap();
        let parsed = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(cfg, parsed);
    }

    #[test]
    fn prop_builder_round_trips_through_minitoml() {
        // Satellite: build → to_toml → from_toml → equal, with random
        // axis draws across every axis kind the experiment grids use
        // plus geometry/seed/warmup perturbations.
        let mechs = CopyMechanism::ALL;
        check("builder ⇄ minitoml round trip", 128, |g| {
            let mut b = SimConfigBuilder::new()
                .requests(1 + g.u64(1 << 20))
                .seed(g.u64(1 << 48))
                .max_cycles(1 + g.u64(1 << 40))
                .warmup_frac(g.f64() * 0.9)
                .preset(*g.pick(&LisaPreset::ALL))
                .mechanism(*g.pick(&mechs))
                .salp(*g.pick(&SalpMode::ALL))
                .placement(*g.pick(&PlacementPolicy::ALL))
                .backend(*g.pick(&BackendKind::ALL))
                .speed(*g.pick(&[SpeedBin::Ddr3_1600, SpeedBin::Ddr4_2400]));
            if g.bool() {
                b = b.cores(1 << g.usize(4));
            }
            if g.bool() {
                b = b.banks(1 << (1 + g.usize(4)));
            }
            if g.bool() {
                b = b.subarrays_per_bank(1 << (1 + g.usize(5)));
            }
            if g.bool() {
                b = b.villa_epoch_cycles(1 + g.u64(1 << 20));
            }
            let cfg = b.build().unwrap();
            let toml = cfg.to_toml();
            let parsed = SimConfig::from_toml(&toml)
                .unwrap_or_else(|e| panic!("reparse failed: {e}\n{toml}"));
            assert_eq!(cfg, parsed, "round trip must be lossless:\n{toml}");
        });
    }
}
