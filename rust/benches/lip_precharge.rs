//! Bench E3 (paper §3.3): linked-precharge circuit latency
//! (paper SPICE: 5 ns vs 13 ns = 2.6x), from the calibrated circuit
//! model, plus the cycle-level tRP values the simulator uses.

use lisa::config::Calibration;
use lisa::dram::timing::SpeedBin;
use lisa::lisa::lip::lip_report;
use lisa::util::bench::Table;

fn main() {
    println!("=== E3: LISA-LIP linked precharge ===\n");
    let cal = Calibration::default();
    let mut t = Table::new(&["speed bin", "tRP circuit ns", "tRP LIP ns", "speedup", "tRP cyc", "tRP_LIP cyc"]);
    for bin in [SpeedBin::Ddr3_1600, SpeedBin::Ddr4_2400] {
        let r = lip_report(bin, &cal);
        t.row(&[
            bin.name().to_string(),
            format!("{:.2}", r.t_rp_circuit_ns),
            format!("{:.2}", r.t_rp_lip_ns),
            format!("{:.2}x", r.speedup),
            format!("{}", r.t_rp_cycles),
            format!("{}", r.t_rp_lip_cycles),
        ]);
    }
    t.print();
    println!("\npaper: 5 ns vs 13 ns = 2.6x");
}
