//! Integration tests for the PJRT runtime + calibration path. These
//! need `make artifacts` to have run; they are skipped (with a notice)
//! when artifacts are absent so `cargo test` stays hermetic.

use std::path::Path;

use lisa::config::Calibration;
use lisa::runtime::{calibrate, CalibrationInputs, Runtime};
use lisa::runtime::loader::{N_LANES, NSCALARS};
use lisa::runtime::calibrate::{scalars_precharge, scalars_rbm, PhysParams};

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    if !dir.join("precharge_single.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("PJRT CPU client"))
}

#[test]
fn artifacts_load_and_execute() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("precharge_single").unwrap();
    let p = PhysParams::default();
    let ones = vec![1.0f32; N_LANES];
    let vdd = vec![p.vdd; N_LANES];
    let out = exe
        .run(&vdd, &vdd, &ones, &ones, &scalars_precharge(&p, false, false))
        .unwrap();
    assert_eq!(out.v_a.len(), N_LANES);
    // Every bitline must settle at VDD/2.
    for &v in &out.v_a {
        assert!((v - p.vdd / 2.0).abs() < 0.05, "v_a = {v}");
    }
    // Nominal settle time ~ 13 ns (tuned circuit).
    let t = out.t_settle[0];
    assert!(t > 11.0 && t < 16.0, "t_settle = {t}");
}

#[test]
fn calibration_matches_checked_in_defaults() {
    // The Calibration::default() values are documented as "what the
    // checked-in circuit model yields". Verify that promise through
    // the full PJRT path.
    let Some(rt) = runtime() else { return };
    let cal = calibrate(&rt, &CalibrationInputs::default()).unwrap();
    let d = Calibration::default();
    assert!(cal.from_artifacts);
    assert!(
        (cal.t_rbm_ns - d.t_rbm_ns).abs() < 0.5,
        "tRBM {} vs default {}",
        cal.t_rbm_ns,
        d.t_rbm_ns
    );
    assert!((cal.t_rp_lip_ns - d.t_rp_lip_ns).abs() < 0.5);
    assert!((cal.t_rp_circuit_ns - d.t_rp_circuit_ns).abs() < 1.0);
    assert!((cal.fast_act_ratio - d.fast_act_ratio).abs() < 0.1);
    // Paper anchor: linked precharge ~2.6x faster.
    let ratio = cal.t_rp_circuit_ns / cal.t_rp_lip_ns;
    assert!(ratio > 2.0 && ratio < 3.2, "LIP ratio {ratio}");
}

#[test]
fn rbm_worst_lane_within_guard_band() {
    // The 60% guard band must cover the Monte-Carlo variation
    // population (calibrate() enforces this; double-check the margin
    // isn't razor-thin either).
    let Some(rt) = runtime() else { return };
    let exe = rt.load("rbm_hop").unwrap();
    let p = PhysParams::default();
    let mut rng = lisa::util::rng::Pcg32::new(1234, 5);
    let gmul: Vec<f32> = (0..N_LANES).map(|_| rng.lognormal_mul(0.05) as f32).collect();
    let cmul: Vec<f32> = (0..N_LANES).map(|_| rng.lognormal_mul(0.05) as f32).collect();
    let mid = vec![p.vdd / 2.0; N_LANES];
    let vdd = vec![p.vdd; N_LANES];
    let out = exe.run(&mid, &vdd, &gmul, &cmul, &scalars_rbm(&p, false)).unwrap();
    let mut t: Vec<f32> = out.t_settle.clone();
    t.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = t[t.len() / 2] as f64;
    let worst = *t.last().unwrap() as f64;
    assert!(worst < median * 1.6, "worst {worst} vs margined {}", median * 1.6);
    assert!(worst > median * 1.05, "variation should spread the population");
}

#[test]
fn scalar_layout_constant_matches() {
    assert_eq!(NSCALARS, 16);
}
