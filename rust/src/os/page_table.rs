//! Flat per-process page table: virtual page number -> physical frame,
//! with the copy-on-write bit that drives fork's lazy copies.
//!
//! A `BTreeMap` (not `HashMap`) keeps every whole-table walk — fork's
//! CoW sweep, checkpoint's dirty scan — in deterministic vpn order, so
//! the frame allocator sees an identical request sequence on every run
//! (the whole simulator is bit-reproducible from the config seed).

use std::collections::BTreeMap;

/// One page-table entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageEntry {
    /// Physical frame (global visible-row index, see `frame_alloc`).
    pub frame: u32,
    /// Copy-on-write: the frame is shared with a forked child and a
    /// store must break the sharing with a page copy first.
    pub cow: bool,
}

/// A flat per-process page table.
#[derive(Debug, Clone, Default)]
pub struct PageTable {
    entries: BTreeMap<u64, PageEntry>,
}

impl PageTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Translate a virtual page number; `None` faults (unmapped).
    pub fn translate(&self, vpn: u64) -> Option<PageEntry> {
        self.entries.get(&vpn).copied()
    }

    /// Install (or replace) a mapping.
    pub fn map(&mut self, vpn: u64, frame: u32, cow: bool) -> Option<PageEntry> {
        self.entries.insert(vpn, PageEntry { frame, cow })
    }

    /// Point `vpn` at a new private frame (CoW break / migration).
    pub fn remap(&mut self, vpn: u64, frame: u32) {
        let e = self.entries.get_mut(&vpn).expect("remap of unmapped page");
        e.frame = frame;
        e.cow = false;
    }

    pub fn unmap(&mut self, vpn: u64) -> Option<PageEntry> {
        self.entries.remove(&vpn)
    }

    /// Mark every mapping copy-on-write (fork); returns the shared
    /// frames in vpn order so the caller can take child references.
    pub fn mark_all_cow(&mut self) -> Vec<u32> {
        let mut frames = Vec::with_capacity(self.entries.len());
        for e in self.entries.values_mut() {
            e.cow = true;
            frames.push(e.frame);
        }
        frames
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate mappings in vpn order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, PageEntry)> + '_ {
        self.entries.iter().map(|(&v, &e)| (v, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_translate_unmap() {
        let mut pt = PageTable::new();
        assert!(pt.translate(3).is_none());
        assert!(pt.map(3, 77, false).is_none());
        assert_eq!(pt.translate(3), Some(PageEntry { frame: 77, cow: false }));
        assert_eq!(pt.len(), 1);
        assert_eq!(pt.unmap(3).unwrap().frame, 77);
        assert!(pt.is_empty());
    }

    #[test]
    fn fork_marks_cow_and_remap_clears_it() {
        let mut pt = PageTable::new();
        pt.map(0, 10, false);
        pt.map(9, 11, false);
        pt.map(4, 12, false);
        // Deterministic vpn order regardless of insertion order.
        assert_eq!(pt.mark_all_cow(), vec![10, 12, 11]);
        assert!(pt.translate(9).unwrap().cow);
        pt.remap(9, 99);
        let e = pt.translate(9).unwrap();
        assert_eq!(e.frame, 99);
        assert!(!e.cow);
    }

    #[test]
    #[should_panic(expected = "remap of unmapped page")]
    fn remap_requires_mapping() {
        PageTable::new().remap(1, 2);
    }
}
