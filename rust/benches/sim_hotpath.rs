//! Perf bench: the simulator's own hot path (EXPERIMENTS.md §Perf).
//! Measures end-to-end simulated DRAM-cycles/second on representative
//! workloads, for both the event-driven fast-forward engine (the
//! default `run()`) and the per-cycle reference loop — the ratio is
//! the repo's headline engine-speed metric.
//!
//! Usage: `cargo bench --bench sim_hotpath [-- REQUESTS]`
//! (REQUESTS defaults to 5000; CI smoke mode passes a small value.)

use std::time::Instant;

use lisa::config::SimConfig;
use lisa::sim::engine::Simulation;
use lisa::util::bench::Table;
use lisa::workloads::mixes;

struct Measurement {
    cycles: u64,
    ff_rate: f64,
    ref_rate: f64,
}

fn bench_workload(name: &str, requests: u64) -> Measurement {
    let mut cfg = SimConfig::default().with_all_lisa();
    cfg.requests_per_core = requests;
    let wl = mixes::workload_by_name(name, &cfg).unwrap();

    let mut ff = Simulation::new(cfg.clone(), wl.clone());
    let t0 = Instant::now();
    let r_ff = ff.run();
    let ff_dt = t0.elapsed().as_secs_f64();

    let mut reference = Simulation::new(cfg, wl);
    let t0 = Instant::now();
    let r_ref = reference.reference_run();
    let ref_dt = t0.elapsed().as_secs_f64();

    assert_eq!(
        r_ff, r_ref,
        "{name}: fast-forward must be cycle-exact vs the reference loop"
    );
    Measurement {
        cycles: r_ff.dram_cycles,
        ff_rate: r_ff.dram_cycles as f64 / ff_dt,
        ref_rate: r_ref.dram_cycles as f64 / ref_dt,
    }
}

fn main() {
    // First numeric argument wins (cargo bench may inject `--bench`).
    let requests: u64 = std::env::args()
        .skip(1)
        .find_map(|s| s.parse().ok())
        .unwrap_or(5_000);
    println!("=== Simulator hot-path throughput ({requests} requests/core) ===\n");
    let mut t = Table::new(&[
        "workload",
        "sim cycles",
        "ff Mcyc/s",
        "ref Mcyc/s",
        "speedup",
    ]);
    let mut worst = f64::INFINITY;
    for name in ["stream4", "random4", "hotspot4", "fork4"] {
        let m = bench_workload(name, requests);
        let speedup = m.ff_rate / m.ref_rate;
        worst = worst.min(speedup);
        t.row(&[
            name.to_string(),
            format!("{}", m.cycles),
            format!("{:.2}", m.ff_rate / 1e6),
            format!("{:.2}", m.ref_rate / 1e6),
            format!("{:.2}x", speedup),
        ]);
    }
    t.print();
    println!("\nworst-case fast-forward speedup: {worst:.2}x");
    println!("target (EXPERIMENTS.md §Perf): >= 3x vs the per-cycle reference loop");
}
