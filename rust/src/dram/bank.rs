//! The DRAM device state machine: per-bank / per-rank / per-channel
//! timing-constraint tracking and command execution, including the
//! RowClone, LISA and SALP/MASA command extensions.
//!
//! The model follows the Ramulator approach: for every command the
//! device can compute the earliest legal issue cycle from a set of
//! "next allowed" registers updated on every issue, plus structural
//! state checks (row open/closed, subarray latched, rank busy).
//!
//! Activation state is tracked **per subarray** (`dram/subarray.rs`):
//! each subarray carries its own `next_act`/`next_pre`/`next_rdwr`/
//! `ras_done`/`sense_done` registers. The configured `SalpMode`
//! decides how much of that independence the bank state machine
//! exposes — from the serialized baseline (`None`: one non-precharged
//! subarray, whole-bank PRE pays full tRP before any ACT) to MASA
//! (every subarray may hold an open row, RD/WR steers the global
//! bitlines by subarray-select). Shared structures stay shared in
//! every mode: the global bitlines/IO (channel RD/WR registers and the
//! per-switch `t_sa_sel`), the bank-scope ACT-to-ACT current limit
//! (tRRD within the bank), and the LISA inter-subarray link path
//! (`busy_until` spans the bank for the duration of an RBM).
//!
//! Data movement *semantics* are modeled with content tags: every row
//! has a 64-bit tag standing in for its 8 KB of data, and every
//! mechanism (activation, RowClone, RBM, channel copy) moves tags
//! exactly the way it would move data. Integration tests assert that
//! each copy mechanism produces the right tag at the destination.

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Result};

use crate::config::{DramConfig, LisaConfig, SalpMode};
use crate::dram::command::Command;
use crate::dram::subarray::{SaState, Subarray};
use crate::dram::timing::Timing;

/// Default content tag of a never-written row (derived from identity,
/// so "uninitialized" rows are still distinguishable in tests).
#[inline]
pub fn default_tag(global_row: u64) -> u64 {
    global_row.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xD1F4_5EED
}

/// Counters for the energy model and experiment reports.
#[derive(Debug, Clone, Default)]
pub struct CommandStats {
    pub n_act: u64,
    pub n_act_fast: u64,
    pub n_pre: u64,
    /// Subset of `n_pre` issued as per-subarray precharges (SALP).
    pub n_pre_sa: u64,
    pub n_pre_lip: u64,
    pub n_rd: u64,
    pub n_wr: u64,
    pub n_ref: u64,
    pub n_rbm_hops: u64,
    pub n_transfer_cols: u64,
    pub n_act_copy: u64,
    pub n_act_store: u64,
    /// RD/WR commands that paid the subarray-select switch (SALP-2 /
    /// MASA designated-subarray hand-off).
    pub n_sa_switch: u64,
}

/// One bank: bank-scope timing registers + per-subarray buffers (each
/// with its own subarray-scope registers) + row tags.
#[derive(Debug, Clone)]
pub struct Bank {
    pub subarrays: Vec<Subarray>,
    /// Earliest cycle an ACT may issue anywhere in the bank: charged
    /// with tRP by whole-bank precharges, tRFC by refresh, and the
    /// intra-bank ACT-to-ACT tRRD gap under SALP modes. Per-subarray
    /// tRP lives in `Subarray::next_act`.
    pub next_act: u64,
    /// Earliest cycle a whole-bank PRE may issue (running max of every
    /// subarray's restore/recovery constraints).
    pub next_pre: u64,
    /// Composite-op occupancy (RBM / Transfer): the inter-subarray
    /// link path and the bank's global-bitline interface are shared,
    /// so composite ops block the whole bank in every SALP mode.
    pub busy_until: u64,
    /// The subarray the global-bitline select currently points at
    /// (SALP-2 / MASA): a RD/WR to a different subarray pays
    /// `t_sa_sel`. `None` in non-select modes and after full PRE.
    pub last_sa: Option<usize>,
    /// Number of non-precharged (open or latched) subarrays,
    /// maintained incrementally on every state transition.
    /// `open_count`/`all_precharged` are hot: the scheduler's prepare
    /// pass, the fast-forward horizon, the refresh machinery and the
    /// copy engines consult them per candidate — O(1) here instead of
    /// a subarray scan per query.
    open_cnt: usize,
    /// Content tags of written rows (absent => default_tag).
    rows: HashMap<usize, u64>,
}

impl Bank {
    fn new(subarrays: usize) -> Self {
        Self {
            subarrays: (0..subarrays).map(|_| Subarray::default()).collect(),
            next_act: 0,
            next_pre: 0,
            busy_until: 0,
            last_sa: None,
            open_cnt: 0,
            rows: HashMap::new(),
        }
    }

    /// The first subarray that currently has an open row, if any.
    pub fn open_subarray(&self) -> Option<usize> {
        self.subarrays
            .iter()
            .position(|sa| matches!(sa.state, SaState::Open { .. }))
    }

    /// The first open row (bank-relative), if any.
    pub fn open_row(&self) -> Option<usize> {
        self.subarrays.iter().find_map(|sa| sa.open_row())
    }

    /// Any subarray not precharged (open OR latched)?
    pub fn all_precharged(&self) -> bool {
        self.open_cnt == 0
    }

    /// Number of non-precharged (open or latched) subarrays — the
    /// quantity `SalpMode::open_cap` bounds.
    pub fn open_count(&self) -> usize {
        self.open_cnt
    }

    /// `open_count` recomputed from subarray state. Tests pin the
    /// incremental counter against this after every state transition.
    pub fn open_count_scan(&self) -> usize {
        self.subarrays.iter().filter(|sa| !sa.is_precharged()).count()
    }

    /// Earliest cycle an ACT may issue, from bank-scope registers only
    /// (per-subarray tRP and rank-scope tRRD/tFAW are the caller's job).
    pub fn act_earliest(&self) -> u64 {
        self.next_act.max(self.busy_until)
    }

    /// Earliest cycle a whole-bank PRE may issue.
    pub fn pre_earliest(&self) -> u64 {
        self.next_pre.max(self.busy_until)
    }

    /// Earliest cycle a RD/WR against subarray `sa` may issue, from
    /// bank/subarray registers (the shared data-bus constraint is the
    /// caller's job).
    pub fn rdwr_earliest(&self, sa: usize) -> u64 {
        self.subarrays[sa].next_rdwr.max(self.busy_until)
    }

    /// Max of every subarray's `next_act` — bounds refresh, which
    /// internally activates rows in all subarrays.
    pub fn sa_next_act_floor(&self) -> u64 {
        self.subarrays.iter().map(|sa| sa.next_act).max().unwrap_or(0)
    }
}

/// One rank: banks + rank-scope constraints (tRRD, tFAW, tRFC).
#[derive(Debug, Clone)]
pub struct Rank {
    pub banks: Vec<Bank>,
    pub next_act: u64,
    /// Timestamps of recent ACTs for the tFAW sliding window.
    act_times: VecDeque<u64>,
    /// Refresh occupancy.
    pub busy_until: u64,
}

impl Rank {
    fn new(banks: usize, subarrays: usize) -> Self {
        Self {
            banks: (0..banks).map(|_| Bank::new(subarrays)).collect(),
            next_act: 0,
            act_times: VecDeque::with_capacity(4),
            busy_until: 0,
        }
    }

    fn faw_earliest(&self, t_faw: u64) -> u64 {
        if self.act_times.len() < 4 {
            0
        } else {
            self.act_times[self.act_times.len() - 4] + t_faw
        }
    }

    fn record_act(&mut self, t: u64) {
        self.act_times.push_back(t);
        while self.act_times.len() > 4 {
            self.act_times.pop_front();
        }
    }
}

/// One channel: ranks + the shared data-bus constraints. RowClone
/// inter-bank transfers also occupy the internal global bus, which
/// shares the I/O path — so they block channel RD/WR (this is the
/// system-level penalty the paper measures for RC-InterSA).
#[derive(Debug, Clone)]
pub struct Channel {
    pub ranks: Vec<Rank>,
    pub next_rd: u64,
    pub next_wr: u64,
}

impl Channel {
    fn new(ranks: usize, banks: usize, subarrays: usize) -> Self {
        Self {
            ranks: (0..ranks).map(|_| Rank::new(banks, subarrays)).collect(),
            next_rd: 0,
            next_wr: 0,
        }
    }
}

/// The whole DRAM device behind one memory controller channel group.
#[derive(Debug, Clone)]
pub struct DramDevice {
    pub cfg: DramConfig,
    pub lisa: LisaConfig,
    pub timing: Timing,
    pub channels: Vec<Channel>,
    pub stats: CommandStats,
}

/// Result of issuing a command: when its effect completes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Issued {
    /// Cycle at which data is available / the operation's result is
    /// usable (e.g. RD: data burst done; RBM: buffers latched).
    pub done_at: u64,
}

impl DramDevice {
    pub fn new(cfg: DramConfig, lisa: LisaConfig, timing: Timing) -> Self {
        let channels = (0..cfg.channels)
            .map(|_| Channel::new(cfg.ranks, cfg.banks, cfg.subarrays_per_bank))
            .collect();
        Self {
            cfg,
            lisa,
            timing,
            channels,
            stats: CommandStats::default(),
        }
    }

    /// Is `sa` a VILLA fast subarray? (Fast subarrays occupy the low
    /// indices of every bank when VILLA is enabled.)
    pub fn is_fast_sa(&self, sa: usize) -> bool {
        self.lisa.villa && sa < self.lisa.fast_subarrays_per_bank
    }

    fn sa_of_row(&self, row: usize) -> usize {
        row / self.cfg.rows_per_subarray
    }

    /// Read a row's content tag.
    pub fn row_tag(&self, ch: usize, rank: usize, bank: usize, row: usize) -> u64 {
        let b = &self.channels[ch].ranks[rank].banks[bank];
        *b.rows.get(&row).unwrap_or(&default_tag(self.global_row(ch, rank, bank, row)))
    }

    /// Overwrite a row's content tag (used by the channel-copy engine:
    /// data that went through the CPU is written back with WRs).
    pub fn set_row_tag(&mut self, ch: usize, rank: usize, bank: usize, row: usize, tag: u64) {
        self.channels[ch].ranks[rank].banks[bank].rows.insert(row, tag);
    }

    fn global_row(&self, ch: usize, rank: usize, bank: usize, row: usize) -> u64 {
        let rows_per_bank = self.cfg.rows_per_bank() as u64;
        (((ch as u64 * self.cfg.ranks as u64 + rank as u64) * self.cfg.banks as u64
            + bank as u64)
            * rows_per_bank)
            + row as u64
    }

    pub fn bank(&self, ch: usize, rank: usize, bank: usize) -> &Bank {
        &self.channels[ch].ranks[rank].banks[bank]
    }

    /// Earliest cycle >= `now` at which `cmd` can legally issue on
    /// channel `ch`. Err if the command is illegal in the current
    /// structural state (e.g. ACT on a bank at its open-subarray cap).
    pub fn earliest(&self, ch: usize, cmd: Command, now: u64) -> Result<u64> {
        let t = &self.timing;
        let mode = self.cfg.salp;
        let chan = &self.channels[ch];
        let rank = &chan.ranks[cmd.rank()];
        let mut earliest = now.max(rank.busy_until);

        match cmd {
            Command::Act { bank, row, .. } => {
                let b = &rank.banks[bank];
                let sa = self.sa_of_row(row);
                if sa >= b.subarrays.len() {
                    bail!("row {row} out of range");
                }
                if !b.subarrays[sa].is_precharged() {
                    bail!("ACT: target subarray {sa} not precharged");
                }
                if b.open_count() >= mode.open_cap(b.subarrays.len()) {
                    bail!("ACT: bank at open-subarray cap ({} mode)", mode.name());
                }
                earliest = earliest
                    .max(b.act_earliest())
                    .max(rank.next_act)
                    .max(rank.faw_earliest(t.t_faw));
                if mode.per_subarray() {
                    earliest = earliest.max(b.subarrays[sa].next_act);
                }
                Ok(earliest)
            }
            Command::ActCopy { bank, row, .. } => {
                let b = &rank.banks[bank];
                let sa = self.sa_of_row(row);
                match b.subarrays[sa].state {
                    SaState::Open { row: open } if open != row => {}
                    SaState::Open { .. } => bail!("ACT_COPY: source == destination row"),
                    _ => bail!("ACT_COPY: subarray {sa} has no open row"),
                }
                // The buffer must be fully restored into the source row
                // before it can be reused to write another row.
                Ok(earliest.max(b.subarrays[sa].ras_done).max(b.busy_until))
            }
            Command::ActStore { bank, row, .. } => {
                let b = &rank.banks[bank];
                let sa = self.sa_of_row(row);
                if b.subarrays[sa].state != SaState::LatchedOnly {
                    bail!("ACT_STORE: subarray {sa} has no latched buffer");
                }
                Ok(earliest.max(b.busy_until))
            }
            Command::Pre { bank, .. } => {
                let b = &rank.banks[bank];
                if b.all_precharged() {
                    bail!("PRE: bank already precharged");
                }
                Ok(earliest.max(b.pre_earliest()))
            }
            Command::PreSa { bank, sa, .. } => {
                if mode == SalpMode::None {
                    bail!("PRE_SA: requires a SALP mode (configured: none)");
                }
                let b = &rank.banks[bank];
                if sa >= b.subarrays.len() {
                    bail!("PRE_SA: subarray {sa} out of range");
                }
                if b.subarrays[sa].is_precharged() {
                    bail!("PRE_SA: subarray {sa} already precharged");
                }
                Ok(earliest.max(b.subarrays[sa].next_pre).max(b.busy_until))
            }
            Command::PreAll { .. } => {
                let mut e = earliest;
                for b in &rank.banks {
                    if !b.all_precharged() {
                        e = e.max(b.next_pre).max(b.busy_until);
                    }
                }
                Ok(e)
            }
            Command::Rd { bank, sa, .. } | Command::Wr { bank, sa, .. } => {
                let b = &rank.banks[bank];
                if sa >= b.subarrays.len() {
                    bail!("RD/WR: subarray {sa} out of range");
                }
                if b.subarrays[sa].open_row().is_none() {
                    bail!("RD/WR: no open row in subarray {sa}");
                }
                let bus = match cmd {
                    Command::Rd { .. } => chan.next_rd,
                    _ => chan.next_wr,
                };
                Ok(earliest.max(b.rdwr_earliest(sa)).max(bus))
            }
            Command::Ref { .. } => {
                for b in &rank.banks {
                    if !b.all_precharged() {
                        bail!("REF: bank not precharged");
                    }
                }
                let mut e = earliest;
                for b in &rank.banks {
                    // Refresh internally activates rows in every
                    // subarray, so it also waits out any in-flight
                    // per-subarray precharge (SALP modes).
                    e = e.max(b.act_earliest()).max(b.sa_next_act_floor());
                }
                Ok(e)
            }
            Command::Rbm { bank, from_sa, to_sa, .. } => {
                let b = &rank.banks[bank];
                if from_sa == to_sa {
                    bail!("RBM: source == destination subarray");
                }
                match b.subarrays[from_sa].state {
                    SaState::Open { .. } | SaState::LatchedOnly => {}
                    SaState::Precharged => bail!("RBM: source buffer not latched"),
                }
                // Every subarray along the path (excluding source) must
                // be precharged so its buffer can sense the moved data.
                let (lo, hi) = (from_sa.min(to_sa), from_sa.max(to_sa));
                for sa in lo..=hi {
                    if sa != from_sa && !b.subarrays[sa].is_precharged() {
                        bail!("RBM: subarray {sa} on path not precharged");
                    }
                }
                // Source must be fully restored if a wordline is up
                // (conservative: RBM perturbs the buffer while cells
                // are still connected).
                let ready = match b.subarrays[from_sa].state {
                    SaState::Open { .. } => b.subarrays[from_sa].ras_done,
                    _ => b.subarrays[from_sa].sense_done,
                };
                Ok(earliest.max(ready).max(b.busy_until))
            }
            Command::Transfer { src_bank, dst_bank, .. } => {
                if src_bank == dst_bank {
                    bail!("TRANSFER: source == destination bank");
                }
                let sb = &rank.banks[src_bank];
                let db = &rank.banks[dst_bank];
                let (Some(s_sa), Some(d_sa)) = (sb.open_subarray(), db.open_subarray()) else {
                    bail!("TRANSFER: both banks need an open row");
                };
                // Both banks' sensing must be complete; the internal
                // bus shares the I/O path, so outstanding RD/WR bursts
                // must drain (approximated by the channel registers).
                Ok(earliest
                    .max(sb.subarrays[s_sa].sense_done)
                    .max(db.subarrays[d_sa].sense_done)
                    .max(sb.busy_until)
                    .max(db.busy_until)
                    .max(chan.next_rd)
                    .max(chan.next_wr))
            }
        }
    }

    /// Issue `cmd` at cycle `at` (must be >= earliest). Returns the
    /// completion information. Errors if timing would be violated —
    /// the scheduler must only issue legal commands.
    pub fn issue(&mut self, ch: usize, cmd: Command, at: u64) -> Result<Issued> {
        let earliest = self.earliest(ch, cmd, at)?;
        if at < earliest {
            bail!(
                "timing violation: {} at {at} < earliest {earliest}",
                cmd.name()
            );
        }
        let t = self.timing.clone();
        let mode = self.cfg.salp;
        let lip_enabled = self.lisa.lip;
        let rows_per_sa = self.cfg.rows_per_subarray;
        let fast_k = if self.lisa.villa {
            self.lisa.fast_subarrays_per_bank
        } else {
            0
        };
        let is_fast = |sa: usize| sa < fast_k;

        let rank_idx = cmd.rank();
        let global_of = |dev: &Self, bank: usize, row: usize| {
            dev.global_row(ch, rank_idx, bank, row)
        };

        match cmd {
            Command::Act { bank, row, .. } => {
                let sa = row / rows_per_sa;
                let fast = is_fast(sa);
                let (t_rcd, t_ras) = if fast {
                    (t.t_rcd_fast, t.t_ras_fast)
                } else {
                    (t.t_rcd, t.t_ras)
                };
                let global = global_of(self, bank, row);
                let chan = &mut self.channels[ch];
                let rank = &mut chan.ranks[rank_idx];
                rank.record_act(at);
                rank.next_act = rank.next_act.max(at + t.t_rrd);
                let b = &mut rank.banks[bank];
                b.next_pre = b.next_pre.max(at + t_ras);
                // ACT-to-ACT in the same bank always requires an
                // intervening PRE (state machine) in the baseline,
                // which enforces tRAS + tRP = tRC; SALP modes allow
                // concurrent activations but still pace them by tRRD
                // (shared in-bank charge pumps).
                if mode.per_subarray() {
                    b.next_act = b.next_act.max(at + t.t_rrd);
                }
                let tag = *b.rows.get(&row).unwrap_or(&default_tag(global));
                // Target subarray was precharged (validated above).
                b.open_cnt += 1;
                let s = &mut b.subarrays[sa];
                s.state = SaState::Open { row };
                s.buffer_tag = Some(tag);
                s.next_rdwr = at + t_rcd;
                s.sense_done = at + t_rcd;
                s.ras_done = at + t_ras;
                s.next_pre = s.next_pre.max(at + t_ras);
                self.stats.n_act += 1;
                if fast {
                    self.stats.n_act_fast += 1;
                }
                Ok(Issued { done_at: at + t_rcd })
            }
            Command::ActCopy { bank, row, .. } => {
                let sa = row / rows_per_sa;
                let fast = is_fast(sa);
                let t_ras = if fast { t.t_ras_fast } else { t.t_ras };
                let chan = &mut self.channels[ch];
                let b = &mut chan.ranks[rank_idx].banks[bank];
                let tag = b.subarrays[sa].buffer_tag.expect("latched buffer"); // lint: allow(panic) reason=scheduler only issues this after RBM latched the buffer
                b.rows.insert(row, tag);
                b.next_pre = b.next_pre.max(at + t_ras);
                let s = &mut b.subarrays[sa];
                s.state = SaState::Open { row };
                s.ras_done = at + t_ras;
                s.sense_done = at; // buffer already full-swing
                s.next_rdwr = s.next_rdwr.max(at);
                s.next_pre = s.next_pre.max(at + t_ras);
                self.stats.n_act_copy += 1;
                Ok(Issued { done_at: at + t_ras })
            }
            Command::ActStore { bank, row, .. } => {
                let sa = row / rows_per_sa;
                let fast = is_fast(sa);
                let t_ras = if fast { t.t_ras_fast } else { t.t_ras };
                let chan = &mut self.channels[ch];
                let b = &mut chan.ranks[rank_idx].banks[bank];
                let tag = b.subarrays[sa].buffer_tag.expect("latched buffer"); // lint: allow(panic) reason=scheduler only issues this after RBM latched the buffer
                b.rows.insert(row, tag);
                b.next_pre = b.next_pre.max(at + t_ras);
                let s = &mut b.subarrays[sa];
                s.state = SaState::Open { row };
                s.ras_done = at + t_ras;
                s.sense_done = at;
                s.next_rdwr = s.next_rdwr.max(at);
                s.next_pre = s.next_pre.max(at + t_ras);
                self.stats.n_act_store += 1;
                Ok(Issued { done_at: at + t_ras })
            }
            Command::Pre { bank, .. } => {
                let chan = &mut self.channels[ch];
                let b = &mut chan.ranks[rank_idx].banks[bank];
                // LIP: a neighbor subarray's idle precharge unit can be
                // linked if it is itself precharged.
                let mut any_fast = false;
                let mut lip_ok = false;
                let n_sa = b.subarrays.len();
                for sa in 0..n_sa {
                    if !b.subarrays[sa].is_precharged() {
                        any_fast |= is_fast(sa);
                        let left_ok = sa > 0 && b.subarrays[sa - 1].is_precharged();
                        let right_ok =
                            sa + 1 < n_sa && b.subarrays[sa + 1].is_precharged();
                        lip_ok |= left_ok || right_ok;
                    }
                }
                let use_lip = lip_enabled && lip_ok;
                let t_rp = match (any_fast, use_lip) {
                    (true, true) => t.t_rp_fast_lip,
                    (true, false) => t.t_rp_fast,
                    (false, true) => t.t_rp_lip,
                    (false, false) => t.t_rp,
                };
                for sa in b.subarrays.iter_mut() {
                    sa.precharge();
                    sa.next_act = sa.next_act.max(at + t_rp);
                }
                b.open_cnt = 0;
                b.next_act = b.next_act.max(at + t_rp);
                b.last_sa = None;
                self.stats.n_pre += 1;
                if use_lip {
                    self.stats.n_pre_lip += 1;
                }
                Ok(Issued { done_at: at + t_rp })
            }
            Command::PreSa { bank, sa, .. } => {
                let chan = &mut self.channels[ch];
                let b = &mut chan.ranks[rank_idx].banks[bank];
                let n_sa = b.subarrays.len();
                let fast = is_fast(sa);
                let left_ok = sa > 0 && b.subarrays[sa - 1].is_precharged();
                let right_ok = sa + 1 < n_sa && b.subarrays[sa + 1].is_precharged();
                let use_lip = lip_enabled && (left_ok || right_ok);
                let t_rp = match (fast, use_lip) {
                    (true, true) => t.t_rp_fast_lip,
                    (true, false) => t.t_rp_fast,
                    (false, true) => t.t_rp_lip,
                    (false, false) => t.t_rp,
                };
                // Target subarray was non-precharged (validated above).
                b.open_cnt -= 1;
                let s = &mut b.subarrays[sa];
                s.precharge();
                s.next_act = s.next_act.max(at + t_rp);
                if b.last_sa == Some(sa) {
                    b.last_sa = None;
                }
                self.stats.n_pre += 1;
                self.stats.n_pre_sa += 1;
                if use_lip {
                    self.stats.n_pre_lip += 1;
                }
                Ok(Issued { done_at: at + t_rp })
            }
            Command::PreAll { .. } => {
                let chan = &mut self.channels[ch];
                let rank = &mut chan.ranks[rank_idx];
                let mut done = at;
                for b in rank.banks.iter_mut() {
                    if !b.all_precharged() {
                        for sa in b.subarrays.iter_mut() {
                            sa.precharge();
                            sa.next_act = sa.next_act.max(at + t.t_rp);
                        }
                        b.open_cnt = 0;
                        b.next_act = b.next_act.max(at + t.t_rp);
                        b.last_sa = None;
                        done = done.max(at + t.t_rp);
                        self.stats.n_pre += 1;
                    }
                }
                Ok(Issued { done_at: done })
            }
            Command::Rd { bank, sa, .. } => {
                let chan = &mut self.channels[ch];
                let b = &mut chan.ranks[rank_idx].banks[bank];
                // Subarray-select hand-off (SALP-2/MASA): a switch
                // delays the data burst, so it pushes the bus pacing
                // and the read-to-precharge recovery along with it —
                // otherwise back-to-back bursts would overlap on the
                // shared channel.
                let mut sel = 0;
                if mode.has_sa_select() {
                    if b.last_sa != Some(sa) {
                        sel = t.t_sa_sel;
                        self.stats.n_sa_switch += 1;
                    }
                    b.last_sa = Some(sa);
                }
                b.next_pre = b.next_pre.max(at + t.t_rtp + sel);
                b.subarrays[sa].next_pre = b.subarrays[sa].next_pre.max(at + t.t_rtp + sel);
                chan.next_rd = chan.next_rd.max(at + t.t_ccd + sel);
                chan.next_wr = chan.next_wr.max(at + t.t_rtw + sel);
                self.stats.n_rd += 1;
                Ok(Issued { done_at: at + t.t_cl + t.t_bl + sel })
            }
            Command::Wr { bank, sa, .. } => {
                let chan = &mut self.channels[ch];
                let b = &mut chan.ranks[rank_idx].banks[bank];
                let mut sel = 0;
                if mode.has_sa_select() {
                    if b.last_sa != Some(sa) {
                        sel = t.t_sa_sel;
                        self.stats.n_sa_switch += 1;
                    }
                    b.last_sa = Some(sa);
                }
                // Write recovery counts from the (possibly delayed)
                // end of the data burst.
                let recover = at + t.t_cwl + t.t_bl + t.t_wr + sel;
                b.next_pre = b.next_pre.max(recover);
                b.subarrays[sa].next_pre = b.subarrays[sa].next_pre.max(recover);
                chan.next_wr = chan.next_wr.max(at + t.t_ccd + sel);
                chan.next_rd = chan.next_rd.max(at + t.t_cwl + t.t_bl + t.t_wtr + sel);
                self.stats.n_wr += 1;
                Ok(Issued { done_at: at + t.t_cwl + t.t_bl + sel })
            }
            Command::Ref { .. } => {
                let chan = &mut self.channels[ch];
                let rank = &mut chan.ranks[rank_idx];
                rank.busy_until = rank.busy_until.max(at + t.t_rfc);
                for b in rank.banks.iter_mut() {
                    b.next_act = b.next_act.max(at + t.t_rfc);
                }
                self.stats.n_ref += 1;
                Ok(Issued { done_at: at + t.t_rfc })
            }
            Command::Rbm { bank, from_sa, to_sa, .. } => {
                let hops = from_sa.abs_diff(to_sa) as u64;
                let chan = &mut self.channels[ch];
                let b = &mut chan.ranks[rank_idx].banks[bank];
                let tag = b.subarrays[from_sa].buffer_tag.expect("latched source"); // lint: allow(panic) reason=RBM legality requires an activated source subarray
                let end = at + hops * t.t_rbm;
                // Data latches into every row buffer along the path
                // (the property behind the paper's 1-to-N extension).
                let (lo, hi) = (from_sa.min(to_sa), from_sa.max(to_sa));
                for sa in lo..=hi {
                    if sa != from_sa {
                        // Path subarrays were precharged (validated);
                        // latching makes them non-precharged.
                        b.open_cnt += 1;
                    }
                    let s = &mut b.subarrays[sa];
                    if sa != from_sa {
                        s.state = SaState::LatchedOnly;
                        s.buffer_tag = Some(tag);
                        s.sense_done = end;
                        s.ras_done = end;
                    }
                    s.next_pre = s.next_pre.max(end);
                }
                // The link path spans the bank: composite occupancy.
                b.busy_until = b.busy_until.max(end);
                b.next_pre = b.next_pre.max(end);
                self.stats.n_rbm_hops += hops;
                Ok(Issued { done_at: end })
            }
            Command::Transfer { src_bank, dst_bank, cols, .. } => {
                let end = at + cols as u64 * t.t_ccd;
                let chan = &mut self.channels[ch];
                let rank = &mut chan.ranks[rank_idx];
                let tag = {
                    let sb = &rank.banks[src_bank];
                    let sa = sb.open_subarray().expect("open src row"); // lint: allow(panic) reason=Transfer legality requires an open source row
                    sb.subarrays[sa].buffer_tag.expect("latched src") // lint: allow(panic) reason=open subarray implies a latched buffer tag
                };
                {
                    let db = &mut rank.banks[dst_bank];
                    let dst_row = db.open_row().expect("open dst row"); // lint: allow(panic) reason=Transfer legality requires an open destination row
                    let dst_sa = db.open_subarray().unwrap(); // lint: allow(panic) reason=open_row() above proved a subarray is open
                    db.rows.insert(dst_row, tag);
                    db.subarrays[dst_sa].buffer_tag = Some(tag);
                    db.subarrays[dst_sa].next_pre = db.subarrays[dst_sa].next_pre.max(end);
                    db.busy_until = db.busy_until.max(end);
                    db.next_pre = db.next_pre.max(end);
                }
                {
                    let sb = &mut rank.banks[src_bank];
                    let src_sa = sb.open_subarray().expect("open src row"); // lint: allow(panic) reason=source row stays open across the transfer
                    sb.subarrays[src_sa].next_pre = sb.subarrays[src_sa].next_pre.max(end);
                    sb.busy_until = sb.busy_until.max(end);
                    sb.next_pre = sb.next_pre.max(end);
                }
                // The internal global bus shares the chip I/O path:
                // block channel RD/WR for the duration (RC-InterSA's
                // key system cost, paper §4.1 / Fig. 3).
                chan.next_rd = chan.next_rd.max(end);
                chan.next_wr = chan.next_wr.max(end);
                self.stats.n_transfer_cols += cols as u64;
                Ok(Issued { done_at: end })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Calibration;
    use crate::dram::timing::SpeedBin;

    fn dev() -> DramDevice {
        let cfg = DramConfig::default();
        let timing = Timing::new(SpeedBin::Ddr3_1600, &Calibration::default());
        DramDevice::new(cfg, LisaConfig::default(), timing)
    }

    fn dev_lisa() -> DramDevice {
        let cfg = DramConfig::default();
        let mut lisa = LisaConfig::default();
        lisa.risc = true;
        lisa.lip = true;
        let timing = Timing::new(SpeedBin::Ddr3_1600, &Calibration::default());
        DramDevice::new(cfg, lisa, timing)
    }

    const ACT0: Command = Command::Act { rank: 0, bank: 0, row: 10 };

    #[test]
    fn act_then_rd_respects_trcd() {
        let mut d = dev();
        d.issue(0, ACT0, 0).unwrap();
        let rd = Command::Rd { rank: 0, bank: 0, sa: 0, col: 3 };
        let e = d.earliest(0, rd, 0).unwrap();
        assert_eq!(e, d.timing.t_rcd);
        // Issuing early must fail.
        assert!(d.issue(0, rd, e - 1).is_err());
        let done = d.issue(0, rd, e).unwrap().done_at;
        assert_eq!(done, e + d.timing.t_cl + d.timing.t_bl);
    }

    #[test]
    fn act_on_open_bank_illegal_without_salp() {
        let mut d = dev();
        d.issue(0, ACT0, 0).unwrap();
        let act2 = Command::Act { rank: 0, bank: 0, row: 700 };
        assert!(d.earliest(0, act2, 100).is_err());
        // Other bank is fine.
        let act_other = Command::Act { rank: 0, bank: 1, row: 700 };
        assert!(d.earliest(0, act_other, 100).is_ok());
    }

    #[test]
    fn masa_allows_two_open_subarrays() {
        let mut d = dev();
        d.cfg.salp = SalpMode::Masa;
        d.issue(0, ACT0, 0).unwrap();
        let act2 = Command::Act { rank: 0, bank: 0, row: 700 }; // different SA
        let e = d.earliest(0, act2, 0).unwrap();
        assert!(e >= d.timing.t_rrd);
        d.issue(0, act2, e).unwrap();
        assert_eq!(d.bank(0, 0, 0).subarrays[0].open_row(), Some(10));
        assert_eq!(d.bank(0, 0, 0).subarrays[1].open_row(), Some(700));
        assert_eq!(d.bank(0, 0, 0).open_count(), 2);
    }

    #[test]
    fn pre_sa_requires_salp_mode_and_precharges_one_subarray() {
        let mut d = dev();
        d.issue(0, ACT0, 0).unwrap();
        let psa = Command::PreSa { rank: 0, bank: 0, sa: 0 };
        assert!(d.earliest(0, psa, 100).is_err(), "PRE_SA illegal in none mode");
        d.cfg.salp = SalpMode::Salp1;
        let e = d.earliest(0, psa, 0).unwrap();
        assert_eq!(e, d.timing.t_ras); // tRAS restore before precharge
        d.issue(0, psa, e).unwrap();
        assert!(d.bank(0, 0, 0).all_precharged());
        assert_eq!(d.stats.n_pre_sa, 1);
        assert_eq!(d.stats.n_pre, 1);
        // Already-precharged subarray is rejected.
        assert!(d.earliest(0, psa, e + 100).is_err());
    }

    #[test]
    fn salp1_overlaps_precharge_with_act_elsewhere() {
        let mut d = dev();
        d.cfg.salp = SalpMode::Salp1;
        d.issue(0, ACT0, 0).unwrap();
        let psa = Command::PreSa { rank: 0, bank: 0, sa: 0 };
        let e = d.earliest(0, psa, 0).unwrap();
        d.issue(0, psa, e).unwrap();
        // An ACT to a *different* subarray overlaps with subarray 0's
        // in-flight tRP...
        let act2 = Command::Act { rank: 0, bank: 0, row: 700 };
        let e2 = d.earliest(0, act2, e).unwrap();
        assert!(e2 < e + d.timing.t_rp, "e2={e2} should overlap tRP");
        // ...but reopening subarray 0 itself pays the full tRP.
        let act0b = Command::Act { rank: 0, bank: 0, row: 11 };
        let e0 = d.earliest(0, act0b, e).unwrap();
        assert_eq!(e0, e + d.timing.t_rp);
    }

    #[test]
    fn salp2_caps_open_subarrays_at_two() {
        let mut d = dev();
        d.cfg.salp = SalpMode::Salp2;
        d.issue(0, ACT0, 0).unwrap();
        let act2 = Command::Act { rank: 0, bank: 0, row: 700 };
        let e = d.earliest(0, act2, 0).unwrap();
        d.issue(0, act2, e).unwrap();
        // A third concurrently open subarray exceeds the cap.
        let act3 = Command::Act { rank: 0, bank: 0, row: 1500 };
        assert!(d.earliest(0, act3, 1000).is_err());
        // Closing one subarray restores headroom.
        let psa = Command::PreSa { rank: 0, bank: 0, sa: 0 };
        let ep = d.earliest(0, psa, 1000).unwrap();
        d.issue(0, psa, ep).unwrap();
        let e3 = d.earliest(0, act3, ep).unwrap();
        d.issue(0, act3, e3).unwrap();
        assert_eq!(d.bank(0, 0, 0).subarrays[2].open_row(), Some(1500));
        assert_eq!(d.bank(0, 0, 0).open_count(), 2);
    }

    #[test]
    fn masa_rd_pays_subarray_select_on_switch_only() {
        let mut d = dev();
        d.cfg.salp = SalpMode::Masa;
        let mut at = 0;
        for sa in 0..4usize {
            let act = Command::Act { rank: 0, bank: 0, row: sa * 512 + 7 };
            let e = d.earliest(0, act, at).unwrap();
            d.issue(0, act, e).unwrap();
            at = e + 1;
        }
        assert_eq!(d.bank(0, 0, 0).open_count(), 4);
        let t = d.timing.clone();
        at += t.t_rcd + t.t_ras; // everything sensed/restored
        let rd0 = Command::Rd { rank: 0, bank: 0, sa: 0, col: 0 };
        let e0 = d.earliest(0, rd0, at).unwrap();
        let d0 = d.issue(0, rd0, e0).unwrap().done_at;
        assert_eq!(d0, e0 + t.t_cl + t.t_bl + t.t_sa_sel, "fresh select pays");
        let rd0b = Command::Rd { rank: 0, bank: 0, sa: 0, col: 1 };
        let e0b = d.earliest(0, rd0b, e0 + 1).unwrap();
        let d0b = d.issue(0, rd0b, e0b).unwrap().done_at;
        assert_eq!(d0b, e0b + t.t_cl + t.t_bl, "same subarray: no switch");
        let rd3 = Command::Rd { rank: 0, bank: 0, sa: 3, col: 0 };
        let e3 = d.earliest(0, rd3, e0b + 1).unwrap();
        let d3 = d.issue(0, rd3, e3).unwrap().done_at;
        assert_eq!(d3, e3 + t.t_cl + t.t_bl + t.t_sa_sel, "switch pays again");
        assert_eq!(d.stats.n_sa_switch, 2);
    }

    #[test]
    fn pre_then_act_respects_trp() {
        let mut d = dev();
        d.issue(0, ACT0, 0).unwrap();
        let pre = Command::Pre { rank: 0, bank: 0 };
        let e_pre = d.earliest(0, pre, 0).unwrap();
        assert_eq!(e_pre, d.timing.t_ras); // tRAS before PRE
        d.issue(0, pre, e_pre).unwrap();
        let e_act = d.earliest(0, ACT0, e_pre).unwrap();
        assert_eq!(e_act, e_pre + d.timing.t_rp);
    }

    #[test]
    fn lip_shortens_precharge() {
        let mut d = dev_lisa();
        d.issue(0, ACT0, 0).unwrap();
        let pre = Command::Pre { rank: 0, bank: 0 };
        let e = d.earliest(0, pre, 0).unwrap();
        d.issue(0, pre, e).unwrap();
        assert_eq!(d.stats.n_pre_lip, 1);
        let e_act = d.earliest(0, ACT0, e).unwrap();
        assert_eq!(e_act, e + d.timing.t_rp_lip);
        assert!(d.timing.t_rp_lip < d.timing.t_rp);
    }

    #[test]
    fn pre_sa_links_precharge_units_under_lip() {
        let mut d = dev_lisa();
        d.cfg.salp = SalpMode::Masa;
        d.issue(0, ACT0, 0).unwrap();
        let psa = Command::PreSa { rank: 0, bank: 0, sa: 0 };
        let e = d.earliest(0, psa, 0).unwrap();
        d.issue(0, psa, e).unwrap();
        // Neighbor (subarray 1) was precharged, so LIP links apply.
        assert_eq!(d.stats.n_pre_lip, 1);
        let e_act = d.earliest(0, ACT0, e).unwrap();
        assert_eq!(e_act, e + d.timing.t_rp_lip);
    }

    #[test]
    fn faw_limits_act_burst() {
        let mut d = dev();
        let mut at = 0;
        for bank in 0..4 {
            let act = Command::Act { rank: 0, bank, row: 0 };
            let e = d.earliest(0, act, at).unwrap();
            d.issue(0, act, e).unwrap();
            at = e;
        }
        // Fifth ACT must wait for the tFAW window.
        let act5 = Command::Act { rank: 0, bank: 4, row: 0 };
        let e5 = d.earliest(0, act5, at).unwrap();
        assert!(e5 >= d.timing.t_faw, "e5={e5} < tFAW={}", d.timing.t_faw);
    }

    #[test]
    fn rowclone_intra_subarray_copies_tag() {
        let mut d = dev();
        d.set_row_tag(0, 0, 0, 10, 0xDEAD);
        d.issue(0, ACT0, 0).unwrap();
        let copy = Command::ActCopy { rank: 0, bank: 0, row: 20 };
        let e = d.earliest(0, copy, 0).unwrap();
        assert_eq!(e, d.timing.t_ras); // restore before reuse
        d.issue(0, copy, e).unwrap();
        assert_eq!(d.row_tag(0, 0, 0, 20), 0xDEAD);
        // Total latency anchor (Table 1): ACT + ACT + PRE = 83.75 ns.
        let pre = Command::Pre { rank: 0, bank: 0 };
        let e_pre = d.earliest(0, pre, e).unwrap();
        let done = d.issue(0, pre, e_pre).unwrap().done_at;
        assert!((d.timing.ns(done) - 83.75).abs() < 1.3, "got {}", d.timing.ns(done));
    }

    #[test]
    fn act_copy_rejects_cross_subarray_row() {
        let mut d = dev();
        d.issue(0, ACT0, 0).unwrap();
        // Row 700 is in subarray 1; buffer is latched in subarray 0.
        let copy = Command::ActCopy { rank: 0, bank: 0, row: 700 };
        assert!(d.earliest(0, copy, 100).is_err());
    }

    #[test]
    fn rbm_moves_tag_across_subarrays() {
        let mut d = dev_lisa();
        d.set_row_tag(0, 0, 0, 10, 0xBEEF);
        d.issue(0, ACT0, 0).unwrap();
        let rbm = Command::Rbm { rank: 0, bank: 0, from_sa: 0, to_sa: 7 };
        let e = d.earliest(0, rbm, 0).unwrap();
        assert_eq!(e, d.timing.t_ras); // source restored first
        let done = d.issue(0, rbm, e).unwrap().done_at;
        assert_eq!(done, e + 7 * d.timing.t_rbm);
        // Destination and every intermediate buffer latched the data.
        for sa in 1..=7 {
            assert_eq!(d.bank(0, 0, 0).subarrays[sa].state, SaState::LatchedOnly);
            assert_eq!(d.bank(0, 0, 0).subarrays[sa].buffer_tag, Some(0xBEEF));
        }
        // ACT_STORE writes it into a destination row.
        let store = Command::ActStore { rank: 0, bank: 0, row: 7 * 512 + 33 };
        let e2 = d.earliest(0, store, done).unwrap();
        d.issue(0, store, e2).unwrap();
        assert_eq!(d.row_tag(0, 0, 0, 7 * 512 + 33), 0xBEEF);
    }

    #[test]
    fn open_count_is_maintained_incrementally() {
        // Every transition class: ACT (pre -> open), RBM (path latches
        // -> non-precharged), ACT_STORE (latched -> open, no change),
        // PRE_SA (one down), PRE (all down). The incremental counter
        // must match a scan of subarray state after each.
        let mut d = dev_lisa();
        d.cfg.salp = SalpMode::Masa;
        let check = |d: &DramDevice, expect: usize| {
            let b = d.bank(0, 0, 0);
            assert_eq!(b.open_count(), expect);
            assert_eq!(b.open_count(), b.open_count_scan(), "counter drifted");
            assert_eq!(b.all_precharged(), expect == 0);
        };
        check(&d, 0);
        d.issue(0, ACT0, 0).unwrap();
        check(&d, 1);
        let rbm = Command::Rbm { rank: 0, bank: 0, from_sa: 0, to_sa: 3 };
        let e = d.earliest(0, rbm, 0).unwrap();
        d.issue(0, rbm, e).unwrap();
        check(&d, 4); // sa0 open + sa1..=3 latched
        let psa = Command::PreSa { rank: 0, bank: 0, sa: 1 };
        let ep = d.earliest(0, psa, e).unwrap();
        d.issue(0, psa, ep).unwrap();
        check(&d, 3);
        let store = Command::ActStore { rank: 0, bank: 0, row: 3 * 512 + 9 };
        let es = d.earliest(0, store, ep).unwrap();
        d.issue(0, store, es).unwrap();
        check(&d, 3); // latched -> open keeps the count
        let pre = Command::Pre { rank: 0, bank: 0 };
        let epre = d.earliest(0, pre, es).unwrap();
        d.issue(0, pre, epre).unwrap();
        check(&d, 0);
    }

    #[test]
    fn rbm_requires_precharged_path() {
        let mut d = dev_lisa();
        d.cfg.salp = SalpMode::Masa;
        d.issue(0, ACT0, 0).unwrap();
        // Open a row in subarray 3 (on the path 0 -> 7).
        let mid = Command::Act { rank: 0, bank: 0, row: 3 * 512 };
        let e = d.earliest(0, mid, 0).unwrap();
        d.issue(0, mid, e).unwrap();
        let rbm = Command::Rbm { rank: 0, bank: 0, from_sa: 0, to_sa: 7 };
        assert!(d.earliest(0, rbm, 1000).is_err());
    }

    #[test]
    fn masa_open_row_off_rbm_path_is_tolerated() {
        // The link-path conflict rule: only subarrays ON the hop path
        // must be precharged; an open row beyond the destination is
        // none of RBM's business (the composition LISA + MASA relies
        // on).
        let mut d = dev_lisa();
        d.cfg.salp = SalpMode::Masa;
        d.issue(0, ACT0, 0).unwrap(); // subarray 0
        let far = Command::Act { rank: 0, bank: 0, row: 12 * 512 };
        let e = d.earliest(0, far, 0).unwrap();
        d.issue(0, far, e).unwrap(); // subarray 12, off the 0->7 path
        let rbm = Command::Rbm { rank: 0, bank: 0, from_sa: 0, to_sa: 7 };
        let e_rbm = d.earliest(0, rbm, e).unwrap();
        d.issue(0, rbm, e_rbm).unwrap();
        assert_eq!(d.bank(0, 0, 0).subarrays[12].open_row(), Some(12 * 512));
    }

    #[test]
    fn transfer_moves_tag_and_blocks_channel() {
        let mut d = dev();
        d.set_row_tag(0, 0, 0, 10, 0xF00D);
        d.issue(0, ACT0, 0).unwrap();
        let act_dst = Command::Act { rank: 0, bank: 1, row: 99 };
        let e = d.earliest(0, act_dst, 0).unwrap();
        d.issue(0, act_dst, e).unwrap();
        let tr = Command::Transfer { rank: 0, src_bank: 0, dst_bank: 1, cols: 128 };
        let e_tr = d.earliest(0, tr, 0).unwrap();
        let done = d.issue(0, tr, e_tr).unwrap().done_at;
        assert_eq!(done, e_tr + 128 * d.timing.t_ccd);
        assert_eq!(d.row_tag(0, 0, 0 + 0, 10), 0xF00D); // src intact
        assert_eq!(d.row_tag(0, 0, 1, 99), 0xF00D); // dst copied
        // Channel reads blocked until the transfer drains.
        assert!(d.channels[0].next_rd >= done);
    }

    #[test]
    fn refresh_requires_precharged_and_blocks_rank() {
        let mut d = dev();
        d.issue(0, ACT0, 0).unwrap();
        assert!(d.earliest(0, Command::Ref { rank: 0 }, 0).is_err());
        let pre = Command::Pre { rank: 0, bank: 0 };
        let e = d.earliest(0, pre, 0).unwrap();
        d.issue(0, pre, e).unwrap();
        let e_ref = d.earliest(0, Command::Ref { rank: 0 }, e).unwrap();
        let done = d.issue(0, Command::Ref { rank: 0 }, e_ref).unwrap().done_at;
        assert_eq!(done, e_ref + d.timing.t_rfc);
        // Nothing can activate during tRFC.
        let e_act = d.earliest(0, ACT0, e_ref).unwrap();
        assert!(e_act >= done);
    }

    #[test]
    fn refresh_waits_out_per_subarray_precharge() {
        let mut d = dev();
        d.cfg.salp = SalpMode::Masa;
        d.issue(0, ACT0, 0).unwrap();
        let psa = Command::PreSa { rank: 0, bank: 0, sa: 0 };
        let e = d.earliest(0, psa, 0).unwrap();
        d.issue(0, psa, e).unwrap();
        // All banks precharged, but subarray 0's tRP is still in
        // flight: REF must not start under it.
        let e_ref = d.earliest(0, Command::Ref { rank: 0 }, e).unwrap();
        assert!(e_ref >= e + d.timing.t_rp, "e_ref={e_ref}");
    }

    #[test]
    fn wr_to_rd_turnaround() {
        let mut d = dev();
        d.issue(0, ACT0, 0).unwrap();
        let t_rcd = d.timing.t_rcd;
        let wr = Command::Wr { rank: 0, bank: 0, sa: 0, col: 0 };
        d.issue(0, wr, t_rcd).unwrap();
        let rd = Command::Rd { rank: 0, bank: 0, sa: 0, col: 1 };
        let e = d.earliest(0, rd, t_rcd).unwrap();
        let t = &d.timing;
        assert_eq!(e, t_rcd + t.t_cwl + t.t_bl + t.t_wtr);
    }

    #[test]
    fn villa_fast_subarray_uses_fast_timing() {
        let mut d = dev_lisa();
        d.lisa.villa = true;
        // Subarray 0 is fast; activate a row there.
        let act_fast = Command::Act { rank: 0, bank: 0, row: 5 };
        d.issue(0, act_fast, 0).unwrap();
        let rd = Command::Rd { rank: 0, bank: 0, sa: 0, col: 0 };
        let e = d.earliest(0, rd, 0).unwrap();
        assert_eq!(e, d.timing.t_rcd_fast);
        assert_eq!(d.stats.n_act_fast, 1);
    }

    #[test]
    fn default_tags_are_stable_and_distinct() {
        let d = dev();
        let t1 = d.row_tag(0, 0, 0, 1);
        let t2 = d.row_tag(0, 0, 0, 2);
        let t1b = d.row_tag(0, 0, 0, 1);
        assert_eq!(t1, t1b);
        assert_ne!(t1, t2);
    }
}
