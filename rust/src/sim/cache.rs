//! Content-addressed result cache for campaign jobs: finished records
//! are stored under `<cache-dir>/<code-version>/<key>.json`, where
//! `key` is the job's content key (`spec::job_key` — a hash over the
//! evaluation mode, base config TOML, axis coordinates, workload name
//! and the fully-built per-point `SimConfig::to_toml()`), so an
//! unchanged re-invocation re-runs zero points and a changed grid
//! re-runs exactly the points whose inputs changed.
//!
//! The code version (crate version + cache schema + the `build.rs`
//! source fingerprint) is part of the path *and* of the key text
//! itself: a rebuilt simulator never resurrects results computed by
//! different code. Writes go through a temp file + `rename` so a concurrent
//! campaign (or a `kill -9`) can never leave a half-written entry that
//! later reads as a hit; the stored key is verified on read as a
//! belt-and-braces check against renamed or corrupted files.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::metrics::json as emit;
use crate::util::json::{self, Value};

/// Bumped whenever the serialized record format (or anything else
/// that invalidates cached results without changing the crate
/// version) changes.
pub const CACHE_SCHEMA: u64 = 1;

/// The version component of the cache namespace: crate version, cache
/// schema, and the build fingerprint `build.rs` computes over the
/// crate sources — so any code change renames the namespace without a
/// hand bump. Folded into the job content key as well, so journals
/// written by other builds fail their key check on resume. The "dev"
/// fallback only appears when the crate is compiled without cargo
/// (no build script ran).
pub fn code_version() -> String {
    format!(
        "{}-s{}-b{}",
        env!("CARGO_PKG_VERSION"),
        CACHE_SCHEMA,
        option_env!("LISA_BUILD_FINGERPRINT").unwrap_or("dev")
    )
}

/// Handle on one version-namespace directory of the cache.
#[derive(Debug, Clone)]
pub struct ResultCache {
    dir: PathBuf,
}

impl ResultCache {
    /// Open (creating if needed) the cache at `dir`, namespaced by
    /// [`code_version`].
    pub fn open(dir: &Path) -> Result<Self> {
        let dir = dir.join(code_version());
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(Self { dir })
    }

    fn entry_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Look up a job's records. Any unreadable, unparseable or
    /// key-mismatched entry is a miss — the cache never errors a
    /// campaign, it only saves work.
    pub fn get(&self, key: &str) -> Option<Vec<Value>> {
        let text = std::fs::read_to_string(self.entry_path(key)).ok()?;
        let v = json::parse(&text).ok()?;
        if v.get("key")?.as_str()? != key {
            return None;
        }
        Some(v.get("records")?.as_array()?.to_vec())
    }

    /// Store a finished job's serialized records under `key`,
    /// atomically (temp file + rename).
    pub fn put(&self, key: &str, records_json: &[String]) -> Result<()> {
        let body = format!(
            "{{\"v\":{CACHE_SCHEMA},\"key\":{},\"records\":[{}]}}\n",
            emit::string(key),
            records_json.join(",")
        );
        let path = self.entry_path(key);
        // Unique temp name per call (pid + sequence), not just per
        // process: two threads putting the same key — duplicate axis
        // values, or concurrent library campaigns — must not
        // interleave writes into one temp file. Writers race only at
        // the (atomic) rename.
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = self.dir.join(format!("{key}.tmp.{}.{seq}", std::process::id()));
        std::fs::write(&tmp, body)
            .with_context(|| format!("writing cache entry {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing cache entry {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_cache(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("lisa-cache-test-{}-{tag}", std::process::id()))
    }

    #[test]
    fn put_then_get_round_trips_and_misses_are_none() {
        let dir = temp_cache("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        let key = "aaaabbbbccccddddeeeeffff00001111";
        assert!(cache.get(key).is_none(), "cold cache");
        cache.put(key, &["{\"ws\":1.5}".to_string(), "{\"ws\":null}".into()]).unwrap();
        let records = cache.get(key).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].get("ws").unwrap().as_f64(), Some(1.5));
        // Overwrites are atomic replacements, not appends.
        cache.put(key, &["{\"ws\":2.5}".to_string()]).unwrap();
        assert_eq!(cache.get(key).unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_or_mismatched_entries_read_as_misses() {
        let dir = temp_cache("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        let key = "00001111222233334444555566667777";
        cache.put(key, &["{\"x\":1}".to_string()]).unwrap();
        // Truncate the entry mid-document: miss, not error.
        let path = dir.join(code_version()).join(format!("{key}.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(cache.get(key).is_none());
        // An entry renamed onto the wrong key fails its stored-key check.
        std::fs::write(&path, text.replace(key, "deadbeef")).unwrap();
        assert!(cache.get(key).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cache_is_namespaced_by_code_version() {
        let version = code_version();
        assert!(version.contains(&format!("s{CACHE_SCHEMA}")));
        // A real build carries the build.rs source fingerprint, so a
        // changed simulator renames the namespace by itself.
        let (_, fp) = version.rsplit_once("-b").unwrap();
        assert_eq!(fp.len(), 16, "16-hex build fingerprint, got {fp:?}");
        assert!(fp.chars().all(|c| c.is_ascii_hexdigit()));
        let dir = temp_cache("namespace");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ResultCache::open(&dir).unwrap();
        let key = "ffff0000ffff0000ffff0000ffff0000";
        cache.put(key, &["1".to_string()]).unwrap();
        assert!(dir.join(code_version()).join(format!("{key}.json")).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
