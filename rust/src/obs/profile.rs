//! Harness self-profiling: per-campaign phase timers and per-worker
//! run/steal counters from the work-stealing scheduler, emitted as one
//! machine-readable `{"profile":…}` stderr line per campaign.

/// What one scheduler worker did: jobs popped from its own deque vs
/// jobs stolen from a victim.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    pub ran: u64,
    pub stolen: u64,
}

/// Where a campaign's wall time went. Phase times overlap-free except
/// `sim_ms` (scheduler wall time), which contains the sink phases —
/// serialization and journal/cache writes happen inside worker sinks.
#[derive(Debug, Clone, Default)]
pub struct CampaignProfile {
    pub threads: usize,
    /// Grid expansion + job construction.
    pub expand_ms: f64,
    /// Wall time of the work-stealing scheduler call (simulation).
    pub sim_ms: f64,
    /// Report serialization (`to_json`) inside the result sink.
    pub serialize_ms: f64,
    /// Journal checkpoint writes inside the result sink.
    pub journal_ms: f64,
    /// Result-cache lookups + write-throughs.
    pub cache_ms: f64,
    /// End-to-end campaign wall time.
    pub total_ms: f64,
    pub workers: Vec<WorkerStats>,
}

impl CampaignProfile {
    /// The `{"profile":…}` stderr line. Times are wall-clock and vary
    /// run to run; the shape (keys, worker count) is stable.
    pub fn to_json(&self) -> String {
        let workers = self
            .workers
            .iter()
            .map(|w| format!("{{\"ran\":{},\"stolen\":{}}}", w.ran, w.stolen))
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"profile\":{{\"threads\":{},\"phases_ms\":{{\"expand\":{:.3},\
             \"sim\":{:.3},\"serialize\":{:.3},\"journal\":{:.3},\
             \"cache\":{:.3},\"total\":{:.3}}},\"workers\":[{}]}}}}",
            self.threads,
            self.expand_ms,
            self.sim_ms,
            self.serialize_ms,
            self.journal_ms,
            self.cache_ms,
            self.total_ms,
            workers,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_line_is_parseable_and_shaped() {
        let p = CampaignProfile {
            threads: 2,
            expand_ms: 1.25,
            sim_ms: 100.0,
            serialize_ms: 3.0,
            journal_ms: 0.5,
            cache_ms: 2.0,
            total_ms: 110.0,
            workers: vec![
                WorkerStats { ran: 5, stolen: 1 },
                WorkerStats { ran: 3, stolen: 0 },
            ],
        };
        let line = p.to_json();
        let v = crate::util::json::parse(&line).unwrap();
        let prof = v.get("profile").expect("profile key");
        assert_eq!(prof.get("threads").and_then(|t| t.as_u64()), Some(2));
        let phases = prof.get("phases_ms").expect("phases");
        assert!(phases.get("sim").and_then(|x| x.as_f64()).unwrap() > 0.0);
        let workers = prof.get("workers").and_then(|w| w.as_array()).unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].get("stolen").and_then(|s| s.as_u64()), Some(1));
    }
}
