//! Per-core trace generators. Each generator captures one memory-
//! behaviour class the paper's workloads exercise:
//!
//! * `Stream`    — sequential scans with high row-buffer locality
//!                 (libquantum/streaming phases);
//! * `Random`    — uniform random lines over a working set
//!                 (mcf-like, row-buffer hostile);
//! * `PointerChase` — dependent loads, MLP = 1 (linked structures);
//! * `HotSpot`   — Zipf-ish skew: a small hot region absorbs most
//!                 accesses (the behaviour LISA-VILLA caches);
//! * `BulkCopy`  — periodic synchronous row copies over a working set,
//!                 with background accesses between them (fork /
//!                 bootup / compile / memcached-class behaviour,
//!                 §3.1: the 50 copy workloads).
//!
//! All generators are deterministic in (seed, parameters).

use crate::config::SimConfig;
use crate::cpu::trace::{Trace, TraceOp};
use crate::util::rng::Pcg32;
use crate::workloads::gc::{self, GcScenario};
use crate::workloads::os_scenarios::{self, OsScenario};

/// What one core runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkloadKind {
    Stream { stride: u64 },
    Random,
    PointerChase,
    HotSpot {
        hot_bytes: u64,
        hot_frac: f64,
        /// Fraction of accesses that are dependent loads (pointer
        /// chasing through the hot structure): these put raw DRAM
        /// latency on the critical path, which is what VILLA's fast
        /// subarrays improve.
        dep_frac: f64,
    },
    BulkCopy {
        /// Rows per copy call.
        rows: u32,
        /// Memory ops between consecutive copies.
        period: u32,
        /// Subarray distance class: copies land `hop_rows` rows away
        /// within the same bank (drives LISA hop counts).
        hop_rows: u64,
    },
    /// Intra-bank subarray ping-pong: bursts of sequential lines from
    /// rows in `subarrays` distinct subarrays of ONE bank, rotating
    /// subarrays between bursts. The row-buffer-hostile pattern SALP
    /// targets: the serialized baseline precharges on every rotation,
    /// MASA keeps all rotation targets open (experiment E10).
    SubarrayPingPong {
        /// Distinct subarrays visited round-robin.
        subarrays: u32,
        /// First subarray index (lets mixes place cores in disjoint
        /// subarray ranges of a shared bank).
        first_sa: u32,
        /// Rows used per subarray (cursor advances after each full
        /// column sweep of a row).
        rows: u32,
        /// Consecutive cache lines per visit.
        burst: u32,
        /// Target bank; `None` = the core's own bank (core % banks).
        bank: Option<u32>,
    },
    /// OS-level scenario (virtual addresses through the OS layer's
    /// page tables and frame allocator; see `workloads/os_scenarios`).
    Os(OsScenario),
    /// GC / heap-traversal scenario: dependent pointer chases with
    /// bulk evacuation phases, also virtual-address level (see
    /// `workloads/gc`).
    Gc(GcScenario),
}

/// A core's workload: kind + working set + intensity.
#[derive(Debug, Clone, Copy)]
pub struct CoreSpec {
    pub kind: WorkloadKind,
    /// Working set in bytes.
    pub wss: u64,
    /// Non-memory instructions per memory op (intensity; lower =
    /// more memory bound).
    pub nonmem: u32,
    /// Fraction of writes.
    pub write_frac: f64,
}

impl CoreSpec {
    /// Generate `n_ops` trace operations for core `core` (cores get
    /// disjoint address regions so mixes don't false-share).
    pub fn generate(&self, cfg: &SimConfig, core: usize, n_ops: usize, seed: u64) -> Trace {
        if let WorkloadKind::Os(scn) = self.kind {
            // OS scenarios are virtual-address traces; the OS layer
            // resolves placement at run time.
            return Trace::new(os_scenarios::generate(
                scn,
                cfg,
                core,
                n_ops,
                seed ^ cfg.seed,
                self.nonmem,
            ));
        }
        if let WorkloadKind::Gc(scn) = self.kind {
            // GC scenarios are virtual-address traces too.
            return Trace::new(gc::generate(
                scn,
                cfg,
                core,
                n_ops,
                seed ^ cfg.seed,
                self.nonmem,
            ));
        }
        let mut rng = Pcg32::new(seed ^ cfg.seed, core as u64 + 101);
        // Each core owns a disjoint region.
        let region = 64u64 << 20;
        let base = core as u64 * region;
        let wss = self.wss.min(region);
        let row_bytes = cfg.dram.row_bytes() as u64;
        let banks = cfg.dram.banks as u64;
        // Same-bank rows are `banks * row_bytes` apart in the default
        // (row : rank : bank : col : ch) mapping.
        let same_bank_row_stride = banks * row_bytes;

        let mut ops = Vec::with_capacity(n_ops);
        let mut cursor = 0u64;
        let mut ops_since_copy = 0u32;
        let mut pp_op = 0u64;
        for _ in 0..n_ops {
            let is_write = rng.chance(self.write_frac);
            match self.kind {
                WorkloadKind::Stream { stride } => {
                    cursor = (cursor + stride * 64) % wss;
                    ops.push(TraceOp::Mem {
                        nonmem: self.nonmem,
                        addr: base + cursor,
                        is_write,
                        dependent: false,
                    });
                }
                WorkloadKind::Random => {
                    let addr = base + (rng.below(wss / 64) * 64);
                    ops.push(TraceOp::Mem {
                        nonmem: self.nonmem,
                        addr,
                        is_write,
                        dependent: false,
                    });
                }
                WorkloadKind::PointerChase => {
                    let addr = base + (rng.below(wss / 64) * 64);
                    ops.push(TraceOp::Mem {
                        nonmem: self.nonmem,
                        addr,
                        is_write: false,
                        dependent: true,
                    });
                }
                WorkloadKind::HotSpot { hot_bytes, hot_frac, dep_frac } => {
                    // DRAM-level row heat: a Zipf-like (log-uniform)
                    // rank distribution over the hot region's rows,
                    // with a random line within the row. The hot region
                    // must exceed the LLC for the heat to be visible at
                    // DRAM (the behaviour LISA-VILLA exploits).
                    let hot = rng.chance(hot_frac);
                    let addr = if hot {
                        let n_rows = (hot_bytes / row_bytes).max(1);
                        // Squaring the uniform draw sharpens the skew
                        // (top-16 rows absorb ~60% of hot accesses),
                        // matching the row-reuse concentration of the
                        // paper's high-hit-rate workloads.
                        let u = rng.f64();
                        let rank = ((u * u) * (n_rows as f64).ln()).exp() as u64;
                        let row = rank.min(n_rows - 1);
                        let col = rng.below(row_bytes / 64) * 64;
                        base + row * row_bytes + col
                    } else {
                        base + hot_bytes + (rng.below((wss - hot_bytes).max(64) / 64) * 64)
                    };
                    let dependent = rng.chance(dep_frac);
                    ops.push(TraceOp::Mem {
                        nonmem: self.nonmem,
                        addr,
                        is_write: is_write && !dependent,
                        dependent,
                    });
                }
                WorkloadKind::BulkCopy { rows, period, hop_rows } => {
                    ops_since_copy += 1;
                    if ops_since_copy >= period {
                        ops_since_copy = 0;
                        // Copies span the full bank row space (they are
                        // row-aligned and only move content tags), so
                        // hop distances up to the paper's 15 subarrays
                        // are exercised regardless of the working-set
                        // size. Each core uses its own bank.
                        let bank = (core % cfg.dram.banks) as u64;
                        // Stay below the smallest possible mapped space
                        // (VILLA reserves up to one subarray per bank)
                        // so byte addresses never wrap across banks.
                        let n_bank_rows = (cfg.dram.rows_per_bank()
                            - cfg.dram.rows_per_subarray)
                            as u64;
                        let hop = hop_rows.max(1).min(n_bank_rows / 2).max(1);
                        // Saturating span: tiny geometries (few rows
                        // per bank) must clamp to a 1-row span rather
                        // than underflow into a u64-sized one.
                        let span = n_bank_rows
                            .saturating_sub(hop + rows as u64 + 1)
                            .max(1);
                        let src_row = rng.below(span);
                        let dst_row = src_row + hop;
                        let bank_off = bank * row_bytes;
                        let src = src_row * same_bank_row_stride + bank_off;
                        let dst = dst_row * same_bank_row_stride + bank_off;
                        ops.push(TraceOp::Copy {
                            nonmem: self.nonmem,
                            src,
                            dst,
                            rows,
                        });
                    } else {
                        // Background traffic between copies.
                        let addr = base + (rng.below(wss / 64) * 64);
                        ops.push(TraceOp::Mem {
                            nonmem: self.nonmem,
                            addr,
                            is_write,
                            dependent: false,
                        });
                    }
                }
                WorkloadKind::SubarrayPingPong { subarrays, first_sa, rows, burst, bank } => {
                    // Raw physical addresses (like BulkCopy): the
                    // subarray/bank targeting is the whole point, so
                    // the per-core `base` region is not used.
                    let n_sa = cfg.dram.subarrays_per_bank as u64;
                    let rows_per_sa = cfg.dram.rows_per_subarray as u64;
                    let cols = cfg.dram.columns as u64;
                    let s = (subarrays.max(1) as u64).min(n_sa);
                    let r = (rows.max(1) as u64).min(rows_per_sa);
                    let b_len = (burst.max(1) as u64).min(cols);
                    let bursts_per_row = (cols / b_len).max(1);
                    let bank_i = bank.map(|b| b as u64).unwrap_or((core % cfg.dram.banks) as u64);
                    let k = pp_op;
                    pp_op += 1;
                    let visit = k / b_len; // which burst
                    let sweep = visit / s; // bursts this subarray has had
                    let sa = (first_sa as u64 + visit % s) % n_sa;
                    let col = (sweep % bursts_per_row) * b_len + k % b_len;
                    let row_in_sa = (sweep / bursts_per_row) % r;
                    let global_row = sa * rows_per_sa + row_in_sa;
                    let addr = global_row * same_bank_row_stride + bank_i * row_bytes + col * 64;
                    ops.push(TraceOp::Mem {
                        nonmem: self.nonmem,
                        addr,
                        is_write,
                        dependent: false,
                    });
                }
                WorkloadKind::Os(_) | WorkloadKind::Gc(_) => unreachable!("handled above"),
            }
        }
        Trace::new(ops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    fn spec(kind: WorkloadKind) -> CoreSpec {
        CoreSpec { kind, wss: 32 << 20, nonmem: 4, write_frac: 0.2 }
    }

    #[test]
    fn generators_are_deterministic() {
        let c = cfg();
        for kind in [
            WorkloadKind::Stream { stride: 1 },
            WorkloadKind::Random,
            WorkloadKind::PointerChase,
            WorkloadKind::HotSpot { hot_bytes: 12 << 20, hot_frac: 0.9, dep_frac: 0.5 },
            WorkloadKind::BulkCopy { rows: 1, period: 50, hop_rows: 512 },
        ] {
            let a = spec(kind).generate(&c, 0, 500, 7);
            let b = spec(kind).generate(&c, 0, 500, 7);
            assert_eq!(a.ops, b.ops);
            let d = spec(kind).generate(&c, 0, 500, 8);
            assert_ne!(a.ops, d.ops, "{kind:?} ignores the seed");
        }
    }

    #[test]
    fn cores_use_disjoint_regions() {
        let c = cfg();
        let t0 = spec(WorkloadKind::Random).generate(&c, 0, 200, 1);
        let t1 = spec(WorkloadKind::Random).generate(&c, 1, 200, 1);
        let max0 = t0.ops.iter().map(|o| match o {
            TraceOp::Mem { addr, .. } => *addr,
            TraceOp::Copy { dst, .. } => *dst,
            TraceOp::Bulk { .. } => 0,
        }).max().unwrap();
        let min1 = t1.ops.iter().map(|o| match o {
            TraceOp::Mem { addr, .. } => *addr,
            TraceOp::Copy { src, .. } => *src,
            TraceOp::Bulk { .. } => u64::MAX,
        }).min().unwrap();
        assert!(max0 < min1, "core regions overlap");
    }

    #[test]
    fn stream_has_sequential_locality() {
        let c = cfg();
        let t = spec(WorkloadKind::Stream { stride: 1 }).generate(&c, 0, 100, 1);
        let addrs: Vec<u64> = t.ops.iter().map(|o| match o {
            TraceOp::Mem { addr, .. } => *addr,
            _ => unreachable!(),
        }).collect();
        for w in addrs.windows(2) {
            assert!(w[1] == w[0] + 64 || w[1] < w[0]); // +64 or wrap
        }
    }

    #[test]
    fn hotspot_skews_accesses() {
        let c = cfg();
        let hot_bytes = 12 << 20;
        let t = spec(WorkloadKind::HotSpot { hot_bytes, hot_frac: 0.9, dep_frac: 0.0 })
            .generate(&c, 0, 2000, 1);
        let hot = t.ops.iter().filter(|o| match o {
            TraceOp::Mem { addr, .. } => *addr < hot_bytes,
            _ => false,
        }).count();
        assert!(hot > 1600, "hot fraction {hot}/2000");
    }

    #[test]
    fn bulk_copy_emits_same_bank_row_aligned_copies() {
        let c = cfg();
        let t = spec(WorkloadKind::BulkCopy { rows: 1, period: 10, hop_rows: 512 })
            .generate(&c, 0, 500, 1);
        let copies: Vec<(u64, u64)> = t.ops.iter().filter_map(|o| match o {
            TraceOp::Copy { src, dst, .. } => Some((*src, *dst)),
            _ => None,
        }).collect();
        assert!(copies.len() >= 40, "{} copies", copies.len());
        use crate::controller::mapping::{Mapper, MappingScheme};
        let m = Mapper::new(&c.dram, MappingScheme::RowRankBankColCh);
        for (src, dst) in copies {
            let s = m.map(src);
            let d = m.map(dst);
            assert_eq!(s.bank, d.bank, "copy crosses banks");
            assert_eq!(s.col, 0);
            assert_ne!(s.row, d.row);
        }
    }

    #[test]
    fn subarray_pingpong_rotates_subarrays_within_one_bank() {
        use crate::controller::mapping::{Mapper, MappingScheme};
        let c = cfg();
        let kind = WorkloadKind::SubarrayPingPong {
            subarrays: 4,
            first_sa: 2,
            rows: 16,
            burst: 8,
            bank: Some(3),
        };
        let t = spec(kind).generate(&c, 0, 512, 1);
        let m = Mapper::new(&c.dram, MappingScheme::RowRankBankColCh);
        let mut seen_sas = std::collections::BTreeSet::new();
        let mut prev_sa = None;
        let mut switches = 0usize;
        for o in &t.ops {
            let TraceOp::Mem { addr, .. } = o else {
                panic!("mem only")
            };
            let a = m.map(*addr);
            assert_eq!(a.bank, 3, "fixed-bank pingpong left its bank");
            let sa = a.row / c.dram.rows_per_subarray;
            assert!((2..6).contains(&sa), "subarray {sa} outside [2,6)");
            if prev_sa.is_some() && prev_sa != Some(sa) {
                switches += 1;
            }
            prev_sa = Some(sa);
            seen_sas.insert(sa);
        }
        assert_eq!(seen_sas.len(), 4, "all four subarrays visited");
        // 512 ops / burst 8 = 64 bursts, each rotating the subarray.
        assert!(switches >= 60, "only {switches} subarray switches");
        // Deterministic and seed-sensitive like every other generator.
        let a = spec(kind).generate(&c, 0, 200, 7);
        let b = spec(kind).generate(&c, 0, 200, 7);
        assert_eq!(a.ops, b.ops);
    }

    #[test]
    fn pointer_chase_is_fully_dependent() {
        let c = cfg();
        let t = spec(WorkloadKind::PointerChase).generate(&c, 0, 50, 1);
        for o in &t.ops {
            assert!(matches!(o, TraceOp::Mem { dependent: true, .. }));
        }
    }
}
