"""Pure-jnp oracle for the bitline phase kernel.

Implements exactly the same two-node Euler integration as
kernels/bitline.py, with no Pallas — this is the correctness reference
the pytest / hypothesis suite compares the kernel against
(python/tests/test_kernel.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitline as bl


def phase_ref(va0, vb0, gmul, cmul, scalars, *, n_steps: int):
    """Reference implementation of kernels.bitline.phase (same signature,
    minus the Pallas tiling knobs)."""
    s = scalars
    dt = s[bl.S_DT]
    vdd = s[bl.S_VDD]
    vmid = vdd * 0.5
    thr = s[bl.S_SENSE_THR]
    tol = s[bl.S_SETTLE_TOL]
    tgt_a = s[bl.S_SETTLE_TGT]
    tgt_b = s[bl.S_SETTLE_TGT_B]
    settle_b = s[bl.S_SETTLE_B] > 0.5

    ga = s[bl.S_G_EXT_A] * gmul
    gb = s[bl.S_G_EXT_B] * gmul
    gl = s[bl.S_G_LINK] * gmul
    gma = s[bl.S_GM_A] * gmul
    gmb = s[bl.S_GM_B] * gmul
    inv_ca = 1.0 / (s[bl.S_C_A] * cmul)
    inv_cb = 1.0 / (s[bl.S_C_B] * cmul)

    zeros = jnp.zeros_like(va0)

    def body(i, carry):
        va, vb, ts, tt, en = carry
        t = (i.astype(jnp.float32) + 1.0) * dt
        i_a = ga * (s[bl.S_V_EXT_A] - va) + gl * (vb - va) + gma * (va - vmid)
        i_b = gb * (s[bl.S_V_EXT_B] - vb) + gl * (va - vb) + gmb * (vb - vmid)
        act_a = ((va > 0.0) & (va < vdd)).astype(va.dtype)
        act_b = ((vb > 0.0) & (vb < vdd)).astype(vb.dtype)
        p = (ga * jnp.abs(s[bl.S_V_EXT_A] - va)
             + gb * jnp.abs(s[bl.S_V_EXT_B] - vb)
             + gma * jnp.abs(va - vmid) * act_a
             + gmb * jnp.abs(vb - vmid) * act_b) * vdd
        en = en + p * dt
        va = jnp.clip(va + dt * i_a * inv_ca, 0.0, vdd)
        vb = jnp.clip(vb + dt * i_b * inv_cb, 0.0, vdd)
        crossed = jnp.abs(va - vmid) >= thr
        ts = jnp.where((ts < 0.0) & crossed, t, ts)
        out_a = jnp.abs(va - tgt_a) > tol
        out_b = jnp.abs(vb - tgt_b) > tol
        outside = jnp.where(settle_b, out_a | out_b, out_a)
        tt = jnp.where(outside, t, tt)
        return va, vb, ts, tt, en

    va, vb, ts, tt, en = jax.lax.fori_loop(
        0, n_steps, body, (va0, vb0, zeros - 1.0, zeros, zeros))
    ts = jnp.where(ts < 0.0, n_steps * dt, ts)
    return va, vb, ts, tt, en
