//! Observability: cycle-level command/copy tracing, per-request
//! latency attribution, and campaign-harness self-profiling. All three
//! tiers are strictly opt-in; with no probe attached and no `--obs`
//! flag, every controller hook is a single branch on a `None`.

pub mod attrib;
pub mod profile;
pub mod trace;

pub use attrib::{Attribution, ObsReport, RequestLatency};
pub use profile::{CampaignProfile, WorkerStats};
pub use trace::{
    to_chrome_trace, to_jsonl, Probe, SharedTraceRing, TraceEvent, TraceKind, TraceRing,
    DEFAULT_RING_CAP,
};

/// The controller's observability state: an optional external probe
/// (tracing) and an optional attribution engine (`--obs`), both fed
/// from the same event stream by one `observe` call.
pub struct Obs {
    pub probe: Option<Box<dyn Probe>>,
    pub attrib: Option<Attribution>,
}

impl Obs {
    pub fn new() -> Self {
        Obs { probe: None, attrib: None }
    }

    pub fn observe(&mut self, ev: &TraceEvent) {
        if let Some(p) = self.probe.as_mut() {
            p.record(ev);
        }
        if let Some(a) = self.attrib.as_mut() {
            a.observe(ev);
        }
    }
}

impl Default for Obs {
    fn default() -> Self {
        Self::new()
    }
}
