//! `lisa` — CLI for the LISA reproduction: calibration, single
//! workload runs, and the paper's experiments (E1-E8).

use std::path::Path;

use anyhow::{bail, Result};

use lisa::cli::Args;
use lisa::config::{CopyMechanism, PlacementPolicy, SalpMode, SimConfig};
use lisa::dram::timing::SpeedBin;
use lisa::sim::campaign;
use lisa::sim::engine::run_workload;
use lisa::sim::experiments as exp;
use lisa::util::bench::Table;
use lisa::workloads::mixes;

const USAGE: &str = "\
lisa — LISA (Low-Cost Inter-Linked Subarrays) full-system reproduction

USAGE: lisa <command> [options]

COMMANDS
  calibrate   --artifacts DIR [--out FILE]   run the circuit model via PJRT,
                                             write calibration.toml
                                             (needs the `runtime` feature)
  run         --workload NAME [--config F] [--requests N] [--threads N] [--ws]
  sweep       [--mechs A,B] [--speeds A,B] [--workloads A,B | --mixes N]
              [--requests N] [--threads N] [--out FILE]
              parallel {mechanism x workload x speed-bin} campaign,
              JSON report to --out (or stdout)
  list-workloads
  table1      [--config F]                   E1: 8 KB copy latency/energy
  rbm         E2: RBM bandwidth vs channel
  lip         E3: linked precharge latency
  fig3        [--requests N] [--mixes N] [--threads N]   E4: LISA-VILLA
  fig4        [--requests N] [--mixes N] [--threads N]   E5/E6: combined speedups
  lip-system  [--requests N] [--mixes N] [--threads N]   E7: LIP system-level
  area        E8: die area overhead
  os          [--requests N] [--threads N] [--mechs A,B] [--policies A,B]
              [--scenarios A,B] [--out FILE]
              E9: OS-level bulk ops (fork / zeroing / checkpoint /
              promotion) across copy mechanisms x placement policies,
              JSON report to --out (or stdout)
  salp        [--requests N] [--threads N] [--mechs A,B] [--modes A,B]
              [--policies A,B] [--workloads A,B] [--out FILE]
              E10: subarray-level parallelism (none|salp1|salp2|masa)
              composed with LISA across copy mechanisms x placement
              policies on intra-bank-conflict workloads,
              JSON report to --out (or stdout)

`--threads 0` (or omitting --threads) auto-detects the available
hardware parallelism on every campaign-backed subcommand.
";

const COMMANDS: &[&str] = &[
    "calibrate",
    "run",
    "sweep",
    "list-workloads",
    "table1",
    "rbm",
    "lip",
    "fig3",
    "fig4",
    "lip-system",
    "area",
    "os",
    "salp",
];

fn load_config(args: &Args) -> Result<SimConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => SimConfig::from_file(Path::new(path))?,
        None => SimConfig::default(),
    };
    // Overlay calibration.toml if present (produced by `lisa calibrate`).
    let cal_path = Path::new(args.opt_or("calibration", "artifacts/calibration.toml"));
    if cal_path.exists() {
        let doc = lisa::config::minitoml::Document::parse(&std::fs::read_to_string(
            cal_path,
        )?)?;
        cfg.apply(&doc)?;
    }
    if let Some(n) = args.opt_u64("requests")? {
        cfg.requests_per_core = n;
    }
    if let Some(s) = args.opt_u64("seed")? {
        cfg.seed = s;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let Some(cmd) = args.check_subcommand(COMMANDS)?.map(str::to_string) else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        "calibrate" => cmd_calibrate(&args),
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "list-workloads" => {
            let cfg = SimConfig::default();
            for w in mixes::all_mixes(&cfg) {
                println!("{}", w.name);
            }
            Ok(())
        }
        "table1" => cmd_table1(&args),
        "rbm" => {
            let cfg = load_config(&args)?;
            let r = exp::rbm_report(&cfg.calibration);
            println!(
                "RBM: {} B/hop in {:.2} ns = {:.0} GB/s vs channel {:.1} GB/s -> {:.1}x \
                 (paper: 500 GB/s vs 19.2 GB/s, 26x)",
                r.row_bytes, r.hop_ns, r.gbps, r.channel_gbps, r.speedup
            );
            Ok(())
        }
        "lip" => {
            let cfg = load_config(&args)?;
            let r = exp::lip_circuit_report(&cfg.calibration);
            println!(
                "LIP precharge: {:.2} ns vs baseline {:.2} ns = {:.2}x \
                 (paper: 5 ns vs 13 ns, 2.6x); tRP {} -> {} cycles",
                r.t_rp_lip_ns, r.t_rp_circuit_ns, r.speedup, r.t_rp_cycles, r.t_rp_lip_cycles
            );
            Ok(())
        }
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig4(&args),
        "lip-system" => cmd_lip_system(&args),
        "os" => cmd_os(&args),
        "salp" => cmd_salp(&args),
        "area" => {
            let cfg = load_config(&args)?;
            let r = exp::area_report(&cfg);
            println!(
                "LISA area overhead: {:.3}% iso transistors ({} devices) + {:.3}% control \
                 = {:.3}% total (paper: 0.8%)",
                r.iso_fraction * 100.0,
                r.n_iso_transistors,
                r.control_fraction * 100.0,
                r.total_fraction * 100.0
            );
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

#[cfg(feature = "runtime")]
fn cmd_calibrate(args: &Args) -> Result<()> {
    use lisa::runtime::{calibrate, CalibrationInputs, Runtime};
    let dir = Path::new(args.opt_or("artifacts", "artifacts"));
    let out = args.opt_or("out", "artifacts/calibration.toml");
    let runtime = Runtime::new(dir)?;
    eprintln!("PJRT platform: {}", runtime.platform());
    let cal = calibrate(&runtime, &CalibrationInputs::default())?;
    println!(
        "calibrated: tRBM={:.2} ns  tRP(lip)={:.2} ns  tRP(circuit)={:.2} ns  \
         fast ratios act/ras/rp = {:.2}/{:.2}/{:.2}",
        cal.t_rbm_ns,
        cal.t_rp_lip_ns,
        cal.t_rp_circuit_ns,
        cal.fast_act_ratio,
        cal.fast_ras_ratio,
        cal.fast_rp_ratio
    );
    std::fs::write(out, SimConfig::calibration_toml(&cal))?;
    println!("wrote {out}");
    Ok(())
}

#[cfg(not(feature = "runtime"))]
fn cmd_calibrate(_args: &Args) -> Result<()> {
    bail!(
        "the PJRT calibration path is not compiled in; rebuild with \
         `cargo build --features runtime` (the simulator ships with the \
         same values as checked-in defaults, so calibration is optional)"
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let name = args.opt_or("workload", "stream4");
    let threads = parse_threads(args)?;
    let wl = mixes::workload_by_name(name, &cfg)?;
    if args.has_flag("ws") {
        // The N alone runs + the shared run go through the campaign
        // runner (deterministic regardless of --threads).
        let (ws, report) = campaign::weighted_speedup(&cfg, &wl, threads);
        println!("workload={name} config={} WS={ws:.3}", report.config_name);
        print_report(&report);
    } else {
        let report = run_workload(&cfg, &wl);
        print_report(&report);
    }
    Ok(())
}

/// Parse a comma-separated list through an item parser.
fn parse_list<T>(s: &str, parse: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(parse)
        .collect()
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let base = load_config(args)?;
    let requests = args.opt_u64("requests")?.unwrap_or(2_000);
    let threads = parse_threads(args)?;
    let mechanisms =
        parse_list(args.opt_or("mechs", "memcpy,lisa-risc"), CopyMechanism::parse)?;
    let speeds = parse_list(args.opt_or("speeds", "ddr3-1600"), SpeedBin::parse)?;
    let workloads: Vec<String> = match args.opt("workloads") {
        Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
        None => {
            // Default grid: the micro suite plus the first N copy mixes.
            let n_mixes = args.opt_usize("mixes")?.unwrap_or(4);
            let mut w: Vec<String> =
                vec!["stream4".into(), "random4".into(), "hotspot4".into(), "fork4".into()];
            w.extend((0..n_mixes).map(|i| format!("copy-mix-{i:02}")));
            w
        }
    };
    let spec = campaign::SweepSpec { base, mechanisms, speeds, workloads, requests, threads };
    let n_points = spec.points().len();
    eprintln!("sweep: {n_points} points on {threads} threads");
    let t0 = std::time::Instant::now();
    let rows = campaign::run_sweep(&spec)?;
    eprintln!("sweep: done in {:.2} s", t0.elapsed().as_secs_f64());

    let mut table = Table::new(&[
        "workload", "speed", "mechanism", "cycles", "IPC sum", "copies", "energy uJ",
    ]);
    for r in &rows {
        table.row(&[
            r.workload.clone(),
            r.speed.to_string(),
            r.mechanism.to_string(),
            format!("{}", r.report.dram_cycles),
            format!("{:.3}", r.report.ipc_sum()),
            format!("{}", r.report.copies),
            format!("{:.1}", r.report.energy.total),
        ]);
    }
    let json = campaign::sweep_json(&rows);
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &json)?;
            table.print();
            println!("wrote {path}");
        }
        None => {
            // JSON goes to stdout (machine-parseable / pipeable); the
            // human-readable table joins the progress lines on stderr.
            eprintln!("{}", table.render());
            print!("{json}");
        }
    }
    Ok(())
}

fn print_report(r: &lisa::metrics::RunReport) {
    println!(
        "workload={} config={} cycles={} reads={} writes={} copies={}",
        r.workload, r.config_name, r.dram_cycles, r.reads, r.writes, r.copies
    );
    println!(
        "  IPC={:?} (sum {:.3})  read-lat={:.1} cyc  row-hit={:.1}%  villa-hit={:.1}%  \
         lip-cov={:.1}%",
        r.ipc.iter().map(|i| (i * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
        r.ipc_sum(),
        r.avg_read_latency_cycles,
        r.row_hit_rate * 100.0,
        r.villa_hit_rate * 100.0,
        r.lip_coverage * 100.0
    );
    println!(
        "  energy: total {:.1} uJ (dynamic {:.1}, background {:.1}, rbm {:.3})",
        r.energy.total,
        r.energy.dynamic_uj(),
        r.energy.background_uj,
        r.energy.rbm_uj
    );
}

fn cmd_table1(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rows = exp::table1(&cfg.calibration)?;
    let mut t = Table::new(&[
        "mechanism",
        "paper ns",
        "ours ns",
        "paper uJ",
        "ours uJ",
    ]);
    for r in rows {
        t.row(&[
            r.label,
            format!("{:.2}", r.paper_latency_ns),
            format!("{:.2}", r.latency_ns),
            format!("{:.3}", r.paper_energy_uj),
            format!("{:.3}", r.energy_uj),
        ]);
    }
    t.print();
    Ok(())
}

/// `--threads N` — shared by every campaign-backed subcommand. Absent
/// or `0` auto-detects the available hardware parallelism.
fn parse_threads(args: &Args) -> Result<usize> {
    Ok(campaign::resolve_threads(args.opt_usize("threads")?))
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let requests = args.opt_u64("requests")?.unwrap_or(3_000);
    let mixes_n = args.opt_usize("mixes")?.unwrap_or(8);
    let rows = exp::fig3(requests, mixes_n, parse_threads(args)?);
    let mut t = Table::new(&["workload", "villa +%", "hit rate %", "rc-inter +%"]);
    for r in &rows {
        t.row(&[
            r.workload.clone(),
            format!("{:+.1}", r.villa_improvement * 100.0),
            format!("{:.1}", r.villa_hit_rate * 100.0),
            format!("{:+.1}", r.rc_inter_improvement * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let requests = args.opt_u64("requests")?.unwrap_or(3_000);
    let mixes_n = args.opt_usize("mixes")?.unwrap_or(50);
    let cmps = exp::fig4(requests, mixes_n, parse_threads(args)?);
    let mut t = Table::new(&["config", "mean WS +%", "geomean x", "max +%", "energy -%"]);
    for c in &cmps {
        t.row(&[
            c.name.clone(),
            format!("{:+.1}", c.mean_ws_improvement() * 100.0),
            format!("{:.3}", c.geomean_speedup()),
            format!("{:+.1}", c.max_ws_improvement() * 100.0),
            format!("{:.1}", c.mean_energy_reduction() * 100.0),
        ]);
    }
    t.print();
    println!("(paper Fig. 4: RISC +59.6%, +VILLA +16.5% over RISC, +LIP +8.8% over RISC+VILLA, all +94.8%, energy -49%)");
    Ok(())
}

fn cmd_os(args: &Args) -> Result<()> {
    let requests = args.opt_u64("requests")?.unwrap_or(2_000);
    let threads = parse_threads(args)?;
    let mechanisms = match args.opt("mechs") {
        Some(s) => parse_list(s, CopyMechanism::parse)?,
        None => exp::E9_MECHANISMS.to_vec(),
    };
    let policies = match args.opt("policies") {
        Some(s) => parse_list(s, PlacementPolicy::parse)?,
        None => PlacementPolicy::ALL.to_vec(),
    };
    let scenarios: Vec<String> = match args.opt("scenarios") {
        Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
        None => exp::E9_SCENARIOS.iter().map(|s| s.to_string()).collect(),
    };
    let n = scenarios.len() * mechanisms.len() * policies.len();
    eprintln!("os: {n} points on {threads} threads");
    let t0 = std::time::Instant::now();
    let rows = exp::e9_os(requests, &mechanisms, &policies, &scenarios, threads)?;
    eprintln!("os: done in {:.2} s", t0.elapsed().as_secs_f64());

    let mut table = Table::new(&[
        "scenario", "mechanism", "policy", "cycles", "IPC sum", "pages", "RISC hit %",
        "faults",
    ]);
    for r in &rows {
        let os = r.report.os.clone().unwrap_or_default();
        table.row(&[
            r.scenario.clone(),
            r.mechanism.to_string(),
            r.policy.to_string(),
            format!("{}", r.report.dram_cycles),
            format!("{:.3}", r.report.ipc_sum()),
            format!("{}", os.pages_copied),
            format!("{:.1}", os.risc_hit_rate() * 100.0),
            format!("{}", os.cow_faults + os.demand_faults),
        ]);
    }
    let json = exp::os_json(&rows);
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &json)?;
            table.print();
            println!("wrote {path}");
        }
        None => {
            eprintln!("{}", table.render());
            print!("{json}");
        }
    }
    Ok(())
}

fn cmd_salp(args: &Args) -> Result<()> {
    let requests = args.opt_u64("requests")?.unwrap_or(2_000);
    let threads = parse_threads(args)?;
    let mechanisms = match args.opt("mechs") {
        Some(s) => parse_list(s, CopyMechanism::parse)?,
        None => exp::E10_MECHANISMS.to_vec(),
    };
    let modes = match args.opt("modes") {
        Some(s) => parse_list(s, SalpMode::parse)?,
        None => SalpMode::ALL.to_vec(),
    };
    let policies = match args.opt("policies") {
        Some(s) => parse_list(s, PlacementPolicy::parse)?,
        None => PlacementPolicy::ALL.to_vec(),
    };
    let workloads: Vec<String> = match args.opt("workloads") {
        Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
        None => exp::E10_WORKLOADS.iter().map(|s| s.to_string()).collect(),
    };
    let n = workloads.len() * mechanisms.len() * modes.len() * policies.len();
    eprintln!("salp: {n} points on {threads} threads");
    let t0 = std::time::Instant::now();
    let rows = exp::e10_salp(requests, &mechanisms, &modes, &policies, &workloads, threads)?;
    eprintln!("salp: done in {:.2} s", t0.elapsed().as_secs_f64());

    let mut table = Table::new(&[
        "workload", "mechanism", "mode", "policy", "cycles", "IPC sum", "row-hit %",
        "copies",
    ]);
    for r in &rows {
        table.row(&[
            r.workload.clone(),
            r.mechanism.to_string(),
            r.mode.to_string(),
            r.policy.to_string(),
            format!("{}", r.report.dram_cycles),
            format!("{:.3}", r.report.ipc_sum()),
            format!("{:.1}", r.report.row_hit_rate * 100.0),
            format!("{}", r.report.copies),
        ]);
    }
    let json = exp::salp_json(&rows);
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &json)?;
            table.print();
            println!("wrote {path}");
        }
        None => {
            eprintln!("{}", table.render());
            print!("{json}");
        }
    }
    Ok(())
}

fn cmd_lip_system(args: &Args) -> Result<()> {
    let requests = args.opt_u64("requests")?.unwrap_or(3_000);
    let mixes_n = args.opt_usize("mixes")?.unwrap_or(50);
    let c = exp::lip_system(requests, mixes_n, parse_threads(args)?);
    println!(
        "LISA-LIP: mean WS improvement {:+.1}% across {} mixes (paper: +10.3%)",
        c.mean_ws_improvement() * 100.0,
        c.ws_improvements.len()
    );
    Ok(())
}
