//! Trace file writer.
//!
//! Streams each core's ops through a bounded buffer (flushed every 64
//! KiB) while tracking stream offsets, then seeks back and patches
//! the fixed-width directory. Writing is as memory-bounded as
//! reading.

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::cpu::trace::Trace;
use crate::trace::format::{
    self, StreamDesc, TraceHeader, MAX_CORES, MAX_NAME_BYTES,
};
use crate::trace::reader::CHUNK_BYTES;

/// Write `traces` (one per core) as a binary trace file named `name`.
pub fn write_trace(path: &Path, name: &str, traces: &[Trace]) -> Result<()> {
    if traces.is_empty() || traces.len() > MAX_CORES as usize {
        bail!("trace must have 1..={MAX_CORES} core streams, got {}", traces.len());
    }
    if name.len() > MAX_NAME_BYTES as usize {
        bail!("workload name is {} bytes (limit {MAX_NAME_BYTES})", name.len());
    }
    for (core, t) in traces.iter().enumerate() {
        if t.ops.is_empty() {
            bail!("core {core} has an empty op stream (replay cycles over ops, so every core needs at least one)");
        }
    }

    let file = File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    let mut w = BufWriter::new(file);

    // Placeholder header: correct fixed part + name, zeroed directory,
    // patched once stream offsets are known.
    let mut header = TraceHeader {
        name: name.to_string(),
        streams: vec![StreamDesc { op_count: 0, offset: 0, len: 0 }; traces.len()],
    };
    w.write_all(&header.encode()).context("writing trace header")?;

    let mut offset = TraceHeader::byte_len(name, traces.len());
    let mut buf: Vec<u8> = Vec::with_capacity(CHUNK_BYTES + 64);
    for (core, t) in traces.iter().enumerate() {
        let mut len = 0u64;
        let mut prev = 0u64;
        for op in &t.ops {
            format::encode_op(&mut buf, op, &mut prev);
            if buf.len() >= CHUNK_BYTES {
                len += buf.len() as u64;
                w.write_all(&buf)
                    .with_context(|| format!("writing core {core} stream"))?;
                buf.clear();
            }
        }
        len += buf.len() as u64;
        w.write_all(&buf).with_context(|| format!("writing core {core} stream"))?;
        buf.clear();
        header.streams[core] =
            StreamDesc { op_count: t.ops.len() as u64, offset, len };
        offset += len;
    }

    // Patch the real directory in place.
    w.seek(SeekFrom::Start(0)).context("seeking back to the trace header")?;
    w.write_all(&header.encode()).context("patching the trace directory")?;
    let file = w
        .into_inner()
        .map_err(|e| anyhow!("flushing trace file: {e}"))?;
    file.sync_all()
        .with_context(|| format!("syncing trace file {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::trace::{BulkOp, TraceOp};
    use crate::trace::reader::TraceReader;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("lisa-trace-writer-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn write_then_read_round_trips() {
        let t0 = Trace::new(vec![
            TraceOp::Mem { nonmem: 4, addr: 4096, is_write: false, dependent: false },
            TraceOp::Copy { nonmem: 10, src: 0, dst: 8192, rows: 1 },
            TraceOp::Bulk { nonmem: 60, op: BulkOp::Fork },
        ]);
        let t1 = Trace::new(vec![TraceOp::Bulk {
            nonmem: 4,
            op: BulkOp::Touch { va: 12288, is_write: true, dependent: true },
        }]);
        let path = tmp("roundtrip.trc");
        write_trace(&path, "mix-a", &[t0.clone(), t1.clone()]).unwrap();

        let mut rd = TraceReader::open(&path).unwrap();
        assert_eq!(rd.header().name, "mix-a");
        assert_eq!(rd.header().streams.len(), 2);
        assert_eq!(rd.header().streams[0].op_count, 3);
        let ops0 = rd.ops(0).unwrap().collect_ops().unwrap();
        let ops1 = rd.ops(1).unwrap().collect_ops().unwrap();
        assert_eq!(ops0, t0.ops);
        assert_eq!(ops1, t1.ops);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_streams_are_rejected() {
        let path = tmp("empty.trc");
        let err = write_trace(&path, "x", &[Trace::new(vec![])]).unwrap_err().to_string();
        assert!(err.contains("empty op stream"), "{err}");
        assert!(write_trace(&path, "x", &[]).is_err());
    }

    #[test]
    fn streams_larger_than_one_chunk_flush_incrementally() {
        // ~200k ops is several chunks of encoded bytes.
        let ops: Vec<TraceOp> = (0..200_000u64)
            .map(|i| TraceOp::Mem {
                nonmem: 2,
                addr: i * 64,
                is_write: i % 7 == 0,
                dependent: false,
            })
            .collect();
        let t = Trace::new(ops);
        let path = tmp("big.trc");
        write_trace(&path, "big", &[t.clone()]).unwrap();
        let mut rd = TraceReader::open(&path).unwrap();
        let back = rd.ops(0).unwrap().collect_ops().unwrap();
        assert_eq!(back.len(), t.ops.len());
        assert_eq!(back[199_999], t.ops[199_999]);
        // The reader stayed within its chunk budget the whole way.
        assert!(
            rd.high_water() <= CHUNK_BYTES + 4096,
            "high water {} exceeds chunk budget",
            rd.high_water()
        );
        std::fs::remove_file(&path).ok();
    }
}
