#!/usr/bin/env python3
"""Validate a Chrome trace-event export from `lisa exp ... --trace-point
IDX --trace-out FILE` (or a `.jsonl` line-delimited export).

Checks, in order:
  1. the file is well-formed JSON (one object with a `traceEvents`
     array, or one JSON object per line for `.jsonl`);
  2. every complete slice (`"ph":"X"`) carries numeric ts/dur/pid/tid
     and a non-empty name, with dur >= 0;
  3. timestamps are monotone non-decreasing per (pid, tid) track;
  4. the trace is non-trivial: it has slices, at least two distinct
     tracks, and row activity (an ACT slice).

Exits non-zero with a message on the first violated invariant; prints a
one-line summary on success. Stdlib only (CI runs it bare).
"""

import json
import sys


def fail(msg):
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load_slices(path):
    """Return the slice records, normalizing both export formats."""
    with open(path) as f:
        text = f.read()
    if path.endswith(".jsonl"):
        # One flat event object per line; synthesize the slice fields
        # the checks below expect from the JSONL schema.
        slices = []
        for i, line in enumerate(text.splitlines()):
            if not line.strip():
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                fail(f"line {i + 1} is not valid JSON: {e}")
            slices.append(
                {
                    "ph": "X",
                    "name": ev["kind"],
                    "ts": ev["cycle"],
                    "dur": max(0, ev["done"] - ev["cycle"]),
                    "pid": ev["ch"],
                    # Same track encoding as the Chrome exporter.
                    "tid": ev["rank"] * 4096
                    + (ev["bank"] + 1) * 64
                    + (ev["sa"] + 1),
                }
            )
        return slices
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as e:
        fail(f"not valid JSON: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("missing traceEvents array")
    return events


def main():
    if len(sys.argv) != 2:
        fail("usage: validate_trace.py TRACE_FILE")
    path = sys.argv[1]
    events = load_slices(path)
    if not events:
        fail("empty trace")
    last_ts = {}
    kinds = {}
    for e in events:
        ph = e.get("ph")
        if ph == "M":
            if e.get("name") not in ("process_name", "thread_name"):
                fail(f"unexpected metadata record {e!r}")
            continue
        if ph != "X":
            fail(f"unexpected phase {ph!r} in {e!r}")
        name = e.get("name")
        if not name:
            fail(f"slice without a name: {e!r}")
        for field in ("ts", "dur", "pid", "tid"):
            if not isinstance(e.get(field), (int, float)):
                fail(f"slice field {field!r} not numeric in {e!r}")
        if e["dur"] < 0:
            fail(f"negative duration in {e!r}")
        track = (e["pid"], e["tid"])
        if last_ts.get(track, e["ts"]) > e["ts"]:
            fail(f"timestamps regressed on track {track} at ts={e['ts']}")
        last_ts[track] = e["ts"]
        kinds[name] = kinds.get(name, 0) + 1
    if not kinds:
        fail("no slices, only metadata")
    if len(last_ts) < 2:
        fail(f"expected >= 2 distinct tracks, got {sorted(last_ts)}")
    if "ACT" not in kinds:
        fail(f"no ACT slice (kinds seen: {sorted(kinds)})")
    total = sum(kinds.values())
    summary = " ".join(f"{k}:{v}" for k, v in sorted(kinds.items()))
    print(
        f"validate_trace: OK: {total} slices on {len(last_ts)} tracks ({summary})"
    )


if __name__ == "__main__":
    main()
