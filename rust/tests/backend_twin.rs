//! Cross-validation contract between the memory-model backends: the
//! calibrated analytical twin must track the cycle-exact controller
//! within the stated IPC / weighted-speedup error bands and agree on
//! every decisive mechanism ranking, over (trimmed) registry grids.
//! The flip side is pinned too: `backend=cycle` — explicit or default
//! — must stay byte-identical to the pre-backend single-controller
//! engine.

use lisa::backend::analytical::{IPC_TOLERANCE_PCT, WS_TOLERANCE_PCT};
use lisa::config::SimConfig;
use lisa::controller::Controller;
use lisa::sim::engine::{run_workload, Simulation};
use lisa::sim::spec::{registry, run, spec_by_name, Record, Report, RunOptions};
use lisa::workloads::mixes::workload_by_name;

/// Relative error of `twin` against ground truth `exact`, in percent.
fn rel_err_pct(twin: f64, exact: f64) -> f64 {
    if exact == 0.0 {
        if twin == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        ((twin - exact) / exact).abs() * 100.0
    }
}

/// Per-spec trimmed option sets: every registry spec is covered, with
/// axis values cut down so the cycle-exact half of each twin campaign
/// stays test-suite sized. The analytical half is cheap by design.
fn trimmed_opts(name: &str) -> RunOptions {
    let base = RunOptions::default().requests(200);
    match name {
        "fig3" => base.mixes(2).axis("preset", &["baseline", "risc-villa"]),
        "fig4" => base.mixes(2).axis("preset", &["baseline", "risc"]),
        "lip-system" => base.mixes(2),
        "e9-os" => base
            .axis("workload", &["os-fork", "os-zero"])
            .axis("mech", &["memcpy", "lisa-risc"])
            .axis("policy", &["packed"]),
        "e10-salp" => base
            .axis("workload", &["salp-pingpong4"])
            .axis("mech", &["memcpy", "lisa-risc"])
            .axis("mode", &["none", "masa"])
            .axis("policy", &["packed"]),
        "sweep" => base
            .axis("workload", &["stream4", "hotspot4"])
            .axis("mech", &["memcpy", "lisa-risc"]),
        other => panic!("trimmed_opts misses registry spec '{other}'"),
    }
}

/// Split a `--backend cycle,analytical` report into its halves. The
/// implicit backend axis is outermost, so the cycle twin of record `i`
/// in the analytical half is record `i` of the cycle half.
fn halves(report: &Report) -> (&[Record], &[Record]) {
    let n = report.records.len();
    assert_eq!(n % 2, 0, "twin grid must be even, got {n}");
    let (cycle, analytical) = report.records.split_at(n / 2);
    for (c, a) in cycle.iter().zip(analytical) {
        assert_eq!(c.axis("backend"), Some("cycle"));
        assert_eq!(a.axis("backend"), Some("analytical"));
        // Twins agree on every other coordinate.
        assert_eq!(c.axes[1..], a.axes[1..]);
    }
    (cycle, analytical)
}

/// All non-backend, non-mech coordinates of a record, as a grouping
/// key for ranking comparisons.
fn group_key(r: &Record) -> String {
    r.axes
        .iter()
        .filter(|(n, _)| n != "backend" && n != "mech")
        .map(|(n, v)| format!("{n}={v};"))
        .collect()
}

#[test]
fn analytical_twin_tracks_cycle_within_tolerance_across_registry() {
    for spec in registry() {
        let opts = trimmed_opts(&spec.name)
            .threads(2)
            .backend(&["cycle", "analytical"]);
        let report = run(&spec, &opts).unwrap_or_else(|e| {
            panic!("{}: twin campaign failed: {e:#}", spec.name)
        });
        // The report carries the contract it is being held to.
        assert!(
            report.to_json().contains("\"backend_tolerance\""),
            "{}: tolerance band missing from twin report",
            spec.name
        );
        let (cycle, analytical) = halves(&report);
        for (c, a) in cycle.iter().zip(analytical) {
            let ipc_err = rel_err_pct(a.report.ipc_sum(), c.report.ipc_sum());
            assert!(
                ipc_err <= IPC_TOLERANCE_PCT,
                "{} {:?}: analytical IPC {:.4} vs cycle {:.4} = {:.1}% > {}%",
                spec.name,
                c.axes,
                a.report.ipc_sum(),
                c.report.ipc_sum(),
                ipc_err,
                IPC_TOLERANCE_PCT
            );
            if let (Some(cw), Some(aw)) = (c.ws, a.ws) {
                let ws_err = rel_err_pct(aw, cw);
                assert!(
                    ws_err <= WS_TOLERANCE_PCT,
                    "{} {:?}: analytical WS {:.4} vs cycle {:.4} = {:.1}% > {}%",
                    spec.name,
                    c.axes,
                    aw,
                    cw,
                    ws_err,
                    WS_TOLERANCE_PCT
                );
            }
        }
        // Mechanism ranking: wherever the ground truth is decisive
        // (>15% apart on a mech axis with everything else fixed), the
        // twin must order the pair the same way. Near-ties are the
        // cycle model's own noise floor and carry no ranking signal.
        if spec.axes.iter().any(|a| a.name == "mech") {
            for (i, ci) in cycle.iter().enumerate() {
                for (j, cj) in cycle.iter().enumerate() {
                    if i == j || group_key(ci) != group_key(cj) {
                        continue;
                    }
                    let (ei, ej) = (ci.report.ipc_sum(), cj.report.ipc_sum());
                    if ei <= ej * 1.15 {
                        continue; // not decisive (or wrong direction)
                    }
                    let (ai, aj) =
                        (analytical[i].report.ipc_sum(), analytical[j].report.ipc_sum());
                    assert!(
                        ai > aj,
                        "{}: ranking flip — cycle has {:?} ({ei:.4}) > {:?} \
                         ({ej:.4}) decisively, analytical says {ai:.4} vs {aj:.4}",
                        spec.name,
                        ci.axes,
                        cj.axes
                    );
                }
            }
        }
    }
}

#[test]
fn twin_campaigns_are_deterministic_at_1_2_8_threads() {
    let spec = spec_by_name("e10-salp").unwrap();
    let opts = trimmed_opts("e10-salp").backend(&["cycle", "analytical"]);
    let reference = run(&spec, &opts.clone().threads(1)).unwrap().to_json();
    for threads in [2, 8] {
        let j = run(&spec, &opts.clone().threads(threads)).unwrap().to_json();
        assert_eq!(j, reference, "twin campaign diverged at {threads} threads");
    }
}

#[test]
fn cycle_backend_is_byte_identical_to_the_direct_controller() {
    // The trait seam is pure delegation: driving the engine through
    // `backend::build` (default cycle config) and through an
    // explicitly injected `Controller` produces the same report bytes.
    let cfg = SimConfig::default();
    let wl = workload_by_name("salp-pingpong4", &cfg).unwrap();
    let via_build = run_workload(&cfg, &wl);
    let mut sim = Simulation::with_model(
        cfg.clone(),
        wl.clone(),
        Box::new(Controller::new(cfg.clone())),
    );
    let via_injection = sim.run();
    assert_eq!(via_build.to_json(), via_injection.to_json());
    assert_eq!(via_build, via_injection);
    // The default config name carries no backend marker — labels (and
    // everything keyed off them) are unchanged from pre-backend builds.
    assert!(!via_build.config_name.contains("backend"), "{}", via_build.config_name);
}

#[test]
fn explicit_cycle_backend_changes_only_the_coordinates() {
    // `--backend cycle` must not perturb any simulated result: the
    // per-record reports are byte-identical to a default run; only the
    // record coordinates (and the report-level tolerance block) show
    // that a backend was chosen.
    let spec = spec_by_name("e10-salp").unwrap();
    let opts = trimmed_opts("e10-salp").threads(2);
    let plain = run(&spec, &opts).unwrap();
    let explicit = run(&spec, &opts.clone().backend(&["cycle"])).unwrap();
    assert_eq!(plain.records.len(), explicit.records.len());
    for (p, e) in plain.records.iter().zip(&explicit.records) {
        assert_eq!(p.report.to_json(), e.report.to_json());
        assert_eq!(p.ws, e.ws);
        assert_eq!(e.axes[0].0, "backend");
        assert_eq!(p.axes[..], e.axes[1..]);
    }
    // Default reports advertise no backend anywhere in their JSON.
    let j = plain.to_json();
    assert!(!j.contains("\"backend\""), "default JSON leaks a backend key");
    assert!(!j.contains("backend_tolerance"));
}
