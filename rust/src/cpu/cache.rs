//! Set-associative writeback caches and the three-level hierarchy
//! (private L1/L2, shared LLC) in front of the memory controller.

/// Result of a single-level cache access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheResult {
    Hit,
    /// Miss; if a dirty victim was evicted, its line address.
    Miss { writeback: Option<u64> },
}

/// One set-associative cache level (64 B lines, LRU, writeback +
/// write-allocate).
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    ways: usize,
    latency: u64,
    /// tag | valid | dirty | lru packed per line.
    tags: Vec<u64>,
    valid: Vec<bool>,
    dirty: Vec<bool>,
    lru: Vec<u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    pub fn new(size_kb: usize, ways: usize, latency: u64) -> Self {
        let lines = (size_kb * 1024) / 64;
        let sets = (lines / ways).max(1);
        assert!(sets.is_power_of_two(), "cache sets must be a power of two");
        let n = sets * ways;
        Self {
            sets,
            ways,
            latency,
            tags: vec![0; n],
            valid: vec![false; n],
            dirty: vec![false; n],
            lru: vec![0; n],
            tick: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    pub fn latency(&self) -> u64 {
        self.latency
    }

    #[inline]
    fn set_of(&self, line_addr: u64) -> usize {
        (line_addr as usize) & (self.sets - 1)
    }

    /// Access a 64 B line (address pre-shifted: `addr >> 6`).
    pub fn access(&mut self, line_addr: u64, is_write: bool) -> CacheResult {
        self.tick += 1;
        let set = self.set_of(line_addr);
        let base = set * self.ways;
        let tag = line_addr;
        for w in 0..self.ways {
            let i = base + w;
            if self.valid[i] && self.tags[i] == tag {
                self.lru[i] = self.tick;
                self.dirty[i] |= is_write;
                self.hits += 1;
                return CacheResult::Hit;
            }
        }
        self.misses += 1;
        // Victim: invalid way first, else LRU.
        let mut victim = base;
        let mut best = u64::MAX;
        for w in 0..self.ways {
            let i = base + w;
            if !self.valid[i] {
                victim = i;
                best = 0;
                break;
            }
            if self.lru[i] < best {
                best = self.lru[i];
                victim = i;
            }
        }
        let writeback = if self.valid[victim] && self.dirty[victim] {
            self.writebacks += 1;
            Some(self.tags[victim])
        } else {
            None
        };
        self.tags[victim] = tag;
        self.valid[victim] = true;
        self.dirty[victim] = is_write;
        self.lru[victim] = self.tick;
        CacheResult::Miss { writeback }
    }

    /// Invalidate a line if present (returns true if it was dirty).
    pub fn invalidate(&mut self, line_addr: u64) -> bool {
        let set = self.set_of(line_addr);
        let base = set * self.ways;
        for w in 0..self.ways {
            let i = base + w;
            if self.valid[i] && self.tags[i] == line_addr {
                self.valid[i] = false;
                return std::mem::take(&mut self.dirty[i]);
            }
        }
        false
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.hits as f64 / t as f64
        }
    }
}

/// Outcome of a hierarchy access.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HierarchyAccess {
    /// Total lookup latency in CPU cycles until the hit level responds
    /// (for misses: latency until the memory request would be sent).
    pub latency: u64,
    /// True if the access must go to memory.
    pub goes_to_memory: bool,
    /// Dirty lines pushed out to memory (line addresses) — become
    /// memory writes.
    pub writebacks: Vec<u64>,
}

/// Private L1+L2 per core, shared LLC.
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub l1: Vec<Cache>,
    pub l2: Vec<Cache>,
    pub llc: Cache,
}

impl Hierarchy {
    pub fn new(cfg: &crate::config::CpuConfig) -> Self {
        Self {
            l1: (0..cfg.cores)
                .map(|_| Cache::new(cfg.l1_kb, cfg.l1_ways, cfg.l1_latency))
                .collect(),
            l2: (0..cfg.cores)
                .map(|_| Cache::new(cfg.l2_kb, cfg.l2_ways, cfg.l2_latency))
                .collect(),
            llc: Cache::new(cfg.llc_kb, cfg.llc_ways, cfg.llc_latency),
        }
    }

    /// Look up `addr` (byte address) for `core`. Fills happen on the
    /// way back implicitly (this model installs on access).
    pub fn access(&mut self, core: usize, addr: u64, is_write: bool) -> HierarchyAccess {
        let line = addr >> 6;
        let mut writebacks = Vec::new();
        let mut latency = self.l1[core].latency();
        match self.l1[core].access(line, is_write) {
            CacheResult::Hit => {
                return HierarchyAccess { latency, goes_to_memory: false, writebacks }
            }
            CacheResult::Miss { writeback } => {
                // L1 victim writes back into L2.
                if let Some(wb) = writeback {
                    if let CacheResult::Miss { writeback: Some(wb2) } =
                        self.l2[core].access(wb, true)
                    {
                        if let CacheResult::Miss { writeback: Some(wb3) } =
                            self.llc.access(wb2, true)
                        {
                            writebacks.push(wb3 << 6);
                        }
                    }
                }
            }
        }
        latency += self.l2[core].latency();
        match self.l2[core].access(line, is_write) {
            CacheResult::Hit => {
                return HierarchyAccess { latency, goes_to_memory: false, writebacks }
            }
            CacheResult::Miss { writeback } => {
                if let Some(wb) = writeback {
                    if let CacheResult::Miss { writeback: Some(wb2) } =
                        self.llc.access(wb, true)
                    {
                        writebacks.push(wb2 << 6);
                    }
                }
            }
        }
        latency += self.llc.latency();
        match self.llc.access(line, is_write) {
            CacheResult::Hit => HierarchyAccess { latency, goes_to_memory: false, writebacks },
            CacheResult::Miss { writeback } => {
                if let Some(wb) = writeback {
                    writebacks.push(wb << 6);
                }
                HierarchyAccess { latency, goes_to_memory: true, writebacks }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CpuConfig;

    #[test]
    fn hit_after_fill() {
        let mut c = Cache::new(32, 8, 4);
        assert!(matches!(c.access(100, false), CacheResult::Miss { .. }));
        assert_eq!(c.access(100, false), CacheResult::Hit);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = Cache::new(1, 2, 1);
        // 1 KB, 2 ways -> 8 sets. Use same-set addresses: stride 8.
        assert!(matches!(c.access(0, false), CacheResult::Miss { .. }));
        assert!(matches!(c.access(8, false), CacheResult::Miss { .. }));
        assert_eq!(c.access(0, false), CacheResult::Hit); // refresh 0
        assert!(matches!(c.access(16, false), CacheResult::Miss { .. })); // evicts 8
        assert_eq!(c.access(0, false), CacheResult::Hit);
        assert!(matches!(c.access(8, false), CacheResult::Miss { .. }));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Cache::new(1, 2, 1);
        c.access(0, true); // dirty
        c.access(8, false);
        // Touch 0 so 8 is LRU... actually evict 0 by keeping 8 fresh:
        c.access(8, false);
        if let CacheResult::Miss { writeback } = c.access(16, false) {
            assert_eq!(writeback, Some(0));
        } else {
            panic!("expected miss");
        }
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn invalidate_removes_line() {
        let mut c = Cache::new(32, 8, 4);
        c.access(5, true);
        assert!(c.invalidate(5));
        assert!(matches!(c.access(5, false), CacheResult::Miss { .. }));
        assert!(!c.invalidate(999));
    }

    #[test]
    fn hierarchy_filters_memory_traffic() {
        let mut h = Hierarchy::new(&CpuConfig::default());
        let a = h.access(0, 0x1000, false);
        assert!(a.goes_to_memory);
        let b = h.access(0, 0x1000, false);
        assert!(!b.goes_to_memory);
        assert_eq!(b.latency, 4); // L1 hit
    }

    #[test]
    fn hierarchy_latency_accumulates_down_levels() {
        let cfg = CpuConfig::default();
        let mut h = Hierarchy::new(&cfg);
        let a = h.access(0, 0x2000, false);
        assert_eq!(a.latency, cfg.l1_latency + cfg.l2_latency + cfg.llc_latency);
    }

    #[test]
    fn dirty_llc_eviction_reaches_memory() {
        // Tiny LLC to force evictions.
        let mut h = Hierarchy::new(&CpuConfig {
            l1_kb: 1,
            l1_ways: 2,
            l2_kb: 1,
            l2_ways: 2,
            llc_kb: 1,
            llc_ways: 2,
            ..CpuConfig::default()
        });
        // Write enough distinct lines to force dirty L1 evictions to
        // cascade all the way out of the LLC.
        let mut wbs = 0;
        for i in 0..256u64 {
            wbs += h.access(0, i * 64, true).writebacks.len();
        }
        assert!(wbs > 0, "no writebacks reached memory");
    }
}
