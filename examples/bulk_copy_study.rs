//! Bulk-copy mechanism study: regenerates Table 1 / Fig. 2 of the
//! paper — 8 KB copy latency and DRAM energy for memcpy, the three
//! RowClone variants and LISA-RISC at 1..15 hops — plus a hop sweep
//! showing LISA's linear scaling.
//!
//! ```sh
//! cargo run --release --example bulk_copy_study
//! ```

use lisa::config::{Calibration, CopyMechanism};
use lisa::copy::isolated_copy;
use lisa::dram::timing::SpeedBin;
use lisa::energy::EnergyModel;
use lisa::sim::experiments::table1;
use lisa::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let cal = Calibration::default();

    println!("== Table 1: 8 KB copy latency and DRAM energy ==\n");
    let mut t = Table::new(&["mechanism", "paper ns", "ours ns", "paper uJ", "ours uJ"]);
    for r in table1(&cal)? {
        t.row(&[
            r.label,
            format!("{:.2}", r.paper_latency_ns),
            format!("{:.2}", r.latency_ns),
            format!("{:.3}", r.paper_energy_uj),
            format!("{:.3}", r.energy_uj),
        ]);
    }
    t.print();

    println!("\n== LISA-RISC hop sweep (linear scaling, paper §3.1.1) ==\n");
    let em = EnergyModel::from_calibration(&cal);
    let mut t = Table::new(&["hops", "latency ns", "energy uJ", "vs RC-InterSA"]);
    let rc = isolated_copy(
        CopyMechanism::RowCloneInterSa,
        7,
        SpeedBin::Ddr3_1600,
        &cal,
    )?;
    for hops in [1, 2, 4, 7, 10, 12, 15] {
        let r = isolated_copy(CopyMechanism::LisaRisc, hops, SpeedBin::Ddr3_1600, &cal)?;
        let e = em.breakdown_uj(&r.stats, 0, 1.25).total;
        t.row(&[
            format!("{hops}"),
            format!("{:.2}", r.latency_ns),
            format!("{:.3}", e),
            format!("{:.1}x faster", rc.latency_ns / r.latency_ns),
        ]);
    }
    t.print();
    Ok(())
}
