//! HLO artifact loading and execution via the PJRT CPU client.
//!
//! Interchange format is HLO *text*, never serialized protos: jax
//! >= 0.5 emits HloModuleProto with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md).

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

/// Scalar-vector length shared with python/compile/kernels/bitline.py.
pub const NSCALARS: usize = 16;
/// Bitline lanes baked into the artifacts (python model.N_LANES).
pub const N_LANES: usize = 4096;

/// Outputs of one circuit phase over the lane population.
#[derive(Debug, Clone)]
pub struct PhaseOutputs {
    pub v_a: Vec<f32>,
    pub v_b: Vec<f32>,
    /// First sense-threshold crossing per lane, ns.
    pub t_sense: Vec<f32>,
    /// Last time outside the settle tolerance per lane, ns.
    pub t_settle: Vec<f32>,
    /// Energy per lane, fJ.
    pub energy: Vec<f32>,
}

impl PhaseOutputs {
    pub fn worst_settle_ns(&self) -> f64 {
        self.t_settle.iter().cloned().fold(0.0f32, f32::max) as f64
    }

    pub fn worst_sense_ns(&self) -> f64 {
        self.t_sense.iter().cloned().fold(0.0f32, f32::max) as f64
    }

    pub fn mean_energy_fj(&self) -> f64 {
        if self.energy.is_empty() {
            return 0.0;
        }
        self.energy.iter().map(|&e| e as f64).sum::<f64>() / self.energy.len() as f64
    }
}

/// One compiled phase entry point.
pub struct PhaseExecutable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl PhaseExecutable {
    /// Execute with the uniform signature
    /// (va0[n], vb0[n], gmul[n], cmul[n], scalars[16]) -> 5 x f32[n].
    pub fn run(
        &self,
        va0: &[f32],
        vb0: &[f32],
        gmul: &[f32],
        cmul: &[f32],
        scalars: &[f32; NSCALARS],
    ) -> Result<PhaseOutputs> {
        let args = [
            xla::Literal::vec1(va0),
            xla::Literal::vec1(vb0),
            xla::Literal::vec1(gmul),
            xla::Literal::vec1(cmul),
            xla::Literal::vec1(&scalars[..]),
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 5 {
            bail!("{}: expected 5 outputs, got {}", self.name, parts.len());
        }
        let mut it = parts.into_iter();
        Ok(PhaseOutputs {
            v_a: it.next().unwrap().to_vec::<f32>()?,
            v_b: it.next().unwrap().to_vec::<f32>()?,
            t_sense: it.next().unwrap().to_vec::<f32>()?,
            t_settle: it.next().unwrap().to_vec::<f32>()?,
            energy: it.next().unwrap().to_vec::<f32>()?,
        })
    }
}

/// The PJRT runtime: one CPU client + compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        if !artifacts_dir.is_dir() {
            bail!(
                "artifacts directory {} not found — run `make artifacts`",
                artifacts_dir.display()
            );
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, dir: artifacts_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one phase artifact (`<name>.hlo.txt`).
    pub fn load(&self, name: &str) -> Result<PhaseExecutable> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        Ok(PhaseExecutable { name: name.to_string(), exe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration with real artifacts lives in rust/tests/; here we
    /// only check the error path (missing directory).
    #[test]
    fn missing_artifacts_dir_is_a_clear_error() {
        match Runtime::new(Path::new("/nonexistent/artifacts")) {
            Ok(_) => panic!("expected an error"),
            Err(e) => assert!(e.to_string().contains("make artifacts")),
        }
    }
}
