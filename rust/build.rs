//! Embeds a content fingerprint of the simulator sources as
//! `LISA_BUILD_FINGERPRINT`, folded into `sim::cache::code_version`:
//! the result-cache namespace (and every journal/cache content key)
//! changes whenever the code does, so a rebuilt binary never serves
//! results computed by different code — without anyone remembering to
//! hand-bump `CACHE_SCHEMA` for behavioral changes.

use std::fs;
use std::path::{Path, PathBuf};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(hash: &mut u64, bytes: &[u8]) {
    for b in bytes {
        *hash ^= u64::from(*b);
        *hash = hash.wrapping_mul(FNV_PRIME);
    }
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn main() {
    let mut files = Vec::new();
    collect(Path::new("src"), &mut files);
    // Deterministic order: read_dir order is filesystem-dependent.
    files.sort();
    let mut hash = FNV_OFFSET;
    for file in &files {
        fnv1a(&mut hash, file.to_string_lossy().as_bytes());
        fnv1a(&mut hash, b"\0");
        fnv1a(&mut hash, &fs::read(file).unwrap_or_default());
        println!("cargo:rerun-if-changed={}", file.display());
    }
    // Directory-level watch catches files added or removed since the
    // per-file list above was emitted.
    println!("cargo:rerun-if-changed=src");
    println!("cargo:rustc-env=LISA_BUILD_FINGERPRINT={hash:016x}");
}
