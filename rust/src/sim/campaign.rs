//! Parallel experiment campaigns: shard independent `Simulation` runs
//! across OS threads with deterministic result ordering.
//!
//! The paper's evaluation sweeps {mechanism × workload × config} grids
//! through the simulator; every point is an independent, deterministic
//! run, so the campaign layer is embarrassingly parallel. Jobs are
//! claimed from an atomic cursor and their results written back by
//! index, so the same campaign at 1, 2 or N threads yields identical
//! ordered results — only wall-clock time changes. Used by the
//! weighted-speedup helper (the N alone runs + 1 shared run), the
//! experiment drivers (E4–E7) and the `sweep` CLI subcommand.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::config::{CopyMechanism, SimConfig};
use crate::dram::timing::SpeedBin;
use crate::metrics::{json, RunReport};
use crate::sim::engine::Simulation;
use crate::workloads::{mixes, Workload};

/// Default worker count: one per available hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a user-supplied `--threads` value: absent or `0` means
/// "auto-detect the available parallelism" (like `make -j` semantics),
/// anything else is taken literally. Shared by every campaign-backed
/// CLI subcommand.
pub fn resolve_threads(requested: Option<usize>) -> usize {
    match requested {
        None | Some(0) => default_threads(),
        Some(n) => n,
    }
}

/// Run `jobs` across up to `threads` workers; results come back in
/// job order regardless of scheduling. Panics in a job propagate.
pub fn run_jobs<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    let slots: Vec<Mutex<Option<F>>> =
        jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = slots[i].lock().expect("job slot").take().expect("claimed once");
                let result = job();
                *out[i].lock().expect("result slot") = Some(result);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().expect("result lock").expect("job completed"))
        .collect()
}

/// Run a batch of (config, workload) simulations in parallel,
/// preserving input order.
pub fn run_reports(points: Vec<(SimConfig, Workload)>, threads: usize) -> Vec<RunReport> {
    let jobs: Vec<_> = points
        .into_iter()
        .map(|(cfg, wl)| move || Simulation::new(cfg, wl).run())
        .collect();
    run_jobs(jobs, threads)
}

/// Alone-run IPCs for every core of a workload (the denominator of
/// weighted speedup), sharded across `threads` workers.
pub fn alone_ipcs(cfg: &SimConfig, workload: &Workload, threads: usize) -> Vec<f64> {
    let jobs: Vec<_> = (0..workload.cores.len())
        .map(|i| {
            let cfg = cfg.clone();
            move || Simulation::new_alone(cfg, workload, i).run().ipc[0]
        })
        .collect();
    run_jobs(jobs, threads)
}

/// Weighted speedup of a workload on a config: the N alone runs and
/// the shared run are independent, so all N+1 go through the campaign
/// runner together.
pub fn weighted_speedup(
    cfg: &SimConfig,
    workload: &Workload,
    threads: usize,
) -> (f64, RunReport) {
    let n = workload.cores.len();
    let jobs: Vec<Box<dyn FnOnce() -> RunReport + Send + '_>> = (0..=n)
        .map(|i| {
            let cfg = cfg.clone();
            let job: Box<dyn FnOnce() -> RunReport + Send + '_> = if i < n {
                Box::new(move || Simulation::new_alone(cfg, workload, i).run())
            } else {
                Box::new(move || Simulation::new(cfg, workload.clone()).run())
            };
            job
        })
        .collect();
    let mut reports = run_jobs(jobs, threads);
    let shared = reports.pop().expect("shared run present");
    let alone: Vec<f64> = reports.iter().map(|r| r.ipc[0]).collect();
    (shared.weighted_speedup(&alone), shared)
}

// ---------------------------------------------------------------------------
// Sweep campaigns: {mechanism × workload × speed-bin} grids.
// ---------------------------------------------------------------------------

/// One point of a sweep grid.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub mechanism: CopyMechanism,
    pub speed: SpeedBin,
    pub workload: String,
}

/// A sweep campaign: the cross product of mechanisms, speed bins and
/// workload names over a base configuration.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub base: SimConfig,
    pub mechanisms: Vec<CopyMechanism>,
    pub speeds: Vec<SpeedBin>,
    pub workloads: Vec<String>,
    pub requests: u64,
    pub threads: usize,
}

impl SweepSpec {
    /// Grid order: workload-major, then speed, then mechanism — so all
    /// mechanism columns for one (workload, speed) row are adjacent.
    pub fn points(&self) -> Vec<SweepPoint> {
        let mut out = Vec::new();
        for workload in &self.workloads {
            for &speed in &self.speeds {
                for &mechanism in &self.mechanisms {
                    out.push(SweepPoint {
                        mechanism,
                        speed,
                        workload: workload.clone(),
                    });
                }
            }
        }
        out
    }
}

/// The base configuration specialized to one grid point. LISA-RISC
/// implies the RISC substrate is present (matching `cfg_risc`); other
/// LISA switches follow the base configuration untouched.
pub fn point_config(base: &SimConfig, point: &SweepPoint, requests: u64) -> SimConfig {
    let mut cfg = base.clone();
    cfg.requests_per_core = requests;
    cfg.dram.speed = point.speed;
    cfg.copy_mechanism = point.mechanism;
    if point.mechanism == CopyMechanism::LisaRisc {
        cfg.lisa.risc = true;
    }
    cfg
}

/// One finished sweep point.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRow {
    pub mechanism: &'static str,
    pub speed: &'static str,
    pub workload: String,
    pub report: RunReport,
}

/// Run the whole grid through the campaign runner. Workload names are
/// resolved up front so a typo fails fast instead of mid-campaign.
pub fn run_sweep(spec: &SweepSpec) -> Result<Vec<SweepRow>> {
    let points = spec.points();
    let mut jobs = Vec::with_capacity(points.len());
    for p in &points {
        let cfg = point_config(&spec.base, p, spec.requests);
        let wl = mixes::workload_by_name(&p.workload, &cfg)?;
        jobs.push(move || Simulation::new(cfg, wl).run());
    }
    let reports = run_jobs(jobs, spec.threads);
    Ok(points
        .into_iter()
        .zip(reports)
        .map(|(p, report)| SweepRow {
            mechanism: p.mechanism.name(),
            speed: p.speed.name(),
            workload: p.workload,
            report,
        })
        .collect())
}

/// JSON document for a finished sweep (`lisa sweep --out report.json`).
pub fn sweep_json(rows: &[SweepRow]) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"mechanism\":{},\"speed\":{},\"workload\":{},\"report\":{}}}",
                json::string(r.mechanism),
                json::string(r.speed),
                json::string(&r.workload),
                r.report.to_json()
            )
        })
        .collect();
    format!("{{\"sweep\":[\n{}\n]}}\n", body.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_zero_autodetects() {
        let auto = default_threads();
        assert!(auto >= 1);
        assert_eq!(resolve_threads(None), auto);
        assert_eq!(resolve_threads(Some(0)), auto);
        assert_eq!(resolve_threads(Some(3)), 3);
        // And a campaign driven by the resolved value still works.
        let jobs: Vec<_> = (0..4u64).map(|i| move || i * 2).collect();
        assert_eq!(run_jobs(jobs, resolve_threads(Some(0))), vec![0, 2, 4, 6]);
    }

    #[test]
    fn run_jobs_preserves_order_across_thread_counts() {
        // Jobs finish in scrambled wall-clock order (varying work), but
        // results must always come back in submission order.
        fn mk_jobs() -> Vec<impl FnOnce() -> (u64, u64) + Send> {
            (0..32u64)
                .map(|i| {
                    move || {
                        // Unequal work so threads interleave.
                        let mut acc = i;
                        for k in 0..((i % 7) * 1000) {
                            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
                        }
                        (i, acc)
                    }
                })
                .collect()
        }
        let serial = run_jobs(mk_jobs(), 1);
        for threads in [2, 4, 8] {
            let parallel = run_jobs(mk_jobs(), threads);
            assert_eq!(serial, parallel, "threads={threads}");
        }
        assert_eq!(run_jobs(Vec::<fn() -> u8>::new(), 4), Vec::<u8>::new());
    }

    #[test]
    fn sweep_grid_shape_and_config() {
        let spec = SweepSpec {
            base: SimConfig::default(),
            mechanisms: vec![CopyMechanism::MemcpyChannel, CopyMechanism::LisaRisc],
            speeds: vec![SpeedBin::Ddr3_1600, SpeedBin::Ddr4_2400],
            workloads: vec!["stream4".into(), "fork4".into()],
            requests: 100,
            threads: 1,
        };
        let points = spec.points();
        assert_eq!(points.len(), 8);
        // Workload-major ordering.
        assert!(points[..4].iter().all(|p| p.workload == "stream4"));
        let cfg = point_config(&spec.base, &points[1], 100);
        assert_eq!(cfg.copy_mechanism, CopyMechanism::LisaRisc);
        assert!(cfg.lisa.risc, "LISA-RISC points enable the substrate");
        assert_eq!(cfg.requests_per_core, 100);
    }

    #[test]
    fn sweep_rejects_unknown_workloads() {
        let spec = SweepSpec {
            base: SimConfig::default(),
            mechanisms: vec![CopyMechanism::MemcpyChannel],
            speeds: vec![SpeedBin::Ddr3_1600],
            workloads: vec!["no-such-workload".into()],
            requests: 100,
            threads: 1,
        };
        assert!(run_sweep(&spec).is_err());
    }

    #[test]
    fn campaign_is_deterministic_across_thread_counts() {
        let spec = SweepSpec {
            base: SimConfig::default(),
            mechanisms: vec![CopyMechanism::MemcpyChannel, CopyMechanism::LisaRisc],
            speeds: vec![SpeedBin::Ddr3_1600],
            workloads: vec!["stream4".into(), "fork4".into()],
            requests: 400,
            threads: 1,
        };
        let serial = run_sweep(&spec).unwrap();
        assert_eq!(serial.len(), 4);
        for threads in [2, 8] {
            let mut spec_n = spec.clone();
            spec_n.threads = threads;
            let parallel = run_sweep(&spec_n).unwrap();
            assert_eq!(serial, parallel, "threads={threads}");
        }
        assert!(serial.iter().all(|r| r.report.dram_cycles > 0));
        assert_eq!(sweep_json(&serial).matches("\"mechanism\"").count(), 4);
    }

    #[test]
    fn parallel_weighted_speedup_matches_serial_engine() {
        let mut cfg = SimConfig::default();
        cfg.requests_per_core = 800;
        let wl = mixes::workload_by_name("random4", &cfg).unwrap();
        let (ws_serial, rep_serial) = crate::sim::engine::weighted_speedup(&cfg, &wl);
        let (ws_par, rep_par) = weighted_speedup(&cfg, &wl, 4);
        assert_eq!(rep_serial, rep_par);
        assert!((ws_serial - ws_par).abs() < 1e-12, "{ws_serial} vs {ws_par}");
        let alone = alone_ipcs(&cfg, &wl, 8);
        assert_eq!(alone, crate::sim::engine::alone_ipcs(&cfg, &wl));
    }
}
